//! End-to-end VQA serving driver (the repo's headline example).
//!
//! Exercises the full system on a real small workload through the public
//! `chime::api::Session` surface, proving all layers compose
//! (DESIGN.md §1, §8):
//!
//!   * functional backend — a request stream served by the AOT-compiled
//!     tiny MLLM through PJRT (real tokens, wall-clock latency);
//!   * simulated backend — the same arrival process served by paper-scale
//!     models on the CHIME hardware simulator with continuous batching
//!     and two-cut-point pipelining (virtual time, energy);
//!   * sharded backend — a saturating burst over 1..=N packages
//!     (`--packages`, default 4) through the multi-package coordinator,
//!     demonstrating near-linear tokens/s scaling;
//!   * streaming protocol — the same sharded deployment driven through
//!     `Session::open_serving` (submit / tick / finish with typed
//!     `ServeEvent`s) under an open-loop Poisson arrival process, with
//!     cross-package work stealing off vs on (DESIGN.md §10).
//!
//! Every backend is one `BackendKind` behind the same builder.
//!
//! Run: cargo run --release --example vqa_serving [-- --requests 24 --packages 4]

use chime::api::{ArrivalProcess, BackendKind, ChimeError, ServeRequest, Session};
use chime::config::MllmConfig;
use chime::coordinator::RoutePolicy;
use chime::util::stats::{fmt_ns, percentile};
use chime::util::Args;

fn main() -> Result<(), ChimeError> {
    let args = Args::parse(std::env::args().skip(1));
    let parse = |name: &str, default: usize| -> Result<usize, ChimeError> {
        match args.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                ChimeError::Invalid(format!("--{name} expects an integer, got {v:?}"))
            }),
        }
    };
    let n = parse("requests", 12)?;

    // ------------------- functional serving (PJRT) ----------------------
    match Session::builder().backend(BackendKind::Functional).build() {
        Ok(mut session) => {
            let mut reqs = session.poisson_requests(11, 4.0, n, 8);
            for r in &mut reqs {
                r.arrival_ns = 0.0;
            }
            let t0 = std::time::Instant::now();
            let out = session.serve(reqs)?;
            println!("== functional backend (tiny MLLM over PJRT, {} requests) ==", n);
            let mut metrics = out.metrics;
            let p50 = metrics.latency_percentile_ns(50.0);
            let p99 = metrics.latency_percentile_ns(99.0);
            println!(
                "  wall time {:.2} s | {} tokens | p50 {} p99 {} | {:.1} tok/s",
                t0.elapsed().as_secs_f64(),
                metrics.tokens,
                fmt_ns(p50),
                fmt_ns(p99),
                metrics.tokens as f64 / t0.elapsed().as_secs_f64(),
            );
            for r in out.responses.iter().take(3) {
                println!("  req {:>2} (seed-varied image) -> {:?}", r.id, r.tokens);
            }
            // Different images must be able to produce different generations.
            let distinct: std::collections::BTreeSet<_> =
                out.responses.iter().map(|r| format!("{:?}", r.tokens)).collect();
            println!("  distinct generations: {}/{}", distinct.len(), out.responses.len());
        }
        Err(e) => println!("({e} — run `make artifacts` to enable the functional backend)"),
    }

    // ------------------- simulated paper-scale serving -------------------
    println!("\n== simulated CHIME serving (paper-scale, virtual time) ==");
    for model in [MllmConfig::fastvlm_0_6b(), MllmConfig::mobilevlm_3b()] {
        for batch in [1usize, 4] {
            let mut session = Session::builder()
                .model_config(model.clone())
                .output_tokens(64)
                .max_batch(batch)
                .build()?;
            let reqs = session.poisson_requests(5, 2.0, n, 64);
            let out = session.serve(reqs)?;
            let mut m = out.metrics;
            let p50 = m.latency_percentile_ns(50.0);
            let p99 = m.latency_percentile_ns(99.0);
            println!(
                "  {:<16} batch {}: {:>7.1} tok/s | p50 latency {:>10} | p99 {:>10} | {:>6.1} tok/J",
                model.name,
                batch,
                m.tokens_per_s(),
                fmt_ns(p50),
                fmt_ns(p99),
                m.tokens_per_j(),
            );
            if !out.shed.is_empty() {
                println!(
                    "  {:<16} batch {}: {} requests shed at admission (stats cover survivors only)",
                    model.name,
                    batch,
                    out.shed.len()
                );
            }
        }
    }

    // ------------------- multi-package sharded scaling --------------------
    let max_packages = parse("packages", 4)?.max(1);
    println!("\n== sharded CHIME serving (saturating burst, {max_packages} package max) ==");
    let model = MllmConfig::fastvlm_0_6b();
    let burst = ServeRequest::burst(n.max(8), 64);
    // Doubling sweep that always ends exactly at --packages.
    let mut counts = Vec::new();
    let mut p = 1usize;
    while p < max_packages {
        counts.push(p);
        p *= 2;
    }
    counts.push(max_packages);
    let mut base_tps = 0.0;
    for packages in counts {
        let mut session = Session::builder()
            .model_config(model.clone())
            .output_tokens(64)
            .backend(BackendKind::Sharded)
            .packages(packages)
            .route(RoutePolicy::LeastLoaded)
            .build()?;
        let out = session.serve(burst.clone())?;
        let mut m = out.metrics;
        if packages == 1 {
            base_tps = m.tokens_per_s();
        }
        let p99 = m.latency_percentile_ns(99.0);
        println!(
            "  {:<16} packages {}: {:>7.1} tok/s ({:>4.2}x) | p99 {:>10} | {:>6.1} tok/J | completions {:?}",
            model.name,
            packages,
            m.tokens_per_s(),
            if base_tps > 0.0 { m.tokens_per_s() / base_tps } else { 0.0 },
            fmt_ns(p99),
            m.tokens_per_j(),
            session.package_completed().unwrap_or_default(),
        );
        if !out.shed.is_empty() {
            println!("    ({} requests shed at admission)", out.shed.len());
        }
    }

    // ------------- event-driven streaming + work stealing ----------------
    // Open-loop Poisson arrivals with skewed token budgets; the streaming
    // session exposes the typed event stream, and work stealing lets idle
    // packages drain the loaded ones' queues — the tail-latency knob.
    println!("\n== streaming serving (open-loop poisson, steal off vs on) ==");
    let arrival = ArrivalProcess::Poisson { rate_per_s: 24.0 };
    for steal in [false, true] {
        let mut session = Session::builder()
            .model_config(model.clone())
            .output_tokens(64)
            .backend(BackendKind::Sharded)
            .packages(max_packages)
            .max_batch(2)
            .work_stealing(steal)
            .build()?;
        let mut reqs = session.requests_for(&arrival, 5, n.max(16), 64)?;
        for (i, r) in reqs.iter_mut().enumerate() {
            r.max_new_tokens = if i % 4 == 0 { 128 } else { 16 }; // skew the budgets
        }
        let mut serving = session.open_serving()?;
        for r in reqs {
            serving.submit(r);
        }
        let events = serving.drain()?;
        let steals = events.iter().filter(|e| e.kind() == "stolen").count();
        if steal {
            for ev in events.iter().filter(|e| e.kind() == "stolen").take(3) {
                println!("  event: req {:>2} {}", ev.id(), ev.kind());
            }
        }
        let out = serving.finish()?;
        let mut latency: Vec<f64> =
            out.responses.iter().map(|r| r.total_latency_ns()).collect();
        println!(
            "  steal {:<3}: {:>3} completed | p99 latency {:>10} | {} steals",
            if steal { "on" } else { "off" },
            out.responses.len(),
            fmt_ns(percentile(&mut latency, 99.0)),
            steals,
        );
    }
    Ok(())
}
