//! End-to-end VQA serving driver (the repo's headline example).
//!
//! Exercises the full system on a real small workload, proving all layers
//! compose (DESIGN.md §1):
//!
//!   * functional backend — a Poisson stream of VQA requests served by
//!     the AOT-compiled tiny MLLM through PJRT (real tokens, wall-clock
//!     latency/throughput);
//!   * simulated backend — the same arrival process served by paper-scale
//!     models on the CHIME hardware simulator with continuous batching
//!     and two-cut-point pipelining (virtual time, energy).
//!
//! Run: cargo run --release --example vqa_serving [-- --requests 24]

use chime::config::{ChimeConfig, MllmConfig};
use chime::coordinator::{BatchPolicy, FunctionalServer, ServeRequest, SimulatedServer};
use chime::model::workload::RequestStream;
use chime::runtime::Manifest;
use chime::util::stats::fmt_ns;
use chime::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("requests", 12);

    // ------------------- functional serving (PJRT) ----------------------
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let mut srv = FunctionalServer::load(&dir)?;
        let meta = &srv.mllm.manifest.config;
        let mut stream = RequestStream::new(11, 4.0, meta.prompt_len, 8, meta.vocab);
        let reqs: Vec<ServeRequest> = stream
            .take(n)
            .into_iter()
            .map(|r| ServeRequest {
                id: r.id,
                prompt: r.prompt,
                image_seed: r.image_seed,
                max_new_tokens: r.max_new_tokens,
                arrival_ns: 0.0,
            })
            .collect();
        let t0 = std::time::Instant::now();
        let (resps, mut metrics) = srv.serve(&reqs)?;
        println!("== functional backend (tiny MLLM over PJRT, {} requests) ==", n);
        let p50 = metrics.latency_percentile_ns(50.0);
        let p99 = metrics.latency_percentile_ns(99.0);
        println!(
            "  wall time {:.2} s | {} tokens | p50 {} p99 {} | {:.1} tok/s",
            t0.elapsed().as_secs_f64(),
            metrics.tokens,
            fmt_ns(p50),
            fmt_ns(p99),
            metrics.tokens as f64 / t0.elapsed().as_secs_f64(),
        );
        for r in resps.iter().take(3) {
            println!("  req {:>2} (seed-varied image) -> {:?}", r.id, r.tokens);
        }
        // Different images must be able to produce different generations.
        let distinct: std::collections::BTreeSet<_> =
            resps.iter().map(|r| format!("{:?}", r.tokens)).collect();
        println!("  distinct generations: {}/{}", distinct.len(), resps.len());
    } else {
        println!("(run `make artifacts` to enable the functional backend)");
    }

    // ------------------- simulated paper-scale serving -------------------
    println!("\n== simulated CHIME serving (paper-scale, virtual time) ==");
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 64;
    for model in [MllmConfig::fastvlm_0_6b(), MllmConfig::mobilevlm_3b()] {
        for batch in [1usize, 4] {
            let mut stream = RequestStream::new(5, 2.0, cfg.workload.text_tokens, 64, model.llm.vocab);
            let reqs: Vec<ServeRequest> = stream
                .take(n)
                .into_iter()
                .map(|r| ServeRequest {
                    id: r.id,
                    prompt: r.prompt,
                    image_seed: r.image_seed,
                    max_new_tokens: r.max_new_tokens,
                    arrival_ns: r.arrival_ns,
                })
                .collect();
            let mut srv = SimulatedServer::new(&model, &cfg, BatchPolicy { max_batch: batch });
            let (_, mut m) = srv.serve(reqs);
            let p50 = m.latency_percentile_ns(50.0);
            let p99 = m.latency_percentile_ns(99.0);
            println!(
                "  {:<16} batch {}: {:>7.1} tok/s | p50 latency {:>10} | p99 {:>10} | {:>6.1} tok/J",
                model.name,
                batch,
                m.tokens_per_s(),
                fmt_ns(p50),
                fmt_ns(p99),
                m.tokens_per_j(),
            );
        }
    }
    Ok(())
}
