//! End-to-end VQA serving driver (the repo's headline example).
//!
//! Exercises the full system on a real small workload, proving all layers
//! compose (DESIGN.md §1):
//!
//!   * functional backend — a Poisson stream of VQA requests served by
//!     the AOT-compiled tiny MLLM through PJRT (real tokens, wall-clock
//!     latency/throughput);
//!   * simulated backend — the same arrival process served by paper-scale
//!     models on the CHIME hardware simulator with continuous batching
//!     and two-cut-point pipelining (virtual time, energy);
//!   * sharded backend — a saturating burst over 1..=N packages
//!     (`--packages`, default 4) through the multi-package coordinator,
//!     demonstrating near-linear tokens/s scaling.
//!
//! Run: cargo run --release --example vqa_serving [-- --requests 24 --packages 4]

use chime::config::{ChimeConfig, MllmConfig};
use chime::coordinator::{
    BatchPolicy, FunctionalServer, RoutePolicy, ServeRequest, ShardedServer, SimulatedServer,
};
use chime::model::workload::RequestStream;
use chime::runtime::Manifest;
use chime::util::stats::fmt_ns;
use chime::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.get_usize("requests", 12);

    // ------------------- functional serving (PJRT) ----------------------
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let mut srv = FunctionalServer::load(&dir)?;
        let meta = &srv.mllm.manifest.config;
        let mut stream = RequestStream::new(11, 4.0, meta.prompt_len, 8, meta.vocab);
        let reqs: Vec<ServeRequest> = stream
            .take(n)
            .into_iter()
            .map(|r| ServeRequest {
                id: r.id,
                prompt: r.prompt,
                image_seed: r.image_seed,
                max_new_tokens: r.max_new_tokens,
                arrival_ns: 0.0,
            })
            .collect();
        let t0 = std::time::Instant::now();
        let (resps, mut metrics) = srv.serve(&reqs)?;
        println!("== functional backend (tiny MLLM over PJRT, {} requests) ==", n);
        let p50 = metrics.latency_percentile_ns(50.0);
        let p99 = metrics.latency_percentile_ns(99.0);
        println!(
            "  wall time {:.2} s | {} tokens | p50 {} p99 {} | {:.1} tok/s",
            t0.elapsed().as_secs_f64(),
            metrics.tokens,
            fmt_ns(p50),
            fmt_ns(p99),
            metrics.tokens as f64 / t0.elapsed().as_secs_f64(),
        );
        for r in resps.iter().take(3) {
            println!("  req {:>2} (seed-varied image) -> {:?}", r.id, r.tokens);
        }
        // Different images must be able to produce different generations.
        let distinct: std::collections::BTreeSet<_> =
            resps.iter().map(|r| format!("{:?}", r.tokens)).collect();
        println!("  distinct generations: {}/{}", distinct.len(), resps.len());
    } else {
        println!("(run `make artifacts` to enable the functional backend)");
    }

    // ------------------- simulated paper-scale serving -------------------
    println!("\n== simulated CHIME serving (paper-scale, virtual time) ==");
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = 64;
    for model in [MllmConfig::fastvlm_0_6b(), MllmConfig::mobilevlm_3b()] {
        for batch in [1usize, 4] {
            let mut stream = RequestStream::new(5, 2.0, cfg.workload.text_tokens, 64, model.llm.vocab);
            let reqs: Vec<ServeRequest> = stream
                .take(n)
                .into_iter()
                .map(|r| ServeRequest {
                    id: r.id,
                    prompt: r.prompt,
                    image_seed: r.image_seed,
                    max_new_tokens: r.max_new_tokens,
                    arrival_ns: r.arrival_ns,
                })
                .collect();
            let mut srv = SimulatedServer::new(
                &model,
                &cfg,
                BatchPolicy { max_batch: batch, ..BatchPolicy::default() },
            );
            let out = srv.serve(reqs);
            let mut m = out.metrics;
            let p50 = m.latency_percentile_ns(50.0);
            let p99 = m.latency_percentile_ns(99.0);
            println!(
                "  {:<16} batch {}: {:>7.1} tok/s | p50 latency {:>10} | p99 {:>10} | {:>6.1} tok/J",
                model.name,
                batch,
                m.tokens_per_s(),
                fmt_ns(p50),
                fmt_ns(p99),
                m.tokens_per_j(),
            );
            if !out.shed.is_empty() {
                println!(
                    "  {:<16} batch {}: {} requests shed at admission (stats cover survivors only)",
                    model.name,
                    batch,
                    out.shed.len()
                );
            }
        }
    }

    // ------------------- multi-package sharded scaling --------------------
    let max_packages = args.get_usize("packages", 4).max(1);
    println!("\n== sharded CHIME serving (saturating burst, {max_packages} package max) ==");
    let model = MllmConfig::fastvlm_0_6b();
    let burst = ServeRequest::burst(n.max(8), 64);
    // Doubling sweep that always ends exactly at --packages.
    let mut counts = Vec::new();
    let mut p = 1usize;
    while p < max_packages {
        counts.push(p);
        p *= 2;
    }
    counts.push(max_packages);
    let mut base_tps = 0.0;
    for packages in counts {
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy::default(),
            packages,
            RoutePolicy::LeastLoaded,
        );
        let out = srv.serve(burst.clone());
        let mut m = out.metrics;
        if packages == 1 {
            base_tps = m.tokens_per_s();
        }
        let p99 = m.latency_percentile_ns(99.0);
        println!(
            "  {:<16} packages {}: {:>7.1} tok/s ({:>4.2}x) | p99 {:>10} | {:>6.1} tok/J | completions {:?}",
            model.name,
            packages,
            m.tokens_per_s(),
            if base_tps > 0.0 { m.tokens_per_s() / base_tps } else { 0.0 },
            fmt_ns(p99),
            m.tokens_per_j(),
            srv.package_completed(),
        );
        if !out.shed.is_empty() {
            println!("    ({} requests shed at admission)", out.shed.len());
        }
    }
    Ok(())
}
