//! Sequence-length sensitivity sweep (paper Fig 8) with KV-tiering
//! introspection: as context grows 128 -> 4k the KV cache climbs the M3D
//! DRAM tiers and (for the big models) spills write-once to RRAM.
//!
//! Driven through `chime::api::Session`: one session per model,
//! `infer_with` per length, and the session's retained memory view for
//! the tier-residency detail — no hand-built plans or engines.
//!
//! Run: cargo run --release --example seqlen_sweep

use chime::api::{ChimeError, Session};
use chime::config::{MllmConfig, WorkloadConfig};
use chime::mapping::tiering;
use chime::util::stats::fmt_bytes;

fn main() -> Result<(), ChimeError> {
    println!("{:<16} {:>8} {:>12} {:>10} {:>14} {:>16}",
             "model", "text", "latency ms", "energy J", "KV bytes", "KV offloaded");
    for model in MllmConfig::paper_models() {
        let mut session = Session::builder().model_config(model.clone()).build()?;
        for text in [128usize, 512, 1024, 2048, 4096] {
            let w = WorkloadConfig { image_size: 512, text_tokens: text, output_tokens: 488 };
            let stats = session.infer_with(&w)?;
            let kv_total = model.llm.kv_bytes_per_token()
                * (w.text_tokens + model.visual_tokens() + w.output_tokens) as u64;
            println!(
                "{:<16} {:>8} {:>12.1} {:>10.3} {:>14} {:>16}",
                model.name,
                text,
                stats.total_time_ns() / 1e6,
                stats.total_energy_j(),
                fmt_bytes(kv_total as f64),
                fmt_bytes(stats.kv_offloaded_bytes as f64),
            );
        }
    }

    // Tier distribution detail for the heaviest case, read straight off
    // the session's retained post-inference memory state.
    println!("\nKV tier residency after a 4k-context MobileVLM-3B inference:");
    let mut session = Session::builder()
        .model_config(MllmConfig::mobilevlm_3b())
        .build()?;
    let w = WorkloadConfig { image_size: 512, text_tokens: 4096, output_tokens: 488 };
    session.infer_with(&w)?;
    let mem = session.memory().expect("sim backend retains memory state");
    let snap = tiering::snapshot(mem.dram);
    for (name, bytes, frac) in &snap.entries {
        println!("  {:<6} {:>12}  ({:.1}%)", name, fmt_bytes(*bytes as f64), frac * 100.0);
    }
    println!(
        "  effective KV stream bandwidth: {:.0} GB/s (tier-0-only would be {:.0} GB/s)",
        snap.effective_bw_gbps,
        session.config().hardware.dram.tier_stream_bw_gbps(0, 1.0)
    );
    println!(
        "  RRAM endurance consumed this inference: {:.3e}",
        mem.rram.endurance_consumed()
    );
    Ok(())
}
