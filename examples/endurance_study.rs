//! Endurance study: the paper's ❷ endurance-aware KV tiering exists to
//! protect the RRAM (limited write endurance) while exploiting its
//! density. This driver quantifies the policy:
//!
//!   * per-inference RRAM write volume under growing contexts;
//!   * projected device lifetime in inferences / years of continuous use;
//!   * the migrate-only-when-reuse-pays rule across tier pairs.
//!
//! Inferences run through `chime::api::Session`; the RRAM ledger is read
//! off the session's retained post-inference memory view.
//!
//! Run: cargo run --release --example endurance_study

use chime::api::{ChimeError, Session};
use chime::config::{ChimeConfig, MllmConfig, WorkloadConfig};
use chime::mapping::tiering;
use chime::util::stats::fmt_bytes;

fn main() -> Result<(), ChimeError> {
    let cfg = ChimeConfig::default();
    let model = MllmConfig::mobilevlm_3b();
    let mut session = Session::builder().model_config(model.clone()).build()?;

    println!("== RRAM write pressure vs context length (MobileVLM 3B) ==");
    println!("{:>8} {:>16} {:>14} {:>24}", "text", "KV offloaded", "endurance", "lifetime (inferences)");
    for text in [512usize, 1024, 2048, 4096, 8192] {
        let w = WorkloadConfig { image_size: 512, text_tokens: text, output_tokens: 488 };
        session.infer_with(&w)?;
        let rram = session.memory().expect("sim backend retains memory state").rram;
        let life = rram.projected_lifetime_inferences(1);
        println!(
            "{:>8} {:>16} {:>14.3e} {:>24}",
            text,
            fmt_bytes(rram.kv_bytes as f64),
            rram.endurance_consumed(),
            if life.is_finite() { format!("{:.2e}", life) } else { "unbounded".into() },
        );
    }

    println!("\n== migration cost/benefit (16-token KV blocks, MobileVLM 3B) ==");
    let block = tiering::KV_BLOCK_TOKENS as u64 * model.llm.kv_bytes_per_token_per_layer();
    println!("block size: {}", fmt_bytes(block as f64));
    println!("{:>10} {:>10} {:>12} {:>10}", "from tier", "to tier", "reads left", "migrate?");
    for (from, to, reads) in [(4, 0, 1000u64), (4, 0, 10), (4, 0, 3), (2, 0, 100), (0, 4, 1000)] {
        let go = tiering::migration_worthwhile(&cfg.hardware.dram, block, from, to, reads);
        println!("{:>10} {:>10} {:>12} {:>10}", from, to, reads, if go { "yes" } else { "no" });
    }

    println!("\n== write-rate budget for a 5-year device ==");
    let rate = tiering::max_write_rate_for_lifetime(&cfg.hardware.rram, 5.0 * 365.0 * 86400.0);
    println!(
        "sustainable: {}/s; observed per-inference offload is typically MBs -> \
         the write-once policy leaves >1000x headroom",
        fmt_bytes(rate)
    );
    Ok(())
}
