//! Quickstart: the CHIME reproduction in ~60 lines, driven entirely
//! through the public `chime::api::Session` surface.
//!
//! 1. Functional path — bring up the AOT-compiled tiny MLLM behind the
//!    `Session` builder (build once with `make artifacts`) and serve a
//!    real VQA request through PJRT: image + prompt -> autoregressive
//!    tokens, Python nowhere in sight.
//! 2. Timing path — simulate the same inference for a paper-scale model
//!    (FastVLM 0.6B) on the CHIME hardware and print the headline
//!    numbers next to the Jetson baseline — which is just another
//!    `Backend` behind the same builder.
//!
//! Run: cargo run --release --example quickstart
//!        [-- --text N --out N --memory first-order|cycle]
//! (the optional flags shrink the VQA workload — used by the example
//! smoke test to keep the run tiny — and pick the chiplet-memory timing
//! fidelity, DESIGN.md §9).

use chime::api::{BackendKind, ChimeError, MemoryFidelity, Session};
use chime::util::Args;

fn main() -> Result<(), ChimeError> {
    let args = Args::from_env();
    let parse = |name: &str| -> Result<Option<usize>, ChimeError> {
        match args.get(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| {
                ChimeError::Invalid(format!("--{name} expects an integer, got {v:?}"))
            }),
        }
    };
    let text = parse("text")?;
    let out = parse("out")?;
    let memory = match args.get("memory") {
        None => None,
        Some(v) => Some(MemoryFidelity::parse(v).ok_or_else(|| {
            ChimeError::Invalid(format!("--memory expects first-order|cycle, got {v:?}"))
        })?),
    };
    let builder = || {
        let mut b = Session::builder().model("fastvlm-0.6b");
        if let Some(n) = text {
            b = b.text_tokens(n);
        }
        if let Some(n) = out {
            b = b.output_tokens(n);
        }
        b
    };

    // ---------- 1. functional inference over the AOT artifacts ----------
    // (no .model(): the functional backend always runs the AOT tiny model)
    match Session::builder().backend(BackendKind::Functional).build() {
        Ok(mut session) => {
            let mut reqs = session.poisson_requests(11, 4.0, 1, 12);
            for r in &mut reqs {
                r.arrival_ns = 0.0;
            }
            let out = session.serve(reqs)?;
            let r = &out.responses[0];
            println!(
                "functional backend generated {:?}\n  ttft {:.2} ms, total {:.2} ms\n",
                r.tokens,
                r.ttft_ns / 1e6,
                r.service_ns / 1e6
            );
        }
        Err(e) => println!("({e} — run `make artifacts` for the functional demo)\n"),
    }

    // ---------- 2. paper-scale timing on the CHIME simulator -------------
    let mut b = builder();
    if let Some(f) = memory {
        b = b.memory_fidelity(f);
    }
    let mut chime = b.build()?;
    let stats = chime.infer()?;
    let w = chime.workload().clone();
    println!(
        "CHIME  {} ({} memory): {:.0} tok/s, {:.0} tok/J, {:.2} W \
         (VQA 512x512, {} in / {} out)",
        chime.model().name,
        chime.memory_fidelity().name(),
        stats.tokens_per_s(),
        stats.tokens_per_j(),
        stats.avg_power_w(),
        w.text_tokens,
        w.output_tokens
    );
    let mut jetson = builder().backend(BackendKind::Jetson).build()?;
    let jet = jetson.infer()?;
    println!(
        "Jetson {}: {:.1} tok/s, {:.2} tok/J  ->  speedup {:.1}x, energy {:.0}x",
        jetson.model().name,
        jet.tokens_per_s(),
        jet.tokens_per_j(),
        stats.tokens_per_s() / jet.tokens_per_s(),
        stats.tokens_per_j() / jet.tokens_per_j()
    );
    Ok(())
}
