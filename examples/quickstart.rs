//! Quickstart: the CHIME reproduction in ~60 lines.
//!
//! 1. Functional path — load the AOT-compiled tiny MLLM (build once with
//!    `make artifacts`) and serve a real VQA request through PJRT:
//!    image + prompt -> autoregressive tokens, Python nowhere in sight.
//! 2. Timing path — simulate the same inference for a paper-scale model
//!    (FastVLM 0.6B) on the CHIME hardware and print the headline
//!    numbers next to the Jetson baseline.
//!
//! Run: cargo run --release --example quickstart [-- --text N --out N]
//! (the optional flags shrink the VQA workload — used by the example
//! smoke test to keep the run tiny).

use chime::baselines::jetson;
use chime::config::{ChimeConfig, JetsonSpec, MllmConfig};
use chime::runtime::{FunctionalMllm, Manifest};
use chime::sim;
use chime::util::Args;

fn main() -> anyhow::Result<()> {
    // ---------- 1. functional inference over the AOT artifacts ----------
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let mllm = FunctionalMllm::load(&dir)?;
        let cfg = &mllm.manifest.config;
        println!(
            "functional model: d={} layers={} vocab={} (seed {})",
            cfg.d_model, cfg.n_layers, cfg.vocab, cfg.seed
        );
        let image = mllm.manifest.synthetic_image();
        let prompt = mllm.manifest.parity.prompt.clone();
        let gen = mllm.generate(&image, &prompt, 12)?;
        println!(
            "generated {:?}\n  encode {:.2} ms, prefill {:.2} ms, decode {:.2} ms",
            gen.tokens,
            gen.encode_ns as f64 / 1e6,
            gen.prefill_ns as f64 / 1e6,
            gen.decode_ns as f64 / 1e6
        );
        mllm.verify_parity()?;
        println!("parity vs python AOT oracle: OK\n");
    } else {
        println!("(artifacts not built — run `make artifacts` for the functional demo)\n");
    }

    // ---------- 2. paper-scale timing on the CHIME simulator -------------
    let args = Args::from_env();
    let mut cfg = ChimeConfig::default();
    cfg.workload.text_tokens = args.get_usize("text", cfg.workload.text_tokens);
    cfg.workload.output_tokens = args.get_usize("out", cfg.workload.output_tokens);
    let model = MllmConfig::fastvlm_0_6b();
    let stats = sim::simulate(&model, &cfg);
    let jet = jetson::run(&model, &cfg.workload, &JetsonSpec::default());
    println!(
        "CHIME  {}: {:.0} tok/s, {:.0} tok/J, {:.2} W (VQA 512x512, {} in / {} out)",
        model.name,
        stats.tokens_per_s(),
        stats.tokens_per_j(),
        stats.avg_power_w(),
        cfg.workload.text_tokens,
        cfg.workload.output_tokens
    );
    println!(
        "Jetson {}: {:.1} tok/s, {:.2} tok/J  ->  speedup {:.1}x, energy {:.0}x",
        model.name,
        jet.tokens_per_s(),
        jet.tokens_per_j(),
        stats.tokens_per_s() / jet.tokens_per_s(),
        stats.tokens_per_j() / jet.tokens_per_j()
    );
    Ok(())
}
