//! Operator-level MLLM workload model.
//!
//! The simulator prices *operators* (GEMM / streaming attention / norm /
//! elementwise), each annotated with FLOPs and byte traffic by source
//! (weights, KV cache, activations). The mapping framework then places
//! operators on chiplets and fuses them into the paper's Table I kernels;
//! the chiplet models turn (FLOPs, bytes, placement) into time and energy.

pub mod backbone;
pub mod connector;
pub mod vision;
pub mod workload;

/// Operator class — determines which execution unit prices it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Dense matmul (weight-stationary GEMM/GEMV on PEs).
    Gemm,
    /// Streaming attention over the KV cache (PE-SFPE pipeline).
    Attention,
    /// LayerNorm/RMSNorm (SFPE reduce-normalize-scale-shift).
    Norm,
    /// Residual adds, activation glue (SFPE elementwise).
    Elementwise,
    /// Embedding-row gather (single row stream).
    Embed,
}

/// Pipeline stage an operator belongs to (used for Fig 1 breakdowns and
/// the mapping framework's workload-aware layout).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    VisionEncoder,
    Connector,
    Backbone,
    LmHead,
}

/// One operator's resource footprint.
#[derive(Debug, Clone)]
pub struct OpCost {
    pub name: &'static str,
    pub kind: OpKind,
    pub stage: Stage,
    /// Which backbone layer (for per-layer scheduling); None outside layers.
    pub layer: Option<usize>,
    /// Multiply-accumulate work, in FLOPs (2 * MACs).
    pub flops: f64,
    /// Weight bytes that must stream from the weight store.
    pub weight_bytes: u64,
    /// KV-cache bytes read (attention over the valid prefix).
    pub kv_read_bytes: u64,
    /// KV-cache bytes appended this step.
    pub kv_write_bytes: u64,
    /// Activation bytes consumed / produced at the operator boundary.
    pub act_in_bytes: u64,
    pub act_out_bytes: u64,
    /// Elementwise/SFPE element count (softmax, norms, residuals).
    pub sfpe_elems: u64,
}

impl OpCost {
    pub fn new(name: &'static str, kind: OpKind, stage: Stage) -> Self {
        OpCost {
            name,
            kind,
            stage,
            layer: None,
            flops: 0.0,
            weight_bytes: 0,
            kv_read_bytes: 0,
            kv_write_bytes: 0,
            act_in_bytes: 0,
            act_out_bytes: 0,
            sfpe_elems: 0,
        }
    }

    /// Total bytes the operator moves (for roofline-style baselines).
    pub fn total_bytes(&self) -> u64 {
        self.weight_bytes
            + self.kv_read_bytes
            + self.kv_write_bytes
            + self.act_in_bytes
            + self.act_out_bytes
    }
}

/// A GEMM helper: y[m,n] = x[m,k] @ w[k,n], FP16 weights.
pub fn gemm_cost(
    name: &'static str,
    stage: Stage,
    m: usize,
    k: usize,
    n: usize,
    bytes_per_param: usize,
) -> OpCost {
    let mut op = OpCost::new(name, OpKind::Gemm, stage);
    op.flops = 2.0 * m as f64 * k as f64 * n as f64;
    op.weight_bytes = (k * n * bytes_per_param) as u64;
    op.act_in_bytes = (m * k * bytes_per_param) as u64;
    op.act_out_bytes = (m * n * bytes_per_param) as u64;
    op
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_cost_accounting() {
        let op = gemm_cost("t", Stage::Backbone, 4, 8, 16, 2);
        assert_eq!(op.flops, 2.0 * 4.0 * 8.0 * 16.0);
        assert_eq!(op.weight_bytes, 8 * 16 * 2);
        assert_eq!(op.act_in_bytes, 4 * 8 * 2);
        assert_eq!(op.act_out_bytes, 4 * 16 * 2);
        assert_eq!(op.total_bytes(), (8 * 16 + 4 * 8 + 4 * 16) as u64 * 2);
    }
}
