//! VQA workload/trace generation (paper §IV-A1: 512x512 image + 128 text
//! tokens in, 488 output tokens by default) plus request-stream generation
//! for the serving coordinator.

use crate::config::{MllmConfig, WorkloadConfig};
use crate::model::{backbone, connector, vision, OpCost};
use crate::util::Prng;

/// A single VQA inference, resolved against a model (token accounting).
#[derive(Debug, Clone)]
pub struct VqaTrace {
    pub model_name: String,
    pub image_size: usize,
    pub text_tokens: usize,
    pub visual_tokens: usize,
    pub output_tokens: usize,
}

impl VqaTrace {
    pub fn new(model: &MllmConfig, w: &WorkloadConfig) -> Self {
        VqaTrace {
            model_name: model.name.clone(),
            image_size: w.image_size,
            text_tokens: w.text_tokens,
            visual_tokens: model.visual_tokens(),
            output_tokens: w.output_tokens,
        }
    }

    /// Prompt length entering prefill (pseudo tokens + text tokens).
    pub fn prefill_len(&self) -> usize {
        self.visual_tokens + self.text_tokens
    }

    /// Final context length after generation.
    pub fn final_len(&self) -> usize {
        self.prefill_len() + self.output_tokens
    }
}

/// The full operator trace for one inference: encoder + connector ops,
/// prefill ops, then one op-list per decode step.
pub struct InferenceOps {
    pub encode: Vec<OpCost>,
    pub prefill: Vec<OpCost>,
    /// decode[i] = ops for generating output token i (position = prefill+i).
    pub decode: Vec<Vec<OpCost>>,
}

/// Expand a trace into operator lists (the simulator's input).
pub fn inference_ops(model: &MllmConfig, trace: &VqaTrace) -> InferenceOps {
    let mut encode = vision::encoder_ops(&model.vision, trace.image_size);
    encode.extend(connector::connector_ops(
        &model.connector,
        model.vision.out_tokens,
        model.llm.d_model,
    ));
    let prefill = backbone::prefill_ops(&model.llm, trace.prefill_len());
    let decode = (0..trace.output_tokens)
        .map(|i| backbone::decode_ops(&model.llm, trace.prefill_len() + i))
        .collect();
    InferenceOps { encode, prefill, decode }
}

/// One serving request (functional path: drives the PJRT engine; timing
/// path: drives the simulator through the same coordinator).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time offset (ns from stream start).
    pub arrival_ns: f64,
    /// Prompt token ids (functional path uses real ids; timing path uses
    /// only the length).
    pub prompt: Vec<i32>,
    /// Image seed (functional path synthesizes a deterministic image).
    pub image_seed: u64,
    /// Requested output tokens.
    pub max_new_tokens: usize,
}

/// Poisson request-stream generator for serving experiments.
pub struct RequestStream {
    prng: Prng,
    next_id: u64,
    clock_ns: f64,
    rate_per_s: f64,
    prompt_len: usize,
    max_new_tokens: usize,
    vocab: usize,
}

impl RequestStream {
    pub fn new(seed: u64, rate_per_s: f64, prompt_len: usize, max_new_tokens: usize,
               vocab: usize) -> Self {
        RequestStream {
            prng: Prng::new(seed),
            next_id: 0,
            clock_ns: 0.0,
            rate_per_s,
            prompt_len,
            max_new_tokens,
            vocab,
        }
    }

    /// Generate the next request (exponential inter-arrival).
    pub fn next_request(&mut self) -> Request {
        self.clock_ns += self.prng.exponential(self.rate_per_s) * 1e9;
        let id = self.next_id;
        self.next_id += 1;
        let prompt = (0..self.prompt_len)
            .map(|_| self.prng.range(0, self.vocab) as i32)
            .collect();
        Request {
            id,
            arrival_ns: self.clock_ns,
            prompt,
            image_seed: self.prng.next_u64(),
            max_new_tokens: self.max_new_tokens,
        }
    }

    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    #[test]
    fn trace_token_accounting() {
        let m = MllmConfig::fastvlm_0_6b();
        let t = VqaTrace::new(&m, &WorkloadConfig::default());
        assert_eq!(t.prefill_len(), 64 + 128);
        assert_eq!(t.final_len(), 64 + 128 + 488);
    }

    #[test]
    fn inference_ops_shape() {
        let m = MllmConfig::tiny();
        let w = WorkloadConfig { image_size: 16, text_tokens: 16, output_tokens: 4 };
        let t = VqaTrace::new(&m, &w);
        let ops = inference_ops(&m, &t);
        assert!(!ops.encode.is_empty());
        assert!(!ops.prefill.is_empty());
        assert_eq!(ops.decode.len(), 4);
        // Later decode steps scan longer KV prefixes.
        let kv = |step: &Vec<OpCost>| -> u64 { step.iter().map(|o| o.kv_read_bytes).sum() };
        assert!(kv(&ops.decode[3]) > kv(&ops.decode[0]));
    }

    #[test]
    fn request_stream_deterministic_and_monotone() {
        let mut a = RequestStream::new(9, 100.0, 16, 8, 256);
        let mut b = RequestStream::new(9, 100.0, 16, 8, 256);
        let ra = a.take(20);
        let rb = b.take(20);
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.arrival_ns, y.arrival_ns);
            assert_eq!(x.prompt, y.prompt);
        }
        for w in ra.windows(2) {
            assert!(w[1].arrival_ns >= w[0].arrival_ns);
        }
    }

    #[test]
    fn request_rate_roughly_matches() {
        let mut s = RequestStream::new(1, 50.0, 4, 4, 256);
        let reqs = s.take(2000);
        let span_s = reqs.last().unwrap().arrival_ns / 1e9;
        let rate = 2000.0 / span_s;
        assert!((rate - 50.0).abs() < 5.0, "rate {rate}");
    }
}
