//! Vision-encoder operator generation (paper Fig 5(a)).
//!
//! Encoders differ in how aggressively they downsample: ViT emits one token
//! per patch (N tokens), PVT reduces over a four-stage pyramid, FastViT-HD
//! compresses to M << N over five stages. The encoder runs once per
//! inference on the DRAM chiplet (paper §III-B1: "the M3D DRAM handles all
//! kernels except the FFN, covering image preprocessing, ... the vision
//! encoder, the connector, and attention").

use crate::config::{VisionEncoder, VisionKind};
use crate::model::{OpCost, OpKind, Stage};

/// Operators for one image through the encoder.
///
/// The encoder is priced as a weight-streaming compute block: its FLOPs
/// and weight bytes are the published aggregates for the architecture;
/// activations are sized from the token geometry. This is deliberately
/// coarser than the backbone model — the paper's profiling (Fig 1(b))
/// shows the encoder at < 15% of time, and its *token output count* is
/// what drives everything downstream.
pub fn encoder_ops(enc: &VisionEncoder, image_size: usize) -> Vec<OpCost> {
    let mut ops = Vec::new();

    // Image preprocessing: patchify + layout (elementwise streaming).
    let mut prep = OpCost::new("vision.preprocess", OpKind::Elementwise, Stage::VisionEncoder);
    let px = (image_size * image_size * 3) as u64;
    prep.sfpe_elems = px;
    prep.act_in_bytes = px; // u8 pixels
    prep.act_out_bytes = px * 2; // FP16 patches
    ops.push(prep);

    // Encoder trunk.
    let mut trunk = OpCost::new(
        match enc.kind {
            VisionKind::Vit => "vision.vit",
            VisionKind::Pvt => "vision.pvt",
            VisionKind::FastVitHd => "vision.fastvit_hd",
        },
        OpKind::Gemm,
        Stage::VisionEncoder,
    );
    // Scale published GFLOPs by actual input area vs the native resolution
    // the constant was quoted at (512^2 for FastViT-HD, 336^2 for ViT-L).
    let native = match enc.kind {
        VisionKind::Vit => 336.0_f64,
        VisionKind::Pvt => 512.0,
        VisionKind::FastVitHd => 512.0,
    };
    let area_scale = (image_size as f64 / native) * (image_size as f64 / native);
    trunk.flops = enc.gflops * 1e9 * area_scale.max(0.05);
    trunk.weight_bytes = enc.weight_bytes();
    trunk.act_in_bytes = px * 2;
    trunk.act_out_bytes = (enc.out_tokens * enc.d_out * 2) as u64;
    // Softmax/norm glue inside the encoder: proportional to token count.
    trunk.sfpe_elems = (enc.out_tokens * enc.d_out * 8) as u64;
    ops.push(trunk);

    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MllmConfig;

    #[test]
    fn fastvit_emits_fewer_tokens_than_vit() {
        let fv = MllmConfig::fastvlm_0_6b().vision;
        let vit = MllmConfig::mobilevlm_1_7b().vision;
        assert!(fv.out_tokens < vit.out_tokens, "M << N (paper Fig 5a)");
    }

    #[test]
    fn encoder_cost_scales_with_resolution() {
        let enc = MllmConfig::fastvlm_0_6b().vision;
        let lo: f64 = encoder_ops(&enc, 256).iter().map(|o| o.flops).sum();
        let hi: f64 = encoder_ops(&enc, 512).iter().map(|o| o.flops).sum();
        assert!((hi / lo - 4.0).abs() < 0.05);
    }

    #[test]
    fn weights_stream_once() {
        let enc = MllmConfig::mobilevlm_3b().vision;
        let w: u64 = encoder_ops(&enc, 512).iter().map(|o| o.weight_bytes).sum();
        assert_eq!(w, enc.weight_bytes());
    }
}
