//! Transformer-backbone operator generation (prefill and decode phases).
//!
//! Operator order within a layer follows the paper's two-cut-point
//! dataflow: [norm -> qkv -> attention -> out-proj -> residual] on the
//! DRAM chiplet, then [norm -> ffn -> residual] on the RRAM chiplet, with
//! `attn_out` / `ffn_out` the only tensors crossing UCIe.

use crate::config::LlmConfig;
use crate::model::{gemm_cost, OpCost, OpKind, Stage};

/// Operators for one decoder layer processing `m` query tokens against a
/// KV prefix of `kv_len` tokens (after this step's append).
pub fn layer_ops(llm: &LlmConfig, layer: usize, m: usize, kv_len: usize) -> Vec<OpCost> {
    let b = llm.bytes_per_param;
    let d = llm.d_model;
    let dq = llm.d_q();
    let dkv = llm.d_kv();
    let mut ops = Vec::with_capacity(9);

    // FUSED_NORM (pre-attention).
    let mut norm1 = OpCost::new("norm.attn", OpKind::Norm, Stage::Backbone);
    norm1.sfpe_elems = (m * d) as u64;
    norm1.act_in_bytes = (m * d * b) as u64;
    norm1.act_out_bytes = (m * d * b) as u64;
    ops.push(norm1);

    // FUSED_QKV_PROJ: three GEMMs sharing the x tile.
    let mut qkv = gemm_cost("qkv_proj", Stage::Backbone, m, d, dq + 2 * dkv, b);
    qkv.name = "qkv_proj";
    ops.push(qkv);

    // FUSED_ATTN_STREAM: Q.K^T + online softmax + P.V over the prefix.
    let mut attn = OpCost::new("attn_stream", OpKind::Attention, Stage::Backbone);
    // GQA: each of n_heads query heads scans kv_len keys of d_head.
    attn.flops = 2.0 * 2.0 * (llm.n_heads * m * kv_len * llm.d_head) as f64;
    attn.kv_read_bytes = (2 * kv_len * dkv * b) as u64; // K and V prefix
    attn.kv_write_bytes = (m as u64) * llm.kv_bytes_per_token_per_layer();
    attn.act_in_bytes = (m * dq * b) as u64;
    attn.act_out_bytes = (m * dq * b) as u64;
    attn.sfpe_elems = (llm.n_heads * m * kv_len) as u64; // online softmax
    ops.push(attn);

    // Attention output projection (DRAM side, feeds the cut point).
    ops.push(gemm_cost("attn_out_proj", Stage::Backbone, m, dq, d, b));

    // Residual add (SFPE). Its output IS AttnOut — the tensor that crosses
    // cut point #1 — so it carries the hidden-state activation bytes.
    let mut res1 = OpCost::new("residual.attn", OpKind::Elementwise, Stage::Backbone);
    res1.sfpe_elems = (m * d) as u64;
    res1.act_in_bytes = (2 * m * d * b) as u64;
    res1.act_out_bytes = (m * d * b) as u64;
    ops.push(res1);

    // FUSED_NORM (pre-FFN). Placed with the FFN on the RRAM side so only
    // attn_out crosses the link (the norm consumes it in place).
    let mut norm2 = OpCost::new("norm.ffn", OpKind::Norm, Stage::Backbone);
    norm2.sfpe_elems = (m * d) as u64;
    norm2.act_in_bytes = (m * d * b) as u64;
    norm2.act_out_bytes = (m * d * b) as u64;
    ops.push(norm2);

    // FUSED_FFN_ACT: all ffn matrices chained in one fused kernel
    // (gate/up/down for SwiGLU; up/down for GELU MLP).
    let mut ffn = OpCost::new("ffn_act", OpKind::Gemm, Stage::Backbone);
    ffn.flops = 2.0 * (llm.ffn_matrices * m * d * llm.d_ffn) as f64;
    ffn.weight_bytes = llm.ffn_weight_bytes_per_layer();
    ffn.act_in_bytes = (m * d * b) as u64;
    ffn.act_out_bytes = (m * d * b) as u64;
    ffn.sfpe_elems = (m * llm.d_ffn) as u64; // activation function
    ops.push(ffn);

    // Residual add (back on the DRAM side after FFNOut returns).
    let mut res2 = OpCost::new("residual.ffn", OpKind::Elementwise, Stage::Backbone);
    res2.sfpe_elems = (m * d) as u64;
    res2.act_in_bytes = (2 * m * d * b) as u64;
    res2.act_out_bytes = (m * d * b) as u64;
    ops.push(res2);

    for op in &mut ops {
        op.layer = Some(layer);
    }
    ops
}

/// Final norm + unembedding GEMV producing logits for `m` positions
/// (decode: m = 1; prefill prices only the last position's logits).
pub fn lm_head_ops(llm: &LlmConfig, m: usize) -> Vec<OpCost> {
    let b = llm.bytes_per_param;
    let mut norm = OpCost::new("norm.final", OpKind::Norm, Stage::LmHead);
    norm.sfpe_elems = (m * llm.d_model) as u64;
    let mut head = gemm_cost("lm_head", Stage::LmHead, m, llm.d_model, llm.vocab, b);
    head.stage = Stage::LmHead;
    vec![norm, head]
}

/// Token-embedding gather for `m` tokens.
pub fn embed_ops(llm: &LlmConfig, m: usize) -> Vec<OpCost> {
    let b = llm.bytes_per_param;
    let mut emb = OpCost::new("embed", OpKind::Embed, Stage::Backbone);
    emb.weight_bytes = (m * llm.d_model * b) as u64; // m rows gathered
    emb.act_out_bytes = (m * llm.d_model * b) as u64;
    vec![emb]
}

/// All backbone ops for a prefill over `s` tokens (KV appended for all s).
pub fn prefill_ops(llm: &LlmConfig, s: usize) -> Vec<OpCost> {
    let mut ops = embed_ops(llm, s);
    for l in 0..llm.n_layers {
        ops.extend(layer_ops(llm, l, s, s));
    }
    ops.extend(lm_head_ops(llm, 1));
    ops
}

/// All backbone ops for one decode step at position `pos` (0-indexed
/// global position; the KV prefix after append is pos + 1).
pub fn decode_ops(llm: &LlmConfig, pos: usize) -> Vec<OpCost> {
    let mut ops = embed_ops(llm, 1);
    for l in 0..llm.n_layers {
        ops.extend(layer_ops(llm, l, 1, pos + 1));
    }
    ops.extend(lm_head_ops(llm, 1));
    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MllmConfig;

    #[test]
    fn decode_streams_all_weights_once() {
        let llm = MllmConfig::mobilevlm_3b().llm;
        let ops = decode_ops(&llm, 100);
        let weight_bytes: u64 = ops.iter().map(|o| o.weight_bytes).sum();
        // Every backbone weight + lm_head + 1 embedding row must stream.
        let expect = llm.n_layers as u64
            * (llm.attn_weight_bytes_per_layer() + llm.ffn_weight_bytes_per_layer())
            + llm.lm_head_bytes()
            + (llm.d_model * llm.bytes_per_param) as u64;
        assert_eq!(weight_bytes, expect);
    }

    #[test]
    fn decode_kv_traffic_grows_with_position() {
        let llm = MllmConfig::fastvlm_0_6b().llm;
        let kv_at = |pos: usize| -> u64 {
            decode_ops(&llm, pos).iter().map(|o| o.kv_read_bytes).sum()
        };
        assert!(kv_at(1000) > kv_at(100));
        // Linear in prefix length (pos+1).
        let a = kv_at(99);
        let b = kv_at(199);
        assert_eq!(b * 100, a * 200);
    }

    #[test]
    fn prefill_flops_quadratic_in_attention() {
        let llm = MllmConfig::fastvlm_0_6b().llm;
        let attn_flops = |s: usize| -> f64 {
            prefill_ops(&llm, s)
                .iter()
                .filter(|o| o.kind == OpKind::Attention)
                .map(|o| o.flops)
                .sum()
        };
        let f1 = attn_flops(128);
        let f2 = attn_flops(256);
        assert!((f2 / f1 - 4.0).abs() < 0.01, "ratio {}", f2 / f1);
    }

    #[test]
    fn every_step_appends_kv_once_per_layer() {
        let llm = MllmConfig::fastvlm_1_7b().llm;
        let ops = decode_ops(&llm, 10);
        let writes: u64 = ops.iter().map(|o| o.kv_write_bytes).sum();
        assert_eq!(writes, llm.kv_bytes_per_token());
    }

    #[test]
    fn layer_indices_assigned() {
        let llm = MllmConfig::tiny().llm;
        let ops = decode_ops(&llm, 5);
        let max_layer = ops.iter().filter_map(|o| o.layer).max().unwrap();
        assert_eq!(max_layer, llm.n_layers - 1);
    }
}
