//! Connector operator generation (paper Fig 5(a)): projects visual
//! features into the language domain, producing pseudo tokens.

use crate::config::{Connector, ConnectorKind};
use crate::model::{OpCost, OpKind, Stage};

/// Operators for projecting `in_tokens` visual features through the
/// connector. Runs on the DRAM chiplet (latency-critical, small).
pub fn connector_ops(conn: &Connector, in_tokens: usize, d_llm: usize) -> Vec<OpCost> {
    let mut ops = Vec::new();

    let mut proj = OpCost::new(
        match conn.kind {
            ConnectorKind::Mlp => "connector.mlp",
            ConnectorKind::Ldp => "connector.ldp",
            ConnectorKind::CrossAttn => "connector.cross_attn",
        },
        match conn.kind {
            ConnectorKind::CrossAttn => OpKind::Attention,
            _ => OpKind::Gemm,
        },
        Stage::Connector,
    );
    proj.flops = conn.gflops * 1e9;
    proj.weight_bytes = conn.weight_bytes();
    proj.act_in_bytes = (in_tokens * d_llm * 2) as u64;
    proj.act_out_bytes = (conn.out_tokens * d_llm * 2) as u64;
    // LDP's depthwise convs + the downsample are elementwise-heavy.
    proj.sfpe_elems = match conn.kind {
        ConnectorKind::Ldp => (in_tokens * d_llm * 4) as u64,
        _ => (conn.out_tokens * d_llm) as u64,
    };
    ops.push(proj);
    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MllmConfig;

    #[test]
    fn ldp_downsamples_tokens() {
        let m = MllmConfig::mobilevlm_1_7b();
        let ops = connector_ops(&m.connector, m.vision.out_tokens, m.llm.d_model);
        let out = ops.last().unwrap().act_out_bytes;
        let inp = ops.last().unwrap().act_in_bytes;
        assert!(out < inp, "LDP must reduce token volume");
    }

    #[test]
    fn mlp_preserves_tokens() {
        let m = MllmConfig::fastvlm_0_6b();
        assert_eq!(m.connector.out_tokens, m.vision.out_tokens);
    }
}
