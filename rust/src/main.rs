//! `chime` — CLI for the CHIME reproduction.
//!
//! Subcommands:
//!   info      — print model zoo (Table II) and hardware configs (III/IV)
//!   simulate  — run one model's VQA inference on the CHIME simulator
//!   serve     — serve an open-loop request stream (sim | functional |
//!               dram-only | jetson | facil backends; --arrival picks the
//!               burst/poisson/trace process, --steal on enables
//!               cross-package work stealing); with --listen HOST:PORT,
//!               serve over HTTP/SSE instead (DESIGN.md §13)
//!   loadgen   — open-loop wall-clock driver for a --listen server
//!               (--target HOST:PORT; renders the tail-latency table)
//!   sweep     — sequence-length sweep (Fig 8)
//!   results   — regenerate paper tables/figures (--fig N | --all)
//!   memcheck  — cross-validate first-order vs cycle-accurate memory
//!   bench     — simulator wall-clock performance (events/s) per backend
//!               × memory fidelity; --snapshot writes BENCH_<pr>.json
//!   parity    — verify the PJRT functional path against the AOT oracle
//!
//! The simulator subcommands accept `--memory first-order|cycle` to pick
//! the chiplet-memory timing fidelity (DESIGN.md §9).
//!
//! The binary is a thin shell over `chime::api::Session`: every backend is
//! constructed through the builder, every failure is a typed `ChimeError`
//! (usage mistakes exit 2, environment/runtime failures exit 1), and every
//! subcommand validates its flags so typos get a suggestion instead of a
//! silent no-op.

use std::time::Duration;

use chime::api::{
    ArrivalProcess, Backend as _, BackendKind, ChimeError, MemoryFidelity, Session, SessionBuilder,
};
use chime::config::{MllmConfig, TopologyKind};
use chime::coordinator::{BatchPolicy, RoutePolicy};
use chime::net::{loadgen, LoadgenConfig, NetServer, ServeOpts};
use chime::results;
use chime::runtime::Manifest;
use chime::util::stats::{fmt_bytes, fmt_ns};
use chime::util::{table, Args, Json, Table};

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("chime: {e}");
            e.exit_code()
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<(), ChimeError> {
    match args.command.as_deref() {
        Some("info") => cmd_info(args),
        Some("simulate") => cmd_simulate(args),
        Some("serve") => cmd_serve(args),
        Some("loadgen") => cmd_loadgen(args),
        Some("sweep") => cmd_sweep(args),
        Some("results") => cmd_results(args),
        Some("memcheck") => cmd_memcheck(args),
        Some("bench") => cmd_bench(args),
        Some("parity") => cmd_parity(args),
        Some(other) => {
            usage();
            Err(ChimeError::Unknown {
                what: "command",
                name: other.to_string(),
                hint: Some(
                    "info simulate serve loadgen sweep results memcheck bench parity".to_string(),
                ),
            })
        }
        None => {
            usage();
            Ok(())
        }
    }
}

fn usage() {
    println!(
        "chime — CHIME paper reproduction (chiplet heterogeneous near-memory MLLM inference)

USAGE: chime <command> [options]

COMMANDS:
  info      [--models] [--hardware]           Table II / III / IV configs
  simulate  [--model NAME] [--all] [--dram-only] [--out N] [--text N] [--json]
            [--memory first-order|cycle] [--topology point-to-point|line|ring|mesh]
            [--threads N] [--trace-out FILE]  write the run's Chrome trace-event JSON
  serve     [--backend sim|functional|dram-only|jetson|facil] [--model NAME]
            [--requests N] [--arrival burst|poisson:R|trace:FILE] [--rate R]
            [--steal on|off] [--seed N] [--batch B] [--tokens N] [--packages N]
            [--route rr|least-loaded] [--queue N] [--memory first-order|cycle]
            [--topology point-to-point|line|ring|mesh]
            [--threads N]  executor worker threads (deterministic: outcomes stay
            bit-identical to --threads 1)  [--wall]  free-running wall-clock
            executor (host events/s scales with --threads; sim backends only)
            [--listen HOST:PORT] [--deterministic] [--addr-file PATH]
            [--trace-out FILE]
            With --listen: serve over HTTP/SSE instead of a local arrival
            stream (POST /v1/submit, GET /v1/stream/<id>, GET /v1/metrics,
            POST /v1/finish, POST /v1/shutdown); drive with `chime loadgen`
  loadgen   --target HOST:PORT [--requests N] [--arrival burst|poisson:R|trace:FILE]
            [--rate R] [--seed N] [--tokens N] [--prompt-tokens N]
            [--timeout-s S] [--shutdown] [--json FILE]
            Open-loop wall-clock driver for a --listen server; renders the
            p50/p95/p99 TTFT/TPOT/latency tail table (--json writes the
            same numbers as canonical JSON)
  sweep     [--model NAME] [--json] [--memory first-order|cycle]
            [--topology point-to-point|line|ring|mesh]
            Fig 8 sequence-length sweep
  results   [--fig 1|6|7|8|9|table5|ablations|scaling|memcheck|tail|perf|fabric]
            [--all] [--json] [--baselines]
  memcheck  [--json]                          first-order vs cycle divergence
  bench     [--json] [--quick] [--snapshot PATH] [--requests N] [--tokens N]
            [--iters N] [--threads N] [--profile PATH]
            simulator events/s benchmark; --threads sizes the sharded4-exec
            executor column (--profile writes the wall-clock-per-span-class
            HOTPATH baseline)
  parity    [--artifacts DIR]                 verify PJRT vs AOT oracle

MODELS: fastvlm-0.6b fastvlm-1.7b mobilevlm-1.7b mobilevlm-3b tiny"
    );
}

/// Reject flags the subcommand does not accept, with a typo suggestion.
fn ensure_known(args: &Args, allowed: &[&str]) -> Result<(), ChimeError> {
    if let Some((flag, suggestion)) = args.unknown(allowed).into_iter().next() {
        return Err(ChimeError::UnknownFlag { flag, suggestion });
    }
    Ok(())
}

/// `--key N` as usize, or a typed usage error (exit 2) — never a panic.
fn usize_arg(args: &Args, name: &str, default: usize) -> Result<usize, ChimeError> {
    match args.get(name) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| {
            ChimeError::Invalid(format!("--{name} expects an integer, got {v:?}"))
        }),
    }
}

/// `--key X` as f64, or a typed usage error (exit 2) — never a panic.
fn f64_arg(args: &Args, name: &str, default: f64) -> Result<f64, ChimeError> {
    match args.get(name) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| {
            ChimeError::Invalid(format!("--{name} expects a number, got {v:?}"))
        }),
    }
}

/// `--memory first-order|cycle` as a fidelity, or a typed usage error.
fn memory_arg(args: &Args) -> Result<Option<MemoryFidelity>, ChimeError> {
    match args.get("memory") {
        None => Ok(None),
        Some(v) => match MemoryFidelity::parse(v) {
            Some(f) => Ok(Some(f)),
            None => Err(ChimeError::Unknown {
                what: "memory fidelity",
                name: v.to_string(),
                hint: Some("first-order cycle".to_string()),
            }),
        },
    }
}

/// `--topology point-to-point|line|ring|mesh` as a fabric topology, or a
/// typed usage error with the accepted spellings.
fn topology_arg(args: &Args) -> Result<Option<TopologyKind>, ChimeError> {
    match args.get("topology") {
        None if args.flag("topology") => Err(ChimeError::Invalid(
            "--topology expects a fabric: point-to-point, line, ring, or mesh".to_string(),
        )),
        None => Ok(None),
        Some(v) => match TopologyKind::parse(v) {
            Some(t) => Ok(Some(t)),
            None => Err(ChimeError::Unknown {
                what: "topology",
                name: v.to_string(),
                hint: Some("point-to-point line ring mesh".to_string()),
            }),
        },
    }
}

/// `--arrival burst|poisson:<rps>|trace:<file>` (with `--rate R` kept as
/// shorthand for `poisson:R`), or a typed usage error — never a panic.
fn arrival_arg(args: &Args) -> Result<ArrivalProcess, ChimeError> {
    if args.flag("arrival") && args.get("arrival").is_none() {
        return Err(ChimeError::Invalid(
            "--arrival expects a process: burst, poisson:<rps>, or trace:<file>".to_string(),
        ));
    }
    match (args.get("arrival"), args.get("rate")) {
        (Some(_), Some(_)) => Err(ChimeError::Invalid(
            "--rate is shorthand for --arrival poisson:<rps>; pass only one".to_string(),
        )),
        (Some(spec), None) => ArrivalProcess::parse(spec),
        (None, _) => {
            let rate = f64_arg(args, "rate", 2.0)?;
            if !rate.is_finite() || rate <= 0.0 {
                return Err(ChimeError::Invalid(format!(
                    "--rate must be finite and positive, got {rate}"
                )));
            }
            Ok(ArrivalProcess::Poisson { rate_per_s: rate })
        }
    }
}

/// `--trace-out FILE`: where to write the Chrome trace-event JSON
/// (load in Perfetto / `chrome://tracing`), or a typed usage error for
/// the value-less spelling.
fn trace_out_arg(args: &Args) -> Result<Option<String>, ChimeError> {
    match args.get("trace-out") {
        None if args.flag("trace-out") => Err(ChimeError::Invalid(
            "--trace-out expects a file path for the Chrome trace-event JSON".to_string(),
        )),
        None => Ok(None),
        Some(p) => Ok(Some(p.to_string())),
    }
}

/// Write the recorded trace of a session's backend as Chrome
/// trace-event JSON (shared by `simulate --trace-out` and the
/// non-listen `serve --trace-out` path).
fn write_trace(session: &mut Session, path: &str) -> Result<(), ChimeError> {
    let tracer = session.backend_mut().take_trace().unwrap_or_default();
    std::fs::write(path, format!("{}\n", tracer.chrome_trace().pretty()))
        .map_err(|e| ChimeError::Runtime(format!("writing trace {path}: {e}")))?;
    println!("wrote trace {path}");
    Ok(())
}

/// `--threads N` as the executor worker count (DESIGN.md §15), or a
/// typed usage error: the value-less spelling and 0 are both rejected (a
/// zero-worker executor can never drain a session).
fn threads_arg(args: &Args) -> Result<usize, ChimeError> {
    if args.flag("threads") && args.get("threads").is_none() {
        return Err(ChimeError::Invalid(
            "--threads expects a worker count (e.g. --threads 4)".to_string(),
        ));
    }
    let n = usize_arg(args, "threads", 1)?;
    if n == 0 {
        return Err(ChimeError::Invalid(
            "--threads 0 can never drain a session; the executor needs at least one \
             worker thread"
                .to_string(),
        ));
    }
    Ok(n)
}

/// `--steal on|off` as a bool, or a typed usage error — never a silent
/// default for a malformed or value-less spelling.
fn steal_arg(args: &Args) -> Result<bool, ChimeError> {
    match args.get("steal") {
        None if args.flag("steal") => Err(ChimeError::Invalid(
            "--steal expects a mode: on or off".to_string(),
        )),
        None => Ok(false),
        Some("on") | Some("true") => Ok(true),
        Some("off") | Some("false") => Ok(false),
        Some(other) => Err(ChimeError::Unknown {
            what: "steal mode",
            name: other.to_string(),
            hint: Some("on off".to_string()),
        }),
    }
}

/// Session builder pre-loaded with the shared CLI knobs
/// (`--config`, `--out`, `--text`).
fn builder_from(args: &Args) -> Result<SessionBuilder, ChimeError> {
    let mut b = Session::builder();
    if let Some(path) = args.get("config") {
        b = b.config_file(path);
    }
    if args.get("out").is_some() {
        b = b.output_tokens(usize_arg(args, "out", 0)?);
    }
    if args.get("text").is_some() {
        b = b.text_tokens(usize_arg(args, "text", 0)?);
    }
    Ok(b)
}

fn cmd_info(args: &Args) -> Result<(), ChimeError> {
    ensure_known(args, &["models", "hardware"])?;
    let both = !args.flag("models") && !args.flag("hardware");
    if args.flag("models") || both {
        let mut t = Table::new(
            "Table II — MLLM model zoo",
            &["model", "vision", "connector", "d_model", "layers", "heads(kv)",
              "d_ffn", "vocab", "params"],
        );
        for m in MllmConfig::paper_models().iter().chain([MllmConfig::tiny()].iter()) {
            t.row(vec![
                m.name.clone(),
                format!("{:?}", m.vision.kind),
                format!("{:?}", m.connector.kind),
                m.llm.d_model.to_string(),
                m.llm.n_layers.to_string(),
                format!("{}({})", m.llm.n_heads, m.llm.n_kv_heads),
                m.llm.d_ffn.to_string(),
                m.llm.vocab.to_string(),
                format!("{:.2}B", m.total_params() as f64 / 1e9),
            ]);
        }
        print!("{}", t.render());
    }
    if args.flag("hardware") || both {
        let hw = chime::config::ChimeConfig::default().hardware;
        let mut t = Table::new("Tables III/IV — CHIME hardware", &["parameter", "value"]);
        t.row(vec!["dram.layers".into(), hw.dram.layers.to_string()]);
        t.row(vec!["dram.tiers".into(), hw.dram.tiers.to_string()]);
        t.row(vec!["dram.tier0 latency".into(), format!("{:.1} ns", hw.dram.tier_latency_ns(0))]);
        t.row(vec!["dram.tier4 latency".into(), format!("{:.1} ns", hw.dram.tier_latency_ns(4))]);
        t.row(vec!["dram.capacity".into(), fmt_bytes(hw.dram.chip_capacity_bytes() as f64)]);
        t.row(vec!["dram.internal bw".into(), format!("{:.0} GB/s", hw.dram.internal_bw_gbps(1.0))]);
        t.row(vec!["rram.layers".into(), hw.rram.layers.to_string()]);
        t.row(vec!["rram.capacity".into(), fmt_bytes(hw.rram.chip_capacity_bytes as f64)]);
        t.row(vec!["rram.interface bw".into(), format!("{:.0} GB/s", hw.rram.interface_bw_gbps(1.0))]);
        t.row(vec!["rram.read stream bw".into(), format!("{:.0} GB/s", hw.rram.read_stream_bw_gbps(1.0))]);
        t.row(vec!["dram_nmp.peak".into(), format!("{} TFLOPS / {} W", hw.dram_nmp.peak_tflops, hw.dram_nmp.peak_power_w)]);
        t.row(vec!["rram_nmp.peak".into(), format!("{} TFLOPS / {} W", hw.rram_nmp.peak_tflops, hw.rram_nmp.peak_power_w)]);
        t.row(vec!["ucie.bandwidth".into(), format!("{} GB/s", hw.ucie.bandwidth_gbps)]);
        t.row(vec!["total die area".into(), format!("{:.2} mm2", hw.total_die_area_mm2())]);
        print!("{}", t.render());
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), ChimeError> {
    ensure_known(
        args,
        &["model", "all", "dram-only", "out", "text", "json", "config", "memory", "topology",
          "threads", "trace-out"],
    )?;
    let threads = threads_arg(args)?;
    let kind = if args.flag("dram-only") { BackendKind::DramOnly } else { BackendKind::Sim };
    let fidelity = memory_arg(args)?;
    let topology = topology_arg(args)?;
    let trace_out = trace_out_arg(args)?;
    if trace_out.is_some() && args.flag("all") {
        return Err(ChimeError::Invalid(
            "--trace-out records one model's run; pass a single --model, not --all".to_string(),
        ));
    }
    let mode = kind.name();
    let models: Vec<MllmConfig> = if args.flag("all") {
        MllmConfig::paper_models()
    } else {
        let name = args.get_or("model", "fastvlm-0.6b");
        vec![MllmConfig::by_name(name).ok_or(ChimeError::Unknown {
            what: "model",
            name: name.to_string(),
            hint: Some("fastvlm-0.6b fastvlm-1.7b mobilevlm-1.7b mobilevlm-3b tiny".to_string()),
        })?]
    };
    let mut t = Table::new(
        "CHIME simulation",
        &["model", "mode", "TTFT", "total", "TPS", "tok/J", "power (W)", "KV offloaded"],
    );
    let mut json_rows = Vec::new();
    for m in &models {
        let mut b = builder_from(args)?.model_config(m.clone()).backend(kind).threads(threads);
        if let Some(f) = fidelity {
            b = b.memory_fidelity(f);
        }
        if let Some(t) = topology {
            b = b.topology(t);
        }
        let mut session = b.build()?;
        if trace_out.is_some() {
            session.backend_mut().set_tracing(true);
        }
        let stats = session.infer()?;
        if let Some(path) = &trace_out {
            write_trace(&mut session, path)?;
        }
        let mode = if kind == BackendKind::Sim { "chime" } else { mode };
        // Label from the session's *effective* fidelity, so a cycle run
        // selected via a --config file is reported the same as --memory.
        let mode = if session.memory_fidelity() == MemoryFidelity::CycleAccurate {
            format!("{mode}+cycle")
        } else {
            mode.to_string()
        };
        t.row(vec![
            m.name.clone(),
            mode.clone(),
            fmt_ns(stats.ttft_ns()),
            fmt_ns(stats.total_time_ns()),
            table::f(stats.tokens_per_s(), 1),
            table::f(stats.tokens_per_j(), 1),
            table::f(stats.avg_power_w(), 2),
            fmt_bytes(stats.kv_offloaded_bytes as f64),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", m.name.as_str().into()),
            ("mode", mode.as_str().into()),
            ("ttft_ns", stats.ttft_ns().into()),
            ("total_ns", stats.total_time_ns().into()),
            ("tps", stats.tokens_per_s().into()),
            ("tok_per_j", stats.tokens_per_j().into()),
            ("power_w", stats.avg_power_w().into()),
        ]));
    }
    if args.flag("json") {
        println!("{}", Json::Arr(json_rows).pretty());
    } else {
        print!("{}", t.render());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), ChimeError> {
    ensure_known(
        args,
        &["backend", "model", "requests", "arrival", "rate", "steal", "seed", "batch",
          "tokens", "packages", "route", "queue", "config", "out", "text", "artifacts",
          "memory", "topology", "threads", "wall", "listen", "deterministic", "addr-file",
          "trace-out"],
    )?;
    if args.flag("listen") {
        return cmd_serve_listen(args);
    }
    for flag in ["deterministic", "addr-file"] {
        if args.flag(flag) {
            return Err(ChimeError::Invalid(format!(
                "--{flag} applies only to the network listener (`chime serve --listen`)"
            )));
        }
    }
    // Validated here for the spelling; the Session builder owns the
    // backend-compatibility checks (--memory cycle or a routed --topology
    // on a backend without the subsystem is a typed Invalid error, same
    // as the config-file path).
    let fidelity = memory_arg(args)?;
    let topology = topology_arg(args)?;
    let trace_out = trace_out_arg(args)?;
    let threads = threads_arg(args)?;
    let wall = args.flag("wall");
    let n = usize_arg(args, "requests", 16)?;
    let arrival = arrival_arg(args)?;
    let steal = steal_arg(args)?;
    let seed = usize_arg(args, "seed", 7)? as u64;
    let batch = usize_arg(args, "batch", 4)?;
    let backend_name = args.get_or("backend", "sim");
    let kind = BackendKind::parse(backend_name).ok_or(ChimeError::Unknown {
        what: "backend",
        name: backend_name.to_string(),
        hint: Some("sim functional dram-only jetson facil".to_string()),
    })?;
    // Stealing moves queued work between sibling packages; on a backend
    // with no package dimension it would be a silent no-op, so reject it
    // up front (same contract as the Session builder).
    if steal && !matches!(kind, BackendKind::Sim | BackendKind::Sharded | BackendKind::DramOnly) {
        return Err(ChimeError::Invalid(format!(
            "backend {} has no sibling packages to steal between; --steal applies to \
             the sharded simulator backends",
            kind.name()
        )));
    }
    // The trace is the simulator's virtual timeline — baselines and the
    // functional path record nothing, so reject instead of writing an
    // empty file.
    if trace_out.is_some()
        && !matches!(kind, BackendKind::Sim | BackendKind::Sharded | BackendKind::DramOnly)
    {
        return Err(ChimeError::Invalid(format!(
            "backend {} records no trace; --trace-out applies to the simulator backends",
            kind.name()
        )));
    }
    // Wall-clock mode races worker threads over real time — there is no
    // deterministic virtual timeline to record, and its work migration is
    // the executor's deques, not the virtual-time steal pass. Both
    // combinations would otherwise be silent lies, so they are rejected.
    if wall && trace_out.is_some() {
        return Err(ChimeError::Invalid(
            "--wall runs the free-running executor, whose event interleaving is not \
             deterministic; --trace-out needs the seeded virtual-time mode (drop --wall, \
             or drop --trace-out and read the host counters it prints instead)"
                .to_string(),
        ));
    }
    if wall && steal {
        return Err(ChimeError::Invalid(
            "--steal is the virtual-time cross-package policy; in --wall mode work \
             migrates through the executor's work-stealing deques instead (drop --steal)"
                .to_string(),
        ));
    }
    if wall && !matches!(kind, BackendKind::Sim | BackendKind::Sharded | BackendKind::DramOnly) {
        return Err(ChimeError::Invalid(format!(
            "backend {} is a single sequential stream; --wall applies to the \
             sim/sharded/dram-only backends",
            kind.name()
        )));
    }
    // Same contract as the Session builder: executor threads drive the
    // simulator's package event loops; a sequential baseline has none.
    if threads > 1
        && !matches!(kind, BackendKind::Sim | BackendKind::Sharded | BackendKind::DramOnly)
    {
        return Err(ChimeError::Invalid(format!(
            "backend {} is a single sequential stream; --threads > 1 applies to the \
             sim/sharded/dram-only backends",
            kind.name()
        )));
    }

    match kind {
        BackendKind::Functional => {
            for flag in ["packages", "route", "queue"] {
                if args.get(flag).is_some() {
                    eprintln!(
                        "note: --{flag} is ignored by the functional backend \
                         (single sequential PJRT stream; sharding is sim-only)"
                    );
                }
            }
            let mut b = builder_from(args)?.backend(BackendKind::Functional);
            if let Some(dir) = args.get("artifacts") {
                b = b.artifacts_dir(dir);
            }
            if let Some(f) = fidelity {
                b = b.memory_fidelity(f);
            }
            if let Some(t) = topology {
                b = b.topology(t);
            }
            let mut session = b.build()?;
            let mut reqs =
                session.requests_for(&arrival, seed, n, usize_arg(args, "tokens", 8)?)?;
            for r in &mut reqs {
                r.arrival_ns = 0.0; // wall-clock stream: queueing from backlog only
            }
            let out = session.serve(reqs)?;
            let mut metrics = out.metrics;
            let p50 = metrics.latency_percentile_ns(50.0);
            let p99 = metrics.latency_percentile_ns(99.0);
            println!(
                "functional backend: {} requests, {} tokens, p50 latency {}, p99 {}, {:.1} tok/s",
                metrics.completed,
                metrics.tokens,
                fmt_ns(p50),
                fmt_ns(p99),
                metrics.tokens_per_s(),
            );
            for r in out.responses.iter().take(4) {
                println!("  req {} -> {:?}", r.id, r.tokens);
            }
        }
        BackendKind::Jetson | BackendKind::Facil => {
            for flag in ["packages", "route", "queue", "batch"] {
                if args.get(flag).is_some() {
                    eprintln!(
                        "note: --{flag} is ignored by the {} baseline \
                         (single sequential stream; sharding is sim-only)",
                        kind.name()
                    );
                }
            }
            let mut b = builder_from(args)?
                .model(args.get_or("model", "fastvlm-0.6b"))
                .backend(kind);
            if let Some(f) = fidelity {
                b = b.memory_fidelity(f);
            }
            if let Some(t) = topology {
                b = b.topology(t);
            }
            let mut session = b.build()?;
            let tokens = usize_arg(args, "tokens", 64)?;
            let reqs = session.requests_for(&arrival, seed, n, tokens)?;
            let out = session.serve(reqs)?;
            let mut metrics = out.metrics;
            let p50 = metrics.latency_percentile_ns(50.0);
            let p99 = metrics.latency_percentile_ns(99.0);
            println!(
                "{} baseline serving {} (sequential stream, {} arrivals): {} reqs completed, \
                 {} tokens, {:.1} tok/s system, p50 latency {}, p99 {}, {:.2} tok/J",
                session.backend_name(),
                session.model().name,
                arrival.spec(),
                metrics.completed,
                metrics.tokens,
                metrics.tokens_per_s(),
                fmt_ns(p50),
                fmt_ns(p99),
                metrics.tokens_per_j(),
            );
        }
        BackendKind::Sim | BackendKind::Sharded | BackendKind::DramOnly => {
            let packages = usize_arg(args, "packages", 1)?;
            let route_name = args.get_or("route", "rr");
            let route = RoutePolicy::parse(route_name).ok_or(ChimeError::Unknown {
                what: "route",
                name: route_name.to_string(),
                hint: Some("rr round-robin ll least-loaded".to_string()),
            })?;
            let policy = BatchPolicy {
                max_batch: batch,
                queue_capacity: usize_arg(args, "queue", BatchPolicy::default().queue_capacity)?,
            };
            // `serve --backend sim` runs the sharded coordinator at any
            // package count (1 package == the SimulatedServer core).
            let kind = if kind == BackendKind::DramOnly {
                BackendKind::DramOnly
            } else {
                BackendKind::Sharded
            };
            let mut b = builder_from(args)?
                .model(args.get_or("model", "fastvlm-0.6b"))
                .backend(kind)
                .packages(packages)
                .route(route)
                .batch(policy)
                .work_stealing(steal)
                .threads(threads);
            if let Some(f) = fidelity {
                b = b.memory_fidelity(f);
            }
            if let Some(t) = topology {
                b = b.topology(t);
            }
            let mut session = b.build()?;
            if trace_out.is_some() {
                session.backend_mut().set_tracing(true);
            }
            let tokens = usize_arg(args, "tokens", 64)?;
            let reqs = session.requests_for(&arrival, seed, n, tokens)?;
            if wall {
                let report = session.serve_wall_clock(reqs, threads)?;
                let mut metrics = report.outcome.metrics.clone();
                let p50 = metrics.latency_percentile_ns(50.0);
                let p99 = metrics.latency_percentile_ns(99.0);
                println!(
                    "wall-clock CHIME serving {} ({} package{}, {} worker thread{}, \
                     {} arrivals{}): {} reqs completed, {} rejected, {} shed, {} tokens, \
                     {:.1} tok/s simulated, p50 latency {}, p99 {}",
                    session.model().name,
                    packages,
                    if packages == 1 { "" } else { "s" },
                    report.workers,
                    if report.workers == 1 { "" } else { "s" },
                    arrival.spec(),
                    if kind == BackendKind::DramOnly { ", dram-only" } else { "" },
                    metrics.completed,
                    metrics.rejected,
                    metrics.shed,
                    metrics.tokens,
                    metrics.tokens_per_s(),
                    fmt_ns(p50),
                    fmt_ns(p99),
                );
                println!(
                    "  host: {:.1} ms wall, {:.0} events/s, {} deque steal{}",
                    report.wall_ns / 1e6,
                    if report.wall_ns > 0.0 {
                        report.events as f64 / (report.wall_ns / 1e9)
                    } else {
                        0.0
                    },
                    report.deque_steals,
                    if report.deque_steals == 1 { "" } else { "s" },
                );
                if !report.outcome.shed.is_empty() {
                    println!(
                        "  returned request ids (rejected by backpressure or shed as \
                         malformed): {:?}",
                        report.outcome.shed.iter().map(|r| r.id).collect::<Vec<_>>()
                    );
                }
                return Ok(());
            }
            // Drive the streaming protocol directly so the steal events
            // are observable (the batch wrapper discards the stream).
            let mut serving = session.open_serving()?;
            for r in reqs {
                serving.submit(r);
            }
            let events = serving.drain()?;
            let steals = events.iter().filter(|e| e.kind() == "stolen").count();
            let out = serving.finish()?;
            let mut metrics = out.metrics;
            let p50 = metrics.latency_percentile_ns(50.0);
            let p99 = metrics.latency_percentile_ns(99.0);
            println!(
                "simulated CHIME serving {} ({} package{}, {} routing, batch {batch}{}, \
                 {} arrivals, steal {}, {} memory, {} fabric): {} reqs completed, \
                 {} rejected, {} shed, {} tokens, \
                 {:.1} tok/s system, p50 latency {}, p99 {}, {:.1} tok/J",
                session.model().name,
                packages,
                if packages == 1 { "" } else { "s" },
                route.name(),
                if kind == BackendKind::DramOnly { ", dram-only" } else { "" },
                arrival.spec(),
                if steal { "on" } else { "off" },
                session.memory_fidelity().name(),
                session.topology().name(),
                metrics.completed,
                metrics.rejected,
                metrics.shed,
                metrics.tokens,
                metrics.tokens_per_s(),
                fmt_ns(p50),
                fmt_ns(p99),
                metrics.tokens_per_j(),
            );
            if steal {
                println!(
                    "  work steals: {steals} ({} moved, mean routed delay {})",
                    fmt_bytes(metrics.stolen_bytes as f64),
                    fmt_ns(metrics.mean_steal_delay_ns()),
                );
            }
            if packages > 1 {
                println!(
                    "  per-package completions: {:?} (KV budget {} per package)",
                    session.package_completed().unwrap_or_default(),
                    fmt_bytes(session.kv_budget_bytes_per_package().unwrap_or(0) as f64),
                );
            }
            if !out.shed.is_empty() {
                println!(
                    "  returned request ids (rejected by backpressure or shed as malformed): {:?}",
                    out.shed.iter().map(|r| r.id).collect::<Vec<_>>()
                );
            }
            if let Some(path) = &trace_out {
                write_trace(&mut session, path)?;
            }
        }
    }
    Ok(())
}

/// `chime serve --listen`: the HTTP/SSE network front end. The session
/// is built inside the server's engine thread (backends are not Send);
/// this thread blocks in `join` until `/v1/shutdown` or SIGINT drains
/// the listener.
fn cmd_serve_listen(args: &Args) -> Result<(), ChimeError> {
    let Some(listen) = args.get("listen") else {
        return Err(ChimeError::Invalid(
            "--listen expects HOST:PORT (e.g. 127.0.0.1:8080, or 127.0.0.1:0 for an \
             ephemeral port)"
                .to_string(),
        ));
    };
    // The listener takes its arrivals from the wire, not from a local
    // arrival process — reject the stream-shaping flags instead of
    // silently ignoring them.
    for flag in ["arrival", "rate", "requests", "seed"] {
        if args.flag(flag) {
            return Err(ChimeError::Invalid(format!(
                "--{flag} does not apply to --listen: the listener takes arrivals from the \
                 wire; shape the load with `chime loadgen --target <addr> --{flag} ...`"
            )));
        }
    }
    // The listener's engine loop already free-runs against wire arrivals;
    // --wall (the batch wall-clock executor) has no meaning here.
    if args.flag("wall") {
        return Err(ChimeError::Invalid(
            "--wall does not apply to --listen: the listener already runs in wall-clock \
             time against wire arrivals; use --threads N to widen its executor"
                .to_string(),
        ));
    }
    let threads = threads_arg(args)?;
    let steal = steal_arg(args)?;
    let fidelity = memory_arg(args)?;
    let topology = topology_arg(args)?;
    let trace_out = trace_out_arg(args)?;
    let deterministic = args.flag("deterministic");
    let default_tokens = usize_arg(args, "tokens", 64)?;
    let backend_name = args.get_or("backend", "sim");
    let kind = BackendKind::parse(backend_name).ok_or(ChimeError::Unknown {
        what: "backend",
        name: backend_name.to_string(),
        hint: Some("sim functional dram-only jetson facil".to_string()),
    })?;
    if trace_out.is_some()
        && !matches!(kind, BackendKind::Sim | BackendKind::Sharded | BackendKind::DramOnly)
    {
        return Err(ChimeError::Invalid(format!(
            "backend {} records no trace; --trace-out applies to the simulator backends",
            kind.name()
        )));
    }
    if threads > 1
        && !matches!(kind, BackendKind::Sim | BackendKind::Sharded | BackendKind::DramOnly)
    {
        return Err(ChimeError::Invalid(format!(
            "backend {} is a single sequential stream; --threads > 1 applies to the \
             sim/sharded/dram-only backends",
            kind.name()
        )));
    }
    let mut b = builder_from(args)?.model(args.get_or("model", "fastvlm-0.6b"));
    match kind {
        BackendKind::Sim | BackendKind::Sharded | BackendKind::DramOnly => {
            // Same mapping as the in-process serve path: `sim` runs the
            // sharded coordinator at any package count.
            let kind =
                if kind == BackendKind::DramOnly { kind } else { BackendKind::Sharded };
            let route_name = args.get_or("route", "rr");
            let route = RoutePolicy::parse(route_name).ok_or(ChimeError::Unknown {
                what: "route",
                name: route_name.to_string(),
                hint: Some("rr round-robin ll least-loaded".to_string()),
            })?;
            b = b
                .backend(kind)
                .packages(usize_arg(args, "packages", 1)?)
                .route(route)
                .batch(BatchPolicy {
                    max_batch: usize_arg(args, "batch", 4)?,
                    queue_capacity: usize_arg(
                        args,
                        "queue",
                        BatchPolicy::default().queue_capacity,
                    )?,
                })
                .work_stealing(steal)
                .threads(threads);
        }
        BackendKind::Functional => {
            b = b.backend(kind);
            if let Some(dir) = args.get("artifacts") {
                b = b.artifacts_dir(dir);
            }
        }
        BackendKind::Jetson | BackendKind::Facil => {
            b = b.backend(kind);
        }
    }
    if let Some(f) = fidelity {
        b = b.memory_fidelity(f);
    }
    if let Some(t) = topology {
        b = b.topology(t);
    }
    let opts = ServeOpts {
        deterministic,
        default_max_new_tokens: default_tokens,
        handle_signals: true,
        trace_out: trace_out.as_deref().map(std::path::PathBuf::from),
        ..ServeOpts::default()
    };
    let server = NetServer::spawn(listen, move || b.build(), opts)?;
    println!("chime serve listening on http://{}", server.addr());
    println!(
        "  endpoints: POST /v1/submit  GET /v1/stream/<id>  GET /v1/metrics  \
         POST /v1/finish  POST /v1/shutdown"
    );
    if deterministic {
        println!(
            "  deterministic replay mode: arrivals pinned from request bodies; tokens \
             stream at finish"
        );
    }
    if let Some(path) = args.get("addr-file") {
        std::fs::write(path, format!("{}\n", server.addr()))
            .map_err(|e| ChimeError::Runtime(format!("writing {path}: {e}")))?;
    }
    let s = server.join()?;
    println!(
        "served: {} submitted, {} completed, {} rejected, {} shed, {} tokens",
        s.submitted, s.completed, s.rejected, s.shed, s.tokens
    );
    if let Some(path) = &trace_out {
        println!("wrote trace {path}");
    }
    Ok(())
}

/// `chime loadgen`: drive a running `--listen` server open-loop and
/// render the wall-clock tail table.
fn cmd_loadgen(args: &Args) -> Result<(), ChimeError> {
    ensure_known(
        args,
        &["target", "requests", "arrival", "rate", "seed", "tokens", "prompt-tokens",
          "timeout-s", "shutdown", "json"],
    )?;
    let Some(target) = args.get("target") else {
        return Err(ChimeError::Invalid(
            "--target expects HOST:PORT of a running `chime serve --listen` server".to_string(),
        ));
    };
    if args.flag("json") && args.get("json").is_none() {
        return Err(ChimeError::Invalid(
            "--json expects a file path for the canonical loadgen report".to_string(),
        ));
    }
    let timeout_s = f64_arg(args, "timeout-s", 120.0)?;
    if !timeout_s.is_finite() || timeout_s <= 0.0 {
        return Err(ChimeError::Invalid(format!(
            "--timeout-s must be finite and positive, got {timeout_s}"
        )));
    }
    let cfg = LoadgenConfig {
        target: target.to_string(),
        requests: usize_arg(args, "requests", 16)?,
        arrival: arrival_arg(args)?,
        seed: usize_arg(args, "seed", 7)? as u64,
        max_new_tokens: usize_arg(args, "tokens", 16)?,
        prompt_tokens: usize_arg(args, "prompt-tokens", 8)?,
        shutdown: args.flag("shutdown"),
        timeout: Duration::from_secs_f64(timeout_s),
    };
    let report = loadgen::run(&cfg)?;
    print!("{}", report.table);
    if let Some(path) = args.get("json") {
        std::fs::write(path, format!("{}\n", report.to_json().pretty()))
            .map_err(|e| ChimeError::Runtime(format!("writing {path}: {e}")))?;
        println!("wrote {path}");
    }
    if let Some(outcome) = &report.outcome {
        println!("server outcome (virtual time): {}", outcome.get("metrics").compact());
    }
    if !report.errors.is_empty() {
        for e in report.errors.iter().take(5) {
            eprintln!("chime loadgen: {e}");
        }
        return Err(ChimeError::Runtime(format!(
            "{} of {} requests failed",
            report.errors.len(),
            report.samples.len() + report.errors.len()
        )));
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), ChimeError> {
    ensure_known(args, &["model", "json", "memory", "topology"])?;
    let fidelity = memory_arg(args)?.unwrap_or(MemoryFidelity::FirstOrder);
    let topology = topology_arg(args)?.unwrap_or_default();
    let e = results::fig8::run_with(fidelity, topology);
    if args.flag("json") {
        println!("{}", e.json.pretty());
    } else {
        print!("{}", e.text);
    }
    Ok(())
}

fn cmd_memcheck(args: &Args) -> Result<(), ChimeError> {
    ensure_known(args, &["json"])?;
    let e = results::memcheck::run();
    if args.flag("json") {
        println!("{}", e.json.pretty());
    } else {
        print!("{}", e.text);
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<(), ChimeError> {
    ensure_known(
        args,
        &["json", "quick", "snapshot", "requests", "tokens", "iters", "profile", "threads"],
    )?;
    if args.flag("snapshot") && args.get("snapshot").is_none() {
        return Err(ChimeError::Invalid(
            "--snapshot expects a file path (e.g. BENCH_006.json)".to_string(),
        ));
    }
    if args.flag("profile") && args.get("profile").is_none() {
        return Err(ChimeError::Invalid(
            "--profile expects a file path (e.g. HOTPATH_009.json)".to_string(),
        ));
    }
    let mut bc = if args.flag("quick") {
        results::perf::BenchConfig::quick()
    } else {
        results::perf::BenchConfig::paper()
    };
    bc.requests = usize_arg(args, "requests", bc.requests)?;
    bc.tokens = usize_arg(args, "tokens", bc.tokens)?;
    bc.iters = usize_arg(args, "iters", bc.iters)?;
    if args.flag("threads") {
        // threads_arg owns the valueless / zero usage errors; the bench
        // default stays the 4-worker exec column, not the serve default.
        bc.exec_threads = threads_arg(args)?;
    }
    if bc.requests == 0 || bc.tokens == 0 || bc.iters == 0 {
        return Err(ChimeError::Invalid(
            "--requests, --tokens, and --iters must be >= 1".to_string(),
        ));
    }
    let e = results::perf::run_with(&bc);
    if args.flag("json") {
        println!("{}", e.json.pretty());
    } else {
        print!("{}", e.text);
    }
    if let Some(path) = args.get("snapshot") {
        std::fs::write(path, format!("{}\n", e.json.pretty()))
            .map_err(|err| ChimeError::Runtime(format!("writing {path}: {err}")))?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("profile") {
        let profile = results::perf::profile_with(&bc);
        println!("{}", profile.text);
        std::fs::write(path, format!("{}\n", profile.json.pretty()))
            .map_err(|err| ChimeError::Runtime(format!("writing {path}: {err}")))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_results(args: &Args) -> Result<(), ChimeError> {
    ensure_known(args, &["fig", "all", "json", "baselines"])?;
    let experiments = if args.flag("all") || args.get("fig").is_none() {
        results::run_all()
    } else {
        let id = args.get("fig").unwrap_or("");
        match results::run_one(id) {
            Some(e) => vec![e],
            None => {
                return Err(ChimeError::Unknown {
                    what: "experiment",
                    name: id.to_string(),
                    hint: Some(
                        "1 6 7 8 9 table5 ablations scaling memcheck tail perf fabric".to_string(),
                    ),
                })
            }
        }
    };
    if args.flag("json") {
        let obj: Vec<Json> = experiments
            .iter()
            .map(|e| Json::obj(vec![("id", e.id.into()), ("data", e.json.clone())]))
            .collect();
        println!("{}", Json::Arr(obj).pretty());
    } else {
        for e in &experiments {
            println!("{}", e.text);
        }
    }
    // Also report the baseline ranges alongside (CLI convenience) — the
    // baselines are Session backends like everything else.
    if args.flag("baselines") {
        for m in MllmConfig::paper_models() {
            let mut j = Session::builder()
                .model_config(m.clone())
                .backend(BackendKind::Jetson)
                .build()?;
            let mut f = Session::builder()
                .model_config(m.clone())
                .backend(BackendKind::Facil)
                .build()?;
            println!(
                "{}: jetson {:.1} tok/s, facil {:.1} tok/s",
                m.name,
                j.infer()?.tokens_per_s(),
                f.infer()?.tokens_per_s()
            );
        }
    }
    Ok(())
}

fn cmd_parity(args: &Args) -> Result<(), ChimeError> {
    ensure_known(args, &["artifacts"])?;
    let dir = std::path::PathBuf::from(
        args.get_or("artifacts", Manifest::default_dir().to_str().unwrap()),
    );
    let m = chime::runtime::FunctionalMllm::load(&dir).map_err(|e| {
        ChimeError::BackendUnavailable {
            backend: "functional",
            reason: format!("{e:#} (run `make artifacts`)"),
        }
    })?;
    m.verify_parity().map_err(|e| ChimeError::Runtime(format!("{e:#}")))?;
    println!(
        "PARITY OK — rust PJRT greedy decode matches the python AOT oracle ({} tokens)",
        m.manifest.parity.n_steps
    );
    Ok(())
}
