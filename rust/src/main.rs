//! `chime` — CLI for the CHIME reproduction.
//!
//! Subcommands:
//!   info      — print model zoo (Table II) and hardware configs (III/IV)
//!   simulate  — run one model's VQA inference on the CHIME simulator
//!   serve     — serve a request stream (simulated or functional backend)
//!   sweep     — sequence-length sweep (Fig 8)
//!   results   — regenerate paper tables/figures (--fig N | --all)
//!   parity    — verify the PJRT functional path against the AOT oracle

use chime::baselines::{facil, jetson};
use chime::config::{ChimeConfig, FacilSpec, JetsonSpec, MllmConfig};
use chime::coordinator::{BatchPolicy, FunctionalServer, RoutePolicy, ServeRequest, ShardedServer};
use chime::model::workload::RequestStream;
use chime::results;
use chime::runtime::Manifest;
use chime::sim;
use chime::util::stats::{fmt_bytes, fmt_ns};
use chime::util::{table, Args, Json, Table};

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("info") => cmd_info(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("results") => cmd_results(&args),
        Some("parity") => cmd_parity(&args),
        Some(other) => {
            eprintln!("unknown command {other:?}");
            usage();
            2
        }
        None => {
            usage();
            0
        }
    };
    std::process::exit(code);
}

fn usage() {
    println!(
        "chime — CHIME paper reproduction (chiplet heterogeneous near-memory MLLM inference)

USAGE: chime <command> [options]

COMMANDS:
  info      [--models] [--hardware]           Table II / III / IV configs
  simulate  [--model NAME] [--all] [--dram-only] [--out N] [--text N] [--json]
  serve     [--backend sim|functional] [--model NAME] [--requests N]
            [--rate R] [--batch B] [--tokens N] [--packages N]
            [--route rr|least-loaded] [--queue N]
  sweep     [--model NAME] [--json]           Fig 8 sequence-length sweep
  results   [--fig 1|6|7|8|9|table5|ablations|scaling] [--all] [--json] [--baselines]
  parity    [--artifacts DIR]                 verify PJRT vs AOT oracle

MODELS: fastvlm-0.6b fastvlm-1.7b mobilevlm-1.7b mobilevlm-3b tiny"
    );
}

fn resolve_model(args: &Args) -> Result<MllmConfig, i32> {
    let name = args.get_or("model", "fastvlm-0.6b");
    MllmConfig::by_name(name).ok_or_else(|| {
        eprintln!("unknown model {name:?}");
        2
    })
}

fn config_from(args: &Args) -> ChimeConfig {
    let mut cfg = ChimeConfig::default();
    if let Some(path) = args.get("config") {
        cfg = cfg
            .with_override_file(path)
            .unwrap_or_else(|e| panic!("config: {e}"));
    }
    cfg.workload.output_tokens = args.get_usize("out", cfg.workload.output_tokens);
    cfg.workload.text_tokens = args.get_usize("text", cfg.workload.text_tokens);
    cfg
}

fn cmd_info(args: &Args) -> i32 {
    let both = !args.flag("models") && !args.flag("hardware");
    if args.flag("models") || both {
        let mut t = Table::new(
            "Table II — MLLM model zoo",
            &["model", "vision", "connector", "d_model", "layers", "heads(kv)",
              "d_ffn", "vocab", "params"],
        );
        for m in MllmConfig::paper_models().iter().chain([MllmConfig::tiny()].iter()) {
            t.row(vec![
                m.name.clone(),
                format!("{:?}", m.vision.kind),
                format!("{:?}", m.connector.kind),
                m.llm.d_model.to_string(),
                m.llm.n_layers.to_string(),
                format!("{}({})", m.llm.n_heads, m.llm.n_kv_heads),
                m.llm.d_ffn.to_string(),
                m.llm.vocab.to_string(),
                format!("{:.2}B", m.total_params() as f64 / 1e9),
            ]);
        }
        print!("{}", t.render());
    }
    if args.flag("hardware") || both {
        let hw = ChimeConfig::default().hardware;
        let mut t = Table::new("Tables III/IV — CHIME hardware", &["parameter", "value"]);
        t.row(vec!["dram.layers".into(), hw.dram.layers.to_string()]);
        t.row(vec!["dram.tiers".into(), hw.dram.tiers.to_string()]);
        t.row(vec!["dram.tier0 latency".into(), format!("{:.1} ns", hw.dram.tier_latency_ns(0))]);
        t.row(vec!["dram.tier4 latency".into(), format!("{:.1} ns", hw.dram.tier_latency_ns(4))]);
        t.row(vec!["dram.capacity".into(), fmt_bytes(hw.dram.chip_capacity_bytes() as f64)]);
        t.row(vec!["dram.internal bw".into(), format!("{:.0} GB/s", hw.dram.internal_bw_gbps(1.0))]);
        t.row(vec!["rram.layers".into(), hw.rram.layers.to_string()]);
        t.row(vec!["rram.capacity".into(), fmt_bytes(hw.rram.chip_capacity_bytes as f64)]);
        t.row(vec!["rram.interface bw".into(), format!("{:.0} GB/s", hw.rram.interface_bw_gbps(1.0))]);
        t.row(vec!["rram.read stream bw".into(), format!("{:.0} GB/s", hw.rram.read_stream_bw_gbps(1.0))]);
        t.row(vec!["dram_nmp.peak".into(), format!("{} TFLOPS / {} W", hw.dram_nmp.peak_tflops, hw.dram_nmp.peak_power_w)]);
        t.row(vec!["rram_nmp.peak".into(), format!("{} TFLOPS / {} W", hw.rram_nmp.peak_tflops, hw.rram_nmp.peak_power_w)]);
        t.row(vec!["ucie.bandwidth".into(), format!("{} GB/s", hw.ucie.bandwidth_gbps)]);
        t.row(vec!["total die area".into(), format!("{:.2} mm2", hw.total_die_area_mm2())]);
        print!("{}", t.render());
    }
    0
}

fn cmd_simulate(args: &Args) -> i32 {
    let cfg = config_from(args);
    let models = if args.flag("all") {
        MllmConfig::paper_models()
    } else {
        match resolve_model(args) {
            Ok(m) => vec![m],
            Err(c) => return c,
        }
    };
    let mut t = Table::new(
        "CHIME simulation",
        &["model", "mode", "TTFT", "total", "TPS", "tok/J", "power (W)", "KV offloaded"],
    );
    let mut json_rows = Vec::new();
    for m in &models {
        let (stats, mode) = if args.flag("dram-only") {
            (sim::simulate_dram_only(m, &cfg), "dram-only")
        } else {
            (sim::simulate(m, &cfg), "chime")
        };
        t.row(vec![
            m.name.clone(),
            mode.into(),
            fmt_ns(stats.ttft_ns()),
            fmt_ns(stats.total_time_ns()),
            table::f(stats.tokens_per_s(), 1),
            table::f(stats.tokens_per_j(), 1),
            table::f(stats.avg_power_w(), 2),
            fmt_bytes(stats.kv_offloaded_bytes as f64),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", m.name.as_str().into()),
            ("mode", mode.into()),
            ("ttft_ns", stats.ttft_ns().into()),
            ("total_ns", stats.total_time_ns().into()),
            ("tps", stats.tokens_per_s().into()),
            ("tok_per_j", stats.tokens_per_j().into()),
            ("power_w", stats.avg_power_w().into()),
        ]));
    }
    if args.flag("json") {
        println!("{}", Json::Arr(json_rows).pretty());
    } else {
        print!("{}", t.render());
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let n = args.get_usize("requests", 16);
    let rate = args.get_f64("rate", 2.0);
    let batch = args.get_usize("batch", 4);
    let backend = args.get_or("backend", "sim");
    match backend {
        "functional" => {
            for flag in ["packages", "route", "queue"] {
                if args.get(flag).is_some() {
                    eprintln!(
                        "note: --{flag} is ignored by the functional backend \
                         (single sequential PJRT stream; sharding is sim-only)"
                    );
                }
            }
            let dir = std::path::PathBuf::from(
                args.get_or("artifacts", Manifest::default_dir().to_str().unwrap()),
            );
            let mut srv = match FunctionalServer::load(&dir) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("functional backend unavailable: {e:#}");
                    return 1;
                }
            };
            let cfgm = srv.mllm.manifest.config.clone_fields();
            let mut stream = RequestStream::new(7, rate, cfgm.0, args.get_usize("tokens", 8), cfgm.1);
            let reqs: Vec<ServeRequest> = stream
                .take(n)
                .into_iter()
                .map(|r| ServeRequest {
                    id: r.id,
                    prompt: r.prompt,
                    image_seed: r.image_seed,
                    max_new_tokens: r.max_new_tokens,
                    arrival_ns: 0.0,
                })
                .collect();
            let (resps, mut metrics) = srv.serve(&reqs).expect("serving failed");
            let p50 = metrics.latency_percentile_ns(50.0);
            let p99 = metrics.latency_percentile_ns(99.0);
            println!(
                "functional backend: {} requests, {} tokens, p50 latency {}, p99 {}, {:.1} tok/s",
                metrics.completed,
                metrics.tokens,
                fmt_ns(p50),
                fmt_ns(p99),
                metrics.tokens_per_s(),
            );
            for r in resps.iter().take(4) {
                println!("  req {} -> {:?}", r.id, r.tokens);
            }
            0
        }
        _ => {
            let model = match resolve_model(args) {
                Ok(m) => m,
                Err(c) => return c,
            };
            let cfg = config_from(args);
            let tokens = args.get_usize("tokens", 64);
            let packages = args.get_usize("packages", 1);
            let route = match RoutePolicy::parse(args.get_or("route", "rr")) {
                Some(r) => r,
                None => {
                    eprintln!("unknown --route (use rr|round-robin|ll|least-loaded)");
                    return 2;
                }
            };
            let policy = BatchPolicy {
                max_batch: batch,
                queue_capacity: args.get_usize("queue", BatchPolicy::default().queue_capacity),
            };
            let mut stream = RequestStream::new(7, rate, cfg.workload.text_tokens, tokens, model.llm.vocab);
            let reqs: Vec<ServeRequest> = stream
                .take(n)
                .into_iter()
                .map(|r| ServeRequest {
                    id: r.id,
                    prompt: r.prompt,
                    image_seed: r.image_seed,
                    max_new_tokens: r.max_new_tokens,
                    arrival_ns: r.arrival_ns,
                })
                .collect();
            let mut srv = ShardedServer::new(&model, &cfg, policy, packages, route);
            let out = srv.serve(reqs);
            let mut metrics = out.metrics;
            let p50 = metrics.latency_percentile_ns(50.0);
            let p99 = metrics.latency_percentile_ns(99.0);
            println!(
                "simulated CHIME serving {} ({} package{}, {} routing, batch {batch}): \
                 {} reqs completed, {} shed, {} tokens, {:.1} tok/s system, \
                 p50 latency {}, p99 {}, {:.1} tok/J",
                model.name,
                packages,
                if packages == 1 { "" } else { "s" },
                route.name(),
                metrics.completed,
                metrics.rejected,
                metrics.tokens,
                metrics.tokens_per_s(),
                fmt_ns(p50),
                fmt_ns(p99),
                metrics.tokens_per_j(),
            );
            if packages > 1 {
                println!(
                    "  per-package completions: {:?} (KV budget {} per package)",
                    srv.package_completed(),
                    fmt_bytes(srv.kv_budget_bytes_per_package() as f64),
                );
            }
            if !out.shed.is_empty() {
                println!(
                    "  shed request ids (admission backpressure): {:?}",
                    out.shed.iter().map(|r| r.id).collect::<Vec<_>>()
                );
            }
            0
        }
    }
}

fn cmd_sweep(args: &Args) -> i32 {
    let e = results::fig8::run();
    if args.flag("json") {
        println!("{}", e.json.pretty());
    } else {
        print!("{}", e.text);
    }
    0
}

fn cmd_results(args: &Args) -> i32 {
    let experiments = if args.flag("all") || args.get("fig").is_none() {
        results::run_all()
    } else {
        match results::run_one(args.get("fig").unwrap_or("")) {
            Some(e) => vec![e],
            None => {
                eprintln!("unknown experiment id (use 1, 6, 7, 8, 9, table5, ablations, scaling)");
                return 2;
            }
        }
    };
    if args.flag("json") {
        let obj: Vec<Json> = experiments
            .iter()
            .map(|e| Json::obj(vec![("id", e.id.into()), ("data", e.json.clone())]))
            .collect();
        println!("{}", Json::Arr(obj).pretty());
    } else {
        for e in &experiments {
            println!("{}", e.text);
        }
    }
    // Also report the baseline ranges alongside (CLI convenience).
    if args.flag("baselines") {
        let cfg = ChimeConfig::default();
        for m in MllmConfig::paper_models() {
            let j = jetson::run(&m, &cfg.workload, &JetsonSpec::default());
            let f = facil::run(&m, &cfg.workload, &FacilSpec::default());
            println!(
                "{}: jetson {:.1} tok/s, facil {:.1} tok/s",
                m.name,
                j.tokens_per_s(),
                f.tokens_per_s()
            );
        }
    }
    0
}

fn cmd_parity(args: &Args) -> i32 {
    let dir = std::path::PathBuf::from(
        args.get_or("artifacts", Manifest::default_dir().to_str().unwrap()),
    );
    match chime::runtime::FunctionalMllm::load(&dir) {
        Ok(m) => match m.verify_parity() {
            Ok(()) => {
                println!(
                    "PARITY OK — rust PJRT greedy decode matches the python AOT oracle ({} tokens)",
                    m.manifest.parity.n_steps
                );
                0
            }
            Err(e) => {
                eprintln!("{e:#}");
                1
            }
        },
        Err(e) => {
            eprintln!("cannot load artifacts: {e:#} (run `make artifacts`)");
            1
        }
    }
}

/// Tiny helper so serve --backend functional can size prompts.
trait CloneFields {
    fn clone_fields(&self) -> (usize, usize);
}
impl CloneFields for chime::runtime::artifact::ModelMeta {
    fn clone_fields(&self) -> (usize, usize) {
        (self.prompt_len, self.vocab)
    }
}
