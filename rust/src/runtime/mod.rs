//! PJRT artifact runtime: loads the HLO-text entry points that
//! `python/compile/aot.py` produced (`make artifacts`) and executes the
//! functional MLLM from the Rust request path. Python is build-time only.

pub mod artifact;
pub mod client;
pub mod mllm;

pub use artifact::Manifest;
pub use client::Runtime;
pub use mllm::{FunctionalMllm, Generation};
