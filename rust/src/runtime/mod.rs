//! PJRT artifact runtime: loads the HLO-text entry points that
//! `python/compile/aot.py` produced (`make artifacts`) and executes the
//! functional MLLM from the Rust request path. Python is build-time only.
//!
//! Backend availability: the default build links the vendored `xla` stub
//! (rust/vendor/xla), whose `PjRtClient::cpu()` reports the PJRT backend
//! unavailable — `FunctionalMllm::load` then fails cleanly and every
//! artifact-gated caller skips. Point the `xla` path dependency at the
//! real crate to enable true functional execution (DESIGN.md §2).

pub mod artifact;
pub mod client;
pub mod mllm;

pub use artifact::Manifest;
pub use client::Runtime;
pub use mllm::{FunctionalMllm, Generation};
