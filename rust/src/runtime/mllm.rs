//! Functional MLLM over the AOT artifacts: encode -> connect -> prefill ->
//! greedy decode, entirely from Rust via PJRT. Python never runs here.
//!
//! This is the functional half of the engine: real tokens out of real
//! tensor math (the tiny model), while `sim` provides the paper-scale
//! timing/energy (DESIGN.md §1).

use anyhow::{anyhow, Result};

use super::artifact::Manifest;
use super::client::{lit, Runtime};

/// A loaded, ready-to-serve functional model.
pub struct FunctionalMllm {
    pub manifest: Manifest,
    runtime: Runtime,
}

/// Output of one generation call.
#[derive(Debug, Clone)]
pub struct Generation {
    pub tokens: Vec<i32>,
    /// Wall-clock nanoseconds spent in PJRT execute calls, by phase.
    pub encode_ns: u128,
    pub prefill_ns: u128,
    pub decode_ns: u128,
}

impl FunctionalMllm {
    /// Load the manifest + compile all entry points.
    pub fn load(dir: &std::path::Path) -> Result<FunctionalMllm> {
        let manifest = Manifest::load(dir)?;
        let mut runtime = Runtime::cpu()?;
        runtime.load_manifest(&manifest)?;
        Ok(FunctionalMllm { manifest, runtime })
    }

    /// Greedy-generate `n_steps` tokens for (image, prompt).
    ///
    /// `image` is row-major [H, W, C] f32; `prompt` must have exactly
    /// `prompt_len` token ids.
    pub fn generate(&self, image: &[f32], prompt: &[i32], n_steps: usize) -> Result<Generation> {
        let cfg = &self.manifest.config;
        if prompt.len() != cfg.prompt_len {
            return Err(anyhow!(
                "prompt must have {} tokens, got {}",
                cfg.prompt_len,
                prompt.len()
            ));
        }
        let expect_img = cfg.img_size * cfg.img_size * cfg.img_channels;
        if image.len() != expect_img {
            return Err(anyhow!("image must have {expect_img} floats, got {}", image.len()));
        }

        // --- vision encoder (DRAM chiplet in the mapping) ------------------
        let t0 = std::time::Instant::now();
        let img = lit::f32_tensor(
            image,
            &[cfg.img_size as i64, cfg.img_size as i64, cfg.img_channels as i64],
        )?;
        let feats = self.runtime.get("vision_encoder")?.run(&[img])?;
        // --- connector ------------------------------------------------------
        let pseudo = self
            .runtime
            .get("connector")?
            .run(&[feats.into_iter().next().unwrap()])?;
        let encode_ns = t0.elapsed().as_nanos();

        // --- prefill ---------------------------------------------------------
        let t1 = std::time::Instant::now();
        let ids = lit::i32_vec(prompt);
        let mut outs = self
            .runtime
            .get("prefill")?
            .run(&[pseudo.into_iter().next().unwrap(), ids])?;
        let mut v_cache = outs.pop().unwrap();
        let mut k_cache = outs.pop().unwrap();
        let logits = outs.pop().unwrap();
        let prefill_ns = t1.elapsed().as_nanos();

        // --- greedy decode ----------------------------------------------------
        let t2 = std::time::Instant::now();
        let mut tokens = Vec::with_capacity(n_steps);
        let mut tok = lit::argmax_f32(&logits)? as i32;
        let decode = self.runtime.get("decode_step")?;
        let mut pos = cfg.prefill_len as i32;
        for _ in 0..n_steps {
            tokens.push(tok);
            if pos as usize >= cfg.max_len {
                break; // KV capacity reached
            }
            let mut outs = decode.run(&[
                lit::i32_scalar(tok),
                lit::i32_scalar(pos),
                k_cache,
                v_cache,
            ])?;
            v_cache = outs.pop().unwrap();
            k_cache = outs.pop().unwrap();
            let logits = outs.pop().unwrap();
            tok = lit::argmax_f32(&logits)? as i32;
            pos += 1;
        }
        let decode_ns = t2.elapsed().as_nanos();

        Ok(Generation { tokens, encode_ns, prefill_ns, decode_ns })
    }

    /// Run the single-call smoke graph (model.hlo.txt) and return the
    /// first-token logits argmax.
    pub fn smoke(&self, image: &[f32], prompt: &[i32]) -> Result<i32> {
        let cfg = &self.manifest.config;
        let img = lit::f32_tensor(
            image,
            &[cfg.img_size as i64, cfg.img_size as i64, cfg.img_channels as i64],
        )?;
        let ids = lit::i32_vec(prompt);
        let outs = self.runtime.get("model")?.run(&[img, ids])?;
        Ok(lit::argmax_f32(&outs[0])? as i32)
    }

    /// Verify the manifest's parity oracle: Rust-side greedy decode must
    /// reproduce the exact token sequence Python recorded at AOT time.
    pub fn verify_parity(&self) -> Result<()> {
        let p = &self.manifest.parity;
        let image = self.manifest.synthetic_image();
        let gen = self.generate(&image, &p.prompt, p.n_steps)?;
        if gen.tokens != p.expected_tokens {
            return Err(anyhow!(
                "parity FAILED: rust {:?} vs python {:?}",
                gen.tokens,
                p.expected_tokens
            ));
        }
        Ok(())
    }
}
