//! PJRT client wrapper: loads HLO-text artifacts and executes them on the
//! CPU PJRT backend (the `xla` crate).
//!
//! HLO *text* is the interchange format — jax >= 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and
//! python/compile/aot.py). All entry points are lowered with
//! return_tuple=True, so every execution returns one tuple literal.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use super::artifact::{EntryPoint, Manifest};

/// A compiled entry point ready to execute.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub n_outputs: usize,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} result", self.name))?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.n_outputs {
            return Err(anyhow!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.n_outputs,
                outs.len()
            ));
        }
        Ok(outs)
    }
}

/// PJRT runtime: one CPU client + compiled executables per entry point.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: BTreeMap<String, Executable>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, executables: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_entry(&mut self, ep: &EntryPoint) -> Result<()> {
        let path = ep
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-UTF-8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", ep.name))?;
        self.executables.insert(
            ep.name.clone(),
            Executable { name: ep.name.clone(), exe, n_outputs: ep.outputs.len() },
        );
        Ok(())
    }

    /// Load every entry point in the manifest.
    pub fn load_manifest(&mut self, manifest: &Manifest) -> Result<()> {
        for ep in manifest.entry_points.values() {
            self.load_entry(ep)?;
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("entry point {name:?} not loaded"))
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }
}

/// Literal construction helpers.
pub mod lit {
    use anyhow::Result;

    /// f32 tensor from a flat vec + dims.
    pub fn f32_tensor(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(dims)?)
    }

    /// i32 scalar.
    pub fn i32_scalar(v: i32) -> xla::Literal {
        xla::Literal::scalar(v)
    }

    /// i32 vector.
    pub fn i32_vec(v: &[i32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    /// Argmax over an f32 literal (greedy decoding).
    pub fn argmax_f32(l: &xla::Literal) -> Result<usize> {
        let v = l.to_vec::<f32>()?;
        Ok(v.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0))
    }

    /// Check whether `path` artifacts exist (skip-gate for tests).
    pub fn artifacts_available(dir: &std::path::Path) -> bool {
        dir.join("manifest.json").exists()
    }
}
