//! Artifact manifest: signatures + parity oracle for the AOT-compiled
//! entry points (`artifacts/manifest.json`, produced by
//! `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// Tensor signature of one entry-point input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<TensorSig> {
        Ok(TensorSig {
            name: j.get("name").as_str().unwrap_or("").to_string(),
            dtype: j
                .get("dtype")
                .as_str()
                .ok_or_else(|| anyhow!("tensor sig missing dtype"))?
                .to_string(),
            shape: j
                .get("shape")
                .as_arr()
                .ok_or_else(|| anyhow!("tensor sig missing shape"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
                .collect::<Result<_>>()?,
        })
    }
}

/// One AOT entry point.
#[derive(Debug, Clone)]
pub struct EntryPoint {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Functional-model config mirrored from python (TinyMLLMConfig).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub n_layers: usize,
    pub vocab: usize,
    pub img_size: usize,
    pub img_channels: usize,
    pub n_vis_tokens: usize,
    pub prompt_len: usize,
    pub max_len: usize,
    pub prefill_len: usize,
    pub seed: i64,
}

/// Greedy-decode parity oracle recorded at AOT time.
#[derive(Debug, Clone)]
pub struct ParityOracle {
    pub prompt: Vec<i32>,
    pub n_steps: usize,
    pub expected_tokens: Vec<i32>,
}

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelMeta,
    pub entry_points: BTreeMap<String, EntryPoint>,
    pub parity: ParityOracle,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts` first)", mpath.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        if j.get("format").as_str() != Some("hlo-text-v1") {
            bail!("unsupported manifest format {:?}", j.get("format"));
        }

        let c = j.get("config");
        let u = |k: &str| -> Result<usize> {
            c.get(k).as_usize().ok_or_else(|| anyhow!("config missing {k}"))
        };
        let config = ModelMeta {
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            d_head: u("d_head")?,
            n_layers: u("n_layers")?,
            vocab: u("vocab")?,
            img_size: u("img_size")?,
            img_channels: u("img_channels")?,
            n_vis_tokens: u("n_vis_tokens")?,
            prompt_len: u("prompt_len")?,
            max_len: u("max_len")?,
            prefill_len: u("prefill_len")?,
            seed: c.get("seed").as_i64().unwrap_or(0),
        };

        let mut entry_points = BTreeMap::new();
        let eps = j
            .get("entry_points")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing entry_points"))?;
        for (name, ep) in eps {
            let file = dir.join(
                ep.get("file")
                    .as_str()
                    .ok_or_else(|| anyhow!("entry {name} missing file"))?,
            );
            let sigs = |k: &str| -> Result<Vec<TensorSig>> {
                ep.get(k)
                    .as_arr()
                    .ok_or_else(|| anyhow!("entry {name} missing {k}"))?
                    .iter()
                    .map(TensorSig::from_json)
                    .collect()
            };
            entry_points.insert(
                name.clone(),
                EntryPoint { name: name.clone(), file, inputs: sigs("inputs")?, outputs: sigs("outputs")? },
            );
        }

        let p = j.get("parity");
        let toks = |k: &str| -> Result<Vec<i32>> {
            p.get(k)
                .as_arr()
                .ok_or_else(|| anyhow!("parity missing {k}"))?
                .iter()
                .map(|v| v.as_i64().map(|x| x as i32).ok_or_else(|| anyhow!("bad token")))
                .collect()
        };
        let parity = ParityOracle {
            prompt: toks("prompt")?,
            n_steps: p.get("n_steps").as_usize().unwrap_or(0),
            expected_tokens: toks("expected_tokens")?,
        };

        Ok(Manifest { dir: dir.to_path_buf(), config, entry_points, parity })
    }

    /// Default artifacts directory: $CHIME_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("CHIME_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn entry(&self, name: &str) -> Result<&EntryPoint> {
        self.entry_points
            .get(name)
            .ok_or_else(|| anyhow!("no entry point {name:?} in manifest"))
    }

    /// The deterministic synthetic image (must match python's
    /// `synthetic_image`: v = ((i*W + j)*C + c) % 11 / 11 - 0.5).
    pub fn synthetic_image(&self) -> Vec<f32> {
        let (h, w, c) = (self.config.img_size, self.config.img_size, self.config.img_channels);
        let mut out = Vec::with_capacity(h * w * c);
        for i in 0..h {
            for j in 0..w {
                for ch in 0..c {
                    let idx = ((i * w + j) * c + ch) % 11;
                    out.push(idx as f32 / 11.0 - 0.5);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_image_formula() {
        let meta = ModelMeta {
            d_model: 64, n_heads: 4, d_head: 16, n_layers: 2, vocab: 256,
            img_size: 2, img_channels: 3, n_vis_tokens: 16, prompt_len: 16,
            max_len: 64, prefill_len: 32, seed: 2,
        };
        let m = Manifest {
            dir: PathBuf::new(),
            config: meta,
            entry_points: BTreeMap::new(),
            parity: ParityOracle { prompt: vec![], n_steps: 0, expected_tokens: vec![] },
        };
        let img = m.synthetic_image();
        assert_eq!(img.len(), 2 * 2 * 3);
        // (i*W+j)*C+c for i=j=c=0 -> 0 % 11 = 0 -> -0.5
        assert!((img[0] + 0.5).abs() < 1e-7);
        // i=0,j=1,c=2 -> (1*3+2)=5 -> 5/11-0.5
        assert!((img[5] - (5.0 / 11.0 - 0.5)).abs() < 1e-7);
    }

    #[test]
    fn manifest_loads_if_artifacts_built() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment yet
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.d_model, 64);
        assert!(m.entry_points.contains_key("decode_step"));
        assert_eq!(m.parity.prompt.len(), m.config.prompt_len);
        for ep in m.entry_points.values() {
            assert!(ep.file.exists(), "{} missing", ep.file.display());
        }
    }
}
