//! Multi-package sharded serving on the L3 coordinator.
//!
//! A *package* is one DRAM+RRAM chiplet pair — a two-machine flow shop
//! with its own admission queue, continuous batcher, KV state, and
//! virtual clock. `ShardedServer` owns N package replicas of one plan
//! (shared read-only weights, independent KV budgets — see
//! `Plan::replicate`), routes each admitted request to a package through
//! a pluggable policy, and merges the per-package virtual-time completion
//! streams into one global `ServingMetrics`.
//!
//! The merge is *event-ordered*, not lockstep: the serve loop repeatedly
//! advances whichever event is earliest in global virtual time — the next
//! request arrival, or the package whose next flow-shop tick starts
//! soonest. Packages therefore tick at their own natural rate (a package
//! draining 1-token requests takes many short ticks while a neighbor
//! grinds a long batch), which is exactly what a lockstep
//! tick-all-packages loop gets wrong.
//!
//! This is the chiplet-scaling direction Cambricon-LLM (arXiv:2409.15654)
//! takes for on-device inference, applied to CHIME's heterogeneous pairs.
//!
//! Serving is **event-driven** (DESIGN.md §10): [`ShardedSession`]
//! implements the streaming protocol — `submit` requests at any virtual
//! time, `tick` to advance the earliest event (an arrival decision or one
//! package flow-shop tick) and receive typed [`ServeEvent`]s, `finish`
//! to collect the [`ServeOutcome`]. The batch [`ShardedServer::serve`]
//! is a thin submit-all-then-drain wrapper over the session, so the two
//! entry points share one scheduling core and cannot drift.
//!
//! With work stealing enabled ([`ShardedServer::set_work_stealing`]),
//! every event additionally runs a steal pass at its virtual timestamp:
//! a package that is idle (no resident batch, no runnable queued work)
//! takes the newest queued-and-arrived request from the most-loaded
//! package that has no free batch slot of its own. Stealing only moves
//! *queued* decode work — in-flight batches are never migrated — so the
//! event-ordered completion merge and every conservation invariant are
//! preserved, and the total token count is untouched by construction.

use std::collections::{BTreeMap, BTreeSet};

use crate::config::{ChimeConfig, ChimeHardware, MllmConfig, TopologyKind, WorkloadConfig};
use crate::mapping::planner::DecodeTemplate;
use crate::mapping::Plan;
use crate::obs::{self, MemStalls, Tracer, Track};
use crate::sim::fabric::{Delivery, Endpoint, Fabric, Link, LinkState};
use crate::sim::memory::{DramState, RramState};
use crate::sim::{InferenceStats, PhaseStats, SimEngine};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::ServingMetrics;
use super::queue::AdmissionQueue;
use super::request::{ServeRequest, ServeResponse};
use super::streaming::{PendingQueue, ServeEvent};

/// Fixed per-steal control overhead: request descriptor, scheduling
/// state, and route metadata that cross the fabric beside the payload.
const STEAL_METADATA_BYTES: u64 = 64;

/// How admitted requests are assigned to packages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through packages in order — fair for homogeneous requests.
    RoundRobin,
    /// Send each request to the package with the fewest outstanding
    /// decode tokens (batcher slots + queued work) — balances skewed
    /// token budgets.
    LeastLoaded,
}

impl RoutePolicy {
    /// Parse a CLI spelling (`rr`, `round-robin`, `ll`, `least-loaded`).
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "rr" | "round-robin" | "roundrobin" => Some(RoutePolicy::RoundRobin),
            "ll" | "least-loaded" | "leastloaded" => Some(RoutePolicy::LeastLoaded),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// Everything `serve` produces: completions (global completion order),
/// requests shed at admission (returned, never silently dropped), and the
/// merged metrics. Conservation invariant:
/// `responses.len() + shed.len() == requests.len()`.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub responses: Vec<ServeResponse>,
    /// Requests rejected by admission backpressure, in arrival order.
    /// A request is shed only when *every* package's queue is full at its
    /// arrival (routing fails over before giving up); the caller owns the
    /// retry/shed decision from there.
    pub shed: Vec<ServeRequest>,
    pub metrics: ServingMetrics,
}

/// A request resident in a package's batch.
struct ActiveRequest {
    req: ServeRequest,
    admitted_ns: f64,
    prefill_done_ns: Option<f64>,
    pos: usize,
    produced: usize,
    energy_j: f64,
}

/// One DRAM+RRAM machine pair: private plan replica, simulator state,
/// admission queue, batcher, and virtual clock.
///
/// Crate-visible so the wall-clock executor (`crate::exec`, DESIGN.md
/// §15) can drive packages from its worker threads through the same
/// `admit`/`step` methods the virtual-time loop uses; the fields stay
/// private to this module.
pub(crate) struct PackageState {
    plan: Plan,
    engine: SimEngine,
    /// §Perf: reusable decode schedule, patched per slot position.
    template: DecodeTemplate,
    queue: AdmissionQueue,
    batcher: Batcher,
    active: BTreeMap<usize, ActiveRequest>,
    clock_ns: f64,
    /// Decode tokens promised to queued (not yet batched) requests —
    /// tracked beside the queue so least-loaded routing is O(1).
    queued_tokens: usize,
    completed: u64,
}

impl PackageState {
    fn new(plan: Plan, hw: &ChimeHardware, policy: &BatchPolicy, dram_only: bool) -> PackageState {
        let engine = if dram_only {
            SimEngine::new_dram_only(hw, &plan)
        } else {
            SimEngine::new(hw, &plan)
        };
        let template = if dram_only {
            plan.decode_template_dram_only()
        } else {
            plan.decode_template()
        };
        PackageState {
            plan,
            engine,
            template,
            queue: AdmissionQueue::new(policy.queue_capacity),
            batcher: Batcher::new(policy.clone()),
            active: BTreeMap::new(),
            clock_ns: 0.0,
            queued_tokens: 0,
            completed: 0,
        }
    }

    /// Reset the scheduling state for a fresh serving session (virtual
    /// clock, queues, routing counters). Hardware state (KV occupancy,
    /// endurance wear) deliberately persists across sessions — the chips
    /// do not forget. A session that was dropped mid-stream leaves queued
    /// and batched requests behind; they belong to the abandoned session
    /// and are discarded here.
    fn reset_session(&mut self) {
        while !self.queue.is_empty() {
            let _ = self.queue.try_pop_batch(usize::MAX);
        }
        self.batcher.slots.clear();
        self.active.clear();
        self.clock_ns = 0.0;
        self.queued_tokens = 0;
        self.completed = 0;
    }

    /// Global virtual time at which this package can next make progress:
    /// its clock while a batch is resident, else the arrival of the
    /// earliest queued request (an idle package fast-forwards to it).
    pub(crate) fn next_event_ns(&self) -> f64 {
        if self.batcher.active() > 0 {
            return self.clock_ns;
        }
        match self.queue.peek_arrival_ns() {
            Some(t) => self.clock_ns.max(t),
            None => f64::INFINITY,
        }
    }

    /// Outstanding decode tokens (batched + queued) — the least-loaded
    /// routing signal.
    pub(crate) fn load_tokens(&self) -> usize {
        self.batcher.outstanding_tokens() + self.queued_tokens
    }

    /// Try to admit a request; on backpressure the request is handed back
    /// to the caller (it is shed, not lost).
    pub(crate) fn admit(&mut self, req: ServeRequest) -> Result<(), ServeRequest> {
        let tokens = req.max_new_tokens;
        match self.queue.admit(req) {
            Ok(()) => {
                self.queued_tokens += tokens;
                Ok(())
            }
            Err((_, req)) => Err(req),
        }
    }

    /// Take the newest queued-and-arrived request for a work steal;
    /// `None` when the queue tail has not arrived by `now_ns` (or the
    /// queue is empty).
    fn steal_back(&mut self, now_ns: f64) -> Option<ServeRequest> {
        let req = self.queue.steal_back(now_ns)?;
        self.queued_tokens = self.queued_tokens.saturating_sub(req.max_new_tokens);
        Some(req)
    }

    /// Receive a stolen request at steal time `now_ns`. The clock bumps
    /// to the steal instant so the thief cannot retroactively start the
    /// request before the steal decision was made; the request goes to
    /// the queue head (its arrival predates anything still queued here).
    fn receive_stolen(&mut self, req: ServeRequest, now_ns: f64) {
        self.clock_ns = self.clock_ns.max(now_ns);
        self.queued_tokens += req.max_new_tokens;
        self.queue.readmit_front(req);
    }

    /// Run one flow-shop tick: fill free slots from the package queue,
    /// price every slot's step on this package's hardware state, advance
    /// the virtual clock by the pipelined tick span, and retire finished
    /// requests. Returns the tick's event stream (`FirstToken`/`Token`
    /// per slot, `Completed` per retirement).
    ///
    /// With a tracer attached the tick additionally records one
    /// `package_step` span and the fabric-leg / memory-stall deltas it
    /// caused (DESIGN.md §14) — a read-only side channel: snapshots are
    /// taken before and after the exact same pricing code, so a traced
    /// tick prices identically to an untraced one.
    pub(crate) fn step(&mut self, pkg: usize, tracer: Option<&mut Tracer>) -> Vec<ServeEvent> {
        // An idle package fast-forwards its clock to the earliest arrival.
        if self.batcher.active() == 0 {
            if let Some(t) = self.queue.peek_arrival_ns() {
                self.clock_ns = self.clock_ns.max(t);
            }
        }
        // Fill free slots with requests that have arrived by the clock.
        while self.batcher.has_capacity()
            && self.queue.peek_arrival_ns().is_some_and(|t| t <= self.clock_ns)
        {
            let Some(req) = self.queue.try_pop_batch(1).pop() else { break };
            self.queued_tokens = self.queued_tokens.saturating_sub(req.max_new_tokens);
            let idx = req.id as usize;
            let ticks = req.max_new_tokens + 1; // +1 tick for encode+prefill
            if !self.batcher.join(idx, ticks) {
                // A dropped join stranded requests forever pre-fix; hand
                // the request back to the queue head instead.
                self.queued_tokens += req.max_new_tokens;
                self.queue.readmit_front(req);
                break;
            }
            self.active.insert(
                idx,
                ActiveRequest {
                    admitted_ns: self.clock_ns.max(req.arrival_ns),
                    req,
                    prefill_done_ns: None,
                    pos: 0,
                    produced: 0,
                    energy_j: 0.0,
                },
            );
        }
        if self.batcher.active() == 0 {
            return Vec::new();
        }
        let span_start_ns = self.clock_ns;
        let fabric_before =
            if tracer.is_some() { obs::link_snapshot(&self.engine.fabric) } else { Vec::new() };
        let stalls_before =
            if tracer.is_some() { MemStalls::of(&self.engine) } else { MemStalls::default() };
        let mut step_energy_j = 0.0;

        // Price each slot's step on this package's shared hardware state.
        let slot_ids: Vec<usize> = self.batcher.slots.iter().map(|s| s.request_idx).collect();
        let mut costs = Vec::with_capacity(slot_ids.len());
        for &idx in &slot_ids {
            let a = self.active.get_mut(&idx).unwrap();
            let stats: PhaseStats = if a.prefill_done_ns.is_none() {
                // Encode + prefill as this slot's first "step".
                let mut s = self.engine.run_kernels(&self.plan.encode_kernels);
                s.merge(&self.engine.run_kernels(&self.plan.prefill_kernels));
                s
            } else {
                let pos = self.plan.trace.prefill_len() + a.pos;
                self.plan.patch_decode_template(&mut self.template, pos);
                self.engine.run_kernels(&self.template.kernels)
            };
            a.energy_j += stats.energy.total_joules();
            step_energy_j += stats.energy.total_joules();
            costs.push((stats.dram_busy_ns, stats.rram_busy_ns + stats.ucie_ns));
        }

        // One pipelined tick across this package's batch.
        let (plan_tick, finished) = self.batcher.tick(&costs);
        self.clock_ns += plan_tick.pipelined_ns;

        if let Some(tr) = tracer {
            tr.span(
                pkg,
                Track::Coordinator,
                "package_step",
                span_start_ns,
                self.clock_ns,
                vec![
                    ("slots", (slot_ids.len() as f64).into()),
                    ("energy_j", step_energy_j.into()),
                ],
            );
            // The engine's fabric is package-local (`Local { package: 0 }`):
            // remap its legs onto this package's global index.
            for (link, bytes, transfers) in obs::link_deltas(&self.engine.fabric, &fabric_before) {
                let global = match link {
                    Link::Local { .. } => Link::Local { package: pkg },
                    inter => inter,
                };
                tr.instant(
                    pkg,
                    Track::Fabric,
                    "fabric_leg",
                    self.clock_ns,
                    vec![
                        ("link", obs::link_label(&global).into()),
                        ("bytes", (bytes as f64).into()),
                        ("transfers", (transfers as f64).into()),
                    ],
                );
            }
            let stall_delta = MemStalls::of(&self.engine).minus(&stalls_before);
            obs::trace_stalls(tr, pkg, self.clock_ns, &stall_delta);
        }

        let mut events = Vec::with_capacity(slot_ids.len() + finished.len());
        for &idx in &slot_ids {
            let a = self.active.get_mut(&idx).unwrap();
            if a.prefill_done_ns.is_none() {
                a.prefill_done_ns = Some(self.clock_ns);
                events.push(ServeEvent::FirstToken { id: a.req.id, time_ns: self.clock_ns });
            } else {
                a.pos += 1;
                a.produced += 1;
                events.push(ServeEvent::Token {
                    id: a.req.id,
                    index: a.produced - 1,
                    time_ns: self.clock_ns,
                });
            }
        }
        for idx in finished {
            let a = self.active.remove(&idx).unwrap();
            let arrival_ns = a.req.arrival_ns;
            let resp = ServeResponse {
                id: a.req.id,
                tokens: vec![0; a.produced],
                queue_ns: a.admitted_ns - arrival_ns,
                ttft_ns: a.prefill_done_ns.unwrap_or(self.clock_ns) - a.admitted_ns,
                service_ns: self.clock_ns - a.admitted_ns,
                energy_j: a.energy_j,
            };
            self.completed += 1;
            events.push(ServeEvent::Completed {
                arrival_ns,
                time_ns: arrival_ns + resp.total_latency_ns(),
                response: resp,
            });
        }
        events
    }
}

/// Totally ordered f64 key for the event index (`total_cmp`; package
/// event times are never NaN — arrivals are finite by the submission
/// guard and virtual clocks only advance by finite spans).
#[derive(Clone, Copy, PartialEq)]
struct EventKey(f64);

impl Eq for EventKey {}

impl PartialOrd for EventKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for EventKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// §Perf: indexed earliest-event selection over the packages. The tick
/// loop used to linear-scan every package's `next_event_ns` on every
/// event (O(P) per tick); the index keeps `(time, package)` keys in a
/// `BTreeSet` so selection is O(log P), with the same tie-break as the
/// legacy scan — lowest package index among equal minima (locked by
/// `indexed_event_selection_matches_the_legacy_linear_scan`). The
/// session refreshes a package's key after every mutation that can move
/// its next event: an arrival admit, a flow-shop step, or a steal.
struct EventIndex {
    ordered: BTreeSet<(EventKey, usize)>,
    key: Vec<f64>,
}

impl EventIndex {
    fn new(packages: &[PackageState]) -> EventIndex {
        let mut index =
            EventIndex { ordered: BTreeSet::new(), key: Vec::with_capacity(packages.len()) };
        for (i, p) in packages.iter().enumerate() {
            let t = p.next_event_ns();
            index.key.push(t);
            index.ordered.insert((EventKey(t), i));
        }
        index
    }

    /// Re-read package `i`'s next event time and reposition its key.
    fn refresh(&mut self, i: usize, packages: &[PackageState]) {
        let t = packages[i].next_event_ns();
        if t.total_cmp(&self.key[i]).is_eq() {
            return;
        }
        self.ordered.remove(&(EventKey(self.key[i]), i));
        self.key[i] = t;
        self.ordered.insert((EventKey(t), i));
    }

    /// The earliest package event: `(time, package)`. Time is INFINITY
    /// when every package is idle with nothing queued.
    fn earliest(&self) -> (f64, usize) {
        match self.ordered.iter().next() {
            Some(&(EventKey(t), i)) => (t, i),
            None => (f64::INFINITY, 0),
        }
    }
}

/// N package replicas behind one admission/routing front door, serving a
/// request stream in virtual time.
pub struct ShardedServer {
    pub policy: BatchPolicy,
    pub route: RoutePolicy,
    packages: Vec<PackageState>,
    rr_next: usize,
    /// Cross-package work stealing (off by default; `set_work_stealing`).
    steal: bool,
    /// The inter-package UCIe fabric steals route over (DESIGN.md §12):
    /// spans every package on the configured topology. `point-to-point`
    /// is the legacy 0-cost baseline; line/ring/mesh charge each steal a
    /// routed DRAM-to-DRAM delivery in latency and link energy.
    steal_fabric: Fabric,
    /// Parallel per-package drain for the batch path (off by default;
    /// `set_parallel`). Bit-identical to sequential by construction.
    parallel: bool,
    /// Executor worker threads for serving drains (`set_threads`,
    /// DESIGN.md §15). 1 (the default) keeps the classic single-thread
    /// event loop; >1 routes `ShardedSession::finish` through the
    /// windowed thread-per-package executor drain — still bit-identical
    /// to sequential by construction.
    threads: usize,
    /// Resolved model/config kept for the `api::Backend` one-shot
    /// inference surface (`run_inference_with`).
    model: MllmConfig,
    cfg: ChimeConfig,
    /// Packages run the single-chiplet DRAM-only plan (Fig 9 ablation).
    dram_only: bool,
    /// Engine state of the most recent `run_inference_with` call, kept so
    /// callers can inspect KV residency / endurance after an inference.
    last_infer: Option<SimEngine>,
    /// Span/event recorder (DESIGN.md §14). `None` (the default) is the
    /// zero-overhead path: every instrumented site is gated on this
    /// option and never snapshots, allocates, or reads a clock. Enabling
    /// it never changes a simulated number — the recorder is a read-only
    /// side channel (locked by `tracing_is_a_bitwise_noop_on_outcomes`).
    tracer: Option<Tracer>,
}

impl ShardedServer {
    /// Build a sharded deployment: one plan, replicated per package
    /// (shared weights, independent KV budgets), each with a private
    /// simulator, queue, and batcher.
    pub fn new(
        model: &MllmConfig,
        cfg: &ChimeConfig,
        policy: BatchPolicy,
        packages: usize,
        route: RoutePolicy,
    ) -> ShardedServer {
        Self::with_mode(model, cfg, policy, packages, route, false)
    }

    /// Build a DRAM-only deployment: every package runs the single-chiplet
    /// ablation plan (`Plan::build_dram_only` + `SimEngine::new_dram_only`),
    /// making Fig 9's baseline servable through the same coordinator.
    pub fn new_dram_only(
        model: &MllmConfig,
        cfg: &ChimeConfig,
        policy: BatchPolicy,
        packages: usize,
        route: RoutePolicy,
    ) -> ShardedServer {
        Self::with_mode(model, cfg, policy, packages, route, true)
    }

    fn with_mode(
        model: &MllmConfig,
        cfg: &ChimeConfig,
        policy: BatchPolicy,
        packages: usize,
        route: RoutePolicy,
        dram_only: bool,
    ) -> ShardedServer {
        assert!(packages >= 1, "a sharded deployment needs at least one package");
        assert!(policy.max_batch >= 1, "max_batch 0 can never serve a request");
        assert!(
            policy.queue_capacity >= 1,
            "queue_capacity 0 can never admit a request"
        );
        let base = if dram_only {
            Plan::build_dram_only(model, &cfg.hardware, &cfg.workload)
        } else {
            Plan::build(model, &cfg.hardware, &cfg.workload)
        };
        let states: Vec<PackageState> = base
            .replicate(packages)
            .into_iter()
            .map(|plan| PackageState::new(plan, &cfg.hardware, &policy, dram_only))
            .collect();
        // Built from the *engine's* link config so the DRAM-only ablation
        // transform (infinite bandwidth = no link) carries over: its
        // routed transfers are free, matching the in-package semantics.
        let steal_fabric = Fabric::new(
            states[0].engine.hw.ucie.clone(),
            cfg.hardware.topology.kind,
            packages,
            0,
        );
        ShardedServer {
            policy,
            route,
            packages: states,
            rr_next: 0,
            steal: false,
            steal_fabric,
            parallel: false,
            threads: 1,
            model: model.clone(),
            cfg: cfg.clone(),
            dram_only,
            last_infer: None,
            tracer: None,
        }
    }

    pub fn package_count(&self) -> usize {
        self.packages.len()
    }

    /// Enable/disable cross-package work stealing for subsequent serving
    /// sessions: an idle package takes queued decode work from the most
    /// loaded one (module docs; a no-op on single-package deployments).
    pub fn set_work_stealing(&mut self, on: bool) {
        self.steal = on;
    }

    /// Whether work stealing is enabled.
    pub fn work_stealing(&self) -> bool {
        self.steal
    }

    /// Enable/disable parallel per-package simulation for batch serving
    /// (`serve` / `ShardedSession::finish`): once no arrivals are pending
    /// and stealing is off, the packages are independent simulators, so
    /// each drains on its own scoped thread and the completion streams
    /// are merged back in exact sequential event-loop order — the outcome
    /// is **bit-identical** to the sequential path (DESIGN.md §11; locked
    /// by `prop_parallel_drain_is_bit_identical_to_sequential`). With
    /// stealing enabled (cross-package coupling at every event) the
    /// sequential path is used regardless of this flag.
    pub fn set_parallel(&mut self, on: bool) {
        self.parallel = on;
    }

    /// Whether parallel per-package draining is enabled.
    pub fn parallel_enabled(&self) -> bool {
        self.parallel
    }

    /// Set the executor worker-thread count for serving drains
    /// (`--threads N`, `Session::builder().threads(n)`; DESIGN.md §15).
    /// With `n > 1` and stealing off, `ShardedSession::finish` drains
    /// every arrival-free window of the event loop on up to `n` scoped
    /// worker threads (one package chunk each) and merges the completion
    /// streams back in exact sequential event-loop order, so the outcome
    /// stays bit-identical to the single-thread path (locked by
    /// `exec_drain_is_bit_identical_to_sequential` and
    /// `prop_exec_drain_is_bit_identical_to_sequential`). With stealing
    /// on — cross-package coupling at every event — the sequential loop
    /// runs regardless, exactly like `set_parallel`. Panics on 0: a
    /// zero-worker executor can never drain (the CLI and the session
    /// builder reject it with a usage error first).
    pub fn set_threads(&mut self, n: usize) {
        assert!(n >= 1, "the executor needs at least one worker thread");
        self.threads = n;
    }

    /// The configured executor worker-thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enable/disable span tracing for subsequent runs (`--trace-out`).
    /// Off by default; while on, serving sessions fall back from the
    /// parallel to the (bit-identical) sequential drain so the record
    /// stream is deterministic.
    pub fn set_tracing(&mut self, on: bool) {
        self.tracer = if on { Some(Tracer::new()) } else { None };
    }

    /// Enable tracing with wall-clock self-profiling on top
    /// (`chime bench --profile`): per-span-class wall time aggregates
    /// beside the virtual-time records. Wall times never enter the trace
    /// export, so traces stay deterministic.
    pub fn set_profiling(&mut self, on: bool) {
        self.tracer = if on { Some(Tracer::with_profiling()) } else { None };
    }

    /// Whether a tracer is attached.
    pub fn tracing_enabled(&self) -> bool {
        self.tracer.is_some()
    }

    /// The tracer, for mid-run inspection (profile aggregates).
    pub fn trace(&self) -> Option<&Tracer> {
        self.tracer.as_ref()
    }

    /// Detach and return the recorded trace (tracing turns off). `None`
    /// when tracing was never enabled.
    pub fn take_trace(&mut self) -> Option<Tracer> {
        self.tracer.take()
    }

    /// The model this deployment serves.
    pub fn model(&self) -> &MllmConfig {
        &self.model
    }

    /// The configuration this deployment was built with.
    pub fn config(&self) -> &ChimeConfig {
        &self.cfg
    }

    /// Whether the packages run the DRAM-only ablation plan.
    pub fn is_dram_only(&self) -> bool {
        self.dram_only
    }

    /// One-shot inference on a fresh engine under workload `w`, in this
    /// deployment's mode (heterogeneous or DRAM-only). The engine is
    /// retained for memory introspection via `last_infer_memory`; the
    /// serving packages' state is untouched.
    pub fn run_inference_with(&mut self, w: &WorkloadConfig) -> InferenceStats {
        let (plan, mut engine) = if self.dram_only {
            let plan = Plan::build_dram_only(&self.model, &self.cfg.hardware, w);
            let engine = SimEngine::new_dram_only(&self.cfg.hardware, &plan);
            (plan, engine)
        } else {
            let plan = Plan::build(&self.model, &self.cfg.hardware, w);
            let engine = SimEngine::new(&self.cfg.hardware, &plan);
            (plan, engine)
        };
        let tracing = self.tracer.is_some();
        let fabric_before =
            if tracing { obs::link_snapshot(&engine.fabric) } else { Vec::new() };
        let stalls_before = if tracing { MemStalls::of(&engine) } else { MemStalls::default() };
        let stats = engine.run_inference(&plan);
        if let Some(tr) = self.tracer.as_mut() {
            // Phase spans laid end to end on package 0's coordinator
            // track: encode, prefill, then the whole decode loop.
            let mut cursor = 0.0;
            for (name, phase) in
                [("encode", &stats.encode), ("prefill", &stats.prefill), ("decode", &stats.decode)]
            {
                tr.span(
                    0,
                    Track::Coordinator,
                    name,
                    cursor,
                    cursor + phase.time_ns,
                    vec![
                        ("kernels", (phase.kernels as f64).into()),
                        ("energy_j", phase.energy.total_joules().into()),
                        ("dram_busy_ns", phase.dram_busy_ns.into()),
                        ("rram_busy_ns", phase.rram_busy_ns.into()),
                        ("ucie_ns", phase.ucie_ns.into()),
                    ],
                );
                cursor += phase.time_ns;
            }
            for (link, bytes, transfers) in obs::link_deltas(&engine.fabric, &fabric_before) {
                tr.instant(
                    0,
                    Track::Fabric,
                    "fabric_leg",
                    cursor,
                    vec![
                        ("link", obs::link_label(&link).into()),
                        ("bytes", (bytes as f64).into()),
                        ("transfers", (transfers as f64).into()),
                    ],
                );
            }
            let stall_delta = MemStalls::of(&engine).minus(&stalls_before);
            obs::trace_stalls(tr, 0, cursor, &stall_delta);
        }
        self.last_infer = Some(engine);
        stats
    }

    /// Memory state (DRAM, RRAM) of the most recent `run_inference_with`.
    /// Always the first-order occupancy/ledger view — both fidelities
    /// share it bit for bit (`sim::memory::cycle` module docs).
    pub fn last_infer_memory(&self) -> Option<(&DramState, &RramState)> {
        self.last_infer.as_ref().map(|e| (e.dram.state(), e.rram.state()))
    }

    /// Completions per package so far (routing/balance diagnostics).
    pub fn package_completed(&self) -> Vec<u64> {
        self.packages.iter().map(|p| p.completed).collect()
    }

    /// The fabric topology this deployment routes steals over.
    pub fn topology(&self) -> TopologyKind {
        self.steal_fabric.kind()
    }

    /// The inter-package steal fabric (per-link telemetry, route
    /// inspection).
    pub fn steal_fabric(&self) -> &Fabric {
        &self.steal_fabric
    }

    /// Merged per-link fabric telemetry across the whole deployment:
    /// each package engine's in-package DRAM↔RRAM link (remapped from
    /// the engine's private `Local { package: 0 }` onto the global
    /// package index) folded together with the inter-package links of
    /// the steal fabric. Engines only ever touch local links and the
    /// steal fabric only ever routes DRAM-to-DRAM (no local legs), so
    /// the two sources never double-count a link.
    pub fn fabric_links(&self) -> BTreeMap<Link, LinkState> {
        let mut merged: BTreeMap<Link, LinkState> = BTreeMap::new();
        for (p, pkg) in self.packages.iter().enumerate() {
            for (link, state) in pkg.engine.fabric.link_states() {
                let global = match *link {
                    Link::Local { .. } => Link::Local { package: p },
                    inter => inter,
                };
                merged.entry(global).or_default().merge(state);
            }
        }
        for (link, state) in self.steal_fabric.link_states() {
            merged.entry(*link).or_default().merge(state);
        }
        merged
    }

    /// Live engine telemetry for export (DESIGN.md §14): the merged
    /// per-link fabric counters flattened onto canonical labels, plus the
    /// memory stall-cause totals summed over the package engines (all
    /// zero at first-order fidelity). Read-only — safe to call mid-run.
    pub fn telemetry(&self) -> obs::EngineTelemetry {
        let links = self
            .fabric_links()
            .iter()
            .map(|(link, s)| obs::LinkTelemetry {
                link: obs::link_label(link),
                bytes: s.bytes,
                transfers: s.transfers,
                busy_ns: s.busy_ns,
                peak_gbps: s.peak_gbps(),
            })
            .collect();
        let mut stalls = MemStalls::default();
        for p in &self.packages {
            stalls.accumulate(&MemStalls::of(&p.engine));
        }
        obs::EngineTelemetry { links, stalls }
    }

    /// Bytes one steal moves across the fabric: fixed control metadata,
    /// the prompt token ids, and the per-token KV context the thief must
    /// materialize for them. Timing-path requests carry an empty prompt
    /// (the plan prices prompts from the workload), so the plan's
    /// prefill length stands in for the prompt there.
    fn steal_payload(&self, req: &ServeRequest) -> u64 {
        let prompt_tokens =
            req.prompt.len().max(self.packages[0].plan.trace.prefill_len()) as u64;
        STEAL_METADATA_BYTES
            + 4 * prompt_tokens
            + self.model.llm.kv_bytes_per_token() * prompt_tokens
    }

    /// Per-package KV headroom (independent budgets — see
    /// `Plan::kv_budget_bytes`).
    pub fn kv_budget_bytes_per_package(&self) -> u64 {
        let p = &self.packages[0];
        p.plan.kv_budget_bytes(&p.engine.hw)
    }

    fn route_for(&mut self) -> usize {
        match self.route {
            RoutePolicy::RoundRobin => {
                let t = self.rr_next % self.packages.len();
                self.rr_next = self.rr_next.wrapping_add(1);
                t
            }
            RoutePolicy::LeastLoaded => {
                let mut best = 0usize;
                let mut best_load = usize::MAX;
                for (i, p) in self.packages.iter().enumerate() {
                    let load = p.load_tokens();
                    if load < best_load {
                        best_load = load;
                        best = i;
                    }
                }
                best
            }
        }
    }

    /// Open an event-driven streaming serving session (DESIGN.md §10):
    /// `submit` requests at any virtual time, `tick` to advance and
    /// receive typed [`ServeEvent`]s, `finish` for the [`ServeOutcome`].
    ///
    /// Each session is independent: virtual clocks and per-package
    /// counters restart at zero (so a server can be reused across
    /// experiments), while simulator hardware state — KV occupancy,
    /// endurance wear — persists, as it did on the pre-sharding engine.
    pub fn open_serving(&mut self) -> ShardedSession<'_> {
        for p in &mut self.packages {
            p.reset_session();
        }
        self.steal_fabric.reset();
        self.rr_next = 0;
        // A fresh session records a fresh trace (wall-clock profile
        // aggregates carry across sessions — `chime bench --profile`
        // measures many serve calls into one baseline).
        if let Some(t) = &self.tracer {
            self.tracer = Some(t.fresh());
        }
        let index = EventIndex::new(&self.packages);
        ShardedSession {
            srv: self,
            index,
            pending: PendingQueue::new(),
            seq: 0,
            seen: BTreeSet::new(),
            done: Vec::new(),
            shed: Vec::new(),
            metrics: ServingMetrics::new(),
        }
    }

    /// Crate-internal entry for the wall-clock executor
    /// (`exec::serve_wall_clock`, DESIGN.md §15): reset the scheduling
    /// state exactly like `open_serving` and hand the package array to
    /// the worker threads. Hardware state (KV occupancy, endurance wear)
    /// persists across sessions, as everywhere else.
    pub(crate) fn begin_wall_session(&mut self) -> &mut [PackageState] {
        for p in &mut self.packages {
            p.reset_session();
        }
        self.steal_fabric.reset();
        self.rr_next = 0;
        &mut self.packages
    }

    /// Serve a request stream in virtual time. Returns completions in
    /// global completion order, shed requests, and merged metrics.
    /// Request ids must be unique within one call (they key batch slots);
    /// a duplicate id panics rather than corrupting accounting.
    ///
    /// This is the batch entry point: a thin submit-everything-then-drain
    /// wrapper over [`ShardedServer::open_serving`], so closed-loop and
    /// streaming callers exercise the same scheduling core.
    pub fn serve(&mut self, requests: Vec<ServeRequest>) -> ServeOutcome {
        let mut session = self.open_serving();
        for r in requests {
            session.submit(r);
        }
        session.finish()
    }
}

/// One `Track::Serving` instant per protocol event — the trace-side
/// mirror of the event stream (`prop_trace_spans_are_well_nested_and_conserving`
/// counts them one to one). `Shed` events carry a non-finite arrival and
/// no timestamp; their instants land at `fallback_ns`.
fn trace_serve_events(tracer: &mut Tracer, events: &[ServeEvent], fallback_ns: f64) {
    for ev in events {
        let ts = ev.time_ns().filter(|t| t.is_finite()).unwrap_or(fallback_ns);
        tracer.instant(0, Track::Serving, ev.kind(), ts, vec![("id", (ev.id() as f64).into())]);
    }
}

/// One event-driven serving session over a [`ShardedServer`] — the
/// engine side of the streaming protocol (`coordinator::streaming`).
///
/// The event loop repeatedly advances whichever event is earliest in
/// global virtual time: the next pending arrival, or the package whose
/// next flow-shop tick starts soonest. With work stealing enabled, every
/// advance is followed by a steal pass at that event's timestamp.
pub struct ShardedSession<'a> {
    srv: &'a mut ShardedServer,
    /// Indexed earliest-event selection over the packages (O(log P) per
    /// tick instead of the legacy O(P) linear scan).
    index: EventIndex,
    pending: PendingQueue,
    /// Submission counter: the arrival-order tiebreak (matches the
    /// stable sort of the pre-streaming batch path).
    seq: u64,
    seen: BTreeSet<u64>,
    done: Vec<(f64, ServeResponse)>,
    shed: Vec<ServeRequest>,
    metrics: ServingMetrics,
}

impl ShardedSession<'_> {
    /// Submit a request at any virtual time. A non-finite arrival can
    /// never be reached by the virtual clock (NaN would wedge the event
    /// loop), so it is shed immediately with a [`ServeEvent::Shed`].
    /// Panics on a duplicate request id — ids key batch slots, and a
    /// collision would corrupt accounting mid-flight.
    pub fn submit(&mut self, req: ServeRequest) -> Vec<ServeEvent> {
        let wall = self.srv.tracer.as_ref().and_then(|t| t.wall_start());
        let req = match super::streaming::guard_submission(
            &mut self.seen,
            &mut self.metrics,
            &mut self.shed,
            req,
        ) {
            Ok(req) => req,
            Err(events) => {
                if let Some(tr) = self.srv.tracer.as_mut() {
                    trace_serve_events(tr, &events, 0.0);
                    tr.wall_end("submit", wall);
                }
                return events;
            }
        };
        self.pending.push(req, self.seq);
        self.seq += 1;
        if let Some(tr) = self.srv.tracer.as_mut() {
            tr.wall_end("submit", wall);
        }
        Vec::new()
    }

    /// Advance the engine by one event — the earliest of the next pending
    /// arrival and the earliest package tick — and return the events it
    /// produced. An empty vector means the session is idle (drained).
    pub fn tick(&mut self) -> Vec<ServeEvent> {
        let wall = self.srv.tracer.as_ref().and_then(|t| t.wall_start());
        // The two candidate events: the next arrival, and the package
        // whose next tick starts earliest in virtual time (indexed; same
        // lowest-index tie-break as the legacy linear scan).
        let t_arr = self.pending.peek_arrival_ns().unwrap_or(f64::INFINITY);
        let (t_pkg, who) = self.index.earliest();
        if t_arr.is_infinite() && t_pkg.is_infinite() {
            return Vec::new(); // drained
        }

        let now_ns;
        let mut events;
        if t_arr <= t_pkg {
            // Arrival first (ties included: a request arriving exactly at
            // a tick boundary may join that tick).
            let req = self.pending.pop().expect("finite t_arr implies a pending request");
            now_ns = req.arrival_ns;
            events = self.process_arrival(req);
        } else {
            now_ns = t_pkg;
            // Disjoint field borrows: the stepping package and the tracer.
            let ShardedServer { packages, tracer, .. } = &mut *self.srv;
            events = packages[who].step(who, tracer.as_mut());
            self.index.refresh(who, &self.srv.packages);
            for ev in &events {
                if let ServeEvent::Completed { arrival_ns, response, .. } = ev {
                    self.metrics.record(*arrival_ns, response);
                    self.done.push((*arrival_ns, response.clone()));
                }
            }
        }
        if self.srv.steal {
            events.extend(self.steal_pass(now_ns));
        }
        if let Some(tr) = self.srv.tracer.as_mut() {
            trace_serve_events(tr, &events, now_ns);
            tr.wall_end("tick", wall);
        }
        events
    }

    /// Tick until idle, returning every event produced.
    pub fn drain(&mut self) -> Vec<ServeEvent> {
        let mut all = Vec::new();
        loop {
            let events = self.tick();
            if events.is_empty() {
                return all;
            }
            all.extend(events);
        }
    }

    /// Drain whatever is still pending and return the accumulated
    /// outcome: completions event-ordered by completion timestamp
    /// (arrival + queue + service; ties break by request id), shed
    /// requests in shed order, and merged metrics.
    ///
    /// With [`ShardedServer::set_parallel`] on (and stealing off), the
    /// remaining per-package work drains on scoped threads and the
    /// completion streams are merged back in sequential event-loop order
    /// — bit-identical to the sequential drain.
    pub fn finish(mut self) -> ServeOutcome {
        // The executor drain (threads > 1) subsumes the older tail-only
        // parallel drain: it parallelizes every arrival-free window, not
        // just the final one, and it threads per-worker tracers through
        // the steps, so it runs under tracing too. Stealing couples the
        // packages at every event — both parallel paths stand down and
        // the sequential loop runs (bit-identity is then trivial).
        if self.srv.threads > 1 && !self.srv.steal {
            self.drain_exec();
        } else if self.srv.parallel
            && !self.srv.steal
            && self.srv.tracer.is_none()
            && self.srv.packages.len() > 1
        {
            // Tracing forces the sequential drain here: the two are
            // bit-identical on outcomes, but only the sequential loop
            // threads the one shared tracer through every step in
            // deterministic order.
            self.drain_parallel();
        }
        self.drain();
        self.take_outcome()
    }

    /// Drain every package to idle in parallel — one scoped thread per
    /// package — then replay the completion stream in the exact order
    /// the sequential event loop would have produced it.
    ///
    /// Safe only once no arrivals are pending and stealing is off: from
    /// that point the packages are fully independent simulators, and the
    /// sequential loop reduces to a deterministic merge of their tick
    /// streams ordered by `(tick start, package index)` — each package's
    /// tick times are non-decreasing, so sorting the union of the streams
    /// by that key reproduces the loop's first-strict-minimum selection.
    /// `metrics.record` is replayed in that merge order because the float
    /// accumulations it drives (energy sum, Welford service summary) are
    /// order-dependent; replaying out of order would still be correct
    /// arithmetic but not bit-identical.
    fn drain_parallel(&mut self) {
        // Arrivals interleave with package ticks through routing and
        // shared admission state: run them on the sequential path first.
        while self.pending.peek_arrival_ns().is_some() {
            self.tick();
        }
        let mut streams: Vec<Vec<(f64, f64, ServeResponse)>> =
            Vec::with_capacity(self.srv.packages.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .srv
                .packages
                .iter_mut()
                .enumerate()
                .map(|(pkg, p)| {
                    scope.spawn(move || {
                        let mut comps = Vec::new();
                        loop {
                            let tick_ns = p.next_event_ns();
                            if !tick_ns.is_finite() {
                                return comps;
                            }
                            for ev in p.step(pkg, None) {
                                if let ServeEvent::Completed { arrival_ns, response, .. } = ev {
                                    comps.push((tick_ns, arrival_ns, response));
                                }
                            }
                        }
                    })
                })
                .collect();
            for h in handles {
                streams.push(h.join().expect("package drain thread panicked"));
            }
        });
        let mut merged: Vec<(f64, usize, usize, f64, ServeResponse)> = Vec::new();
        for (pkg, stream) in streams.into_iter().enumerate() {
            for (seq, (tick_ns, arrival_ns, resp)) in stream.into_iter().enumerate() {
                merged.push((tick_ns, pkg, seq, arrival_ns, resp));
            }
        }
        merged.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
        for (_, _, _, arrival_ns, resp) in merged {
            self.metrics.record(arrival_ns, &resp);
            self.done.push((arrival_ns, resp));
        }
        for i in 0..self.srv.packages.len() {
            self.index.refresh(i, &self.srv.packages);
        }
    }

    /// Executor drain (DESIGN.md §15): partition virtual time at the
    /// pending arrival timestamps and run each arrival-free *window* on
    /// up to `ShardedServer::threads` scoped worker threads, one package
    /// chunk per worker. Within a window the packages are independent
    /// simulators (stealing is off, and arrivals — the only cross-package
    /// coupling, via routing and shared admission — sit exactly at the
    /// window boundaries), so each package steps privately while its next
    /// event starts *strictly before* the next arrival; the strict bound
    /// mirrors `tick`'s arrival-first tie-break (`t_arr <= t_pkg`). The
    /// collected tick streams merge by `(tick start, package, seq)` —
    /// per-package tick times are non-decreasing, so the sort reproduces
    /// the sequential loop's first-strict-minimum selection — and
    /// `metrics.record` replays in that merge order (its float
    /// accumulations are order-dependent; out-of-order replay would be
    /// correct arithmetic but not bit-identical). The boundary arrival
    /// itself is then processed by one ordinary sequential `tick`.
    ///
    /// Under tracing each worker records into a fresh per-worker
    /// [`Tracer`]; the worker tracks merge deterministically into the
    /// session tracer (`Tracer::merge_workers`) and the serving instants
    /// replay in merge order, so a fixed request stream yields the same
    /// trace for every worker count — though not the byte-same record
    /// order as the sequential loop, which interleaves tick spans and
    /// serving instants differently. Outcomes are bit-identical either
    /// way (tracing is a bitwise no-op on every simulated number).
    fn drain_exec(&mut self) {
        let workers = self.srv.threads.min(self.srv.packages.len()).max(1);
        loop {
            let t_arr = self.pending.peek_arrival_ns().unwrap_or(f64::INFINITY);
            let tracing = self.srv.tracer.is_some();
            let n = self.srv.packages.len();
            let chunk = n.div_ceil(workers);
            // (tick start, package, per-package seq, tick events).
            let mut ticks: Vec<(f64, usize, usize, Vec<ServeEvent>)> = Vec::new();
            let mut worker_traces: Vec<Tracer> = Vec::new();
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .srv
                    .packages
                    .chunks_mut(chunk)
                    .enumerate()
                    .map(|(w, slab)| {
                        scope.spawn(move || {
                            let mut tr = tracing.then(Tracer::new);
                            let mut out = Vec::new();
                            for (off, p) in slab.iter_mut().enumerate() {
                                let pkg = w * chunk + off;
                                let mut seq = 0usize;
                                loop {
                                    // Times are never NaN (module docs),
                                    // so `>=` is the exact negation of
                                    // the strict window bound.
                                    let tick_ns = p.next_event_ns();
                                    if tick_ns >= t_arr {
                                        break;
                                    }
                                    let events = p.step(pkg, tr.as_mut());
                                    if events.is_empty() {
                                        // No progress (mirrors the
                                        // sequential drain's stop).
                                        break;
                                    }
                                    out.push((tick_ns, pkg, seq, events));
                                    seq += 1;
                                }
                            }
                            (out, tr)
                        })
                    })
                    .collect();
                for h in handles {
                    let (out, tr) = h.join().expect("exec worker thread panicked");
                    ticks.extend(out);
                    if let Some(tr) = tr {
                        worker_traces.push(tr);
                    }
                }
            });
            ticks.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
            if let Some(tr) = self.srv.tracer.as_mut() {
                tr.merge_workers(worker_traces);
            }
            for (tick_ns, _pkg, _seq, events) in &ticks {
                for ev in events {
                    if let ServeEvent::Completed { arrival_ns, response, .. } = ev {
                        self.metrics.record(*arrival_ns, response);
                        self.done.push((*arrival_ns, response.clone()));
                    }
                }
                if let Some(tr) = self.srv.tracer.as_mut() {
                    trace_serve_events(tr, events, *tick_ns);
                }
            }
            for i in 0..self.srv.packages.len() {
                self.index.refresh(i, &self.srv.packages);
            }
            if self.pending.peek_arrival_ns().is_none() {
                return;
            }
            // Every package now sits at or past `t_arr`: the sequential
            // tick processes exactly the boundary arrival (admission,
            // routing, inline zero-token completion) in loop order.
            self.tick();
        }
    }

    /// Per-event admission decision, replicating the batch path exactly:
    /// zero-token requests complete inline; everything else routes via
    /// the policy with index-order failover, and is rejected only when
    /// the whole deployment is out of queue capacity.
    fn process_arrival(&mut self, req: ServeRequest) -> Vec<ServeEvent> {
        let (id, arrival_ns) = (req.id, req.arrival_ns);
        if req.max_new_tokens == 0 {
            // Zero-token requests have no decode work to schedule:
            // complete immediately (pre-fix, `.max(1)` silently inflated
            // them to one generated token).
            self.metrics.record_admitted();
            let resp = ServeResponse {
                id,
                tokens: Vec::new(),
                queue_ns: 0.0,
                ttft_ns: 0.0,
                service_ns: 0.0,
                energy_j: 0.0,
            };
            self.metrics.record(arrival_ns, &resp);
            self.done.push((arrival_ns, resp.clone()));
            return vec![
                ServeEvent::Admitted { id, time_ns: arrival_ns, package: None },
                ServeEvent::Completed { arrival_ns, time_ns: arrival_ns, response: resp },
            ];
        }
        // Route to the policy's package; if its queue is full, fail over
        // to the next package with room (in index order) — a request is
        // rejected only when the *whole* deployment is out of capacity.
        let target = self.srv.route_for();
        let n = self.srv.packages.len();
        let mut req = Some(req);
        for off in 0..n {
            let pkg = (target + off) % n;
            match self.srv.packages[pkg].admit(req.take().unwrap()) {
                Ok(()) => {
                    self.metrics.record_admitted();
                    self.index.refresh(pkg, &self.srv.packages);
                    return vec![ServeEvent::Admitted {
                        id,
                        time_ns: arrival_ns,
                        package: Some(pkg),
                    }];
                }
                Err(r) => req = Some(r),
            }
        }
        let r = req.expect("failover loop hands the request back on rejection");
        self.metrics.record_rejected();
        let ev = ServeEvent::Rejected { request: r.clone(), time_ns: arrival_ns };
        self.shed.push(r);
        vec![ev]
    }

    /// Work-stealing pass at virtual time `now_ns`: while some package is
    /// idle (no resident batch, no queued work runnable by `now_ns`) and
    /// another — the most loaded, with no free batch slot of its own —
    /// has a queued-and-arrived request, move that victim's newest queued
    /// request to the idle package. Terminates in at most one steal per
    /// package per pass: a thief is masked out once it receives work. On
    /// point-to-point the mask is redundant (the 0-cost steal lands at
    /// `now_ns`, so the idle predicate retires the thief by itself); on
    /// routed topologies the payload lands in the future and the mask is
    /// what stops one idle package from draining every victim queue at a
    /// single instant.
    fn steal_pass(&mut self, now_ns: f64) -> Vec<ServeEvent> {
        let wall = self.srv.tracer.as_ref().and_then(|t| t.wall_start());
        let mut events = Vec::new();
        let mut stole = vec![false; self.srv.packages.len()];
        loop {
            let pkgs = &self.srv.packages;
            let thief = pkgs.iter().enumerate().find_map(|(i, p)| {
                (!stole[i]
                    && p.batcher.active() == 0
                    && p.queue.peek_arrival_ns().map_or(true, |t| t > now_ns))
                .then_some(i)
            });
            let Some(thief) = thief else { break };
            let mut victim: Option<(usize, usize)> = None;
            for (i, p) in pkgs.iter().enumerate() {
                if i == thief || p.batcher.has_capacity() {
                    continue;
                }
                if !p.queue.peek_back_arrival_ns().is_some_and(|t| t <= now_ns) {
                    continue;
                }
                let load = p.load_tokens();
                if victim.map_or(true, |(_, best)| load > best) {
                    victim = Some((i, load));
                }
            }
            let Some((victim, _)) = victim else { break };
            let Some(req) = self.srv.packages[victim].steal_back(now_ns) else { break };
            let id = req.id;
            let bytes = self.srv.steal_payload(&req);
            // Route the payload DRAM-to-DRAM over the package fabric.
            // `point-to-point` is the legacy 0-cost baseline — every
            // pre-fabric outcome stays bit-identical; the routed
            // topologies charge the delivery latency (the thief cannot
            // start the request before the payload lands) and per-hop
            // UCIe link energy.
            let fabric_before = if self.srv.tracer.is_some() {
                obs::link_snapshot(&self.srv.steal_fabric)
            } else {
                Vec::new()
            };
            let delivery = if self.srv.steal_fabric.kind() == TopologyKind::PointToPoint {
                Delivery::free()
            } else {
                self.srv.steal_fabric.advance_to(now_ns);
                self.srv.steal_fabric.transfer(
                    Endpoint::dram(victim),
                    Endpoint::dram(thief),
                    bytes,
                )
            };
            if let Some(tr) = self.srv.tracer.as_mut() {
                // Steal-fabric links already carry global package indices.
                for (link, leg_bytes, transfers) in
                    obs::link_deltas(&self.srv.steal_fabric, &fabric_before)
                {
                    tr.instant(
                        thief,
                        Track::Fabric,
                        "fabric_leg",
                        now_ns,
                        vec![
                            ("link", obs::link_label(&link).into()),
                            ("bytes", (leg_bytes as f64).into()),
                            ("transfers", (transfers as f64).into()),
                        ],
                    );
                }
            }
            self.srv.packages[thief].receive_stolen(req, now_ns + delivery.delivery_ns);
            stole[thief] = true;
            self.metrics.record_steal(bytes, delivery.delivery_ns);
            self.metrics.energy_j += delivery.energy_pj * 1e-12;
            self.index.refresh(victim, &self.srv.packages);
            self.index.refresh(thief, &self.srv.packages);
            events.push(ServeEvent::Stolen {
                id,
                from: victim,
                to: thief,
                bytes,
                time_ns: now_ns,
            });
        }
        if let Some(tr) = self.srv.tracer.as_mut() {
            tr.wall_end("steal_pass", wall);
        }
        events
    }

    /// Sort the completion stream into the event-ordered merge and hand
    /// the outcome out (used by both `finish` and the protocol adapter).
    pub(crate) fn take_outcome(&mut self) -> ServeOutcome {
        let mut done = std::mem::take(&mut self.done);
        done.sort_by(|a, b| {
            let fa = a.0 + a.1.total_latency_ns();
            let fb = b.0 + b.1.total_latency_ns();
            fa.total_cmp(&fb).then(a.1.id.cmp(&b.1.id))
        });
        ServeOutcome {
            responses: done.into_iter().map(|(_, r)| r).collect(),
            shed: std::mem::take(&mut self.shed),
            metrics: std::mem::take(&mut self.metrics),
        }
    }
}

impl super::streaming::ServeProtocol for ShardedSession<'_> {
    fn submit(&mut self, req: ServeRequest) -> Vec<ServeEvent> {
        ShardedSession::submit(self, req)
    }

    fn tick(&mut self) -> Result<Vec<ServeEvent>, crate::api::ChimeError> {
        Ok(ShardedSession::tick(self))
    }

    fn finish(&mut self) -> ServeOutcome {
        self.take_outcome()
    }

    fn telemetry(&self) -> Option<obs::EngineTelemetry> {
        Some(self.srv.telemetry())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    /// Tiny-model config with a small workload: cheap enough for many
    /// serve calls per test.
    fn tiny_cfg() -> (MllmConfig, ChimeConfig) {
        let mut cfg = ChimeConfig::default();
        cfg.workload = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 4 };
        (MllmConfig::tiny(), cfg)
    }

    fn burst(tokens: &[usize]) -> Vec<ServeRequest> {
        tokens
            .iter()
            .enumerate()
            .map(|(i, &t)| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: t,
                arrival_ns: 0.0,
            })
            .collect()
    }

    #[test]
    fn round_robin_spreads_a_homogeneous_burst() {
        let (model, cfg) = tiny_cfg();
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy::default(),
            2,
            RoutePolicy::RoundRobin,
        );
        let out = srv.serve(burst(&[4; 8]));
        assert_eq!(out.responses.len(), 8);
        assert!(out.shed.is_empty());
        assert_eq!(srv.package_completed(), vec![4, 4]);
        assert_eq!(out.metrics.completed, 8);
        assert_eq!(out.metrics.admitted, 8);
        assert_eq!(out.metrics.rejected, 0);
        assert_eq!(out.metrics.tokens, 32);
    }

    #[test]
    fn least_loaded_balances_skewed_token_budgets() {
        // Alternating heavy/light requests: round-robin piles every heavy
        // request onto package 0; least-loaded balances total tokens and
        // must drain the burst strictly sooner (deterministic virtual time).
        let (model, cfg) = tiny_cfg();
        let skew = [64usize, 1, 64, 1, 64, 1, 64, 1];
        let run = |route: RoutePolicy| {
            let mut srv = ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, route);
            let out = srv.serve(burst(&skew));
            assert_eq!(out.responses.len(), 8);
            (out.metrics.span_ns(), srv.package_completed())
        };
        let (rr_span, _) = run(RoutePolicy::RoundRobin);
        let (ll_span, ll_completed) = run(RoutePolicy::LeastLoaded);
        assert!(
            ll_span < rr_span,
            "least-loaded {ll_span} must drain before round-robin {rr_span}"
        );
        // Both packages took part under least-loaded.
        assert!(ll_completed.iter().all(|&c| c > 0), "{ll_completed:?}");
    }

    #[test]
    fn admission_fails_over_before_shedding() {
        // A full routed package must not shed while a sibling has queue
        // room: skewed least-loaded routing may pick a full target, and
        // the failover scan admits the request elsewhere.
        let (model, cfg) = tiny_cfg();
        let policy = BatchPolicy { max_batch: 1, queue_capacity: 2 };
        let mut srv = ShardedServer::new(&model, &cfg, policy, 3, RoutePolicy::LeastLoaded);
        // Skewed burst: least-loaded routes the light requests onto one
        // package until its queue fills, then *must* fail over (pre-fix
        // this shed requests 4 and 5 while siblings had room). 6 requests
        // into 3 packages x 2-deep queues fit exactly: nothing may shed.
        let out = srv.serve(burst(&[1, 10, 10, 1, 10, 1]));
        assert!(out.shed.is_empty(), "shed with aggregate capacity free");
        assert_eq!(out.responses.len(), 6);
    }

    #[test]
    fn sharded_backpressure_sheds_to_caller() {
        let (model, cfg) = tiny_cfg();
        let policy = BatchPolicy { max_batch: 1, queue_capacity: 1 };
        let mut srv = ShardedServer::new(&model, &cfg, policy, 2, RoutePolicy::RoundRobin);
        let out = srv.serve(burst(&[4; 10]));
        // 2 packages x 1-deep queues admit 2 of a simultaneous burst of 10.
        assert_eq!(out.responses.len(), 2);
        assert_eq!(out.shed.len(), 8);
        assert_eq!(out.metrics.rejected, 8);
        assert_eq!(out.metrics.offered(), 10);
        // Identity of every request is preserved across the split.
        let mut ids: Vec<u64> = out
            .responses
            .iter()
            .map(|r| r.id)
            .chain(out.shed.iter().map(|r| r.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn responses_come_back_in_global_completion_order() {
        let (model, cfg) = tiny_cfg();
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy::default(),
            3,
            RoutePolicy::LeastLoaded,
        );
        let mut reqs = burst(&[8, 2, 6, 1, 4, 3, 7, 5]);
        for (i, r) in reqs.iter_mut().enumerate() {
            r.arrival_ns = i as f64 * 1e5;
        }
        let out = srv.serve(reqs);
        assert_eq!(out.responses.len(), 8);
        let finish: Vec<f64> = out
            .responses
            .iter()
            .map(|r| {
                // arrival = id * 1e5 by construction above.
                r.id as f64 * 1e5 + r.total_latency_ns()
            })
            .collect();
        for w in finish.windows(2) {
            assert!(w[0] <= w[1], "responses not completion-ordered: {finish:?}");
        }
    }

    #[test]
    fn single_package_sharded_server_matches_simulated_server_contract() {
        // The 1-package sharded core is the SimulatedServer engine; its
        // per-request causality invariants must hold under mixed arrivals.
        let (model, cfg) = tiny_cfg();
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 1, RoutePolicy::RoundRobin);
        let mut reqs = burst(&[4; 6]);
        for (i, r) in reqs.iter_mut().enumerate() {
            r.arrival_ns = i as f64 * 5e4;
        }
        let out = srv.serve(reqs);
        assert_eq!(out.responses.len(), 6);
        for r in &out.responses {
            assert!(r.queue_ns >= 0.0);
            assert!(r.ttft_ns > 0.0);
            assert!(r.service_ns >= r.ttft_ns);
            assert!(r.energy_j > 0.0);
        }
    }

    #[test]
    fn non_finite_arrivals_are_shed_not_spun_on() {
        // A NaN/infinite arrival can never be reached by the virtual
        // clock; it must come back shed instead of wedging the event loop.
        let (model, cfg) = tiny_cfg();
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, RoutePolicy::RoundRobin);
        let mut reqs = burst(&[4, 4, 4]);
        reqs[1].arrival_ns = f64::NAN;
        reqs[2].arrival_ns = f64::INFINITY;
        let out = srv.serve(reqs);
        assert_eq!(out.responses.len(), 1);
        assert_eq!(out.shed.len(), 2);
        assert_eq!(out.metrics.shed, 2, "non-finite arrivals count as shed");
        assert_eq!(out.metrics.rejected, 0, "no backpressure rejections here");
        assert_eq!(out.metrics.offered(), 3);
        let mut shed_ids: Vec<u64> = out.shed.iter().map(|r| r.id).collect();
        shed_ids.sort_unstable();
        assert_eq!(shed_ids, vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "duplicate request id")]
    fn duplicate_request_ids_are_rejected_loudly() {
        let (model, cfg) = tiny_cfg();
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 1, RoutePolicy::RoundRobin);
        let mut reqs = burst(&[2, 5]);
        reqs[1].id = 0;
        let _ = srv.serve(reqs);
    }

    #[test]
    fn serve_calls_are_independent_sessions() {
        // Review regression: package clocks/counters must restart per
        // serve() — a second t=0 burst must not queue behind the first
        // call's entire drain time.
        let (model, cfg) = tiny_cfg();
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, RoutePolicy::RoundRobin);
        let first = srv.serve(burst(&[4; 6]));
        assert_eq!(first.responses.len(), 6);
        let second = srv.serve(burst(&[4; 6]));
        assert_eq!(second.responses.len(), 6);
        assert_eq!(srv.package_completed().iter().sum::<u64>(), 6, "per-call counters");
        // A fresh t=0 burst fills empty slots at clock 0: the first
        // admitted requests see zero queueing, which is impossible if the
        // previous session's clock leaked into this one.
        assert!(
            second.responses.iter().any(|r| r.queue_ns == 0.0),
            "second session inherited the first session's clock: {:?}",
            second.responses.iter().map(|r| r.queue_ns).collect::<Vec<_>>()
        );
    }

    #[test]
    fn dram_only_deployment_serves_and_is_slower() {
        // The ablation is servable through the same coordinator, and a
        // single-chiplet package must drain a burst strictly slower than
        // the heterogeneous pair (Fig 9's result, on the serving path).
        let (model, cfg) = tiny_cfg();
        let run = |dram_only: bool| {
            let mut srv = if dram_only {
                ShardedServer::new_dram_only(
                    &model,
                    &cfg,
                    BatchPolicy::default(),
                    1,
                    RoutePolicy::RoundRobin,
                )
            } else {
                ShardedServer::new(&model, &cfg, BatchPolicy::default(), 1, RoutePolicy::RoundRobin)
            };
            let out = srv.serve(burst(&[4; 4]));
            assert_eq!(out.responses.len(), 4);
            out.metrics.span_ns()
        };
        let het = run(false);
        let solo = run(true);
        assert!(solo > het, "dram-only span {solo} vs heterogeneous {het}");
    }

    #[test]
    fn streaming_session_is_bit_identical_to_batch_serve() {
        // The batch call is a wrapper over the session; driving the
        // session by hand (submit + tick + finish) must produce the same
        // outcome byte for byte.
        let (model, cfg) = tiny_cfg();
        let mut reqs = burst(&[4, 0, 2, 6, 4, 3]);
        for (i, r) in reqs.iter_mut().enumerate() {
            r.arrival_ns = i as f64 * 3e4;
        }
        let mut batch_srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, RoutePolicy::LeastLoaded);
        let batch = batch_srv.serve(reqs.clone());
        let mut stream_srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, RoutePolicy::LeastLoaded);
        let mut session = stream_srv.open_serving();
        for r in reqs {
            session.submit(r);
        }
        while !session.tick().is_empty() {}
        let streamed = session.finish();
        assert_eq!(batch.responses.len(), streamed.responses.len());
        for (a, b) in batch.responses.iter().zip(&streamed.responses) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.queue_ns.to_bits(), b.queue_ns.to_bits());
            assert_eq!(a.ttft_ns.to_bits(), b.ttft_ns.to_bits());
            assert_eq!(a.service_ns.to_bits(), b.service_ns.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
        assert_eq!(batch.metrics.completed, streamed.metrics.completed);
        assert_eq!(batch.metrics.tokens, streamed.metrics.tokens);
    }

    #[test]
    fn streaming_events_follow_the_lifecycle_contract() {
        let (model, cfg) = tiny_cfg();
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, RoutePolicy::RoundRobin);
        let mut session = srv.open_serving();
        let mut reqs = burst(&[3, 0, 2]);
        reqs[2].arrival_ns = 1e5;
        for r in reqs {
            assert!(session.submit(r).is_empty(), "finite submissions emit no events");
        }
        let events = session.drain();
        // Per-request bookkeeping: admission, first token, every token,
        // completion — in causal order, never before arrival.
        let of = |id: u64| -> Vec<&ServeEvent> {
            events.iter().filter(|e| e.id() == id).collect()
        };
        // id 0: 3 tokens -> admitted + first + 3 tokens + completed.
        let kinds: Vec<&str> = of(0).iter().map(|e| e.kind()).collect();
        assert_eq!(kinds, ["admitted", "first-token", "token", "token", "token", "completed"]);
        // id 1: zero tokens -> inline completion, no token events.
        let kinds: Vec<&str> = of(1).iter().map(|e| e.kind()).collect();
        assert_eq!(kinds, ["admitted", "completed"]);
        // Causality: event times are monotone per request and >= arrival.
        for id in [0u64, 2] {
            let times: Vec<f64> = of(id).iter().filter_map(|e| e.time_ns()).collect();
            for w in times.windows(2) {
                assert!(w[0] <= w[1], "id {id}: out-of-order events {times:?}");
            }
            let arrival = if id == 2 { 1e5 } else { 0.0 };
            assert!(times.iter().all(|&t| t >= arrival), "id {id}: event before arrival");
        }
        let out = session.finish();
        assert_eq!(out.responses.len(), 3);
    }

    #[test]
    fn submitting_a_non_finite_arrival_sheds_immediately() {
        let (model, cfg) = tiny_cfg();
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 1, RoutePolicy::RoundRobin);
        let mut session = srv.open_serving();
        let mut req = burst(&[4]).pop().unwrap();
        req.arrival_ns = f64::NAN;
        let events = session.submit(req);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].kind(), "shed");
        let out = session.finish();
        assert_eq!(out.shed.len(), 1);
        assert_eq!(out.metrics.shed, 1);
        assert_eq!(out.metrics.rejected, 0);
    }

    #[test]
    fn rejected_and_shed_are_counted_independently() {
        // One NaN arrival (shed at submission) plus a burst that overflows
        // a 1-deep queue (rejected by backpressure): the two counters must
        // move independently and still conserve the offered load.
        let (model, cfg) = tiny_cfg();
        let policy = BatchPolicy { max_batch: 1, queue_capacity: 1 };
        let mut srv = ShardedServer::new(&model, &cfg, policy, 1, RoutePolicy::RoundRobin);
        let mut reqs = burst(&[4; 5]);
        reqs[4].arrival_ns = f64::NAN;
        let out = srv.serve(reqs);
        assert_eq!(out.metrics.shed, 1, "exactly the NaN arrival is shed");
        assert!(out.metrics.rejected > 0, "the t=0 burst must overflow queue depth 1");
        assert_eq!(out.metrics.offered(), 5);
        assert_eq!(
            out.metrics.completed + out.metrics.rejected + out.metrics.shed,
            5,
            "conservation across both counters"
        );
        // Both outcomes hand the request back to the caller.
        assert_eq!(
            out.shed.len() as u64,
            out.metrics.rejected + out.metrics.shed,
            "every rejected or shed request is returned"
        );
    }

    #[test]
    fn work_stealing_rebalances_a_skewed_drain() {
        // Round-robin piles every heavy request onto package 0 (8 heavy,
        // batch 2 -> 6 queued); package 1 drains its light requests, goes
        // idle, and with stealing on must take queued work and finish the
        // burst strictly sooner — with exactly the same token output.
        let (model, cfg) = tiny_cfg();
        let skew: Vec<usize> =
            (0..16).map(|i| if i % 2 == 0 { 64 } else { 1 }).collect();
        let policy = BatchPolicy { max_batch: 2, queue_capacity: 1024 };
        let run = |steal: bool| {
            let mut srv =
                ShardedServer::new(&model, &cfg, policy.clone(), 2, RoutePolicy::RoundRobin);
            srv.set_work_stealing(steal);
            let mut session = srv.open_serving();
            for r in burst(&skew) {
                session.submit(r);
            }
            let events = session.drain();
            let steals = events.iter().filter(|e| e.kind() == "stolen").count();
            let out = session.finish();
            assert_eq!(out.responses.len(), 16);
            assert!(out.shed.is_empty());
            (out.metrics.span_ns(), out.metrics.tokens, steals)
        };
        let (span_off, tokens_off, steals_off) = run(false);
        let (span_on, tokens_on, steals_on) = run(true);
        assert_eq!(steals_off, 0, "stealing must not fire when disabled");
        assert!(steals_on > 0, "skewed drain must trigger steals");
        assert!(
            span_on < span_off,
            "stealing must drain strictly sooner: {span_on} vs {span_off}"
        );
        assert_eq!(tokens_on, tokens_off, "stealing must not change token output");
    }

    #[test]
    fn work_stealing_is_a_bitwise_noop_on_one_package() {
        // A single package can never be thief and victim at once: steal
        // on/off must produce byte-identical outcomes.
        let (model, cfg) = tiny_cfg();
        let run = |steal: bool| {
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy { max_batch: 2, queue_capacity: 1024 },
                1,
                RoutePolicy::RoundRobin,
            );
            srv.set_work_stealing(steal);
            srv.serve(burst(&[8, 2, 5, 1]))
        };
        let (off, on) = (run(false), run(true));
        assert_eq!(off.responses.len(), on.responses.len());
        for (a, b) in off.responses.iter().zip(&on.responses) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.service_ns.to_bits(), b.service_ns.to_bits());
            assert_eq!(a.queue_ns.to_bits(), b.queue_ns.to_bits());
        }
    }

    #[test]
    fn routed_topologies_charge_steals_the_point_to_point_baseline_does_not() {
        // Same skewed drain on 4 packages with stealing on, across the
        // fabric topologies. Every topology moves the same kind of
        // payload (steals and stolen bytes are counted everywhere), but
        // only the routed topologies pay a delivery latency — the
        // point-to-point default is the legacy 0-cost baseline.
        let (model, cfg_base) = tiny_cfg();
        let skew: Vec<usize> = (0..16).map(|i| if i % 2 == 0 { 64 } else { 1 }).collect();
        let run = |kind: TopologyKind| {
            let mut cfg = cfg_base.clone();
            cfg.hardware.topology.kind = kind;
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy { max_batch: 2, queue_capacity: 1024 },
                4,
                RoutePolicy::RoundRobin,
            );
            assert_eq!(srv.topology(), kind);
            srv.set_work_stealing(true);
            let mut session = srv.open_serving();
            for r in burst(&skew) {
                session.submit(r);
            }
            let events = session.drain();
            for ev in &events {
                if let ServeEvent::Stolen { bytes, .. } = ev {
                    assert!(*bytes > 0, "{kind:?}: steal payload must be positive");
                }
            }
            let out = session.finish();
            assert_eq!(out.responses.len(), 16);
            out.metrics
        };
        let p2p = run(TopologyKind::PointToPoint);
        assert!(p2p.steals > 0, "skewed drain must trigger steals");
        assert!(p2p.stolen_bytes > 0, "steal payloads are counted on every topology");
        assert_eq!(p2p.steal_delay_ns, 0.0, "point-to-point is the 0-cost baseline");
        for kind in [TopologyKind::Line, TopologyKind::Ring, TopologyKind::Mesh] {
            let routed = run(kind);
            assert!(routed.steals > 0, "{kind:?}: steals must still fire");
            assert!(routed.stolen_bytes > 0, "{kind:?}: stolen bytes must be counted");
            assert!(
                routed.steal_delay_ns > 0.0,
                "{kind:?}: routed steals must pay a strictly positive delivery"
            );
            assert!(
                routed.mean_steal_delay_ns() > p2p.mean_steal_delay_ns(),
                "{kind:?}: mean steal delay must exceed the free baseline"
            );
        }
    }

    #[test]
    fn fabric_links_merge_engine_locals_with_steal_fabric_inters() {
        // After a stealing session on a ring, the merged telemetry must
        // show every package's local DRAM<->RRAM link (remapped onto its
        // global index) plus strictly positive traffic on at least one
        // inter-package link, and the inter-link totals must agree with
        // the steal fabric's per-link counters exactly.
        let (model, mut cfg) = tiny_cfg();
        cfg.hardware.topology.kind = TopologyKind::Ring;
        let skew: Vec<usize> = (0..16).map(|i| if i % 2 == 0 { 64 } else { 1 }).collect();
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy { max_batch: 2, queue_capacity: 1024 },
            4,
            RoutePolicy::RoundRobin,
        );
        srv.set_work_stealing(true);
        let out = srv.serve(burst(&skew));
        assert!(out.metrics.steals > 0, "the skewed drain must steal");
        let links = srv.fabric_links();
        for p in 0..4 {
            let local = &links[&Link::Local { package: p }];
            assert!(
                local.bytes > 0,
                "package {p}: cut-point traffic must land on its local link"
            );
        }
        let inter_bytes: u64 = links
            .iter()
            .filter(|(l, _)| matches!(l, Link::Inter { .. }))
            .map(|(_, s)| s.bytes)
            .sum();
        let steal_inter: u64 = srv.steal_fabric().link_states().map(|(_, s)| s.bytes).sum();
        assert!(inter_bytes > 0, "steals must put bytes on inter-package links");
        assert_eq!(
            inter_bytes, steal_inter,
            "inter-package traffic comes only from the steal fabric"
        );
        assert!(
            links.iter().any(|(l, s)| matches!(l, Link::Inter { .. }) && s.peak_gbps() > 0.0),
            "a used inter link must report a positive peak"
        );
    }

    #[test]
    fn indexed_event_selection_matches_the_legacy_linear_scan() {
        // The BTreeSet event index replaced a per-tick linear scan whose
        // tie-break was "first strict minimum" (lowest package index among
        // equal times). Drive a skewed stream tick by tick, with and
        // without stealing, and assert the index picks exactly what the
        // legacy scan would have picked before every tick.
        let (model, cfg) = tiny_cfg();
        for steal in [false, true] {
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy { max_batch: 2, queue_capacity: 64 },
                3,
                RoutePolicy::RoundRobin,
            );
            srv.set_work_stealing(steal);
            let mut session = srv.open_serving();
            let mut reqs = burst(&[8, 1, 5, 2, 7, 1, 3, 4]);
            for (i, r) in reqs.iter_mut().enumerate() {
                r.arrival_ns = i as f64 * 2e4;
            }
            for r in reqs {
                session.submit(r);
            }
            let mut ticks = 0u32;
            loop {
                // Legacy reference: linear scan, first strict minimum.
                let mut t_pkg = f64::INFINITY;
                let mut who = 0usize;
                for (i, p) in session.srv.packages.iter().enumerate() {
                    let t = p.next_event_ns();
                    if t < t_pkg {
                        t_pkg = t;
                        who = i;
                    }
                }
                let (t_idx, who_idx) = session.index.earliest();
                assert_eq!(
                    t_idx.to_bits(),
                    t_pkg.to_bits(),
                    "steal {steal} tick {ticks}: index time drifted from the scan"
                );
                if t_pkg.is_finite() {
                    assert_eq!(
                        who_idx, who,
                        "steal {steal} tick {ticks}: index tie-break drifted from the scan"
                    );
                }
                if session.tick().is_empty() {
                    break;
                }
                ticks += 1;
            }
            assert!(ticks > 10, "steal {steal}: the stream must exercise many ticks");
            assert_eq!(session.finish().responses.len(), 8);
        }
    }

    #[test]
    fn parallel_drain_is_bit_identical_to_sequential() {
        // With stealing off, the parallel per-package drain must replay
        // the completion stream in exact sequential order — every float
        // in every response and in the merged metrics matches bitwise.
        let (model, cfg) = tiny_cfg();
        let skew = [8usize, 1, 5, 0, 7, 2, 3, 6, 4, 1, 2, 8];
        let run = |parallel: bool| {
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy { max_batch: 2, queue_capacity: 64 },
                4,
                RoutePolicy::LeastLoaded,
            );
            srv.set_parallel(parallel);
            let mut reqs = burst(&skew);
            for (i, r) in reqs.iter_mut().enumerate() {
                r.arrival_ns = i as f64 * 3e4;
            }
            srv.serve(reqs)
        };
        let (seq, par) = (run(false), run(true));
        assert_eq!(seq.responses.len(), par.responses.len());
        for (a, b) in seq.responses.iter().zip(&par.responses) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.queue_ns.to_bits(), b.queue_ns.to_bits());
            assert_eq!(a.ttft_ns.to_bits(), b.ttft_ns.to_bits());
            assert_eq!(a.service_ns.to_bits(), b.service_ns.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
        assert_eq!(seq.metrics.completed, par.metrics.completed);
        assert_eq!(seq.metrics.tokens, par.metrics.tokens);
        assert_eq!(
            seq.metrics.energy_j.to_bits(),
            par.metrics.energy_j.to_bits(),
            "order-dependent energy accumulation must replay identically"
        );
        assert_eq!(
            seq.metrics.service.stddev().to_bits(),
            par.metrics.service.stddev().to_bits(),
            "order-dependent Welford summary must replay identically"
        );
        assert_eq!(seq.metrics.span_ns().to_bits(), par.metrics.span_ns().to_bits());
    }

    #[test]
    fn exec_drain_is_bit_identical_to_sequential() {
        // The windowed executor drain (threads > 1) parallelizes every
        // arrival-free window under active mid-stream arrivals — not just
        // the tail — and must still replay the completion stream in exact
        // sequential event-loop order: every float in every response and
        // in the merged metrics matches bitwise, for even and uneven
        // package/worker chunkings alike.
        let (model, cfg) = tiny_cfg();
        let skew = [8usize, 1, 5, 0, 7, 2, 3, 6, 4, 1, 2, 8];
        let run = |threads: usize| {
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy { max_batch: 2, queue_capacity: 64 },
                4,
                RoutePolicy::LeastLoaded,
            );
            srv.set_threads(threads);
            let mut reqs = burst(&skew);
            for (i, r) in reqs.iter_mut().enumerate() {
                r.arrival_ns = i as f64 * 3e4;
            }
            srv.serve(reqs)
        };
        let seq = run(1);
        for threads in [2, 3, 4, 7] {
            let exec = run(threads);
            assert_eq!(seq.responses.len(), exec.responses.len(), "threads {threads}");
            for (a, b) in seq.responses.iter().zip(&exec.responses) {
                assert_eq!(a.id, b.id, "threads {threads}");
                assert_eq!(a.queue_ns.to_bits(), b.queue_ns.to_bits(), "threads {threads}");
                assert_eq!(a.ttft_ns.to_bits(), b.ttft_ns.to_bits(), "threads {threads}");
                assert_eq!(a.service_ns.to_bits(), b.service_ns.to_bits(), "threads {threads}");
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "threads {threads}");
            }
            assert_eq!(seq.metrics.completed, exec.metrics.completed);
            assert_eq!(seq.metrics.tokens, exec.metrics.tokens);
            assert_eq!(
                seq.metrics.energy_j.to_bits(),
                exec.metrics.energy_j.to_bits(),
                "threads {threads}: order-dependent energy accumulation must replay identically"
            );
            assert_eq!(
                seq.metrics.service.stddev().to_bits(),
                exec.metrics.service.stddev().to_bits(),
                "threads {threads}: order-dependent Welford summary must replay identically"
            );
            assert_eq!(seq.metrics.span_ns().to_bits(), exec.metrics.span_ns().to_bits());
        }
    }

    #[test]
    fn exec_drain_with_stealing_falls_back_to_the_sequential_loop() {
        // Stealing couples the packages at every event, so the executor
        // stands down and the sequential loop runs: threads must be a
        // bitwise no-op on a stealing session (and steals must still
        // fire, proving the path wasn't silently disabled).
        let (model, cfg) = tiny_cfg();
        let skew: Vec<usize> = (0..12).map(|i| if i % 2 == 0 { 32 } else { 1 }).collect();
        let run = |threads: usize| {
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy { max_batch: 2, queue_capacity: 1024 },
                3,
                RoutePolicy::RoundRobin,
            );
            srv.set_work_stealing(true);
            srv.set_threads(threads);
            srv.serve(burst(&skew))
        };
        let (one, four) = (run(1), run(4));
        assert!(one.metrics.steals > 0, "the skewed drain must steal");
        assert_eq!(one.metrics.steals, four.metrics.steals);
        assert_eq!(one.responses.len(), four.responses.len());
        for (a, b) in one.responses.iter().zip(&four.responses) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.service_ns.to_bits(), b.service_ns.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
        assert_eq!(one.metrics.energy_j.to_bits(), four.metrics.energy_j.to_bits());
    }

    #[test]
    fn exec_drain_traces_deterministically_across_worker_counts() {
        // Per-worker tracers merge by (start, pid, per-worker order) —
        // keys that are invariant to how packages were chunked across
        // workers — so a fixed stream must export the byte-same Chrome
        // trace for every thread count, and tracing must stay a bitwise
        // no-op on the outcome.
        let (model, cfg) = tiny_cfg();
        let run = |threads: usize, traced: bool| {
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy { max_batch: 2, queue_capacity: 64 },
                4,
                RoutePolicy::LeastLoaded,
            );
            srv.set_threads(threads);
            srv.set_tracing(traced);
            let mut reqs = burst(&[6, 1, 4, 0, 3, 5, 2, 7]);
            for (i, r) in reqs.iter_mut().enumerate() {
                r.arrival_ns = i as f64 * 4e4;
            }
            let out = srv.serve(reqs);
            (out, srv.take_trace().map(|t| t.chrome_trace().pretty()))
        };
        let (seq_out, _) = run(1, false);
        let (t2_out, t2_trace) = run(2, true);
        let (t4_out, t4_trace) = run(4, true);
        for exec in [&t2_out, &t4_out] {
            assert_eq!(seq_out.responses.len(), exec.responses.len());
            for (a, b) in seq_out.responses.iter().zip(&exec.responses) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.service_ns.to_bits(), b.service_ns.to_bits());
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            }
            assert_eq!(seq_out.metrics.energy_j.to_bits(), exec.metrics.energy_j.to_bits());
        }
        let (t2_trace, t4_trace) = (t2_trace.unwrap(), t4_trace.unwrap());
        assert!(!t2_trace.is_empty());
        assert_eq!(t2_trace, t4_trace, "worker count must not move a traced byte");
    }

    #[test]
    fn abandoned_sessions_do_not_poison_the_next_one() {
        // Drop a session mid-stream (submitted but not drained): the next
        // open must start from a clean schedule and serve normally.
        let (model, cfg) = tiny_cfg();
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, RoutePolicy::RoundRobin);
        {
            let mut session = srv.open_serving();
            for r in burst(&[4; 6]) {
                session.submit(r);
            }
            let _ = session.tick(); // leave work queued and batched
        }
        let out = srv.serve(burst(&[4; 6]));
        assert_eq!(out.responses.len(), 6);
        assert!(out.shed.is_empty());
        assert_eq!(out.metrics.tokens, 24);
    }

    #[test]
    fn one_shot_inference_matches_the_free_functions() {
        // `run_inference_with` is the api::Backend infer path; it must be
        // bit-identical to the pre-existing sim free functions.
        let (model, cfg) = tiny_cfg();
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 1, RoutePolicy::RoundRobin);
        let a = srv.run_inference_with(&cfg.workload);
        let b = crate::sim::simulate(&model, &cfg);
        assert_eq!(a.total_time_ns(), b.total_time_ns());
        assert_eq!(a.total_energy_j(), b.total_energy_j());
        assert_eq!(a.kv_offloaded_bytes, b.kv_offloaded_bytes);
        let (dram, rram) = srv.last_infer_memory().expect("engine retained");
        assert!(dram.bytes_read > 0);
        assert!(rram.lifetime_read_bytes > 0);

        let mut solo = ShardedServer::new_dram_only(
            &model,
            &cfg,
            BatchPolicy::default(),
            1,
            RoutePolicy::RoundRobin,
        );
        assert!(solo.is_dram_only());
        let c = solo.run_inference_with(&cfg.workload);
        let d = crate::sim::simulate_dram_only(&model, &cfg);
        assert_eq!(c.total_time_ns(), d.total_time_ns());
        assert_eq!(c.total_energy_j(), d.total_energy_j());
    }

    #[test]
    fn kv_budget_is_reported_per_package() {
        let (model, cfg) = tiny_cfg();
        let srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 4, RoutePolicy::RoundRobin);
        assert_eq!(srv.package_count(), 4);
        let budget = srv.kv_budget_bytes_per_package();
        assert!(budget > 0);
        // Replicas do not split the budget: every package gets full headroom.
        let solo =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 1, RoutePolicy::RoundRobin);
        assert_eq!(budget, solo.kv_budget_bytes_per_package());
    }

    #[test]
    fn tracing_is_a_bitwise_noop_on_outcomes() {
        // The load-bearing invariant of the obs subsystem: attaching a
        // tracer must not move a single bit of any simulated number —
        // instrumentation is a read-only side channel, not a behavioral
        // fork. Exercised with stealing on (the most coupled path).
        let (model, mut cfg) = tiny_cfg();
        cfg.hardware.topology.kind = TopologyKind::Ring;
        let skew: Vec<usize> = (0..12).map(|i| if i % 2 == 0 { 32 } else { 1 }).collect();
        let run = |traced: bool| {
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy { max_batch: 2, queue_capacity: 1024 },
                3,
                RoutePolicy::RoundRobin,
            );
            srv.set_work_stealing(true);
            srv.set_tracing(traced);
            let out = srv.serve(burst(&skew));
            let trace = srv.take_trace();
            assert_eq!(trace.is_some(), traced);
            if traced {
                assert!(!trace.unwrap().is_empty(), "a traced drain must record spans");
            }
            out
        };
        let (off, on) = (run(false), run(true));
        assert_eq!(off.responses.len(), on.responses.len());
        for (a, b) in off.responses.iter().zip(&on.responses) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.queue_ns.to_bits(), b.queue_ns.to_bits());
            assert_eq!(a.ttft_ns.to_bits(), b.ttft_ns.to_bits());
            assert_eq!(a.service_ns.to_bits(), b.service_ns.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
        assert_eq!(off.metrics.energy_j.to_bits(), on.metrics.energy_j.to_bits());
        assert_eq!(off.metrics.span_ns().to_bits(), on.metrics.span_ns().to_bits());
    }

    #[test]
    fn traced_fabric_legs_conserve_the_link_byte_counters() {
        // Σ `fabric_leg` bytes in the trace, grouped by link label, must
        // equal the merged per-link byte counters exactly — the trace is
        // an event-level decomposition of the same traffic.
        let (model, mut cfg) = tiny_cfg();
        cfg.hardware.topology.kind = TopologyKind::Ring;
        let skew: Vec<usize> = (0..16).map(|i| if i % 2 == 0 { 64 } else { 1 }).collect();
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy { max_batch: 2, queue_capacity: 1024 },
            4,
            RoutePolicy::RoundRobin,
        );
        srv.set_work_stealing(true);
        srv.set_tracing(true);
        let out = srv.serve(burst(&skew));
        assert!(out.metrics.steals > 0, "the skewed drain must steal");
        let trace = srv.take_trace().expect("tracing was on");
        let mut traced: BTreeMap<String, u64> = BTreeMap::new();
        for r in trace.records() {
            if r.name != "fabric_leg" {
                continue;
            }
            let link = r
                .args
                .iter()
                .find(|(k, _)| *k == "link")
                .and_then(|(_, v)| v.as_str())
                .expect("fabric_leg instants carry a link label")
                .to_string();
            let bytes = r
                .args
                .iter()
                .find(|(k, _)| *k == "bytes")
                .and_then(|(_, v)| v.as_f64())
                .expect("fabric_leg instants carry a byte count") as u64;
            *traced.entry(link).or_default() += bytes;
        }
        let counters: BTreeMap<String, u64> = srv
            .fabric_links()
            .iter()
            .filter(|(_, s)| s.bytes > 0)
            .map(|(l, s)| (crate::obs::link_label(l), s.bytes))
            .collect();
        assert!(!counters.is_empty());
        assert_eq!(traced, counters, "trace legs must decompose the link counters");
    }

    #[test]
    fn traces_are_deterministic_and_sessions_start_fresh() {
        let (model, cfg) = tiny_cfg();
        let run = || {
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                BatchPolicy::default(),
                2,
                RoutePolicy::LeastLoaded,
            );
            srv.set_tracing(true);
            let mut reqs = burst(&[4, 0, 2, 6]);
            for (i, r) in reqs.iter_mut().enumerate() {
                r.arrival_ns = i as f64 * 3e4;
            }
            let _ = srv.serve(reqs);
            srv.take_trace().unwrap().chrome_trace().pretty()
        };
        assert_eq!(run(), run(), "same seed, byte-identical trace export");

        // A second session must not accumulate the first session's records.
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, RoutePolicy::RoundRobin);
        srv.set_tracing(true);
        let _ = srv.serve(burst(&[4; 4]));
        let first_len = srv.trace().unwrap().records().len();
        let _ = srv.serve(burst(&[4; 4]));
        let second_len = srv.trace().unwrap().records().len();
        assert!(first_len > 0);
        assert_eq!(first_len, second_len, "each session records a fresh trace");
    }

    #[test]
    fn serving_instants_mirror_the_event_stream() {
        let (model, cfg) = tiny_cfg();
        let mut srv =
            ShardedServer::new(&model, &cfg, BatchPolicy::default(), 2, RoutePolicy::RoundRobin);
        srv.set_tracing(true);
        let mut session = srv.open_serving();
        let mut kinds: BTreeMap<&'static str, usize> = BTreeMap::new();
        for r in burst(&[3, 0, 2]) {
            for ev in session.submit(r) {
                *kinds.entry(ev.kind()).or_default() += 1;
            }
        }
        loop {
            let events = session.tick();
            if events.is_empty() {
                break;
            }
            for ev in &events {
                *kinds.entry(ev.kind()).or_default() += 1;
            }
        }
        drop(session);
        let trace = srv.take_trace().unwrap();
        let mut traced: BTreeMap<&'static str, usize> = BTreeMap::new();
        for r in trace.records() {
            if r.track == Track::Serving {
                *traced.entry(r.name).or_default() += 1;
            }
        }
        assert_eq!(traced, kinds, "one serving instant per protocol event");
    }

    #[test]
    fn telemetry_aggregates_links_and_stalls() {
        let (model, mut cfg) = tiny_cfg();
        cfg.hardware.topology.kind = TopologyKind::Ring;
        let skew: Vec<usize> = (0..16).map(|i| if i % 2 == 0 { 64 } else { 1 }).collect();
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy { max_batch: 2, queue_capacity: 1024 },
            4,
            RoutePolicy::RoundRobin,
        );
        srv.set_work_stealing(true);
        let _ = srv.serve(burst(&skew));
        let t = srv.telemetry();
        assert_eq!(t.links.len(), srv.fabric_links().len());
        assert!(t.links.iter().any(|l| l.link.starts_with("local") && l.bytes > 0));
        assert!(t.links.iter().any(|l| l.link.starts_with("inter") && l.bytes > 0));
        // First-order memory fidelity (the default) has no stall causes.
        assert!(!t.stalls.any());
    }
}
