//! L3 serving coordinator: admission queue with backpressure, continuous
//! decode batcher, two-cut-point (2-machine flow-shop) pipeline
//! scheduling, multi-package sharding with cross-package work stealing,
//! the event-driven streaming protocol (`streaming`), open-loop arrival
//! processes (`arrivals`), and the serving engines (simulated paper-scale
//! + functional PJRT). This is the request path — Python is never on it.

pub mod arrivals;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod pipeline;
pub mod queue;
pub mod request;
pub mod sharded;
pub mod streaming;

pub use arrivals::{ArrivalPoint, ArrivalProcess};
pub use batcher::{BatchPolicy, Batcher};
pub use engine::{FunctionalServer, FunctionalSession, SequentialTimeline, SimulatedServer};
pub use metrics::ServingMetrics;
pub use queue::{AdmissionQueue, AdmitError};
pub use request::{ServeRequest, ServeResponse};
pub use sharded::{RoutePolicy, ServeOutcome, ShardedServer, ShardedSession};
pub use streaming::{ServeEvent, ServeProtocol, ServingSession};
