//! Decode batcher: continuous (token-level) batching for edge serving.
//!
//! Active requests are decoded in interleaved ticks: each tick advances
//! every active request by one token, with the tick's chiplet work
//! pipelined via Johnson's rule (`pipeline`). New requests join as slots
//! free up (the paper's "variable sequences ... without rebuilds").

use super::pipeline::{schedule_tick, StepWork};

/// A slot in the running batch.
#[derive(Debug, Clone)]
pub struct Slot {
    pub request_idx: usize,
    pub remaining_tokens: usize,
}

/// Batch policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max concurrent decode streams (KV-capacity bound on edge).
    pub max_batch: usize,
    /// Admission-queue depth per package: beyond this the engine sheds
    /// load (the request is returned to the caller and counted in
    /// `ServingMetrics::rejected`, never silently dropped).
    pub queue_capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, queue_capacity: 1024 }
    }
}

/// Continuous batcher state machine (engine-agnostic: the engine supplies
/// per-slot step costs, the batcher owns membership + tick scheduling).
pub struct Batcher {
    pub policy: BatchPolicy,
    pub slots: Vec<Slot>,
}

/// Result of scheduling one decode tick.
#[derive(Debug, Clone)]
pub struct TickPlan {
    /// Slot order (by `request_idx`) after Johnson's rule.
    pub order: Vec<usize>,
    /// Pipelined tick time (ns).
    pub pipelined_ns: f64,
    /// Serial tick time (ns) — what a non-pipelined coordinator would pay.
    pub serial_ns: f64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, slots: Vec::new() }
    }

    pub fn has_capacity(&self) -> bool {
        self.slots.len() < self.policy.max_batch
    }

    pub fn active(&self) -> usize {
        self.slots.len()
    }

    /// Decode ticks still owed to the active slots — the batcher's share
    /// of a package's outstanding load (least-loaded routing input).
    pub fn outstanding_tokens(&self) -> usize {
        self.slots.iter().map(|s| s.remaining_tokens).sum()
    }

    /// Join a request with its decode budget.
    pub fn join(&mut self, request_idx: usize, tokens: usize) -> bool {
        if !self.has_capacity() {
            return false;
        }
        self.slots.push(Slot { request_idx, remaining_tokens: tokens });
        true
    }

    /// Plan one tick given per-slot (dram_ns, rram_ns) costs, then retire
    /// slots that produced their last token. Returns the plan and the
    /// request indices that finished this tick.
    pub fn tick(&mut self, costs: &[(f64, f64)]) -> (TickPlan, Vec<usize>) {
        assert_eq!(costs.len(), self.slots.len(), "one cost pair per slot");
        // `StepWork::new` validates the costs: a NaN/∞ from the pricing
        // engine is an invariant violation, caught here rather than
        // corrupting the Johnson ordering downstream.
        let jobs: Vec<StepWork> = self
            .slots
            .iter()
            .zip(costs)
            .map(|(s, &(d, r))| StepWork::new(s.request_idx, d, r))
            .collect();
        let (order, pipelined_ns, serial_ns) = schedule_tick(&jobs);
        let plan = TickPlan {
            order: order.iter().map(|j| j.id).collect(),
            pipelined_ns,
            serial_ns,
        };
        let mut finished = Vec::new();
        for s in &mut self.slots {
            s.remaining_tokens -= 1;
            if s.remaining_tokens == 0 {
                finished.push(s.request_idx);
            }
        }
        self.slots.retain(|s| s.remaining_tokens > 0);
        (plan, finished)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_respected() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, ..BatchPolicy::default() });
        assert!(b.join(0, 4));
        assert!(b.join(1, 4));
        assert!(!b.join(2, 4));
        assert_eq!(b.active(), 2);
    }

    #[test]
    fn outstanding_tokens_track_remaining_work() {
        let mut b = Batcher::new(BatchPolicy::default());
        assert_eq!(b.outstanding_tokens(), 0);
        b.join(0, 3);
        b.join(1, 5);
        assert_eq!(b.outstanding_tokens(), 8);
        b.tick(&[(1.0, 1.0), (1.0, 1.0)]);
        assert_eq!(b.outstanding_tokens(), 6);
    }

    #[test]
    #[should_panic(expected = "not a finite non-negative time")]
    fn tick_rejects_non_finite_costs() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.join(0, 2);
        b.tick(&[(f64::NAN, 1.0)]);
    }

    #[test]
    fn tick_retires_finished_slots() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, ..BatchPolicy::default() });
        b.join(7, 1);
        b.join(8, 2);
        let (_, finished) = b.tick(&[(1.0, 1.0), (1.0, 1.0)]);
        assert_eq!(finished, vec![7]);
        assert_eq!(b.active(), 1);
        let (_, finished) = b.tick(&[(1.0, 1.0)]);
        assert_eq!(finished, vec![8]);
        assert_eq!(b.active(), 0);
    }

    #[test]
    fn tick_pipelines_multi_request_work() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, ..BatchPolicy::default() });
        b.join(0, 10);
        b.join(1, 10);
        b.join(2, 10);
        let (plan, _) = b.tick(&[(10.0, 20.0), (10.0, 20.0), (10.0, 20.0)]);
        assert!(plan.pipelined_ns < plan.serial_ns);
        assert_eq!(plan.order.len(), 3);
    }

    #[test]
    #[should_panic]
    fn tick_requires_matching_costs() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.join(0, 2);
        b.tick(&[]); // wrong arity
    }
}
