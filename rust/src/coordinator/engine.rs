//! The serving engines: the admission queue, continuous batcher, and
//! two-cut-point pipeline scheduler compose into two backends:
//!
//! * **Simulated** — paper-scale models on the CHIME hardware simulator,
//!   virtual time (drives every throughput/latency experiment). This is a
//!   thin wrapper over the single-package case of `ShardedServer`, so the
//!   solo and sharded paths share one scheduling core.
//! * **Functional** — the tiny AOT-compiled MLLM on PJRT, real tokens and
//!   wall-clock time, with simulated CHIME energy attached per request.
//!
//! Python never runs on this path; the functional backend only loads
//! pre-built `artifacts/*.hlo.txt`.

use crate::api::ChimeError;
use crate::config::{ChimeConfig, MllmConfig, WorkloadConfig};
use crate::runtime::FunctionalMllm;
use crate::sim::memory::{DramState, RramState};
use crate::sim::InferenceStats;
use crate::util::Prng;

use std::collections::{BTreeSet, VecDeque};

use super::batcher::BatchPolicy;
use super::metrics::ServingMetrics;
use super::request::{ServeRequest, ServeResponse};
use super::sharded::{RoutePolicy, ServeOutcome, ShardedServer, ShardedSession};
use super::streaming::{self, ServeEvent, ServeProtocol};

/// Virtual-time simulated serving engine (paper-scale models): the
/// single-package deployment of the sharded coordinator.
pub struct SimulatedServer {
    inner: ShardedServer,
}

impl SimulatedServer {
    pub fn new(model: &MllmConfig, cfg: &ChimeConfig, policy: BatchPolicy) -> Self {
        SimulatedServer {
            inner: ShardedServer::new(model, cfg, policy, 1, RoutePolicy::RoundRobin),
        }
    }

    /// Serve a request stream in virtual time. Returns completions in
    /// completion order, requests shed at admission (never silently
    /// dropped), and aggregate metrics.
    pub fn serve(&mut self, requests: Vec<ServeRequest>) -> ServeOutcome {
        self.inner.serve(requests)
    }

    /// Open an event-driven streaming serving session (the sharded
    /// session of the single-package core — DESIGN.md §10).
    pub fn open_serving(&mut self) -> ShardedSession<'_> {
        self.inner.open_serving()
    }

    /// The model this server serves.
    pub fn model(&self) -> &MllmConfig {
        self.inner.model()
    }

    /// The configuration this server was built with.
    pub fn config(&self) -> &ChimeConfig {
        self.inner.config()
    }

    /// One-shot inference on a fresh engine (the `api::Backend` infer
    /// path); serving state is untouched.
    pub fn run_inference_with(&mut self, w: &WorkloadConfig) -> InferenceStats {
        self.inner.run_inference_with(w)
    }

    /// Memory state of the most recent `run_inference_with`.
    pub fn last_infer_memory(&self) -> Option<(&DramState, &RramState)> {
        self.inner.last_infer_memory()
    }

    /// Set the executor worker-thread count for serving drains
    /// (forwarded to the sharded core; DESIGN.md §15).
    pub fn set_threads(&mut self, n: usize) {
        self.inner.set_threads(n);
    }

    /// Serve in free-running wall-clock mode on the parallel executor
    /// (forwarded to the sharded core; DESIGN.md §15).
    pub fn serve_wall_clock(
        &mut self,
        requests: Vec<ServeRequest>,
        threads: usize,
    ) -> crate::exec::WallReport {
        crate::exec::serve_wall_clock(&mut self.inner, requests, threads)
    }

    /// Enable/disable span tracing (forwarded to the sharded core).
    pub fn set_tracing(&mut self, on: bool) {
        self.inner.set_tracing(on);
    }

    /// Enable tracing with wall-clock self-profiling (forwarded).
    pub fn set_profiling(&mut self, on: bool) {
        self.inner.set_profiling(on);
    }

    /// Detach the recorded trace (forwarded to the sharded core).
    pub fn take_trace(&mut self) -> Option<crate::obs::Tracer> {
        self.inner.take_trace()
    }
}

/// One-timebase queueing ledger for a sequential (single-stream) server.
///
/// Arrival timestamps and service durations share the same ns timeline:
/// a request arriving while the stream is busy queues for exactly the
/// stream's backlog; one arriving after the stream drains starts at once.
/// This replaces the pre-fix accounting that subtracted virtual arrivals
/// from wall-clock `Instant::elapsed()` — two unrelated timebases whose
/// difference was meaningless and usually clamped to zero.
#[derive(Debug, Clone, Copy, Default)]
pub struct SequentialTimeline {
    free_ns: f64,
}

impl SequentialTimeline {
    pub fn new() -> Self {
        SequentialTimeline { free_ns: 0.0 }
    }

    /// Queue delay for a request arriving at `arrival_ns` given the work
    /// already accepted onto the stream. Non-negative by construction.
    pub fn begin(&self, arrival_ns: f64) -> f64 {
        (self.free_ns - arrival_ns).max(0.0)
    }

    /// Account `service_ns` of stream time for a request that arrived at
    /// `arrival_ns`; returns the stream's new free timestamp. Idle gaps
    /// (arrival after the stream drained) do not count as backlog.
    pub fn finish(&mut self, arrival_ns: f64, service_ns: f64) -> f64 {
        self.free_ns = self.free_ns.max(arrival_ns) + service_ns;
        self.free_ns
    }
}

/// Functional serving engine: real tokens from the AOT artifacts.
pub struct FunctionalServer {
    pub mllm: FunctionalMllm,
    /// Tiny-model simulator used to attach CHIME energy estimates.
    sim_cfg: ChimeConfig,
}

impl FunctionalServer {
    /// Load the AOT artifacts and bring up the PJRT runtime. Fails with a
    /// typed [`ChimeError::BackendUnavailable`] when the artifacts are
    /// missing or the PJRT backend (vendored stub by default) is off.
    pub fn load(artifacts_dir: &std::path::Path) -> Result<FunctionalServer, ChimeError> {
        let mllm = FunctionalMllm::load(artifacts_dir).map_err(|e| {
            ChimeError::BackendUnavailable { backend: "functional", reason: format!("{e:#}") }
        })?;
        let mut sim_cfg = ChimeConfig::default();
        sim_cfg.workload = WorkloadConfig {
            image_size: mllm.manifest.config.img_size,
            text_tokens: mllm.manifest.config.prompt_len,
            output_tokens: 1, // rescaled per request below
        };
        Ok(FunctionalServer { mllm, sim_cfg })
    }

    /// Deterministic per-request image from the seed.
    pub fn image_for_seed(&self, seed: u64) -> Vec<f32> {
        let c = &self.mllm.manifest.config;
        let n = c.img_size * c.img_size * c.img_channels;
        let mut prng = Prng::new(seed);
        (0..n).map(|_| prng.f32() - 0.5).collect()
    }

    /// Serve requests sequentially (single PJRT stream). Service times are
    /// measured wall-clock; queueing is accounted on the request timeline
    /// via `SequentialTimeline` so both sides of the subtraction share a
    /// timebase. A thin submit-all-then-drain wrapper over
    /// [`FunctionalServer::open_serving`]. Note the legacy tuple return
    /// carries completions + metrics only; requests shed at submission
    /// (non-finite arrivals) are visible through the `api::Backend::serve`
    /// surface, which returns the full `ServeOutcome`.
    pub fn serve(
        &mut self,
        requests: &[ServeRequest],
    ) -> Result<(Vec<ServeResponse>, ServingMetrics), ChimeError> {
        let mut session = self.open_serving();
        for req in requests {
            session.submit(req.clone());
        }
        let out = session.finish()?;
        Ok((out.responses, out.metrics))
    }

    /// Open an event-driven streaming serving session over the single
    /// PJRT stream. Requests are processed one per `tick` in submission
    /// order (the stream is sequential; there is no cross-request
    /// scheduling to reorder). The engine measures per-request phase
    /// totals only, so all of a request's `Token` events carry its
    /// completion timestamp (streaming module docs).
    pub fn open_serving(&mut self) -> FunctionalSession<'_> {
        // Simulated CHIME energy per generated token for the tiny model.
        let mut wcfg = self.sim_cfg.clone();
        wcfg.workload.output_tokens = 8;
        let tiny = MllmConfig::tiny();
        let ref_stats = crate::sim::simulate_with_workload(&tiny, &wcfg, &wcfg.workload);
        let energy_per_token = ref_stats.total_energy_j() / ref_stats.output_tokens as f64;
        FunctionalSession {
            srv: self,
            energy_per_token,
            queue: VecDeque::new(),
            seen: BTreeSet::new(),
            timeline: SequentialTimeline::new(),
            responses: Vec::new(),
            shed: Vec::new(),
            metrics: ServingMetrics::new(),
        }
    }
}

/// One streaming serving session over the sequential PJRT stream
/// (`FunctionalServer::open_serving`).
pub struct FunctionalSession<'a> {
    srv: &'a mut FunctionalServer,
    energy_per_token: f64,
    queue: VecDeque<ServeRequest>,
    seen: BTreeSet<u64>,
    timeline: SequentialTimeline,
    responses: Vec<ServeResponse>,
    shed: Vec<ServeRequest>,
    metrics: ServingMetrics,
}

impl FunctionalSession<'_> {
    /// Enqueue a request on the sequential stream (processed in
    /// submission order; arrivals only drive queueing accounting).
    /// Non-finite arrivals are shed — a NaN would poison the timeline —
    /// and duplicate ids panic, per the protocol contract.
    pub fn submit(&mut self, req: ServeRequest) -> Vec<ServeEvent> {
        let req = match streaming::guard_submission(
            &mut self.seen,
            &mut self.metrics,
            &mut self.shed,
            req,
        ) {
            Ok(req) => req,
            Err(events) => return events,
        };
        self.queue.push_back(req);
        Vec::new()
    }

    /// Run one request end to end on the PJRT stream and emit its event
    /// stream. Empty when the session is idle.
    pub fn tick(&mut self) -> Result<Vec<ServeEvent>, ChimeError> {
        let Some(req) = self.queue.pop_front() else {
            return Ok(Vec::new());
        };
        self.metrics.record_admitted();
        let queue_ns = self.timeline.begin(req.arrival_ns);
        let image = self.srv.image_for_seed(req.image_seed);
        let gen = self.srv.mllm.generate(&image, &req.prompt, req.max_new_tokens)?;
        let service_ns = (gen.encode_ns + gen.prefill_ns + gen.decode_ns) as f64;
        self.timeline.finish(req.arrival_ns, service_ns);
        let resp = ServeResponse {
            id: req.id,
            tokens: gen.tokens.clone(),
            queue_ns,
            ttft_ns: (gen.encode_ns + gen.prefill_ns) as f64,
            service_ns,
            energy_j: self.energy_per_token * gen.tokens.len() as f64,
        };
        self.metrics.record(req.arrival_ns, &resp);
        let events = streaming::sequential_request_events(&req, &resp);
        self.responses.push(resp);
        Ok(events)
    }

    /// Drain the queue and return the outcome: completions in processing
    /// order (the sequential stream *is* the completion order), requests
    /// shed at submission (non-finite arrivals), and merged metrics.
    pub fn finish(mut self) -> Result<ServeOutcome, ChimeError> {
        while !self.tick()?.is_empty() {}
        Ok(self.take_outcome())
    }

    fn take_outcome(&mut self) -> ServeOutcome {
        ServeOutcome {
            responses: std::mem::take(&mut self.responses),
            shed: std::mem::take(&mut self.shed),
            metrics: std::mem::take(&mut self.metrics),
        }
    }
}

impl ServeProtocol for FunctionalSession<'_> {
    fn submit(&mut self, req: ServeRequest) -> Vec<ServeEvent> {
        FunctionalSession::submit(self, req)
    }

    fn tick(&mut self) -> Result<Vec<ServeEvent>, ChimeError> {
        FunctionalSession::tick(self)
    }

    fn finish(&mut self) -> ServeOutcome {
        self.take_outcome()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(n: usize, arrival_gap_ns: f64, tokens: usize) -> Vec<ServeRequest> {
        (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: tokens,
                arrival_ns: i as f64 * arrival_gap_ns,
            })
            .collect()
    }

    #[test]
    fn simulated_server_completes_all() {
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 8;
        let mut srv = SimulatedServer::new(&MllmConfig::fastvlm_0_6b(), &cfg, BatchPolicy::default());
        let out = srv.serve(reqs(6, 1e6, 8));
        assert_eq!(out.responses.len(), 6);
        assert!(out.shed.is_empty());
        assert_eq!(out.metrics.completed, 6);
        assert_eq!(out.metrics.admitted, 6);
        assert_eq!(out.metrics.tokens, 48);
        for r in &out.responses {
            assert!(r.service_ns > 0.0);
            assert!(r.ttft_ns > 0.0);
            assert!(r.energy_j > 0.0);
        }
    }

    #[test]
    fn batching_increases_system_throughput() {
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 16;
        let burst = || reqs(8, 0.0, 16); // all arrive at t=0
        let mut solo = SimulatedServer::new(
            &MllmConfig::mobilevlm_3b(),
            &cfg,
            BatchPolicy { max_batch: 1, ..BatchPolicy::default() },
        );
        let m1 = solo.serve(burst()).metrics;
        let mut batched = SimulatedServer::new(
            &MllmConfig::mobilevlm_3b(),
            &cfg,
            BatchPolicy { max_batch: 4, ..BatchPolicy::default() },
        );
        let m4 = batched.serve(burst()).metrics;
        // Gain is bounded by (D+R)/max(D,R): with the 3B model's FFN-heavy
        // RRAM side the theoretical ceiling is ~1.6x; a short 16-token run
        // with prefill amortization lands lower. Require a real gain.
        assert!(
            m4.tokens_per_s() > m1.tokens_per_s() * 1.05,
            "batch4 {} vs batch1 {}",
            m4.tokens_per_s(),
            m1.tokens_per_s()
        );
    }

    #[test]
    fn queueing_shows_up_under_burst() {
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 4;
        let mut srv = SimulatedServer::new(
            &MllmConfig::fastvlm_0_6b(),
            &cfg,
            BatchPolicy { max_batch: 1, ..BatchPolicy::default() },
        );
        let mut metrics = srv.serve(reqs(5, 0.0, 4)).metrics;
        // With batch 1 and simultaneous arrivals, later requests queue.
        assert!(metrics.mean_queue_ns() > 0.0);
        assert!(metrics.latency_percentile_ns(99.0) > metrics.latency_percentile_ns(10.0));
    }

    #[test]
    fn capacity_one_queue_sheds_but_never_loses_requests() {
        // Regression (silent request loss): pre-fix, `queue.admit(r).ok()`
        // discarded Full/Closed rejections — a shed request vanished with
        // `responses.len() < requests.len()` and no signal anywhere.
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 4;
        let policy = BatchPolicy { max_batch: 1, queue_capacity: 1 };
        let mut srv = SimulatedServer::new(&MllmConfig::fastvlm_0_6b(), &cfg, policy);
        let out = srv.serve(reqs(6, 0.0, 4)); // simultaneous burst
        assert_eq!(
            out.responses.len() + out.shed.len(),
            6,
            "no request may vanish: {} completed + {} shed",
            out.responses.len(),
            out.shed.len()
        );
        assert!(!out.shed.is_empty(), "a capacity-1 queue must shed a burst of 6");
        assert_eq!(out.metrics.rejected, out.shed.len() as u64);
        assert_eq!(out.metrics.completed, out.responses.len() as u64);
        assert_eq!(out.metrics.offered(), 6);
        // Shed requests keep their identity for caller-side retry.
        let mut ids: Vec<u64> = out
            .responses
            .iter()
            .map(|r| r.id)
            .chain(out.shed.iter().map(|r| r.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
    }

    #[test]
    fn zero_token_requests_complete_immediately_with_no_tokens() {
        // Regression: pre-fix, `max_new_tokens.max(1)` silently generated
        // one token for a zero-token request.
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 4;
        let mut srv = SimulatedServer::new(&MllmConfig::fastvlm_0_6b(), &cfg, BatchPolicy::default());
        let mut rs = reqs(3, 1e6, 4);
        rs[1].max_new_tokens = 0;
        let out = srv.serve(rs);
        assert_eq!(out.responses.len(), 3);
        assert!(out.shed.is_empty());
        let zero = out.responses.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(zero.tokens.len(), 0, "zero-token request must produce no tokens");
        assert_eq!(zero.service_ns, 0.0);
        assert_eq!(out.metrics.tokens, 8, "only the two 4-token requests generate");
        assert_eq!(out.metrics.completed, 3);
    }

    #[test]
    fn sequential_timeline_measures_queueing_in_one_timebase() {
        // Regression (timebase mixing): pre-fix, queue_ns subtracted a
        // virtual arrival from wall-clock elapsed — future-dated arrivals
        // clamped to 0 and arrival-0 requests absorbed harness overhead.
        let mut t = SequentialTimeline::new();
        // Three simultaneous arrivals, services 10/20/30 ns: each queues
        // behind exactly the predecessors' service time.
        assert_eq!(t.begin(0.0), 0.0);
        t.finish(0.0, 10.0);
        assert_eq!(t.begin(0.0), 10.0);
        t.finish(0.0, 20.0);
        assert_eq!(t.begin(0.0), 30.0);
        t.finish(0.0, 30.0);
        // A request arriving after the stream drains never queues...
        assert_eq!(t.begin(100.0), 0.0);
        t.finish(100.0, 5.0);
        // ...and the idle gap does not count as backlog for the next one.
        assert_eq!(t.begin(105.0), 0.0);
    }

    #[test]
    fn sequential_timeline_is_never_negative_and_skips_idle_gaps() {
        let mut t = SequentialTimeline::new();
        assert_eq!(t.begin(1e12), 0.0); // far-future arrival, idle stream
        t.finish(1e12, 7.0);
        // A stale arrival pays the full backlog, in the same timebase.
        assert_eq!(t.begin(0.0), 1e12 + 7.0);
    }
}
