//! The serving engine: joins the admission queue, the continuous batcher,
//! the two-cut-point pipeline scheduler, and one of two backends:
//!
//! * **Simulated** — paper-scale models on the CHIME hardware simulator,
//!   virtual time (drives every throughput/latency experiment);
//! * **Functional** — the tiny AOT-compiled MLLM on PJRT, real tokens and
//!   wall-clock time, with simulated CHIME energy attached per request.
//!
//! Python never runs on this path; the functional backend only loads
//! pre-built `artifacts/*.hlo.txt`.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{ChimeConfig, MllmConfig, WorkloadConfig};
use crate::mapping::Plan;
use crate::runtime::FunctionalMllm;
use crate::sim::{PhaseStats, SimEngine};
use crate::util::Prng;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::ServingMetrics;
use super::queue::AdmissionQueue;
use super::request::{ServeRequest, ServeResponse};

/// Virtual-time simulated serving engine (paper-scale models).
pub struct SimulatedServer {
    pub cfg: ChimeConfig,
    pub model: MllmConfig,
    plan: Plan,
    engine: SimEngine,
    policy: BatchPolicy,
    /// §Perf: reusable decode schedule, patched per slot position.
    template: crate::mapping::planner::DecodeTemplate,
}

struct ActiveRequest {
    req: ServeRequest,
    admitted_ns: f64,
    prefill_done_ns: Option<f64>,
    pos: usize,
    produced: usize,
    energy_j: f64,
}

impl SimulatedServer {
    pub fn new(model: &MllmConfig, cfg: &ChimeConfig, policy: BatchPolicy) -> Self {
        let plan = Plan::build(model, &cfg.hardware, &cfg.workload);
        let engine = SimEngine::new(&cfg.hardware, &plan);
        let template = plan.decode_template();
        SimulatedServer { cfg: cfg.clone(), model: model.clone(), plan, engine, policy, template }
    }

    /// Serve a request stream in virtual time. Requests must be sorted by
    /// arrival. Returns completions in finish order + aggregate metrics.
    pub fn serve(&mut self, mut requests: Vec<ServeRequest>) -> (Vec<ServeResponse>, ServingMetrics) {
        requests.sort_by(|a, b| a.arrival_ns.partial_cmp(&b.arrival_ns).unwrap());
        let queue = AdmissionQueue::new(usize::MAX / 2);
        let mut batcher = Batcher::new(self.policy.clone());
        let mut active: BTreeMap<usize, ActiveRequest> = BTreeMap::new();
        let mut responses = Vec::new();
        let mut metrics = ServingMetrics::new();
        let mut clock_ns = 0.0_f64;
        let mut next_arrival = 0usize;
        let mut arrivals: BTreeMap<u64, f64> = BTreeMap::new();

        loop {
            // Admit arrivals that have happened by `clock`.
            while next_arrival < requests.len()
                && requests[next_arrival].arrival_ns <= clock_ns
            {
                let r = requests[next_arrival].clone();
                arrivals.insert(r.id, r.arrival_ns);
                queue.admit(r).ok();
                next_arrival += 1;
            }
            // Fill free slots from the queue.
            while batcher.has_capacity() && !queue.is_empty() {
                let mut batch = queue.try_pop_batch(1);
                if let Some(req) = batch.pop() {
                    let idx = req.id as usize;
                    let tokens = req.max_new_tokens.max(1);
                    batcher.join(idx, tokens + 1); // +1 tick for prefill
                    active.insert(
                        idx,
                        ActiveRequest {
                            admitted_ns: clock_ns.max(req.arrival_ns),
                            req,
                            prefill_done_ns: None,
                            pos: 0,
                            produced: 0,
                            energy_j: 0.0,
                        },
                    );
                }
            }

            if batcher.active() == 0 {
                if next_arrival >= requests.len() {
                    break; // drained
                }
                // Idle: jump to the next arrival.
                clock_ns = clock_ns.max(requests[next_arrival].arrival_ns);
                continue;
            }

            // Price each slot's step on the shared hardware state.
            let slot_ids: Vec<usize> = batcher.slots.iter().map(|s| s.request_idx).collect();
            let mut costs = Vec::with_capacity(slot_ids.len());
            for &idx in &slot_ids {
                let a = active.get_mut(&idx).unwrap();
                let stats: PhaseStats = if a.prefill_done_ns.is_none() {
                    // Encode + prefill as this slot's first "step".
                    let mut s = self.engine.run_kernels(&self.plan.encode_kernels);
                    s.merge(&self.engine.run_kernels(&self.plan.prefill_kernels));
                    s
                } else {
                    let pos = self.plan.trace.prefill_len() + a.pos;
                    self.plan.patch_decode_template(&mut self.template, pos);
                    self.engine.run_kernels(&self.template.kernels)
                };
                a.energy_j += stats.energy.total_joules();
                costs.push((stats.dram_busy_ns, stats.rram_busy_ns + stats.ucie_ns));
            }

            // One pipelined tick across the batch.
            let (plan_tick, finished) = batcher.tick(&costs);
            clock_ns += plan_tick.pipelined_ns;

            // Advance request state.
            for &idx in &slot_ids {
                let a = active.get_mut(&idx).unwrap();
                if a.prefill_done_ns.is_none() {
                    a.prefill_done_ns = Some(clock_ns);
                } else {
                    a.pos += 1;
                    a.produced += 1;
                }
            }
            for idx in finished {
                let a = active.remove(&idx).unwrap();
                let arrival = arrivals[&a.req.id];
                let resp = ServeResponse {
                    id: a.req.id,
                    tokens: vec![0; a.produced],
                    queue_ns: a.admitted_ns - arrival,
                    ttft_ns: a.prefill_done_ns.unwrap_or(clock_ns) - a.admitted_ns,
                    service_ns: clock_ns - a.admitted_ns,
                    energy_j: a.energy_j,
                };
                metrics.record(arrival, &resp);
                responses.push(resp);
            }
        }
        (responses, metrics)
    }
}

/// Functional serving engine: real tokens from the AOT artifacts.
pub struct FunctionalServer {
    pub mllm: FunctionalMllm,
    /// Tiny-model simulator used to attach CHIME energy estimates.
    sim_cfg: ChimeConfig,
}

impl FunctionalServer {
    pub fn load(artifacts_dir: &std::path::Path) -> Result<FunctionalServer> {
        let mllm = FunctionalMllm::load(artifacts_dir)?;
        let mut sim_cfg = ChimeConfig::default();
        sim_cfg.workload = WorkloadConfig {
            image_size: mllm.manifest.config.img_size,
            text_tokens: mllm.manifest.config.prompt_len,
            output_tokens: 1, // rescaled per request below
        };
        Ok(FunctionalServer { mllm, sim_cfg })
    }

    /// Deterministic per-request image from the seed.
    pub fn image_for_seed(&self, seed: u64) -> Vec<f32> {
        let c = &self.mllm.manifest.config;
        let n = c.img_size * c.img_size * c.img_channels;
        let mut prng = Prng::new(seed);
        (0..n).map(|_| prng.f32() - 0.5).collect()
    }

    /// Serve requests sequentially (single PJRT stream), real wall time.
    pub fn serve(&mut self, requests: &[ServeRequest]) -> Result<(Vec<ServeResponse>, ServingMetrics)> {
        let mut responses = Vec::new();
        let mut metrics = ServingMetrics::new();
        let t0 = std::time::Instant::now();
        // Simulated CHIME energy per generated token for the tiny model.
        let mut wcfg = self.sim_cfg.clone();
        wcfg.workload.output_tokens = 8;
        let tiny = MllmConfig::tiny();
        let ref_stats = crate::sim::simulate_with_workload(&tiny, &wcfg, &wcfg.workload);
        let energy_per_token = ref_stats.total_energy_j() / ref_stats.output_tokens as f64;

        for req in requests {
            let now_ns = t0.elapsed().as_nanos() as f64;
            let queue_ns = (now_ns - req.arrival_ns).max(0.0);
            let image = self.image_for_seed(req.image_seed);
            let gen = self.mllm.generate(&image, &req.prompt, req.max_new_tokens)?;
            let resp = ServeResponse {
                id: req.id,
                tokens: gen.tokens.clone(),
                queue_ns,
                ttft_ns: (gen.encode_ns + gen.prefill_ns) as f64,
                service_ns: (gen.encode_ns + gen.prefill_ns + gen.decode_ns) as f64,
                energy_j: energy_per_token * gen.tokens.len() as f64,
            };
            metrics.record(req.arrival_ns, &resp);
            responses.push(resp);
        }
        Ok((responses, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs(n: usize, arrival_gap_ns: f64, tokens: usize) -> Vec<ServeRequest> {
        (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: tokens,
                arrival_ns: i as f64 * arrival_gap_ns,
            })
            .collect()
    }

    #[test]
    fn simulated_server_completes_all() {
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 8;
        let mut srv = SimulatedServer::new(&MllmConfig::fastvlm_0_6b(), &cfg, BatchPolicy::default());
        let (resps, metrics) = srv.serve(reqs(6, 1e6, 8));
        assert_eq!(resps.len(), 6);
        assert_eq!(metrics.completed, 6);
        assert_eq!(metrics.tokens, 48);
        for r in &resps {
            assert!(r.service_ns > 0.0);
            assert!(r.ttft_ns > 0.0);
            assert!(r.energy_j > 0.0);
        }
    }

    #[test]
    fn batching_increases_system_throughput() {
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 16;
        let burst = || reqs(8, 0.0, 16); // all arrive at t=0
        let mut solo = SimulatedServer::new(
            &MllmConfig::mobilevlm_3b(),
            &cfg,
            BatchPolicy { max_batch: 1 },
        );
        let (_, m1) = solo.serve(burst());
        let mut batched = SimulatedServer::new(
            &MllmConfig::mobilevlm_3b(),
            &cfg,
            BatchPolicy { max_batch: 4 },
        );
        let (_, m4) = batched.serve(burst());
        // Gain is bounded by (D+R)/max(D,R): with the 3B model's FFN-heavy
        // RRAM side the theoretical ceiling is ~1.6x; a short 16-token run
        // with prefill amortization lands lower. Require a real gain.
        assert!(
            m4.tokens_per_s() > m1.tokens_per_s() * 1.05,
            "batch4 {} vs batch1 {}",
            m4.tokens_per_s(),
            m1.tokens_per_s()
        );
    }

    #[test]
    fn queueing_shows_up_under_burst() {
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 4;
        let mut srv = SimulatedServer::new(
            &MllmConfig::fastvlm_0_6b(),
            &cfg,
            BatchPolicy { max_batch: 1 },
        );
        let (_, mut metrics) = srv.serve(reqs(5, 0.0, 4));
        // With batch 1 and simultaneous arrivals, later requests queue.
        assert!(metrics.mean_queue_ns() > 0.0);
        assert!(metrics.latency_percentile_ns(99.0) > metrics.latency_percentile_ns(10.0));
    }
}
