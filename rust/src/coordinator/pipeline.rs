//! Two-cut-point pipeline scheduling across concurrent requests.
//!
//! Within one request, a decode step is a strict chain
//! attn(l) -> UCIe -> ffn(l) -> UCIe -> attn(l+1): the two chiplets can
//! never overlap for a single stream (paper §III-C ❶: "Attention(t+1)
//! can start only after the final FFN(t) output"). With *multiple*
//! in-flight requests, however, the DRAM chiplet can run request B's
//! attention while the RRAM chiplet runs request A's FFN — a classic
//! two-machine flow shop. The batcher uses Johnson's rule (optimal for
//! 2-machine flow-shop makespan) to order the decode steps of a tick.

/// One request's per-step work split across the two chiplets (ns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepWork {
    /// Request index (caller-defined handle).
    pub id: usize,
    /// Total DRAM-chiplet time of the step (all layers' attention side).
    pub dram_ns: f64,
    /// Total RRAM-chiplet time of the step (all layers' FFN side).
    pub rram_ns: f64,
}

/// Johnson's rule ordering for a 2-machine flow shop: jobs with
/// dram < rram go first (ascending dram), the rest last (descending rram).
/// Minimizes makespan when every job flows DRAM -> RRAM.
pub fn johnson_order(jobs: &[StepWork]) -> Vec<StepWork> {
    let mut first: Vec<StepWork> = jobs.iter().copied().filter(|j| j.dram_ns < j.rram_ns).collect();
    let mut second: Vec<StepWork> = jobs.iter().copied().filter(|j| j.dram_ns >= j.rram_ns).collect();
    first.sort_by(|a, b| a.dram_ns.partial_cmp(&b.dram_ns).unwrap());
    second.sort_by(|a, b| b.rram_ns.partial_cmp(&a.rram_ns).unwrap());
    first.extend(second);
    first
}

/// Flow-shop makespan for a given order: machine 1 = DRAM chiplet,
/// machine 2 = RRAM chiplet, every job visits DRAM then RRAM.
pub fn makespan(order: &[StepWork]) -> f64 {
    let mut dram_free = 0.0_f64;
    let mut rram_free = 0.0_f64;
    for j in order {
        dram_free += j.dram_ns;
        rram_free = dram_free.max(rram_free) + j.rram_ns;
    }
    rram_free
}

/// Serial (non-pipelined) execution time — the single-request lower bound
/// and the DRAM-only behaviour.
pub fn serial_time(jobs: &[StepWork]) -> f64 {
    jobs.iter().map(|j| j.dram_ns + j.rram_ns).sum()
}

/// Schedule one decode tick: Johnson-order the jobs, return
/// (ordered jobs, pipelined makespan, serial time).
pub fn schedule_tick(jobs: &[StepWork]) -> (Vec<StepWork>, f64, f64) {
    let order = johnson_order(jobs);
    let span = makespan(&order);
    let serial = serial_time(jobs);
    (order, span, serial)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn j(id: usize, d: f64, r: f64) -> StepWork {
        StepWork { id, dram_ns: d, rram_ns: r }
    }

    #[test]
    fn single_job_no_overlap() {
        let jobs = [j(0, 10.0, 20.0)];
        let (_, span, serial) = schedule_tick(&jobs);
        assert_eq!(span, 30.0);
        assert_eq!(serial, 30.0);
    }

    #[test]
    fn two_jobs_overlap() {
        let jobs = [j(0, 10.0, 20.0), j(1, 10.0, 20.0)];
        let (_, span, serial) = schedule_tick(&jobs);
        assert_eq!(serial, 60.0);
        // Job 1's DRAM work hides under job 0's RRAM work.
        assert_eq!(span, 10.0 + 20.0 + 20.0);
    }

    #[test]
    fn johnson_beats_or_equals_any_fixed_order() {
        // Classic example where ordering matters.
        let jobs = [j(0, 5.0, 2.0), j(1, 1.0, 6.0), j(2, 9.0, 7.0), j(3, 3.0, 8.0), j(4, 10.0, 4.0)];
        let (order, span, _) = schedule_tick(&jobs);
        // Exhaustive check over all permutations (5! = 120).
        let mut best = f64::INFINITY;
        let mut idx: Vec<usize> = (0..jobs.len()).collect();
        permutohedron_heap(&mut idx, &mut |perm: &[usize]| {
            let o: Vec<StepWork> = perm.iter().map(|&i| jobs[i]).collect();
            best = best.min(makespan(&o));
        });
        assert!((span - best).abs() < 1e-9, "johnson {span} vs optimal {best}");
        // Johnson's first group is ascending by dram.
        assert_eq!(order[0].id, 1);
    }

    // Minimal Heap's-algorithm permutation helper for the test.
    fn permutohedron_heap(idx: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
        fn heap(k: usize, a: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
            if k == 1 {
                f(a);
                return;
            }
            for i in 0..k {
                heap(k - 1, a, f);
                if k % 2 == 0 {
                    a.swap(i, k - 1);
                } else {
                    a.swap(0, k - 1);
                }
            }
        }
        let n = idx.len();
        heap(n, idx, f);
    }

    #[test]
    fn pipelining_bounded_by_bottleneck_machine() {
        let jobs: Vec<StepWork> = (0..16).map(|i| j(i, 3.0, 7.0)).collect();
        let (_, span, serial) = schedule_tick(&jobs);
        // Long pipeline: makespan -> first dram + sum(rram).
        assert!((span - (3.0 + 16.0 * 7.0)).abs() < 1e-9);
        assert!(span < serial);
    }
}
