//! Two-cut-point pipeline scheduling across concurrent requests.
//!
//! Within one request, a decode step is a strict chain
//! attn(l) -> UCIe -> ffn(l) -> UCIe -> attn(l+1): the two chiplets can
//! never overlap for a single stream (paper §III-C ❶: "Attention(t+1)
//! can start only after the final FFN(t) output"). With *multiple*
//! in-flight requests, however, the DRAM chiplet can run request B's
//! attention while the RRAM chiplet runs request A's FFN — a classic
//! two-machine flow shop. The batcher uses Johnson's rule (optimal for
//! 2-machine flow-shop makespan) to order the decode steps of a tick.
//!
//! With multiple *packages* (DRAM+RRAM machine pairs), each package is an
//! independent flow shop: `schedule_dispatch` Johnson-orders every
//! package's tick and reports the cross-package step span (packages run
//! concurrently, so the dispatch step drains when the slowest one does).

/// One request's per-step work split across the two chiplets (ns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepWork {
    /// Request index (caller-defined handle).
    pub id: usize,
    /// Total DRAM-chiplet time of the step (all layers' attention side).
    pub dram_ns: f64,
    /// Total RRAM-chiplet time of the step (all layers' FFN side).
    pub rram_ns: f64,
}

impl StepWork {
    /// Build a job, rejecting non-finite or negative chiplet costs: a NaN
    /// cost would poison the Johnson ordering and the makespan recurrence
    /// silently, so the invariant is enforced at the construction boundary.
    pub fn new(id: usize, dram_ns: f64, rram_ns: f64) -> StepWork {
        assert!(
            dram_ns.is_finite() && dram_ns >= 0.0,
            "job {id}: dram cost {dram_ns} is not a finite non-negative time"
        );
        assert!(
            rram_ns.is_finite() && rram_ns >= 0.0,
            "job {id}: rram cost {rram_ns} is not a finite non-negative time"
        );
        StepWork { id, dram_ns, rram_ns }
    }
}

/// Johnson's rule ordering for a 2-machine flow shop: jobs with
/// dram < rram go first (ascending dram), the rest last (descending rram).
/// Minimizes makespan when every job flows DRAM -> RRAM. Total-order
/// comparisons keep this panic-free on any float input; ties keep the
/// caller's order (stable sort), so equal-cost jobs stay deterministic.
pub fn johnson_order(jobs: &[StepWork]) -> Vec<StepWork> {
    // Exhaustive partition (predicate true/false), so a NaN-cost job can
    // never fall out of both halves the way `a < b` / `a >= b` filters did.
    let (mut first, mut second): (Vec<StepWork>, Vec<StepWork>) =
        jobs.iter().copied().partition(|j| j.dram_ns < j.rram_ns);
    first.sort_by(|a, b| a.dram_ns.total_cmp(&b.dram_ns));
    second.sort_by(|a, b| b.rram_ns.total_cmp(&a.rram_ns));
    first.extend(second);
    first
}

/// Flow-shop makespan for a given order: machine 1 = DRAM chiplet,
/// machine 2 = RRAM chiplet, every job visits DRAM then RRAM.
pub fn makespan(order: &[StepWork]) -> f64 {
    let mut dram_free = 0.0_f64;
    let mut rram_free = 0.0_f64;
    for j in order {
        dram_free += j.dram_ns;
        rram_free = dram_free.max(rram_free) + j.rram_ns;
    }
    rram_free
}

/// Serial (non-pipelined) execution time — the single-request lower bound
/// and the DRAM-only behaviour.
pub fn serial_time(jobs: &[StepWork]) -> f64 {
    jobs.iter().map(|j| j.dram_ns + j.rram_ns).sum()
}

/// Schedule one decode tick: Johnson-order the jobs, return
/// (ordered jobs, pipelined makespan, serial time).
pub fn schedule_tick(jobs: &[StepWork]) -> (Vec<StepWork>, f64, f64) {
    let order = johnson_order(jobs);
    let span = makespan(&order);
    let serial = serial_time(jobs);
    (order, span, serial)
}

/// One package's scheduled tick inside a cross-package dispatch step.
#[derive(Debug, Clone)]
pub struct PackageTick {
    /// Package index the jobs were routed to.
    pub package: usize,
    /// Johnson-ordered jobs for this package's flow shop.
    pub order: Vec<StepWork>,
    /// Pipelined makespan of this package's tick (ns).
    pub pipelined_ns: f64,
    /// Serial (non-pipelined) time of this package's jobs (ns).
    pub serial_ns: f64,
}

/// A scheduled dispatch step across N independent packages.
#[derive(Debug, Clone)]
pub struct DispatchStep {
    pub ticks: Vec<PackageTick>,
    /// Step span: packages run concurrently, so the dispatch step drains
    /// when the slowest package's flow shop does (max of package spans).
    pub makespan_ns: f64,
    /// What one package would pay running every job serially — the
    /// no-pipelining, no-sharding reference time.
    pub serial_ns: f64,
}

/// Generalize the 2-machine flow shop to per-package machine pairs: each
/// package's jobs are Johnson-ordered independently (packages share no
/// chiplet, so their flow shops never interact), and the step's span is
/// the slowest package. `per_package[p]` holds the jobs routed to package
/// `p` this tick; empty packages contribute zero time.
///
/// This is the *lockstep reference* model — what one globally
/// synchronized dispatch step would cost — used by benches and tests to
/// quantify a sharding decision in isolation. The serving engine
/// (`coordinator::sharded`) deliberately does NOT run packages in
/// lockstep: its event-ordered loop lets each package tick at its own
/// rate, which strictly dominates this bound.
pub fn schedule_dispatch(per_package: &[Vec<StepWork>]) -> DispatchStep {
    let mut ticks = Vec::with_capacity(per_package.len());
    let mut makespan_ns = 0.0_f64;
    let mut serial_ns = 0.0_f64;
    for (package, jobs) in per_package.iter().enumerate() {
        let (order, pipelined, serial) = schedule_tick(jobs);
        makespan_ns = makespan_ns.max(pipelined);
        serial_ns += serial;
        ticks.push(PackageTick { package, order, pipelined_ns: pipelined, serial_ns: serial });
    }
    DispatchStep { ticks, makespan_ns, serial_ns }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn j(id: usize, d: f64, r: f64) -> StepWork {
        StepWork { id, dram_ns: d, rram_ns: r }
    }

    #[test]
    fn single_job_no_overlap() {
        let jobs = [j(0, 10.0, 20.0)];
        let (_, span, serial) = schedule_tick(&jobs);
        assert_eq!(span, 30.0);
        assert_eq!(serial, 30.0);
    }

    #[test]
    fn two_jobs_overlap() {
        let jobs = [j(0, 10.0, 20.0), j(1, 10.0, 20.0)];
        let (_, span, serial) = schedule_tick(&jobs);
        assert_eq!(serial, 60.0);
        // Job 1's DRAM work hides under job 0's RRAM work.
        assert_eq!(span, 10.0 + 20.0 + 20.0);
    }

    #[test]
    fn johnson_beats_or_equals_any_fixed_order() {
        // Classic example where ordering matters.
        let jobs = [j(0, 5.0, 2.0), j(1, 1.0, 6.0), j(2, 9.0, 7.0), j(3, 3.0, 8.0), j(4, 10.0, 4.0)];
        let (order, span, _) = schedule_tick(&jobs);
        // Exhaustive check over all permutations (5! = 120).
        let mut best = f64::INFINITY;
        let mut idx: Vec<usize> = (0..jobs.len()).collect();
        permutohedron_heap(&mut idx, &mut |perm: &[usize]| {
            let o: Vec<StepWork> = perm.iter().map(|&i| jobs[i]).collect();
            best = best.min(makespan(&o));
        });
        assert!((span - best).abs() < 1e-9, "johnson {span} vs optimal {best}");
        // Johnson's first group is ascending by dram.
        assert_eq!(order[0].id, 1);
    }

    // Minimal Heap's-algorithm permutation helper for the test.
    fn permutohedron_heap(idx: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
        fn heap(k: usize, a: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
            if k == 1 {
                f(a);
                return;
            }
            for i in 0..k {
                heap(k - 1, a, f);
                if k % 2 == 0 {
                    a.swap(i, k - 1);
                } else {
                    a.swap(0, k - 1);
                }
            }
        }
        let n = idx.len();
        heap(n, idx, f);
    }

    #[test]
    fn pipelining_bounded_by_bottleneck_machine() {
        let jobs: Vec<StepWork> = (0..16).map(|i| j(i, 3.0, 7.0)).collect();
        let (_, span, serial) = schedule_tick(&jobs);
        // Long pipeline: makespan -> first dram + sum(rram).
        assert!((span - (3.0 + 16.0 * 7.0)).abs() < 1e-9);
        assert!(span < serial);
    }

    #[test]
    fn nan_costs_do_not_panic_or_drop_jobs() {
        // Regression: partial_cmp().unwrap() panicked on NaN, and the old
        // `dram >= rram` partition silently dropped NaN jobs from both
        // halves. johnson_order must stay total and permutation-preserving.
        let jobs = [
            j(0, f64::NAN, 1.0),
            j(1, 2.0, f64::NAN),
            j(2, 1.0, 3.0),
            j(3, f64::NAN, f64::NAN),
        ];
        let order = johnson_order(&jobs);
        let mut ids: Vec<usize> = order.iter().map(|x| x.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3], "NaN jobs must not be lost");
    }

    #[test]
    #[should_panic(expected = "not a finite non-negative time")]
    fn step_work_rejects_nan_at_construction() {
        StepWork::new(0, f64::NAN, 1.0);
    }

    #[test]
    #[should_panic(expected = "not a finite non-negative time")]
    fn step_work_rejects_infinite_rram_cost() {
        StepWork::new(0, 1.0, f64::INFINITY);
    }

    #[test]
    fn tied_costs_keep_stable_deterministic_order() {
        // dram == rram ties land in the second group; equal keys must keep
        // input order (stable sort) so scheduling stays deterministic.
        let jobs = [j(0, 5.0, 5.0), j(1, 5.0, 5.0), j(2, 5.0, 5.0)];
        let order = johnson_order(&jobs);
        assert_eq!(order.iter().map(|x| x.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(makespan(&order), 5.0 + 3.0 * 5.0);
    }

    #[test]
    fn dispatch_step_spans_slowest_package() {
        // Two packages: pkg0 has the heavy tick, pkg1 the light one.
        let per_pkg = vec![
            vec![j(0, 10.0, 20.0), j(1, 10.0, 20.0)],
            vec![j(2, 1.0, 2.0)],
        ];
        let step = schedule_dispatch(&per_pkg);
        assert_eq!(step.ticks.len(), 2);
        assert_eq!(step.ticks[0].package, 0);
        // pkg0: 10 + 20 + 20 = 50; pkg1: 3. Step = slowest package.
        assert!((step.ticks[0].pipelined_ns - 50.0).abs() < 1e-9);
        assert!((step.ticks[1].pipelined_ns - 3.0).abs() < 1e-9);
        assert!((step.makespan_ns - 50.0).abs() < 1e-9);
        // Serial reference = all jobs on one pair, no pipelining.
        assert!((step.serial_ns - (60.0 + 3.0)).abs() < 1e-9);
    }

    #[test]
    fn dispatch_handles_empty_packages() {
        let step = schedule_dispatch(&[Vec::new(), vec![j(0, 4.0, 6.0)]]);
        assert_eq!(step.ticks[0].order.len(), 0);
        assert_eq!(step.ticks[0].pipelined_ns, 0.0);
        assert!((step.makespan_ns - 10.0).abs() < 1e-9);
    }

    #[test]
    fn sharding_scales_a_saturated_tick() {
        // 8 identical jobs on 1 package vs split 4/4 across 2: the step
        // span must drop by ~2x (each package is an independent flow shop).
        let jobs: Vec<StepWork> = (0..8).map(|i| j(i, 3.0, 7.0)).collect();
        let one = schedule_dispatch(&[jobs.clone()]);
        let two = schedule_dispatch(&[jobs[..4].to_vec(), jobs[4..].to_vec()]);
        assert!(
            two.makespan_ns < one.makespan_ns / 1.5,
            "2-package dispatch {:.1} vs 1-package {:.1}",
            two.makespan_ns,
            one.makespan_ns
        );
    }
}
