//! Serving request/response types for the CHIME coordinator.

/// An inbound VQA request.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    /// Prompt token ids (functional path uses them; timing path uses the
    /// length).
    pub prompt: Vec<i32>,
    /// Deterministic image seed; the functional engine synthesizes pixels
    /// from it so requests differ without shipping real images.
    pub image_seed: u64,
    pub max_new_tokens: usize,
    /// Arrival timestamp (ns, virtual or wall clock per engine mode).
    pub arrival_ns: f64,
}

/// Completion record for one request.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time from arrival to admission (queueing).
    pub queue_ns: f64,
    /// Time to first token (encode + prefill after admission).
    pub ttft_ns: f64,
    /// Total service time (admission -> last token).
    pub service_ns: f64,
    /// Simulated energy for the request (J; 0 in functional-only mode).
    pub energy_j: f64,
}

impl ServeRequest {
    /// A saturating burst for timing-path experiments: `n` requests, all
    /// arriving at t=0 with a `tokens` decode budget each, ids and image
    /// seeds 0..n, no prompt tokens (the simulated path prices prompts
    /// from the plan's workload, not the request).
    pub fn burst(n: usize, tokens: usize) -> Vec<ServeRequest> {
        (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: tokens,
                arrival_ns: 0.0,
            })
            .collect()
    }
}

impl ServeResponse {
    pub fn total_latency_ns(&self) -> f64 {
        self.queue_ns + self.service_ns
    }

    pub fn decode_tps(&self) -> f64 {
        if self.service_ns <= self.ttft_ns || self.tokens.is_empty() {
            return 0.0;
        }
        self.tokens.len() as f64 / ((self.service_ns - self.ttft_ns) / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_accounting() {
        let r = ServeResponse {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            queue_ns: 100.0,
            ttft_ns: 50.0,
            service_ns: 250.0,
            energy_j: 0.0,
        };
        assert_eq!(r.total_latency_ns(), 350.0);
        let tps = r.decode_tps();
        assert!((tps - 4.0 / (200.0 / 1e9)).abs() < 1e-3);
    }
}
