//! Serving request/response types for the CHIME coordinator.

/// An inbound VQA request.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: u64,
    /// Prompt token ids (functional path uses them; timing path uses the
    /// length).
    pub prompt: Vec<i32>,
    /// Deterministic image seed; the functional engine synthesizes pixels
    /// from it so requests differ without shipping real images.
    pub image_seed: u64,
    pub max_new_tokens: usize,
    /// Arrival timestamp (ns, virtual or wall clock per engine mode).
    pub arrival_ns: f64,
}

/// Completion record for one request.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time from arrival to admission (queueing).
    pub queue_ns: f64,
    /// Time to first token (encode + prefill after admission).
    pub ttft_ns: f64,
    /// Total service time (admission -> last token).
    pub service_ns: f64,
    /// Simulated energy for the request (J; 0 in functional-only mode).
    pub energy_j: f64,
}

impl ServeRequest {
    /// A saturating burst for timing-path experiments: `n` requests, all
    /// arriving at t=0 with a `tokens` decode budget each, ids and image
    /// seeds 0..n, no prompt tokens (the simulated path prices prompts
    /// from the plan's workload, not the request).
    pub fn burst(n: usize, tokens: usize) -> Vec<ServeRequest> {
        (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: tokens,
                arrival_ns: 0.0,
            })
            .collect()
    }
}

impl ServeResponse {
    /// Arrival-to-completion latency (queueing + service).
    ///
    /// Edge contract: a zero-token completion has no schedulable work and
    /// completes at its arrival, so `queue_ns == ttft_ns == service_ns ==
    /// 0` and the total latency is exactly `0`. Shed requests never get a
    /// `ServeResponse` at all — they come back as `ServeOutcome::shed`
    /// and are excluded from every latency statistic.
    pub fn total_latency_ns(&self) -> f64 {
        self.queue_ns + self.service_ns
    }

    /// Decode-phase span: first-token instant to completion, ns.
    pub fn decode_span_ns(&self) -> f64 {
        (self.service_ns - self.ttft_ns).max(0.0)
    }

    /// Time per output token over the decode phase (the serving-tail
    /// "TPOT" metric), ns/token. Zero-token completions have no decode
    /// phase and report `0.0`.
    pub fn tpot_ns(&self) -> f64 {
        if self.tokens.is_empty() {
            return 0.0;
        }
        self.decode_span_ns() / self.tokens.len() as f64
    }

    /// Steady decode rate, tokens/s.
    ///
    /// Edge contract (previously a silent `0.0` in both cases):
    /// * zero-token completions have no decode phase — `0.0` (there is
    ///   no rate to report, and `0` cannot be mistaken for a throughput
    ///   because no tokens exist);
    /// * a completion with tokens but zero decode span (`service_ns ==
    ///   ttft_ns`, e.g. a degenerate analytic baseline price) decoded
    ///   instantaneously — `f64::INFINITY`, which is the honest limit,
    ///   instead of a `0.0` that silently understates an infinitely fast
    ///   decode as an infinitely slow one.
    pub fn decode_tps(&self) -> f64 {
        if self.tokens.is_empty() {
            return 0.0;
        }
        let span = self.decode_span_ns();
        if span <= 0.0 {
            return f64::INFINITY;
        }
        self.tokens.len() as f64 / (span / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_accounting() {
        let r = ServeResponse {
            id: 1,
            tokens: vec![1, 2, 3, 4],
            queue_ns: 100.0,
            ttft_ns: 50.0,
            service_ns: 250.0,
            energy_j: 0.0,
        };
        assert_eq!(r.total_latency_ns(), 350.0);
        assert_eq!(r.decode_span_ns(), 200.0);
        assert_eq!(r.tpot_ns(), 50.0);
        let tps = r.decode_tps();
        assert!((tps - 4.0 / (200.0 / 1e9)).abs() < 1e-3);
    }

    #[test]
    fn zero_token_completion_reports_zero_everything() {
        // Contract: no schedulable work -> completes at arrival with zero
        // latency, zero decode span, and a 0.0 (not NaN) rate.
        let r = ServeResponse {
            id: 0,
            tokens: vec![],
            queue_ns: 0.0,
            ttft_ns: 0.0,
            service_ns: 0.0,
            energy_j: 0.0,
        };
        assert_eq!(r.total_latency_ns(), 0.0);
        assert_eq!(r.decode_span_ns(), 0.0);
        assert_eq!(r.tpot_ns(), 0.0);
        assert_eq!(r.decode_tps(), 0.0);
    }

    #[test]
    fn instantaneous_decode_reports_infinity_not_zero() {
        // Regression: a service_ns == ttft_ns completion with tokens used
        // to silently report 0 tps — indistinguishable from "no decode".
        let r = ServeResponse {
            id: 1,
            tokens: vec![0, 0],
            queue_ns: 5.0,
            ttft_ns: 100.0,
            service_ns: 100.0,
            energy_j: 0.0,
        };
        assert_eq!(r.decode_span_ns(), 0.0);
        assert_eq!(r.tpot_ns(), 0.0);
        assert!(r.decode_tps().is_infinite() && r.decode_tps() > 0.0);
        // And service slightly *below* ttft (float noise) clamps, not
        // negates.
        let r2 = ServeResponse { service_ns: 99.9999, ..r };
        assert_eq!(r2.decode_span_ns(), 0.0);
        assert!(r2.decode_tps().is_infinite());
    }
}
