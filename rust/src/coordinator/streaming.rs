//! Event-driven streaming serving protocol.
//!
//! The batch call `serve(Vec<ServeRequest>)` forces closed-loop
//! experiments: every arrival is known up front and the caller sees one
//! aggregate outcome at the end. Production-shaped LLM serving is
//! open-loop — requests arrive continuously, tokens stream out
//! incrementally, and admission/backpressure decisions happen per event.
//! This module defines that seam:
//!
//! * [`ServeEvent`] — the typed event stream a serving engine emits:
//!   `Admitted`, `Rejected`, `Shed`, `FirstToken`, `Token`, `Completed`,
//!   plus `Stolen` for cross-package work stealing.
//! * [`ServeProtocol`] — the engine-side protocol: `submit` a request at
//!   any virtual time, `tick` to advance the engine by one event, and
//!   `finish` to collect the accumulated [`ServeOutcome`]. Implemented by
//!   the sharded coordinator, the functional PJRT engine, and the
//!   baseline adapters.
//! * [`ServingSession`] — the caller-facing handle (a boxed
//!   [`ServeProtocol`]) returned by `api::Backend::open_serving`, with
//!   `drain`/`finish` conveniences.
//!
//! The legacy batch call is a thin drain-everything wrapper over this
//! protocol (`api::Backend::serve` is a provided trait method), so the
//! two surfaces can never drift: one engine, two entry points.
//!
//! ## Event contract
//!
//! For every submitted request, exactly one of `Admitted`, `Rejected`
//! (admission backpressure: every queue full at arrival), or `Shed`
//! (unschedulable: non-finite arrival timestamp) is emitted. An admitted
//! request with a non-zero token budget then emits one `FirstToken`
//! (marking TTFT — end of encode+prefill), `max_new_tokens` `Token`
//! events with monotone indices, and one `Completed`. A zero-token
//! request completes immediately at its arrival: `Admitted` then
//! `Completed`, no token events. No event ever precedes the request's
//! arrival time, and each request's own events are causally ordered.
//! The *global* stream is ordered by event processing, not by timestamp:
//! a tick's events carry the tick's end time while the loop picks work
//! by earliest start time, so events of different requests may
//! interleave with non-monotone timestamps. Sequential single-stream
//! engines (functional PJRT, Jetson/FACIL baselines) measure only
//! per-request phase totals, so they emit all of a request's `Token`
//! events at its completion timestamp rather than an interpolated
//! intra-request timeline.

use std::collections::{BTreeSet, BinaryHeap};

use crate::api::ChimeError;
use crate::util::Json;

use super::metrics::ServingMetrics;
use super::request::{ServeRequest, ServeResponse};
use super::sharded::ServeOutcome;

/// One typed event from a streaming serving engine. Times are in the
/// engine's timebase (virtual ns for the simulator backends; the request
/// timeline for the wall-clock engines).
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// The request passed admission. `package` is the DRAM+RRAM package
    /// it was queued on; `None` for inline completions (zero-token
    /// requests never touch a package) and single-stream engines.
    Admitted {
        /// Request id.
        id: u64,
        /// Admission time (the request's arrival).
        time_ns: f64,
        /// Package the request was queued on, when one exists.
        package: Option<usize>,
    },
    /// Admission backpressure: every package queue was full at arrival.
    /// The request is handed back — never silently dropped.
    Rejected {
        /// The rejected request, returned to the caller.
        request: ServeRequest,
        /// Rejection time (the request's arrival).
        time_ns: f64,
    },
    /// The request can never be scheduled (non-finite arrival timestamp);
    /// it is shed at submission, before entering the event loop.
    Shed {
        /// The unschedulable request, returned to the caller.
        request: ServeRequest,
    },
    /// Encode+prefill finished — the TTFT instant for this request.
    FirstToken {
        /// Request id.
        id: u64,
        /// Time the first token is available.
        time_ns: f64,
    },
    /// One decode token was produced.
    Token {
        /// Request id.
        id: u64,
        /// Zero-based token index within the request.
        index: usize,
        /// Time the token was produced.
        time_ns: f64,
    },
    /// The request finished; carries the full completion record.
    Completed {
        /// The request's arrival time (keyed for completion-order merges).
        arrival_ns: f64,
        /// Completion time (`arrival_ns` + total latency).
        time_ns: f64,
        /// The completion record.
        response: ServeResponse,
    },
    /// Work stealing moved a queued request from a loaded package to an
    /// idle one (emitted only with stealing enabled).
    Stolen {
        /// Request id.
        id: u64,
        /// Package the request was queued on.
        from: usize,
        /// Idle package that took it.
        to: usize,
        /// Payload the steal moved across the fabric (request metadata +
        /// prompt tokens + per-token KV context), in bytes.
        bytes: u64,
        /// Steal time.
        time_ns: f64,
    },
}

impl ServeEvent {
    /// The request id this event concerns.
    pub fn id(&self) -> u64 {
        match self {
            ServeEvent::Admitted { id, .. }
            | ServeEvent::FirstToken { id, .. }
            | ServeEvent::Token { id, .. }
            | ServeEvent::Stolen { id, .. } => *id,
            ServeEvent::Rejected { request, .. } | ServeEvent::Shed { request } => request.id,
            ServeEvent::Completed { response, .. } => response.id,
        }
    }

    /// The event's timestamp, when it has a meaningful one (`Shed`
    /// requests carry a non-finite arrival and no event time).
    pub fn time_ns(&self) -> Option<f64> {
        match self {
            ServeEvent::Admitted { time_ns, .. }
            | ServeEvent::Rejected { time_ns, .. }
            | ServeEvent::FirstToken { time_ns, .. }
            | ServeEvent::Token { time_ns, .. }
            | ServeEvent::Completed { time_ns, .. }
            | ServeEvent::Stolen { time_ns, .. } => Some(*time_ns),
            ServeEvent::Shed { .. } => None,
        }
    }

    /// Short kind tag for logs and tables.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeEvent::Admitted { .. } => "admitted",
            ServeEvent::Rejected { .. } => "rejected",
            ServeEvent::Shed { .. } => "shed",
            ServeEvent::FirstToken { .. } => "first-token",
            ServeEvent::Token { .. } => "token",
            ServeEvent::Completed { .. } => "completed",
            ServeEvent::Stolen { .. } => "stolen",
        }
    }

    /// Wire form of the event — the SSE `data:` payload of the network
    /// serving front end (DESIGN.md §13). Every variant carries its
    /// `kind` tag; `Completed` flattens the [`ServeResponse`] record
    /// (token count rather than the token ids — the ids are synthetic).
    /// `Shed` omits its arrival: shed arrivals are non-finite by
    /// construction and have no JSON spelling.
    pub fn to_json(&self) -> Json {
        match self {
            ServeEvent::Admitted { id, time_ns, package } => Json::obj(vec![
                ("kind", self.kind().into()),
                ("id", (*id as i64).into()),
                ("time_ns", (*time_ns).into()),
                ("package", package.map_or(Json::Null, Json::from)),
            ]),
            ServeEvent::Rejected { request, time_ns } => Json::obj(vec![
                ("kind", self.kind().into()),
                ("id", (request.id as i64).into()),
                ("time_ns", (*time_ns).into()),
                ("max_new_tokens", request.max_new_tokens.into()),
            ]),
            ServeEvent::Shed { request } => Json::obj(vec![
                ("kind", self.kind().into()),
                ("id", (request.id as i64).into()),
                ("max_new_tokens", request.max_new_tokens.into()),
            ]),
            ServeEvent::FirstToken { id, time_ns } => Json::obj(vec![
                ("kind", self.kind().into()),
                ("id", (*id as i64).into()),
                ("time_ns", (*time_ns).into()),
            ]),
            ServeEvent::Token { id, index, time_ns } => Json::obj(vec![
                ("kind", self.kind().into()),
                ("id", (*id as i64).into()),
                ("index", (*index).into()),
                ("time_ns", (*time_ns).into()),
            ]),
            ServeEvent::Completed { arrival_ns, time_ns, response } => Json::obj(vec![
                ("kind", self.kind().into()),
                ("id", (response.id as i64).into()),
                ("arrival_ns", (*arrival_ns).into()),
                ("time_ns", (*time_ns).into()),
                ("tokens", response.tokens.len().into()),
                ("queue_ns", response.queue_ns.into()),
                ("ttft_ns", response.ttft_ns.into()),
                ("service_ns", response.service_ns.into()),
                ("energy_j", response.energy_j.into()),
            ]),
            ServeEvent::Stolen { id, from, to, bytes, time_ns } => Json::obj(vec![
                ("kind", self.kind().into()),
                ("id", (*id as i64).into()),
                ("from", (*from).into()),
                ("to", (*to).into()),
                ("bytes", (*bytes as i64).into()),
                ("time_ns", (*time_ns).into()),
            ]),
        }
    }
}

/// The engine-side streaming protocol. Object-safe: `api::Backend`
/// returns implementations boxed inside a [`ServingSession`].
pub trait ServeProtocol {
    /// Submit a request at any virtual time. May emit immediate events
    /// (e.g. [`ServeEvent::Shed`] for a non-finite arrival). Panics on a
    /// duplicate request id within one session — ids key batch slots, and
    /// a collision would corrupt accounting mid-flight.
    fn submit(&mut self, req: ServeRequest) -> Vec<ServeEvent>;

    /// Advance the engine by one event (an arrival decision or one
    /// engine step) and return the events it produced. An empty vector
    /// means the session is idle: nothing pending and nothing in flight.
    fn tick(&mut self) -> Result<Vec<ServeEvent>, ChimeError>;

    /// Take the accumulated outcome (completions in global completion
    /// order, shed requests, merged metrics). Call after draining; the
    /// [`ServingSession`] wrapper enforces this by consuming itself.
    fn finish(&mut self) -> ServeOutcome;

    /// Live engine-side telemetry — per-link fabric counters and memory
    /// stall totals — for mid-run export (the net server's Prometheus
    /// endpoint, DESIGN.md §14). `None` (the default) for engines
    /// without a simulated fabric: the functional PJRT runtime and the
    /// analytic baselines.
    fn telemetry(&self) -> Option<crate::obs::EngineTelemetry> {
        None
    }
}

/// Caller-facing handle for one streaming serving session, returned by
/// `api::Backend::open_serving`. Dropping a session without finishing it
/// discards its in-flight requests; the engine resets on the next open.
pub struct ServingSession<'a> {
    inner: Box<dyn ServeProtocol + 'a>,
}

impl<'a> ServingSession<'a> {
    /// Wrap an engine-side protocol implementation.
    pub fn new(inner: Box<dyn ServeProtocol + 'a>) -> ServingSession<'a> {
        ServingSession { inner }
    }

    /// Submit a request (see [`ServeProtocol::submit`]).
    pub fn submit(&mut self, req: ServeRequest) -> Vec<ServeEvent> {
        self.inner.submit(req)
    }

    /// Advance by one event (see [`ServeProtocol::tick`]).
    pub fn tick(&mut self) -> Result<Vec<ServeEvent>, ChimeError> {
        self.inner.tick()
    }

    /// Live engine telemetry (see [`ServeProtocol::telemetry`]).
    pub fn telemetry(&self) -> Option<crate::obs::EngineTelemetry> {
        self.inner.telemetry()
    }

    /// Tick until idle, returning every event produced.
    pub fn drain(&mut self) -> Result<Vec<ServeEvent>, ChimeError> {
        let mut all = Vec::new();
        loop {
            let events = self.inner.tick()?;
            if events.is_empty() {
                return Ok(all);
            }
            all.extend(events);
        }
    }

    /// Drain whatever is still pending (discarding those events) and
    /// return the accumulated [`ServeOutcome`]. The legacy batch
    /// `serve(Vec<_>)` is exactly submit-all + `finish`.
    pub fn finish(mut self) -> Result<ServeOutcome, ChimeError> {
        self.drain()?;
        Ok(self.inner.finish())
    }
}

/// Shared submission guard for every streaming engine: panics on a
/// duplicate request id (the [`ServeProtocol::submit`] contract — ids
/// key completion records) and sheds non-finite arrivals (they can
/// never be scheduled on any timeline). Returns the request back when
/// it is schedulable, or the already-recorded [`ServeEvent::Shed`].
pub(crate) fn guard_submission(
    seen: &mut BTreeSet<u64>,
    metrics: &mut ServingMetrics,
    shed: &mut Vec<ServeRequest>,
    req: ServeRequest,
) -> Result<ServeRequest, Vec<ServeEvent>> {
    assert!(
        seen.insert(req.id),
        "duplicate request id {}: ids must be unique per serve call",
        req.id
    );
    if !req.arrival_ns.is_finite() {
        metrics.record_shed();
        let ev = ServeEvent::Shed { request: req.clone() };
        shed.push(req);
        return Err(vec![ev]);
    }
    Ok(req)
}

/// Event stream for one request completed end to end by a sequential
/// single-stream engine (functional PJRT, analytic baselines): `Admitted`
/// at arrival, `FirstToken` at the TTFT instant, every `Token` at the
/// completion timestamp (these engines price whole phases, not tokens),
/// `Completed` last. Zero-token completions emit `Admitted` +
/// `Completed` only.
pub(crate) fn sequential_request_events(
    req: &ServeRequest,
    resp: &ServeResponse,
) -> Vec<ServeEvent> {
    let start_ns = req.arrival_ns + resp.queue_ns;
    let done_ns = req.arrival_ns + resp.total_latency_ns();
    let mut events = Vec::with_capacity(resp.tokens.len() + 3);
    events.push(ServeEvent::Admitted { id: req.id, time_ns: req.arrival_ns, package: None });
    if !resp.tokens.is_empty() {
        events.push(ServeEvent::FirstToken { id: req.id, time_ns: start_ns + resp.ttft_ns });
        for index in 0..resp.tokens.len() {
            events.push(ServeEvent::Token { id: req.id, index, time_ns: done_ns });
        }
    }
    events.push(ServeEvent::Completed {
        arrival_ns: req.arrival_ns,
        time_ns: done_ns,
        response: resp.clone(),
    });
    events
}

/// Arrival-ordered pending queue shared by the streaming engines: a
/// min-heap on `(arrival_ns, tiebreak)`. The sharded coordinator breaks
/// ties by submission order (matching the legacy stable sort); the
/// sequential baselines break ties by request id (matching their legacy
/// explicit sort key). Arrivals are finite by construction — non-finite
/// submissions are shed before insertion.
pub(crate) struct PendingQueue {
    heap: BinaryHeap<Pending>,
}

struct Pending {
    arrival_ns: f64,
    tiebreak: u64,
    req: ServeRequest,
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.arrival_ns.total_cmp(&other.arrival_ns).is_eq() && self.tiebreak == other.tiebreak
    }
}

impl Eq for Pending {}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want the earliest first.
        other
            .arrival_ns
            .total_cmp(&self.arrival_ns)
            .then(other.tiebreak.cmp(&self.tiebreak))
    }
}

impl PendingQueue {
    pub(crate) fn new() -> PendingQueue {
        PendingQueue { heap: BinaryHeap::new() }
    }

    pub(crate) fn push(&mut self, req: ServeRequest, tiebreak: u64) {
        debug_assert!(req.arrival_ns.is_finite(), "shed non-finite arrivals before queueing");
        self.heap.push(Pending { arrival_ns: req.arrival_ns, tiebreak, req });
    }

    pub(crate) fn peek_arrival_ns(&self) -> Option<f64> {
        self.heap.peek().map(|p| p.arrival_ns)
    }

    pub(crate) fn pop(&mut self) -> Option<ServeRequest> {
        self.heap.pop().map(|p| p.req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival_ns: f64) -> ServeRequest {
        ServeRequest { id, prompt: vec![], image_seed: id, max_new_tokens: 4, arrival_ns }
    }

    #[test]
    fn pending_queue_pops_in_arrival_then_tiebreak_order() {
        let mut q = PendingQueue::new();
        q.push(req(2, 5.0), 2);
        q.push(req(0, 1.0), 0);
        q.push(req(3, 5.0), 1); // same arrival as id 2, earlier tiebreak
        q.push(req(1, 3.0), 3);
        assert_eq!(q.peek_arrival_ns(), Some(1.0));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![0, 1, 3, 2]);
        assert_eq!(q.peek_arrival_ns(), None);
    }

    #[test]
    fn events_serialize_with_kind_tags_and_finite_numbers() {
        let admitted = ServeEvent::Admitted { id: 1, time_ns: 10.0, package: Some(0) };
        assert_eq!(
            admitted.to_json().compact(),
            r#"{"id":1,"kind":"admitted","package":0,"time_ns":10}"#
        );
        let inline = ServeEvent::Admitted { id: 2, time_ns: 0.0, package: None };
        assert!(inline.to_json().get("package").is_null());
        let token = ServeEvent::Token { id: 1, index: 2, time_ns: 30.5 };
        assert_eq!(token.to_json().compact(), r#"{"id":1,"index":2,"kind":"token","time_ns":30.5}"#);
        // Shed requests carry a non-finite arrival, which has no JSON
        // spelling — the wire form must omit it entirely.
        let shed = ServeEvent::Shed { request: req(9, f64::INFINITY) };
        let json = shed.to_json();
        assert!(json.get("arrival_ns").is_null() && json.get("time_ns").is_null());
        assert_eq!(json.get("kind").as_str(), Some("shed"));
        let completed = ServeEvent::Completed {
            arrival_ns: 5.0,
            time_ns: 20.0,
            response: ServeResponse {
                id: 3,
                tokens: vec![7, 8],
                queue_ns: 1.0,
                ttft_ns: 2.0,
                service_ns: 15.0,
                energy_j: 0.25,
            },
        };
        let json = completed.to_json();
        assert_eq!(json.get("tokens").as_i64(), Some(2));
        assert_eq!(json.get("energy_j").as_f64(), Some(0.25));
        for ev in [&admitted, &token, &completed] {
            assert_eq!(ev.to_json().get("kind").as_str(), Some(ev.kind()));
        }
    }

    #[test]
    fn event_accessors_report_id_kind_and_time() {
        let ev = ServeEvent::Token { id: 7, index: 3, time_ns: 42.0 };
        assert_eq!(ev.id(), 7);
        assert_eq!(ev.kind(), "token");
        assert_eq!(ev.time_ns(), Some(42.0));
        let shed = ServeEvent::Shed { request: req(9, f64::NAN) };
        assert_eq!(shed.id(), 9);
        assert_eq!(shed.time_ns(), None);
    }
}
