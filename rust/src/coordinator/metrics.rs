//! Serving metrics registry: latency distributions, throughput, energy.
//!
//! **Zero-denominator policy:** every rate/mean helper
//! ([`ServingMetrics::tokens_per_s`], [`ServingMetrics::requests_per_s`],
//! [`ServingMetrics::mean_queue_ns`],
//! [`ServingMetrics::mean_steal_delay_ns`],
//! [`ServingMetrics::tokens_per_j`]) returns `0.0` — never `NaN` or
//! `inf` — when its denominator is empty or zero. Consumers (the
//! canonical serve-outcome JSON, the Prometheus exposition) rely on
//! every value being finite; `rate_helpers_are_zero_not_nan_on_empty`
//! locks the policy per helper.

use crate::util::stats::{percentile, Summary};

use super::request::ServeResponse;

/// Aggregated serving metrics over a set of completed requests.
#[derive(Debug, Clone, Default)]
pub struct ServingMetrics {
    pub completed: u64,
    /// Requests accepted past admission (== `completed` once the engine
    /// drains; they differ only while requests are in flight).
    pub admitted: u64,
    /// Requests rejected at admission by backpressure (queue full /
    /// closed). Conservation: every offered request is admitted, rejected,
    /// or shed, so `admitted + rejected + shed == offered` and, at drain,
    /// `completed + rejected + shed == offered`.
    pub rejected: u64,
    /// Requests shed before admission (malformed, e.g. a non-finite
    /// arrival time) — kept distinct from `rejected` so backpressure and
    /// input-validation failures are independently countable, matching
    /// the `ServeEvent::{Rejected, Shed}` distinction.
    pub shed: u64,
    pub tokens: u64,
    /// Cross-package work steals executed (0 with stealing off).
    pub steals: u64,
    /// Payload bytes work stealing moved across the fabric (request
    /// metadata + prompt tokens + per-token KV context).
    pub stolen_bytes: u64,
    /// Total routed delivery latency steals paid (ns). Zero on the
    /// point-to-point topology, which is the legacy 0-cost baseline.
    pub steal_delay_ns: f64,
    latency_ns: Vec<f64>,
    ttft_ns: Vec<f64>,
    queue_ns: Vec<f64>,
    pub energy_j: f64,
    pub service: Summary,
    /// Virtual/wall span covered (max completion - min arrival), ns.
    first_arrival_ns: f64,
    last_completion_ns: f64,
}

impl ServingMetrics {
    pub fn new() -> Self {
        ServingMetrics {
            first_arrival_ns: f64::INFINITY,
            last_completion_ns: 0.0,
            ..Default::default()
        }
    }

    pub fn record(&mut self, arrival_ns: f64, r: &ServeResponse) {
        self.completed += 1;
        self.tokens += r.tokens.len() as u64;
        self.latency_ns.push(r.total_latency_ns());
        self.ttft_ns.push(r.queue_ns + r.ttft_ns);
        self.queue_ns.push(r.queue_ns);
        self.energy_j += r.energy_j;
        self.service.push(r.service_ns);
        self.first_arrival_ns = self.first_arrival_ns.min(arrival_ns);
        self.last_completion_ns = self
            .last_completion_ns
            .max(arrival_ns + r.total_latency_ns());
    }

    /// Count a request accepted past admission.
    pub fn record_admitted(&mut self) {
        self.admitted += 1;
    }

    /// Count a request rejected at admission (backpressure / shutdown).
    pub fn record_rejected(&mut self) {
        self.rejected += 1;
    }

    /// Count a request shed before admission (malformed input).
    pub fn record_shed(&mut self) {
        self.shed += 1;
    }

    /// Count one cross-package work steal: the payload it moved and the
    /// routed delivery latency it paid (0 on point-to-point).
    pub fn record_steal(&mut self, bytes: u64, delay_ns: f64) {
        self.steals += 1;
        self.stolen_bytes += bytes;
        self.steal_delay_ns += delay_ns;
    }

    /// Mean routed delivery latency per steal (ns); `0.0` with no steals
    /// (zero-denominator policy, see the module doc).
    pub fn mean_steal_delay_ns(&self) -> f64 {
        if self.steals == 0 {
            return 0.0;
        }
        self.steal_delay_ns / self.steals as f64
    }

    /// Total requests offered to the engine (admitted, rejected, or shed).
    pub fn offered(&self) -> u64 {
        self.admitted + self.rejected + self.shed
    }

    pub fn span_ns(&self) -> f64 {
        (self.last_completion_ns - self.first_arrival_ns).max(0.0)
    }

    /// System throughput over the covered span (tokens/s); `0.0` when no
    /// request completed, so the span is empty (zero-denominator policy).
    pub fn tokens_per_s(&self) -> f64 {
        if self.span_ns() <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / (self.span_ns() / 1e9)
    }

    /// Requests/s over the covered span; `0.0` on an empty span
    /// (zero-denominator policy, see the module doc).
    pub fn requests_per_s(&self) -> f64 {
        if self.span_ns() <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / (self.span_ns() / 1e9)
    }

    pub fn latency_percentile_ns(&mut self, p: f64) -> f64 {
        percentile(&mut self.latency_ns, p)
    }

    pub fn ttft_percentile_ns(&mut self, p: f64) -> f64 {
        percentile(&mut self.ttft_ns, p)
    }

    /// Mean admission-queue wait (ns); `0.0` with no completions
    /// (zero-denominator policy, see the module doc).
    pub fn mean_queue_ns(&self) -> f64 {
        if self.queue_ns.is_empty() {
            return 0.0;
        }
        self.queue_ns.iter().sum::<f64>() / self.queue_ns.len() as f64
    }

    /// Energy efficiency (tokens/J); `0.0` when no energy was metered —
    /// zero, not `inf`, even if tokens were somehow counted without
    /// energy (zero-denominator policy, see the module doc).
    pub fn tokens_per_j(&self) -> f64 {
        if self.energy_j <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.energy_j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resp(id: u64, q: f64, ttft: f64, svc: f64, n: usize) -> ServeResponse {
        ServeResponse {
            id,
            tokens: vec![0; n],
            queue_ns: q,
            ttft_ns: ttft,
            service_ns: svc,
            energy_j: 0.001,
        }
    }

    #[test]
    fn throughput_over_span() {
        let mut m = ServingMetrics::new();
        // Two requests, 10 tokens each, finishing 1 s after first arrival.
        m.record(0.0, &resp(0, 0.0, 1e8, 5e8, 10));
        m.record(2e8, &resp(1, 0.0, 1e8, 8e8, 10));
        assert_eq!(m.tokens, 20);
        let span = m.span_ns();
        assert_eq!(span, 1e9);
        assert!((m.tokens_per_s() - 20.0).abs() < 1e-9);
        assert!((m.requests_per_s() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn admission_accounting_conserves_offered_load() {
        let mut m = ServingMetrics::new();
        for i in 0..5 {
            m.record_admitted();
            m.record(0.0, &resp(i, 0.0, 1.0, 2.0, 1));
        }
        for _ in 0..3 {
            m.record_rejected();
        }
        for _ in 0..2 {
            m.record_shed();
        }
        assert_eq!(m.admitted, 5);
        assert_eq!(m.rejected, 3);
        assert_eq!(m.shed, 2);
        assert_eq!(m.offered(), 10);
        assert_eq!(m.completed + m.rejected + m.shed, m.offered());
    }

    #[test]
    fn steal_accounting_sums_bytes_and_delay() {
        let mut m = ServingMetrics::new();
        assert_eq!(m.mean_steal_delay_ns(), 0.0);
        m.record_steal(1000, 0.0); // point-to-point: free
        m.record_steal(3000, 500.0); // routed: paid
        assert_eq!(m.steals, 2);
        assert_eq!(m.stolen_bytes, 4000);
        assert_eq!(m.steal_delay_ns, 500.0);
        assert_eq!(m.mean_steal_delay_ns(), 250.0);
    }

    #[test]
    fn rate_helpers_are_zero_not_nan_on_empty() {
        // One assertion per rate/mean helper: a fresh registry (every
        // denominator zero) yields exactly 0.0 — the finite-by-policy
        // contract the Prometheus exposition and outcome JSON rely on.
        let m = ServingMetrics::new();
        assert_eq!(m.tokens_per_s(), 0.0);
        assert_eq!(m.requests_per_s(), 0.0);
        assert_eq!(m.mean_queue_ns(), 0.0);
        assert_eq!(m.mean_steal_delay_ns(), 0.0);
        assert_eq!(m.tokens_per_j(), 0.0);
        // Default-built (not ::new) has a 0-width span, not a negative
        // one — the guards hold there too.
        let d = ServingMetrics::default();
        assert_eq!(d.tokens_per_s(), 0.0);
        assert_eq!(d.requests_per_s(), 0.0);
        // Tokens counted without metered energy must not divide by zero.
        let mut e = ServingMetrics::new();
        e.tokens = 5;
        assert_eq!(e.tokens_per_j(), 0.0);
        assert!(e.tokens_per_s().is_finite());
    }

    #[test]
    fn percentiles_and_energy() {
        let mut m = ServingMetrics::new();
        for i in 0..10 {
            m.record(i as f64, &resp(i, 10.0, 50.0, 100.0 + i as f64, 2));
        }
        assert!(m.latency_percentile_ns(50.0) > 100.0);
        assert!(m.latency_percentile_ns(99.0) >= m.latency_percentile_ns(50.0));
        assert!((m.tokens_per_j() - 20.0 / 0.01).abs() < 1e-9);
        assert_eq!(m.mean_queue_ns(), 10.0);
    }
}
