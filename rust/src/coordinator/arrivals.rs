//! Open-loop arrival processes for serving experiments.
//!
//! The streaming protocol (`coordinator::streaming`) decouples *when
//! requests arrive* from *how they are served*; this module owns the
//! arrival side. Three processes cover the serving literature's
//! standard shapes:
//!
//! * `burst` — every request at t=0 (the closed-loop saturation test the
//!   batch API forced);
//! * `poisson:<rps>` — seeded memoryless arrivals at `<rps>` requests/s,
//!   deterministic for a given seed (tail-latency experiments);
//! * `trace:<file>` — replay a JSON trace: an array whose entries are
//!   either a number (arrival time, **seconds**) or an object
//!   `{"arrival_s": 1.5, "tokens": 32}` with an optional per-request
//!   decode budget.
//!
//! `api::Session::requests_for` turns a process into a backend-sized
//! request stream; `chime serve --arrival <spec>` is the CLI spelling.

use crate::api::ChimeError;
use crate::util::{Json, Prng};

/// Hint listing the accepted `--arrival` spellings.
pub const ARRIVAL_HINT: &str = "burst poisson:<rps> trace:<file>";

/// One request slot from an arrival process: when it arrives, and an
/// optional trace-dictated decode budget.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrivalPoint {
    /// Arrival time in ns from stream start.
    pub arrival_ns: f64,
    /// Per-request decode budget, when the trace dictates one.
    pub max_new_tokens: Option<usize>,
}

/// An open-loop arrival process specification (module docs).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Every request arrives at t=0.
    Burst,
    /// Seeded Poisson arrivals at `rate_per_s` requests per second.
    Poisson {
        /// Mean arrival rate, requests/s. Finite and positive.
        rate_per_s: f64,
    },
    /// Replay arrivals (and optional token budgets) from a JSON file.
    Trace {
        /// Path to the trace file.
        path: String,
    },
}

impl ArrivalProcess {
    /// Parse a CLI spelling: `burst`, `poisson:<rps>`, `trace:<file>`.
    /// Malformed specs are usage errors (exit 2).
    pub fn parse(spec: &str) -> Result<ArrivalProcess, ChimeError> {
        if spec == "burst" {
            return Ok(ArrivalProcess::Burst);
        }
        if let Some(rate) = spec.strip_prefix("poisson:") {
            let rate_per_s: f64 = rate.parse().map_err(|_| {
                ChimeError::Invalid(format!(
                    "--arrival poisson expects a rate in requests/s, got {rate:?}"
                ))
            })?;
            if !rate_per_s.is_finite() || rate_per_s <= 0.0 {
                return Err(ChimeError::Invalid(format!(
                    "--arrival poisson rate must be finite and positive, got {rate_per_s}"
                )));
            }
            return Ok(ArrivalProcess::Poisson { rate_per_s });
        }
        if let Some(path) = spec.strip_prefix("trace:") {
            if path.is_empty() {
                return Err(ChimeError::Invalid(
                    "--arrival trace expects a file path (trace:<file>)".to_string(),
                ));
            }
            return Ok(ArrivalProcess::Trace { path: path.to_string() });
        }
        Err(ChimeError::Unknown {
            what: "arrival process",
            name: spec.to_string(),
            hint: Some(ARRIVAL_HINT.to_string()),
        })
    }

    /// Canonical spelling (round-trips through [`ArrivalProcess::parse`]).
    pub fn spec(&self) -> String {
        match self {
            ArrivalProcess::Burst => "burst".to_string(),
            ArrivalProcess::Poisson { rate_per_s } => format!("poisson:{rate_per_s}"),
            ArrivalProcess::Trace { path } => format!("trace:{path}"),
        }
    }

    /// Load and validate the points of a `trace:` process. Entries must
    /// be non-negative finite times; the file dictates the request count.
    pub fn trace_points(path: &str) -> Result<Vec<ArrivalPoint>, ChimeError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            ChimeError::Invalid(format!("--arrival trace {path:?} unreadable: {e}"))
        })?;
        let json = Json::parse(&text).map_err(|e| {
            ChimeError::Invalid(format!("--arrival trace {path:?} is not valid JSON: {e}"))
        })?;
        let entries = json.as_arr().ok_or_else(|| {
            ChimeError::Invalid(format!(
                "--arrival trace {path:?} must be a JSON array of arrivals"
            ))
        })?;
        let mut points = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let (arrival_s, tokens) = match e {
                Json::Num(s) => (*s, None),
                Json::Obj(_) => {
                    let s = e.get("arrival_s").as_f64().ok_or_else(|| {
                        ChimeError::Invalid(format!(
                            "--arrival trace {path:?} entry {i}: missing numeric \"arrival_s\""
                        ))
                    })?;
                    let tokens = match e.get("tokens") {
                        Json::Null => None,
                        t => Some(t.as_usize().ok_or_else(|| {
                            ChimeError::Invalid(format!(
                                "--arrival trace {path:?} entry {i}: \"tokens\" must be a \
                                 non-negative integer"
                            ))
                        })?),
                    };
                    (s, tokens)
                }
                _ => {
                    return Err(ChimeError::Invalid(format!(
                        "--arrival trace {path:?} entry {i}: expected a number or an object"
                    )))
                }
            };
            if !arrival_s.is_finite() || arrival_s < 0.0 {
                return Err(ChimeError::Invalid(format!(
                    "--arrival trace {path:?} entry {i}: arrival {arrival_s} must be finite \
                     and non-negative"
                )));
            }
            points.push(ArrivalPoint { arrival_ns: arrival_s * 1e9, max_new_tokens: tokens });
        }
        if points.is_empty() {
            return Err(ChimeError::Invalid(format!(
                "--arrival trace {path:?} contains no arrivals"
            )));
        }
        // Ordering policy: traces need not be pre-sorted — entries are
        // sorted by arrival here, and equal-time entries keep file order
        // (stable sort). Downstream consumers (the pending heap, the
        // loadgen's open-loop sleep-until pacing) all assume a
        // non-decreasing timeline.
        points.sort_by(|a, b| a.arrival_ns.total_cmp(&b.arrival_ns));
        Ok(points)
    }

    /// Materialize `n` arrival points from this process (the loadgen's
    /// open-loop schedule; `api::Session::requests_for` is the
    /// simulator-side equivalent that also synthesizes prompts):
    ///
    /// * `Burst` — `n` points at t=0;
    /// * `Poisson` — `n` seeded cumulative exponential inter-arrivals,
    ///   the same `Prng::exponential` stream convention as
    ///   `model::workload::RequestStream`;
    /// * `Trace` — the file's points (`n` is ignored; the file dictates
    ///   the count), sorted per [`ArrivalProcess::trace_points`].
    pub fn points(&self, seed: u64, n: usize) -> Result<Vec<ArrivalPoint>, ChimeError> {
        match self {
            ArrivalProcess::Burst => {
                Ok(vec![ArrivalPoint { arrival_ns: 0.0, max_new_tokens: None }; n])
            }
            ArrivalProcess::Poisson { rate_per_s } => {
                let mut prng = Prng::new(seed);
                let mut clock_ns = 0.0;
                Ok((0..n)
                    .map(|_| {
                        clock_ns += prng.exponential(*rate_per_s) * 1e9;
                        ArrivalPoint { arrival_ns: clock_ns, max_new_tokens: None }
                    })
                    .collect())
            }
            ArrivalProcess::Trace { path } => Self::trace_points(path),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_round_trip() {
        for spec in ["burst", "poisson:2.5", "trace:/tmp/t.json"] {
            let p = ArrivalProcess::parse(spec).unwrap();
            assert_eq!(p.spec(), spec);
            assert_eq!(ArrivalProcess::parse(&p.spec()).unwrap(), p);
        }
        assert_eq!(
            ArrivalProcess::parse("poisson:8").unwrap(),
            ArrivalProcess::Poisson { rate_per_s: 8.0 }
        );
    }

    #[test]
    fn malformed_specs_are_usage_errors() {
        for spec in ["fourier", "poisson", "poisson:", "poisson:fast", "poisson:-2",
                     "poisson:inf", "trace:"] {
            let err = ArrivalProcess::parse(spec).unwrap_err();
            assert_eq!(err.exit_code(), 2, "{spec}: {err}");
        }
        // The unknown-name path carries the accepted spellings.
        match ArrivalProcess::parse("uniform") {
            Err(ChimeError::Unknown { what, hint, .. }) => {
                assert_eq!(what, "arrival process");
                assert!(hint.unwrap().contains("poisson"));
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn trace_files_parse_numbers_and_objects() {
        let path = std::env::temp_dir().join("chime_arrival_trace_test.json");
        std::fs::write(&path, r#"[0, 0.5, {"arrival_s": 1.5, "tokens": 3}]"#).unwrap();
        let pts = ArrivalProcess::trace_points(path.to_str().unwrap()).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0], ArrivalPoint { arrival_ns: 0.0, max_new_tokens: None });
        assert_eq!(pts[1].arrival_ns, 0.5e9);
        assert_eq!(pts[2], ArrivalPoint { arrival_ns: 1.5e9, max_new_tokens: Some(3) });
    }

    #[test]
    fn trace_points_sort_unsorted_arrivals_stably() {
        let path = std::env::temp_dir().join("chime_arrival_trace_sort_test.json");
        // Out of order, with two equal-time entries whose token budgets
        // distinguish them: the stable sort must keep file order (3 then 9).
        std::fs::write(
            &path,
            r#"[2.0, {"arrival_s": 0.5, "tokens": 3}, 0.25, {"arrival_s": 0.5, "tokens": 9}]"#,
        )
        .unwrap();
        let pts = ArrivalProcess::trace_points(path.to_str().unwrap()).unwrap();
        std::fs::remove_file(&path).ok();
        let arrivals: Vec<f64> = pts.iter().map(|p| p.arrival_ns).collect();
        assert_eq!(arrivals, vec![0.25e9, 0.5e9, 0.5e9, 2.0e9]);
        assert_eq!(pts[1].max_new_tokens, Some(3), "equal arrivals keep file order");
        assert_eq!(pts[2].max_new_tokens, Some(9));
    }

    #[test]
    fn points_cover_every_process_and_match_the_request_stream_convention() {
        let burst = ArrivalProcess::Burst.points(7, 3).unwrap();
        assert_eq!(burst.len(), 3);
        assert!(burst.iter().all(|p| p.arrival_ns == 0.0 && p.max_new_tokens.is_none()));
        // Poisson points replay the RequestStream cumulative-exponential
        // convention bit for bit at the same seed and rate.
        let poisson = ArrivalProcess::Poisson { rate_per_s: 50.0 }.points(7, 4).unwrap();
        let mut prng = Prng::new(7);
        let mut clock_ns = 0.0;
        for p in &poisson {
            clock_ns += prng.exponential(50.0) * 1e9;
            assert_eq!(p.arrival_ns.to_bits(), clock_ns.to_bits());
        }
        assert!(poisson.windows(2).all(|w| w[0].arrival_ns <= w[1].arrival_ns));
        let path = std::env::temp_dir().join("chime_arrival_points_trace_test.json");
        std::fs::write(&path, "[0.5, 0.25]").unwrap();
        let process = ArrivalProcess::Trace { path: path.to_str().unwrap().to_string() };
        let trace = process.points(7, 99).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(trace.len(), 2, "the file dictates the count");
        assert_eq!(trace[0].arrival_ns, 0.25e9, "sorted");
    }

    #[test]
    fn bad_trace_files_are_usage_errors() {
        let dir = std::env::temp_dir();
        let cases: [(&str, &str); 5] = [
            ("chime_trace_nonjson.json", "not json"),
            ("chime_trace_nonarray.json", r#"{"arrival_s": 1}"#),
            ("chime_trace_badentry.json", r#"[true]"#),
            ("chime_trace_negative.json", r#"[-1.0]"#),
            ("chime_trace_empty.json", r#"[]"#),
        ];
        for (name, body) in cases {
            let path = dir.join(name);
            std::fs::write(&path, body).unwrap();
            let err = ArrivalProcess::trace_points(path.to_str().unwrap()).unwrap_err();
            std::fs::remove_file(&path).ok();
            assert_eq!(err.exit_code(), 2, "{name}: {err}");
        }
        let err = ArrivalProcess::trace_points("/nonexistent/chime/trace.json").unwrap_err();
        assert_eq!(err.exit_code(), 2);
        assert!(err.to_string().contains("unreadable"));
    }
}
