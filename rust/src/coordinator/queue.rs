//! Admission queue with bounded capacity and backpressure.
//!
//! Edge devices cannot buffer unbounded work: beyond `capacity` the queue
//! rejects new requests (the caller sheds load or retries). Thread-safe —
//! producers (request sources) and the consumer (the serving loop) share
//! it behind a mutex + condvar.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use super::request::ServeRequest;

/// Rejection reason surfaced to producers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// Queue at capacity (backpressure).
    Full,
    /// Queue shut down.
    Closed,
}

struct Inner {
    items: VecDeque<ServeRequest>,
    closed: bool,
}

/// Bounded MPSC admission queue.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    notify: Condvar,
    capacity: usize,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> Self {
        AdmissionQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            notify: Condvar::new(),
            capacity,
        }
    }

    /// Try to admit a request. Non-blocking: backpressure is immediate.
    /// On rejection the request is handed back with the reason — the
    /// producer owns the shed/retry decision, and nothing is silently
    /// dropped (the pre-fix signature consumed rejected requests).
    pub fn admit(&self, req: ServeRequest) -> Result<(), (AdmitError, ServeRequest)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((AdmitError::Closed, req));
        }
        if g.items.len() >= self.capacity {
            return Err((AdmitError::Full, req));
        }
        g.items.push_back(req);
        self.notify.notify_one();
        Ok(())
    }

    /// Pop up to `n` requests, blocking until at least one is available or
    /// the queue is closed (returns an empty vec then).
    pub fn pop_batch(&self, n: usize) -> Vec<ServeRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let take = n.min(g.items.len());
                return g.items.drain(..take).collect();
            }
            if g.closed {
                return Vec::new();
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Non-blocking drain of up to `n`.
    pub fn try_pop_batch(&self, n: usize) -> Vec<ServeRequest> {
        let mut g = self.inner.lock().unwrap();
        let take = n.min(g.items.len());
        g.items.drain(..take).collect()
    }

    /// Arrival timestamp of the request at the head of the queue, if any.
    /// The virtual-time dispatcher uses this to decide when an idle
    /// package can start its next tick.
    pub fn peek_arrival_ns(&self) -> Option<f64> {
        self.inner.lock().unwrap().items.front().map(|r| r.arrival_ns)
    }

    /// Return an already-admitted request to the head of the queue after a
    /// failed downstream handoff (e.g. a batcher slot raced away between
    /// the capacity check and the join). Deliberately ignores `capacity`:
    /// the request passed admission once and must not be silently dropped.
    pub fn readmit_front(&self, req: ServeRequest) {
        let mut g = self.inner.lock().unwrap();
        g.items.push_front(req);
        self.notify.notify_one();
    }

    /// Arrival timestamp of the newest queued request, if any — the work
    /// stealing victim check (the back of an arrival-ordered queue is the
    /// request that would wait longest).
    pub fn peek_back_arrival_ns(&self) -> Option<f64> {
        self.inner.lock().unwrap().items.back().map(|r| r.arrival_ns)
    }

    /// Pop the newest queued request if it has arrived by `now_ns` (work
    /// stealing: an idle package takes the request that would otherwise
    /// wait longest here; stealing not-yet-arrived work would let the
    /// scheduler act on the future). Ignores `closed` — a steal is a
    /// transfer between sibling queues, not a new admission.
    pub fn steal_back(&self, now_ns: f64) -> Option<ServeRequest> {
        let mut g = self.inner.lock().unwrap();
        if g.items.back().is_some_and(|r| r.arrival_ns <= now_ns) {
            g.items.pop_back()
        } else {
            None
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: admits fail, blocked consumers wake with empties.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> ServeRequest {
        ServeRequest { id, prompt: vec![], image_seed: 0, max_new_tokens: 4, arrival_ns: 0.0 }
    }

    #[test]
    fn backpressure_at_capacity_returns_the_request() {
        let q = AdmissionQueue::new(2);
        assert!(q.admit(req(0)).is_ok());
        assert!(q.admit(req(1)).is_ok());
        // The shed request comes back intact, with the reason.
        let (err, returned) = q.admit(req(2)).unwrap_err();
        assert_eq!(err, AdmitError::Full);
        assert_eq!(returned.id, 2);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn fifo_order() {
        let q = AdmissionQueue::new(10);
        for i in 0..5 {
            q.admit(req(i)).unwrap();
        }
        let batch = q.try_pop_batch(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_rejects_and_wakes() {
        let q = Arc::new(AdmissionQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_batch(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_empty());
        let (err, returned) = q.admit(req(9)).unwrap_err();
        assert_eq!(err, AdmitError::Closed);
        assert_eq!(returned.id, 9);
    }

    #[test]
    fn peek_and_readmit_preserve_fifo_head() {
        let q = AdmissionQueue::new(2);
        assert_eq!(q.peek_arrival_ns(), None);
        let mut r0 = req(0);
        r0.arrival_ns = 7.0;
        q.admit(r0).unwrap();
        q.admit(req(1)).unwrap();
        assert_eq!(q.peek_arrival_ns(), Some(7.0));
        let popped = q.try_pop_batch(1).pop().unwrap();
        assert_eq!(popped.id, 0);
        q.admit(req(2)).unwrap(); // queue full again
        // Readmit goes back to the head even past capacity: the request
        // was already admitted once and must not be shed on the way back.
        q.readmit_front(popped);
        assert_eq!(q.len(), 3);
        assert_eq!(q.peek_arrival_ns(), Some(7.0));
        assert_eq!(q.try_pop_batch(1).pop().unwrap().id, 0);
    }

    #[test]
    fn steal_back_takes_only_arrived_work_from_the_tail() {
        let q = AdmissionQueue::new(4);
        let mut r0 = req(0);
        r0.arrival_ns = 1.0;
        let mut r1 = req(1);
        r1.arrival_ns = 5.0;
        q.admit(r0).unwrap();
        q.admit(r1).unwrap();
        assert_eq!(q.peek_back_arrival_ns(), Some(5.0));
        // The back has not arrived by t=3: nothing to steal.
        assert!(q.steal_back(3.0).is_none());
        assert_eq!(q.len(), 2);
        // By t=5 it has; the steal takes the tail and leaves the head.
        let stolen = q.steal_back(5.0).unwrap();
        assert_eq!(stolen.id, 1);
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek_arrival_ns(), Some(1.0));
        // Empty queue: nothing to steal.
        q.try_pop_batch(1);
        assert!(q.steal_back(100.0).is_none());
    }

    #[test]
    fn concurrent_producers() {
        let q = Arc::new(AdmissionQueue::new(1000));
        let mut handles = vec![];
        for t in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    q.admit(req(t * 1000 + i)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.len(), 400);
    }
}
