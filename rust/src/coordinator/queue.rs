//! Admission queue with bounded capacity and backpressure.
//!
//! Edge devices cannot buffer unbounded work: beyond `capacity` the queue
//! rejects new requests (the caller sheds load or retries). Thread-safe —
//! producers (request sources) and the consumer (the serving loop) share
//! it behind a mutex + condvar.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use super::request::ServeRequest;

/// Rejection reason surfaced to producers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// Queue at capacity (backpressure).
    Full,
    /// Queue shut down.
    Closed,
}

struct Inner {
    items: VecDeque<ServeRequest>,
    closed: bool,
}

/// Bounded MPSC admission queue.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    notify: Condvar,
    capacity: usize,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> Self {
        AdmissionQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            notify: Condvar::new(),
            capacity,
        }
    }

    /// Try to admit a request. Non-blocking: backpressure is immediate.
    pub fn admit(&self, req: ServeRequest) -> Result<(), AdmitError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(AdmitError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(AdmitError::Full);
        }
        g.items.push_back(req);
        self.notify.notify_one();
        Ok(())
    }

    /// Pop up to `n` requests, blocking until at least one is available or
    /// the queue is closed (returns an empty vec then).
    pub fn pop_batch(&self, n: usize) -> Vec<ServeRequest> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.items.is_empty() {
                let take = n.min(g.items.len());
                return g.items.drain(..take).collect();
            }
            if g.closed {
                return Vec::new();
            }
            g = self.notify.wait(g).unwrap();
        }
    }

    /// Non-blocking drain of up to `n`.
    pub fn try_pop_batch(&self, n: usize) -> Vec<ServeRequest> {
        let mut g = self.inner.lock().unwrap();
        let take = n.min(g.items.len());
        g.items.drain(..take).collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: admits fail, blocked consumers wake with empties.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> ServeRequest {
        ServeRequest { id, prompt: vec![], image_seed: 0, max_new_tokens: 4, arrival_ns: 0.0 }
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = AdmissionQueue::new(2);
        assert!(q.admit(req(0)).is_ok());
        assert!(q.admit(req(1)).is_ok());
        assert_eq!(q.admit(req(2)), Err(AdmitError::Full));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn fifo_order() {
        let q = AdmissionQueue::new(10);
        for i in 0..5 {
            q.admit(req(i)).unwrap();
        }
        let batch = q.try_pop_batch(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_rejects_and_wakes() {
        let q = Arc::new(AdmissionQueue::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_batch(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_empty());
        assert_eq!(q.admit(req(9)), Err(AdmitError::Closed));
    }

    #[test]
    fn concurrent_producers() {
        let q = Arc::new(AdmissionQueue::new(1000));
        let mut handles = vec![];
        for t in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    q.admit(req(t * 1000 + i)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(q.len(), 400);
    }
}
