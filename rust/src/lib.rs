//! # CHIME — chiplet-based heterogeneous near-memory acceleration for
//! edge multimodal-LLM inference (paper reproduction).
//!
//! Reproduction of Chen, Tian, Pan, Li, Xu & Rosing (CS.AR 2025). The
//! crate provides, as a library:
//!
//! - [`api`]: the public execution API — a typed [`api::ChimeError`]
//!   taxonomy, the polymorphic [`api::Backend`] trait (simulator,
//!   DRAM-only ablation, sharded, functional PJRT, Jetson/FACIL
//!   baselines), and the builder-style [`api::Session`] front door that
//!   the CLI and every example drive (DESIGN.md §8);
//! - [`config`]: the paper's hardware (Tables III/IV) and model (Table II)
//!   configurations plus calibration knobs;
//! - [`model`]: an operator-level MLLM workload model (vision encoder,
//!   connector, transformer backbone, VQA traces);
//! - [`mapping`]: the paper's mapping framework — workload-aware data
//!   layout, endurance-aware KV-cache tiering, kernel locality-aware
//!   fusion (Table I);
//! - [`sim`]: the CHIME hardware simulator — tiered M3D DRAM, M3D RRAM
//!   with endurance accounting, UCIe link, NMP timing, two-cut-point
//!   pipeline;
//! - [`baselines`]: Jetson Orin NX, FACIL, and the DRAM-only ablation;
//! - [`runtime`]: PJRT functional runtime loading the AOT-compiled JAX
//!   artifacts (the tiny MLLM) — Python never runs on the request path;
//! - [`coordinator`]: the L3 serving coordinator (request queue, batcher,
//!   pipelined engine joining functional execution with simulated timing,
//!   the event-driven streaming serving protocol with open-loop arrival
//!   processes, and cross-package work stealing);
//! - [`exec`]: the parallel serving runtime — a lock-free Chase-Lev
//!   work-stealing deque ([`exec::deque`], atomics only), the
//!   free-running wall-clock executor ([`exec::serve_wall_clock`]) with
//!   thread-per-package-chunk workers behind `--threads N --wall`, and
//!   the thread plumbing for the deterministic windowed executor drain
//!   in [`coordinator::sharded::ShardedSession`] (DESIGN.md §15);
//! - [`net`]: the std-only network serving front end — a minimal
//!   HTTP/1.1 layer, the `chime serve --listen` SSE ingress over the
//!   streaming protocol, and the `chime loadgen` open-loop wall-clock
//!   driver (DESIGN.md §13);
//! - [`obs`]: zero-overhead-when-disabled observability — the
//!   virtual-time span/event [`obs::Tracer`], the Chrome
//!   trace-event/Perfetto exporter behind `--trace-out`, and the
//!   Prometheus text exposition for `/v1/metrics` (DESIGN.md §14);
//! - [`results`]: the paper-results harness — one module per table/figure.
//!
//! See DESIGN.md (repo root) for the system inventory, the two-cut-point
//! pipeline, and the Table I kernel mapping; EXPERIMENTS.md for the
//! paper-vs-measured table and the golden-snapshot workflow
//! (`rust/tests/golden_paper.rs`). The crate is network-dependency-free:
//! `anyhow` and `xla` resolve to vendored path crates under rust/vendor/
//! (the `xla` stub gates the functional path off until the real PJRT
//! build closure is supplied).

pub mod api;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod mapping;
pub mod model;
pub mod net;
pub mod obs;
pub mod results;
pub mod runtime;
pub mod sim;
pub mod util;
