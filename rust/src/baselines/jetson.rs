//! Jetson Orin NX baseline: analytic roofline + overhead model.
//!
//! The paper uses the Jetson board as a measured baseline (7.4–11 TPS at
//! 7–13 W across the four models — Fig 6(b)). We do not have the board
//! (repro band 0), so we model it as the paper's numbers imply: a
//! memory-bandwidth-bound decode roofline plus a large fixed per-step
//! overhead (kernel launches, framework scheduling, cross-modal data
//! transfers over the shared LPDDR bus) that flattens TPS across model
//! sizes. Calibration constants live in `config::hardware::JetsonSpec`
//! and are recorded in EXPERIMENTS.md.

use crate::config::{JetsonSpec, MllmConfig, WorkloadConfig};
use crate::model::workload::{inference_ops, VqaTrace};
use crate::model::{OpCost, Stage};

/// Platform-level result for one inference on a baseline.
#[derive(Debug, Clone)]
pub struct BaselineStats {
    pub platform: &'static str,
    pub model: String,
    pub encode_ns: f64,
    pub prefill_ns: f64,
    pub decode_ns: f64,
    pub output_tokens: usize,
    pub avg_power_w: f64,
    /// Per-stage decode time breakdown (Fig 1(c)): (label, ns).
    pub decode_breakdown: Vec<(&'static str, f64)>,
}

impl BaselineStats {
    pub fn total_ns(&self) -> f64 {
        self.encode_ns + self.prefill_ns + self.decode_ns
    }

    pub fn tokens_per_s(&self) -> f64 {
        self.output_tokens as f64 / (self.total_ns() / 1e9)
    }

    pub fn energy_j(&self) -> f64 {
        self.avg_power_w * self.total_ns() / 1e9
    }

    pub fn tokens_per_j(&self) -> f64 {
        self.output_tokens as f64 / self.energy_j()
    }
}

/// Time for a set of ops under the GPU roofline: max(bytes/BW, flops/peak).
fn roofline_ns(ops: &[OpCost], spec: &JetsonSpec) -> f64 {
    let bytes: u64 = ops.iter().map(|o| o.total_bytes()).sum();
    let flops: f64 = ops.iter().map(|o| o.flops).sum();
    let bw = spec.dram_bw_gbps * spec.bw_utilization; // bytes/ns
    let fl = spec.peak_fp16_tflops * 1e3 * spec.flops_utilization; // flops/ns
    (bytes as f64 / bw).max(flops / fl)
}

/// Simulate one VQA inference on the Jetson model.
pub fn run(model: &MllmConfig, w: &WorkloadConfig, spec: &JetsonSpec) -> BaselineStats {
    let trace = VqaTrace::new(model, w);
    let ops = inference_ops(model, &trace);

    // Encoder + connector: compute-bound on the GPU; one-off.
    let encode_ns = roofline_ns(&ops.encode, spec) + spec.step_overhead_ms * 1e6 * 0.5;

    // Prefill: large-batch GEMMs, compute-bound roofline + one step's
    // overhead (graph capture amortizes launches across layers).
    let prefill_ns = roofline_ns(&ops.prefill, spec) + spec.step_overhead_ms * 1e6;

    // Decode: per-step roofline + fixed overhead per step. The overhead —
    // not bandwidth — dominates for the small models, which is exactly the
    // Fig 6(b) observation (flat 7–11 TPS).
    //
    // Fig 1(c) attribution: on the GPU each op class runs as several CUDA
    // kernels, and for small-batch decode the *launch* cost rivals the
    // byte cost — which is why the paper's GPT-2 profile shows elementwise
    // ops at 26.4% despite moving almost no data. Kernel counts per layer:
    // MHA = 5 (QKV proj, QK^T, softmax, PV, O proj), FFN = 2 GEMM+act,
    // elementwise = 4 (2 norms + 2 residuals), plus embed + lm_head.
    let n_layers = model.llm.n_layers as f64;
    let launches_per_layer = 5.0 + 2.0 + 4.0;
    let launch_ns = spec.step_overhead_ms * 1e6 / (n_layers * launches_per_layer + 2.0);
    let bw = spec.dram_bw_gbps * spec.bw_utilization;
    let mut decode_ns = 0.0;
    let mut mha_ns = 0.0;
    let mut ffn_ns = 0.0;
    let mut elem_ns = 0.0;
    let mut other_ns = 0.0;
    for step in &ops.decode {
        let t = roofline_ns(step, spec) + spec.step_overhead_ms * 1e6;
        decode_ns += t;
        for o in step {
            let bytes_ns = o.total_bytes() as f64 / bw;
            match o.name {
                "attn_stream" => mha_ns += bytes_ns + 3.0 * launch_ns,
                "qkv_proj" | "attn_out_proj" => mha_ns += bytes_ns + launch_ns,
                "ffn_act" => ffn_ns += bytes_ns + 2.0 * launch_ns,
                "norm.attn" | "norm.ffn" | "residual.attn" | "residual.ffn" => {
                    elem_ns += bytes_ns + launch_ns
                }
                "norm.final" => elem_ns += bytes_ns + launch_ns,
                _ => other_ns += bytes_ns + launch_ns,
            }
        }
    }

    // Power: interpolate in the module envelope by model size (larger
    // models keep the memory system busier). NOTE: the paper's Fig 6(b)
    // quotes 7-13 W board draw, but its own Table V energy efficiencies
    // (0.28-0.74 token/J at 7.4-11 TPS) imply 15-26 W total power; we
    // follow Table V, since energy efficiency is the headline metric
    // (discrepancy recorded in EXPERIMENTS.md).
    let params_b = model.llm.total_params() as f64 / 1e9;
    let frac = ((params_b - 0.5) / (2.7 - 0.5)).clamp(0.0, 1.0);
    let avg_power_w = 15.0 + frac * 10.0;

    BaselineStats {
        platform: "jetson-orin-nx",
        model: model.name.clone(),
        encode_ns,
        prefill_ns,
        decode_ns,
        output_tokens: trace.output_tokens,
        avg_power_w,
        decode_breakdown: vec![
            ("MHA", mha_ns),
            ("FFN", ffn_ns),
            ("elementwise", elem_ns),
            ("other", other_ns),
        ],
    }
}

/// Fig 1(b): execution-time share of encoder / connector / backbone on
/// the GPU baseline. The paper's profile (backbone 85.4–95.7%, encoder +
/// connector 4.2–14.5%) is a short-generation profiling run — with the
/// full 488-token VQA answer the backbone asymptotically approaches 100%
/// — so the breakdown is measured at a 24-token profiling length.
pub fn stage_breakdown(model: &MllmConfig, w: &WorkloadConfig, spec: &JetsonSpec)
    -> Vec<(Stage, f64)> {
    let mut profile_w = w.clone();
    profile_w.output_tokens = 24;
    let trace = VqaTrace::new(model, &profile_w);
    let ops = inference_ops(model, &trace);
    // Encoder/connector GPU time: roofline + launch overhead for the many
    // small stage kernels (vision towers are kernel-count heavy).
    let enc_roof: f64 = roofline_ns(
        &ops.encode
            .iter()
            .filter(|o| o.stage == Stage::VisionEncoder)
            .cloned()
            .collect::<Vec<_>>(),
        spec,
    );
    let conn_roof: f64 = roofline_ns(
        &ops.encode
            .iter()
            .filter(|o| o.stage == Stage::Connector)
            .cloned()
            .collect::<Vec<_>>(),
        spec,
    );
    let enc = enc_roof + 1.5 * spec.step_overhead_ms * 1e6;
    let conn = conn_roof + 0.25 * spec.step_overhead_ms * 1e6;
    let stats = run(model, &profile_w, spec);
    let backbone = stats.prefill_ns + stats.decode_ns;
    let total = enc + conn + backbone;
    vec![
        (Stage::VisionEncoder, enc / total),
        (Stage::Connector, conn / total),
        (Stage::Backbone, backbone / total),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    #[test]
    fn tps_in_paper_envelope() {
        let spec = JetsonSpec::default();
        let w = WorkloadConfig::default();
        for m in MllmConfig::paper_models() {
            let s = run(&m, &w, &spec);
            let tps = s.tokens_per_s();
            assert!(
                (5.0..16.0).contains(&tps),
                "{}: {tps} TPS outside the plausible Jetson window",
                m.name
            );
            assert!((14.0..26.0).contains(&s.avg_power_w));
        }
    }

    #[test]
    fn tps_flat_across_models() {
        // Paper Fig 6(b): Jetson sits at 7-11 TPS regardless of size.
        let spec = JetsonSpec::default();
        let w = WorkloadConfig::default();
        let tps: Vec<f64> = MllmConfig::paper_models()
            .iter()
            .map(|m| run(m, &w, &spec).tokens_per_s())
            .collect();
        let max = tps.iter().cloned().fold(f64::MIN, f64::max);
        let min = tps.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min < 2.2, "spread {}..{} too wide", min, max);
    }

    #[test]
    fn energy_efficiency_below_one_token_per_j() {
        // Paper Table V: 0.28-0.74 token/J.
        let spec = JetsonSpec::default();
        let w = WorkloadConfig::default();
        for m in MllmConfig::paper_models() {
            let s = run(&m, &w, &spec);
            let tj = s.tokens_per_j();
            assert!((0.2..1.0).contains(&tj), "{}: {tj} tok/J", m.name);
        }
    }

    #[test]
    fn backbone_dominates_stage_breakdown() {
        // Paper Fig 1(b): backbone 85.4-95.7%.
        let spec = JetsonSpec::default();
        let w = WorkloadConfig::default();
        for m in MllmConfig::paper_models() {
            let b = stage_breakdown(&m, &w, &spec);
            let backbone = b
                .iter()
                .find(|(s, _)| *s == Stage::Backbone)
                .unwrap()
                .1;
            assert!(backbone > 0.8, "{}: backbone {backbone}", m.name);
        }
    }

    #[test]
    fn mha_largest_decode_component() {
        // Paper Fig 1(c): MHA 44% > FFN 29% > elementwise 26% on GPU.
        let spec = JetsonSpec::default();
        let w = WorkloadConfig::default();
        let s = run(&MllmConfig::mobilevlm_1_7b(), &w, &spec);
        let get = |n: &str| {
            s.decode_breakdown
                .iter()
                .find(|(l, _)| *l == n)
                .unwrap()
                .1
        };
        // With the KV prefix growing to 600+, attention bytes rival FFN.
        assert!(get("MHA") > 0.0 && get("FFN") > 0.0);
    }
}
