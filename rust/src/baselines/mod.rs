//! Baseline platform models for the paper's comparisons: Jetson Orin NX
//! (GPU), FACIL (near-bank DRAM PIM), and the M3D DRAM-only CHIME
//! ablation (implemented inside the simulator via
//! `sim::simulate_dram_only` / `mapping::Plan::build_dram_only`).

pub mod facil;
pub mod jetson;

pub use jetson::BaselineStats;
