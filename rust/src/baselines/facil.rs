//! FACIL baseline (HPCA'25): flexible DRAM address mapping for SoC-PIM
//! cooperative on-device LLM inference.
//!
//! Published envelope (paper Table V): near-bank LPDDR PIM, 7.7–19.3
//! token/s at 5.7–38.5 W, ~200 mm² at 15 nm. We model it as a
//! SoC+PIM split: GEMV-class work (coverage fraction) runs near-bank at
//! the internal bandwidth; the remainder (softmax, norms, attention glue)
//! runs on the SoC over the external interface, with a per-step
//! orchestration overhead for the SoC<->PIM handoffs.

use crate::config::{FacilSpec, MllmConfig, WorkloadConfig};
use crate::model::workload::{inference_ops, VqaTrace};
use crate::model::{OpCost, OpKind};

use super::jetson::BaselineStats;

fn split_step_ns(step: &[OpCost], spec: &FacilSpec) -> f64 {
    let mut pim_bytes: f64 = 0.0;
    let mut soc_bytes: f64 = 0.0;
    for o in step {
        match o.kind {
            // Weight-streaming GEMV work is PIM-eligible.
            OpKind::Gemm | OpKind::Embed => {
                pim_bytes += o.weight_bytes as f64 * spec.pim_coverage;
                soc_bytes += o.weight_bytes as f64 * (1.0 - spec.pim_coverage)
                    + (o.act_in_bytes + o.act_out_bytes) as f64;
            }
            // Attention KV scans: near-bank eligible too (FACIL maps the
            // KV cache), same coverage.
            OpKind::Attention => {
                let kv = (o.kv_read_bytes + o.kv_write_bytes) as f64;
                pim_bytes += kv * spec.pim_coverage;
                soc_bytes += kv * (1.0 - spec.pim_coverage)
                    + (o.act_in_bytes + o.act_out_bytes) as f64;
            }
            // Softmax/norm/elementwise stay on the SoC.
            OpKind::Norm | OpKind::Elementwise => {
                soc_bytes += (o.act_in_bytes + o.act_out_bytes).max(o.sfpe_elems * 2) as f64;
            }
        }
    }
    let pim_bw = spec.internal_bw_gbps * spec.bw_utilization;
    let soc_bw = spec.external_bw_gbps * spec.bw_utilization;
    // SoC and PIM phases serialize (the cooperative handoff), per step.
    pim_bytes / pim_bw + soc_bytes / soc_bw
}

/// Simulate one VQA inference on the FACIL model.
pub fn run(model: &MllmConfig, w: &WorkloadConfig, spec: &FacilSpec) -> BaselineStats {
    let trace = VqaTrace::new(model, w);
    let ops = inference_ops(model, &trace);

    // Encoder/connector/prefill run on the SoC (FACIL targets decode).
    let soc_bw = spec.external_bw_gbps * spec.bw_utilization;
    let encode_bytes: u64 = ops.encode.iter().map(|o| o.total_bytes()).sum();
    let encode_flops: f64 = ops.encode.iter().map(|o| o.flops).sum();
    // SoC compute: a mobile-class NPU ~ 5 TFLOPS effective.
    let encode_ns = (encode_bytes as f64 / soc_bw).max(encode_flops / 5e3);
    let prefill_bytes: u64 = ops.prefill.iter().map(|o| o.total_bytes()).sum();
    let prefill_flops: f64 = ops.prefill.iter().map(|o| o.flops).sum();
    let prefill_ns =
        (prefill_bytes as f64 / soc_bw).max(prefill_flops / 5e3) + spec.step_overhead_ms * 1e6;

    let mut decode_ns = 0.0;
    for step in &ops.decode {
        decode_ns += split_step_ns(step, spec) + spec.step_overhead_ms * 1e6;
    }

    // Power: PIM-active decode pushes toward the top of the envelope for
    // large models; interpolate like the paper's range.
    let params_b = model.llm.total_params() as f64 / 1e9;
    let frac = ((params_b - 0.5) / (2.7 - 0.5)).clamp(0.0, 1.0);
    let avg_power_w = 12.0 + frac * 14.0;

    BaselineStats {
        platform: "facil",
        model: model.name.clone(),
        encode_ns,
        prefill_ns,
        decode_ns,
        output_tokens: trace.output_tokens,
        avg_power_w,
        decode_breakdown: vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{JetsonSpec, WorkloadConfig};

    #[test]
    fn tps_in_published_envelope() {
        let spec = FacilSpec::default();
        let w = WorkloadConfig::default();
        for m in MllmConfig::paper_models() {
            let s = run(&m, &w, &spec);
            let tps = s.tokens_per_s();
            assert!(
                (6.0..26.0).contains(&tps),
                "{}: {tps} TPS outside FACIL's published window",
                m.name
            );
        }
    }

    #[test]
    fn faster_than_jetson() {
        // Paper Table V: FACIL 7.7-19.3 TPS > Jetson 7.4-11 TPS per model.
        let w = WorkloadConfig::default();
        let fs = FacilSpec::default();
        let js = JetsonSpec::default();
        for m in MllmConfig::paper_models() {
            let f = run(&m, &w, &fs).tokens_per_s();
            let j = super::super::jetson::run(&m, &w, &js).tokens_per_s();
            assert!(f > j * 0.95, "{}: facil {f} vs jetson {j}", m.name);
        }
    }

    #[test]
    fn energy_efficiency_band() {
        // Paper Table V: 0.50-1.35 token/J.
        let w = WorkloadConfig::default();
        let spec = FacilSpec::default();
        for m in MllmConfig::paper_models() {
            let tj = run(&m, &w, &spec).tokens_per_j();
            assert!((0.3..1.7).contains(&tj), "{}: {tj} tok/J", m.name);
        }
    }
}
