//! End-to-end mapping plan: MLLM + workload -> placed, fused, scheduled
//! kernel lists for every phase (the mapping framework's output, consumed
//! by the simulation engine and the coordinator).

use crate::config::{ChimeHardware, MllmConfig, WorkloadConfig};
use crate::model::workload::{inference_ops, VqaTrace};
use crate::model::{backbone, OpCost};
use crate::sim::kernels::FusedKernel;

use super::fusion::{fuse_ops, validate};
use super::layout::WeightLayout;

/// A reusable decode-step kernel schedule (see `Plan::decode_template`).
#[derive(Clone)]
pub struct DecodeTemplate {
    pub kernels: Vec<FusedKernel>,
    /// Indices of the position-dependent FUSED_ATTN_STREAM kernels.
    attn_idx: Vec<usize>,
}

/// A fully-resolved execution plan for one model on CHIME.
#[derive(Clone)]
pub struct Plan {
    pub model: MllmConfig,
    pub layout: WeightLayout,
    pub trace: VqaTrace,
    /// Encoder + connector kernels (run once per inference).
    pub encode_kernels: Vec<FusedKernel>,
    /// Prefill kernels over the full prompt.
    pub prefill_kernels: Vec<FusedKernel>,
}

impl Plan {
    /// Build the plan. Panics only on internal fusion invariant violations
    /// (validated here so downstream code can trust the schedule).
    pub fn build(model: &MllmConfig, hw: &ChimeHardware, w: &WorkloadConfig) -> Plan {
        let trace = VqaTrace::new(model, w);
        let ops = inference_ops(model, &trace);
        let encode_kernels = fuse_ops(&ops.encode, model.vision.out_tokens.max(1));
        let prefill_kernels = fuse_ops(&ops.prefill, trace.prefill_len());
        validate(&encode_kernels).expect("encode fusion invariant");
        validate(&prefill_kernels).expect("prefill fusion invariant");
        Plan {
            model: model.clone(),
            layout: WeightLayout::plan(model, hw),
            trace,
            encode_kernels,
            prefill_kernels,
        }
    }

    /// Clone this plan once per package for multi-package sharded serving.
    ///
    /// The *schedule* is shared: every package runs the same model with the
    /// same weight layout (each package physically holds its own replica of
    /// the read-only weights, so the layout bytes are identical). The *KV
    /// budget* is independent: each package's `SimEngine` owns a private
    /// DRAM tier/RRAM state, so one package's KV growth or offload never
    /// consumes another's headroom (`kv_budget_bytes` per package).
    pub fn replicate(&self, packages: usize) -> Vec<Plan> {
        assert!(packages >= 1, "a sharded deployment needs at least one package");
        (0..packages).map(|_| self.clone()).collect()
    }

    /// Per-package KV headroom: DRAM stack capacity not claimed by the
    /// resident weights. Every package replica gets this full budget —
    /// KV caches are request-private and never shared across packages.
    pub fn kv_budget_bytes(&self, hw: &ChimeHardware) -> u64 {
        hw.dram
            .chip_capacity_bytes()
            .saturating_sub(self.layout.dram_weight_bytes)
    }

    /// DRAM-only ablation plan: same fusion, all weights in DRAM, FFN
    /// kernels re-placed onto the DRAM chiplet (no second chiplet).
    pub fn build_dram_only(model: &MllmConfig, hw: &ChimeHardware, w: &WorkloadConfig) -> Plan {
        let mut plan = Self::build(model, hw, w);
        plan.layout = WeightLayout::plan_dram_only(model, hw);
        for k in plan
            .encode_kernels
            .iter_mut()
            .chain(plan.prefill_kernels.iter_mut())
        {
            k.placement = crate::sim::kernels::Placement::DramChiplet;
            k.cut_in = false;
            k.cut_out = false;
        }
        plan
    }

    /// Kernels for decode step at global position `pos` (prefix pos+1
    /// after append). Generated on demand — the schedule depends on the
    /// growing KV prefix.
    pub fn decode_kernels(&self, pos: usize) -> Vec<FusedKernel> {
        let ops = backbone::decode_ops(&self.model.llm, pos);
        fuse_ops(&ops, 1)
    }

    /// §Perf hot path: a reusable decode-step schedule. Only the
    /// attention kernels depend on the step position (KV reads, score
    /// FLOPs, online-softmax work all scale with the kv_len prefix), so
    /// the template is fused once and `patch_decode_template` updates
    /// just those fields — avoiding the per-step op-list rebuild + fusion
    /// pass that dominated the simulator profile (EXPERIMENTS.md §Perf).
    pub fn decode_template(&self) -> DecodeTemplate {
        let kernels = self.decode_kernels(0); // kv_len = 1 reference
        let attn_idx: Vec<usize> = kernels
            .iter()
            .enumerate()
            .filter(|(_, k)| k.kind == crate::sim::kernels::FusedKind::FusedAttnStream)
            .map(|(i, _)| i)
            .collect();
        DecodeTemplate { kernels, attn_idx }
    }

    /// DRAM-only variant of the template (Fig 9 ablation).
    pub fn decode_template_dram_only(&self) -> DecodeTemplate {
        let mut t = self.decode_template();
        for k in &mut t.kernels {
            k.placement = crate::sim::kernels::Placement::DramChiplet;
            k.cut_in = false;
            k.cut_out = false;
        }
        t
    }

    /// Re-target a decode template at global position `pos`.
    pub fn patch_decode_template(&self, t: &mut DecodeTemplate, pos: usize) {
        let llm = &self.model.llm;
        let kv_len = pos + 1;
        let b = llm.bytes_per_param;
        for &i in &t.attn_idx {
            let k = &mut t.kernels[i];
            // ops[0] is the attn_stream op (see fusion::fuse_ops grouping).
            let op = &mut k.ops[0];
            debug_assert_eq!(op.name, "attn_stream");
            op.flops = 2.0 * 2.0 * (llm.n_heads * kv_len * llm.d_head) as f64;
            op.kv_read_bytes = (2 * kv_len * llm.d_kv() * b) as u64;
            op.sfpe_elems = (llm.n_heads * kv_len) as u64;
        }
    }

    /// DRAM-only variant of a decode step.
    pub fn decode_kernels_dram_only(&self, pos: usize) -> Vec<FusedKernel> {
        let mut ks = self.decode_kernels(pos);
        for k in &mut ks {
            k.placement = crate::sim::kernels::Placement::DramChiplet;
            k.cut_in = false;
            k.cut_out = false;
        }
        ks
    }

    /// Total weight bytes streamed per decode step (roofline sanity).
    pub fn decode_weight_bytes(&self) -> u64 {
        self.decode_kernels(self.trace.prefill_len())
            .iter()
            .map(|k| k.weight_bytes())
            .sum()
    }

    /// All operators of a decode step (for baselines that price raw ops).
    pub fn decode_raw_ops(&self, pos: usize) -> Vec<OpCost> {
        backbone::decode_ops(&self.model.llm, pos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChimeConfig;
    use crate::sim::kernels::Placement;

    #[test]
    fn plan_builds_for_all_models() {
        let cfg = ChimeConfig::default();
        for m in MllmConfig::paper_models() {
            let p = Plan::build(&m, &cfg.hardware, &cfg.workload);
            assert!(!p.prefill_kernels.is_empty());
            assert!(!p.encode_kernels.is_empty());
            assert_eq!(p.layout.spill_bytes, 0);
            let dk = p.decode_kernels(p.trace.prefill_len());
            assert!(dk.iter().any(|k| k.placement == Placement::RramChiplet));
        }
    }

    #[test]
    fn dram_only_plan_has_single_placement() {
        let cfg = ChimeConfig::default();
        let m = MllmConfig::mobilevlm_3b();
        let p = Plan::build_dram_only(&m, &cfg.hardware, &cfg.workload);
        let dk = p.decode_kernels_dram_only(200);
        assert!(dk.iter().all(|k| k.placement == Placement::DramChiplet));
        assert!(dk.iter().all(|k| !k.cut_in && !k.cut_out));
        assert_eq!(p.layout.rram_weight_bytes, 0);
    }

    #[test]
    fn template_path_matches_fresh_fusion() {
        // §Perf regression guard: the patched template must be
        // numerically identical to rebuilding the schedule from scratch.
        let cfg = ChimeConfig::default();
        for m in [MllmConfig::fastvlm_0_6b(), MllmConfig::mobilevlm_3b()] {
            let p = Plan::build(&m, &cfg.hardware, &cfg.workload);
            let mut tmpl = p.decode_template();
            for pos in [p.trace.prefill_len(), p.trace.prefill_len() + 137, 4000] {
                p.patch_decode_template(&mut tmpl, pos);
                let fresh = p.decode_kernels(pos);
                assert_eq!(tmpl.kernels.len(), fresh.len());
                for (a, b) in tmpl.kernels.iter().zip(&fresh) {
                    assert_eq!(a.kind, b.kind);
                    assert_eq!(a.placement, b.placement);
                    assert_eq!(a.weight_bytes(), b.weight_bytes());
                    assert_eq!(a.kv_read_bytes(), b.kv_read_bytes());
                    assert_eq!(a.kv_write_bytes(), b.kv_write_bytes());
                    assert_eq!(a.sfpe_elems(), b.sfpe_elems());
                    assert!((a.flops() - b.flops()).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn replicated_plans_share_weights_with_independent_kv_budgets() {
        let cfg = ChimeConfig::default();
        let m = MllmConfig::fastvlm_0_6b();
        let p = Plan::build(&m, &cfg.hardware, &cfg.workload);
        let replicas = p.replicate(3);
        assert_eq!(replicas.len(), 3);
        for r in &replicas {
            // Shared schedule/weights: identical layout bytes and kernels.
            assert_eq!(r.layout.dram_weight_bytes, p.layout.dram_weight_bytes);
            assert_eq!(r.layout.rram_weight_bytes, p.layout.rram_weight_bytes);
            assert_eq!(r.prefill_kernels.len(), p.prefill_kernels.len());
            // Independent (full, not divided) KV budget per package.
            assert_eq!(r.kv_budget_bytes(&cfg.hardware), p.kv_budget_bytes(&cfg.hardware));
        }
        let budget = p.kv_budget_bytes(&cfg.hardware);
        assert!(budget > 0, "weights must leave KV headroom");
        assert_eq!(
            budget,
            cfg.hardware.dram.chip_capacity_bytes() - p.layout.dram_weight_bytes
        );
        // Each replica drives its own engine: KV growth in one engine must
        // not show up in a sibling built from another replica.
        let mut e0 = crate::sim::SimEngine::new(&cfg.hardware, &replicas[0]);
        let e1 = crate::sim::SimEngine::new(&cfg.hardware, &replicas[1]);
        let ks = replicas[0].decode_kernels(replicas[0].trace.prefill_len());
        let _ = e0.run_kernels(&ks);
        let kv0: u64 = e0.dram.state().tiers.iter().map(|t| t.kv).sum();
        let kv1: u64 = e1.dram.state().tiers.iter().map(|t| t.kv).sum();
        assert!(kv0 > 0, "decode step must append KV");
        assert_eq!(kv1, 0, "sibling package's KV state must be untouched");
    }

    #[test]
    #[should_panic(expected = "at least one package")]
    fn replicate_rejects_zero_packages() {
        let cfg = ChimeConfig::default();
        let p = Plan::build(&MllmConfig::fastvlm_0_6b(), &cfg.hardware, &cfg.workload);
        let _ = p.replicate(0);
    }

    #[test]
    fn decode_weight_bytes_match_model_accounting() {
        let cfg = ChimeConfig::default();
        let m = MllmConfig::fastvlm_0_6b();
        let p = Plan::build(&m, &cfg.hardware, &cfg.workload);
        let llm = &m.llm;
        let expect = llm.n_layers as u64
            * (llm.attn_weight_bytes_per_layer() + llm.ffn_weight_bytes_per_layer())
            + llm.lm_head_bytes()
            + (llm.d_model * llm.bytes_per_param) as u64;
        assert_eq!(p.decode_weight_bytes(), expect);
    }
}
