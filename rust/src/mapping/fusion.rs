//! ❸ Kernel locality-aware fusion (paper §III-C, Table I).
//!
//! Groups the model's operator stream into fused near-memory kernels whose
//! boundaries coincide with chiplet boundaries. Intermediates inside a
//! fused kernel never leave the logic die; only AttnOut / FFNOut cross
//! the package (the two cut points).

use crate::model::OpCost;
use crate::sim::kernels::{FusedKernel, FusedKind, Placement};

use super::layout::place_op;

/// Fuse an operator list (one phase: encode / prefill / one decode step)
/// into the Table I kernel schedule. `m_rows` is the activation row count
/// of the phase (prefill length, or 1 for decode).
pub fn fuse_ops(ops: &[OpCost], m_rows: usize) -> Vec<FusedKernel> {
    let mut kernels: Vec<FusedKernel> = Vec::new();

    let mut push = |kind: FusedKind, group: Vec<OpCost>, cut_in: bool, cut_out: bool| {
        if group.is_empty() {
            return;
        }
        let placement = place_op(&group[0]);
        debug_assert!(
            group.iter().all(|o| place_op(o) == placement),
            "fusion must never span a chiplet boundary ({:?})",
            kind
        );
        let layer = group[0].layer;
        kernels_push(&mut kernels, FusedKernel {
            kind,
            placement,
            layer,
            m_rows,
            ops: group,
            cut_in,
            cut_out,
        });
    };

    let mut i = 0;
    while i < ops.len() {
        let op = &ops[i];
        match op.name {
            // Vision encoder block: preprocess + trunk fuse on DRAM.
            "vision.preprocess" => {
                let mut group = vec![op.clone()];
                while i + 1 < ops.len() && ops[i + 1].name.starts_with("vision.") {
                    i += 1;
                    group.push(ops[i].clone());
                }
                push(FusedKind::VisionBlock, group, false, false);
            }
            n if n.starts_with("vision.") => {
                push(FusedKind::VisionBlock, vec![op.clone()], false, false);
            }
            n if n.starts_with("connector.") => {
                push(FusedKind::ConnectorBlock, vec![op.clone()], false, false);
            }
            "embed" => push(FusedKind::Embed, vec![op.clone()], false, false),
            "norm.attn" => push(FusedKind::FusedNorm, vec![op.clone()], false, false),
            "qkv_proj" => push(FusedKind::FusedQkvProj, vec![op.clone()], false, false),
            // FUSED_ATTN_STREAM absorbs the output projection and the
            // residual add: scores, online softmax, PV accumulate, O-proj,
            // and the residual all stay in PU shared memory. Its output is
            // AttnOut — cut point #1.
            "attn_stream" => {
                let mut group = vec![op.clone()];
                while i + 1 < ops.len()
                    && matches!(ops[i + 1].name, "attn_out_proj" | "residual.attn")
                {
                    i += 1;
                    group.push(ops[i].clone());
                }
                push(FusedKind::FusedAttnStream, group, false, true);
            }
            // FUSED_FFN_ACT absorbs the pre-FFN norm: AttnOut arrives over
            // UCIe (cut_in), is normalized in place, chained through both
            // GEMMs + activation, and FFNOut streams back (cut_out).
            "norm.ffn" => {
                let mut group = vec![op.clone()];
                if i + 1 < ops.len() && ops[i + 1].name == "ffn_act" {
                    i += 1;
                    group.push(ops[i].clone());
                }
                push(FusedKind::FusedFfnAct, group, true, true);
            }
            "ffn_act" => push(FusedKind::FusedFfnAct, vec![op.clone()], true, true),
            "residual.ffn" => {
                push(FusedKind::Elementwise, vec![op.clone()], false, false)
            }
            // Final norm + unembedding fuse into the LM head GEMV.
            "norm.final" => {
                let mut group = vec![op.clone()];
                if i + 1 < ops.len() && ops[i + 1].name == "lm_head" {
                    i += 1;
                    group.push(ops[i].clone());
                }
                push(FusedKind::LmHead, group, false, false);
            }
            "lm_head" => push(FusedKind::LmHead, vec![op.clone()], false, false),
            other => panic!("fusion pass: unknown operator {other:?}"),
        }
        i += 1;
    }
    kernels
}

fn kernels_push(kernels: &mut Vec<FusedKernel>, k: FusedKernel) {
    kernels.push(k);
}

/// Fusion invariants (enforced in tests + proptests):
/// 1. every kernel's ops share one placement;
/// 2. cut_in/cut_out appear only on chiplet-boundary kernels;
/// 3. the kernel sequence alternates chiplets only at cut points.
pub fn validate(kernels: &[FusedKernel]) -> Result<(), String> {
    let mut prev_placement: Option<Placement> = None;
    let mut prev_cut_out = false;
    for k in kernels {
        for op in &k.ops {
            if place_op(op) != k.placement {
                return Err(format!(
                    "kernel {:?} contains op {} placed on the other chiplet",
                    k.kind, op.name
                ));
            }
        }
        if let Some(p) = prev_placement {
            if p != k.placement && !(prev_cut_out || k.cut_in) {
                return Err(format!(
                    "chiplet switch into {:?} without a cut point",
                    k.kind
                ));
            }
        }
        prev_placement = Some(k.placement);
        prev_cut_out = k.cut_out;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MllmConfig;
    use crate::model::backbone;

    #[test]
    fn decode_step_fuses_to_table_i_schedule() {
        let llm = MllmConfig::fastvlm_0_6b().llm;
        let ops = backbone::decode_ops(&llm, 50);
        let kernels = fuse_ops(&ops, 1);
        validate(&kernels).unwrap();
        // Per layer: NORM, QKV, ATTN(+proj+res), FFN(+norm), ELEMENTWISE
        // = 5 kernels; plus EMBED and LM_HEAD.
        assert_eq!(kernels.len(), 2 + 5 * llm.n_layers);
        let ffn: Vec<_> = kernels
            .iter()
            .filter(|k| k.kind == FusedKind::FusedFfnAct)
            .collect();
        assert_eq!(ffn.len(), llm.n_layers);
        for k in &ffn {
            assert_eq!(k.placement, Placement::RramChiplet);
            assert!(k.cut_in && k.cut_out);
            // The pre-FFN norm was absorbed.
            assert_eq!(k.ops.len(), 2);
        }
    }

    #[test]
    fn attn_kernel_absorbs_projection_and_residual() {
        let llm = MllmConfig::tiny().llm;
        let ops = backbone::decode_ops(&llm, 3);
        let kernels = fuse_ops(&ops, 1);
        let attn = kernels
            .iter()
            .find(|k| k.kind == FusedKind::FusedAttnStream)
            .unwrap();
        let names: Vec<_> = attn.ops.iter().map(|o| o.name).collect();
        assert_eq!(names, vec!["attn_stream", "attn_out_proj", "residual.attn"]);
        assert!(attn.cut_out, "AttnOut is cut point #1");
    }

    #[test]
    fn exactly_two_cut_points_per_layer() {
        let llm = MllmConfig::mobilevlm_1_7b().llm;
        let ops = backbone::decode_ops(&llm, 7);
        let kernels = fuse_ops(&ops, 1);
        let cuts_out = kernels.iter().filter(|k| k.cut_out).count();
        // AttnOut + FFNOut per layer = 2 cut-point producers per layer.
        assert_eq!(cuts_out, 2 * llm.n_layers);
    }

    #[test]
    fn prefill_fusion_carries_m_rows() {
        let llm = MllmConfig::tiny().llm;
        let ops = backbone::prefill_ops(&llm, 32);
        let kernels = fuse_ops(&ops, 32);
        assert!(kernels.iter().all(|k| k.m_rows == 32));
        validate(&kernels).unwrap();
    }
}
