//! ❷ Endurance-aware KV-cache tiered scheduling (paper §III-C).
//!
//! Mechanics (tier fill, cold-offload) live in `sim::memory::dram`; this
//! module holds the *policy* analysis: block hotness, the
//! migrate-only-when-reuse-outweighs-transfer-cost rule, and reporting
//! helpers for the tiering experiments.

use crate::config::{DramConfig, RramConfig};
use crate::sim::memory::{DramState, KvResidency};

/// KV block granularity (tokens). The paper writes KV "blocks"; 16 tokens
/// per block keeps migration decisions coarse enough to amortize DMA.
pub const KV_BLOCK_TOKENS: usize = 16;

/// Cost-benefit check for migrating a KV block between tiers (or to
/// RRAM): migrate only when the total read-time saving over the expected
/// remaining reads exceeds the one-time move cost (paper: "migrates data
/// only when reuse outweighs transfer cost").
pub fn migration_worthwhile(
    dram: &DramConfig,
    block_bytes: u64,
    from_tier: usize,
    to_tier: usize,
    expected_remaining_reads: u64,
) -> bool {
    let from_bw = dram.tier_stream_bw_gbps(from_tier, 1.0);
    let to_bw = dram.tier_stream_bw_gbps(to_tier, 1.0);
    let per_read_saving_ns = block_bytes as f64 / from_bw - block_bytes as f64 / to_bw;
    if per_read_saving_ns <= 0.0 {
        return false;
    }
    // Move cost: read from source + write to destination.
    let move_cost_ns = block_bytes as f64 / from_bw + block_bytes as f64 / to_bw;
    per_read_saving_ns * expected_remaining_reads as f64 > move_cost_ns
}

/// Offload decision for the cold tail: one-shot write-once to RRAM is
/// worthwhile when DRAM pressure would otherwise push *hot* data up-tier.
/// (DramState applies this mechanically when capacity runs out; this
/// predicate exposes the policy for tests/ablation.)
pub fn offload_worthwhile(dram_free_bytes: u64, incoming_bytes: u64) -> bool {
    incoming_bytes > dram_free_bytes
}

/// Endurance guard: writes/s the RRAM can absorb for a target lifetime.
pub fn max_write_rate_for_lifetime(
    rram: &RramConfig,
    target_lifetime_s: f64,
) -> f64 {
    // Ideal wear-leveling: capacity * endurance total writes over lifetime.
    rram.chip_capacity_bytes as f64 * rram.endurance_writes as f64 / target_lifetime_s
}

/// Snapshot of the KV tier distribution for reporting.
#[derive(Debug, Clone)]
pub struct TierSnapshot {
    /// (tier index or RRAM, bytes, fraction).
    pub entries: Vec<(String, u64, f64)>,
    pub total_bytes: u64,
    /// Effective KV stream bandwidth implied by the mix (GB/s).
    pub effective_bw_gbps: f64,
}

pub fn snapshot(dram: &DramState) -> TierSnapshot {
    let dist = dram.kv_distribution();
    let total: u64 = dist.iter().map(|(_, b)| b).sum();
    let mut entries = Vec::new();
    let mut inv_bw_weighted = 0.0;
    for (res, bytes) in &dist {
        let frac = if total > 0 { *bytes as f64 / total as f64 } else { 0.0 };
        let (name, bw) = match res {
            KvResidency::Tier(t) => (
                format!("tier{t}"),
                dram.cfg.tier_stream_bw_gbps(*t, 1.0),
            ),
            // Cold RRAM reads: interface bandwidth (see RramState).
            KvResidency::Rram => ("rram".to_string(), 512.0 * 0.85),
        };
        inv_bw_weighted += frac / bw;
        entries.push((name, *bytes, frac));
    }
    let effective_bw = if inv_bw_weighted > 0.0 { 1.0 / inv_bw_weighted } else { 0.0 };
    TierSnapshot { entries, total_bytes: total, effective_bw_gbps: effective_bw }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DramConfig;

    #[test]
    fn migration_needs_enough_reuse() {
        let d = DramConfig::default();
        let block = (KV_BLOCK_TOKENS * 1024) as u64;
        // Moving up (4 -> 0) with many remaining reads: worth it.
        assert!(migration_worthwhile(&d, block, 4, 0, 1000));
        // One remaining read cannot amortize the move.
        assert!(!migration_worthwhile(&d, block, 4, 0, 1));
        // Moving down (0 -> 4) never saves read time.
        assert!(!migration_worthwhile(&d, block, 0, 4, 1000));
    }

    #[test]
    fn offload_only_under_pressure() {
        assert!(!offload_worthwhile(1000, 500));
        assert!(offload_worthwhile(100, 500));
    }

    #[test]
    fn write_rate_budget_is_huge_for_write_once() {
        let r = RramConfig::default();
        // 5-year lifetime.
        let rate = max_write_rate_for_lifetime(&r, 5.0 * 365.0 * 86400.0);
        // Budget must vastly exceed any per-inference KV offload volume
        // (MBs per inference, ~seconds per inference -> ~MB/s demand).
        assert!(rate > 1e7, "rate {rate} B/s");
    }

    #[test]
    fn snapshot_effective_bw_between_extremes() {
        let mut dram = DramState::new(DramConfig::default());
        dram.place_weights(2 * dram.cfg.tier_capacity_bytes).unwrap();
        dram.append_kv(dram.cfg.tier_capacity_bytes / 2); // tier 2
        dram.append_kv(dram.cfg.tier_capacity_bytes); // fills t2, spills t3
        let snap = snapshot(&dram);
        assert!(snap.total_bytes > 0);
        let bw0 = dram.cfg.tier_stream_bw_gbps(0, 1.0);
        let bw4 = dram.cfg.tier_stream_bw_gbps(4, 1.0);
        assert!(snap.effective_bw_gbps < bw0);
        assert!(snap.effective_bw_gbps > bw4);
    }
}
