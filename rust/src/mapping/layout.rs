//! ❶ Workload-aware data layout (paper §III-C).
//!
//! Static placement of model components onto the heterogeneous memories,
//! governed by the strict two-cut-point dataflow: everything except the
//! FFN lives with the DRAM chiplet (QKV/O weights, embeddings, encoder +
//! connector weights, KV cache); FFN weights are resident in RRAM. The
//! only activations that may cross UCIe are AttnOut and FFNOut.

use crate::config::{ChimeHardware, MllmConfig};
use crate::model::{OpCost, OpKind, Stage};
use crate::sim::kernels::Placement;
use crate::sim::memory::dram::WeightClass;

/// Placement rule for a single operator (the two-cut-point partitioning).
pub fn place_op(op: &OpCost) -> Placement {
    match (op.stage, op.name) {
        // The FFN block (pre-norm + both GEMMs + activation) is the only
        // RRAM-side work in steady state.
        (Stage::Backbone, "ffn_act") | (Stage::Backbone, "norm.ffn") => Placement::RramChiplet,
        // Everything else — attention, norms, projections, encoder,
        // connector, lm_head, embeddings — executes near DRAM.
        _ => Placement::DramChiplet,
    }
}

/// Static weight-placement plan for one model.
#[derive(Debug, Clone)]
pub struct WeightLayout {
    /// Bytes placed in the M3D DRAM tiers (attention/QKV/O + embeddings +
    /// encoder + connector).
    pub dram_weight_bytes: u64,
    /// DRAM bytes by heat class, in placement-priority order (hottest
    /// first -> fastest tiers). Sums to `dram_weight_bytes`.
    pub dram_classes: Vec<(WeightClass, u64)>,
    /// Bytes resident in M3D RRAM (FFN weights [+ untied lm_head spill]).
    pub rram_weight_bytes: u64,
    /// Bytes that fit in neither (0 for all Table II models).
    pub spill_bytes: u64,
}

impl WeightLayout {
    /// Compute the layout for `model` on `hw`. DRAM-side weights are
    /// packed bottom-up into the fastest tiers; FFN weights go to RRAM.
    /// If a weight class overflows its home device, it spills to the
    /// other; only then does `spill_bytes` become nonzero.
    pub fn plan(model: &MllmConfig, hw: &ChimeHardware) -> WeightLayout {
        let llm = &model.llm;
        let attn = llm.n_layers as u64
            * (llm.attn_weight_bytes_per_layer() + llm.norm_weight_bytes_per_layer());
        let lm_head = if llm.tied_embeddings { 0 } else { llm.lm_head_bytes() };
        let embed = llm.embedding_bytes();
        let visconn = model.vision.weight_bytes() + model.connector.weight_bytes();
        let mut classes = vec![
            (WeightClass::Attn, attn),
            (WeightClass::LmHead, lm_head),
            (WeightClass::Embed, embed),
            (WeightClass::VisionConn, visconn),
        ];
        let mut dram: u64 = classes.iter().map(|(_, b)| b).sum();
        let mut rram = llm.ffn_weight_bytes_per_layer() * llm.n_layers as u64;

        let dram_cap = hw.dram.chip_capacity_bytes();
        let rram_cap = hw.rram.chip_capacity_bytes;
        let mut spill = 0u64;

        if rram > rram_cap {
            // FFN overflow migrates back to DRAM (never happens for the
            // Table II models; guards custom configs).
            let over = rram - rram_cap;
            rram = rram_cap;
            dram += over;
            classes.insert(1, (WeightClass::Ffn, over));
        }
        if dram > dram_cap {
            let over = dram - dram_cap;
            dram = dram_cap;
            let free_rram = rram_cap - rram;
            let to_rram = over.min(free_rram);
            rram += to_rram;
            spill = over - to_rram;
            // Trim the coldest classes to what actually fits.
            let mut drop = over;
            for (_, b) in classes.iter_mut().rev() {
                let cut = drop.min(*b);
                *b -= cut;
                drop -= cut;
                if drop == 0 { break; }
            }
        }
        classes.retain(|(_, b)| *b > 0);
        WeightLayout {
            dram_weight_bytes: dram,
            dram_classes: classes,
            rram_weight_bytes: rram,
            spill_bytes: spill,
        }
    }

    /// DRAM-only ablation layout (Fig 9): *all* weights stream from DRAM.
    /// FFN joins the hot set (it streams every token), placed after the
    /// attention weights.
    pub fn plan_dram_only(model: &MllmConfig, hw: &ChimeHardware) -> WeightLayout {
        let full = Self::plan(model, hw);
        let ffn = full.rram_weight_bytes;
        let total = full.dram_weight_bytes + ffn;
        let dram_cap = hw.dram.chip_capacity_bytes();
        let dram = total.min(dram_cap);
        let mut classes = full.dram_classes.clone();
        classes.insert(1, (WeightClass::Ffn, ffn));
        // Trim coldest classes to capacity.
        let mut drop = total.saturating_sub(dram_cap);
        for (_, b) in classes.iter_mut().rev() {
            let cut = drop.min(*b);
            *b -= cut;
            drop -= cut;
            if drop == 0 { break; }
        }
        classes.retain(|(_, b)| *b > 0);
        WeightLayout {
            dram_weight_bytes: dram,
            dram_classes: classes,
            rram_weight_bytes: 0,
            spill_bytes: total - dram,
        }
    }
}

/// Sanity: is this operator allowed to carry weights on its placement?
/// (KV reads are DRAM/tier business; FFN weights must not stream over
/// UCIe — that is the whole point of the layout.)
pub fn placement_consistent(op: &OpCost) -> bool {
    match place_op(op) {
        Placement::RramChiplet => op.kind != OpKind::Attention && op.kv_read_bytes == 0,
        Placement::DramChiplet => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChimeHardware;
    use crate::model::backbone;

    #[test]
    fn ffn_goes_to_rram_everything_else_dram() {
        let llm = MllmConfig::fastvlm_0_6b().llm;
        let ops = backbone::decode_ops(&llm, 10);
        for op in &ops {
            let p = place_op(op);
            if op.name == "ffn_act" || op.name == "norm.ffn" {
                assert_eq!(p, Placement::RramChiplet, "{}", op.name);
            } else {
                assert_eq!(p, Placement::DramChiplet, "{}", op.name);
            }
            assert!(placement_consistent(op), "{}", op.name);
        }
    }

    #[test]
    fn table_ii_models_fit_without_spill() {
        let hw = ChimeHardware::default();
        for m in MllmConfig::paper_models() {
            let l = WeightLayout::plan(&m, &hw);
            assert_eq!(l.spill_bytes, 0, "{} spills", m.name);
            assert!(l.rram_weight_bytes <= hw.rram.chip_capacity_bytes);
            assert!(l.dram_weight_bytes <= hw.dram.chip_capacity_bytes());
        }
    }

    #[test]
    fn ffn_weights_dominate_rram_share() {
        let hw = ChimeHardware::default();
        let m = MllmConfig::mobilevlm_3b();
        let l = WeightLayout::plan(&m, &hw);
        let ffn = m.llm.ffn_weight_bytes_per_layer() * m.llm.n_layers as u64;
        assert_eq!(l.rram_weight_bytes, ffn);
    }

    #[test]
    fn dram_only_moves_everything() {
        let hw = ChimeHardware::default();
        let m = MllmConfig::fastvlm_1_7b();
        let het = WeightLayout::plan(&m, &hw);
        let solo = WeightLayout::plan_dram_only(&m, &hw);
        assert_eq!(solo.rram_weight_bytes, 0);
        assert_eq!(
            solo.dram_weight_bytes + solo.spill_bytes,
            het.dram_weight_bytes + het.rram_weight_bytes
        );
    }
}
