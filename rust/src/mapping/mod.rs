//! The CHIME mapping framework (paper §III-C): ❶ workload-aware data
//! layout, ❷ endurance-aware KV-cache tiering, ❸ kernel locality-aware
//! fusion, composed by the planner into executable schedules.

pub mod fusion;
pub mod layout;
pub mod planner;
pub mod tiering;

pub use layout::WeightLayout;
pub use planner::Plan;
