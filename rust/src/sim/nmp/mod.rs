//! Near-memory-processor timing models (PE tensor cores + SFPE SIMD).

pub mod pe;
pub mod sfpe;
