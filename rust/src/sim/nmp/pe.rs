//! Processing-element (tensor-core) timing model.
//!
//! Each PE carries an r x c MAC array with double-buffered SRAM: while one
//! tile computes, the next streams in, hiding movement latency (paper
//! §III-B1). The chiplet-level kernel time is therefore
//! max(stream, compute) rather than their sum — the double-buffer model.

use crate::config::NmpConfig;

/// GEMM compute time on the PE cluster (all PUs), ns.
///
/// `m` is the activation-row dimension. Decode is GEMV-shaped (m = 1),
/// but the PEs use an output-stationary mapping: the r x c MAC array
/// parallelizes over *output neurons*, so a single activation row still
/// feeds every MAC (each weight byte is consumed exactly once — the
/// near-memory design premise). Utilization therefore does not collapse
/// with m; only a sustained-fraction derate applies (pipeline fill,
/// edge tiles).
pub fn gemm_compute_ns(nmp: &NmpConfig, flops: f64, m: usize) -> f64 {
    let _ = m; // kept in the signature: prefill/decode call sites differ
    let sustain = 0.85;
    let eff = nmp.peak_flops_per_ns() * sustain;
    flops / eff
}

/// Energy burned by the PE cluster for `busy_ns` of compute at a given
/// activity factor (fraction of peak dynamic power), pJ.
pub fn compute_energy_pj(nmp: &NmpConfig, busy_ns: f64, activity: f64) -> f64 {
    // W * ns = nJ; *1000 -> pJ.
    nmp.peak_power_w * activity.clamp(0.0, 1.0) * busy_ns * 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_stationary_gemv_keeps_macs_fed() {
        let nmp = NmpConfig::dram_default();
        let flops = 1e9;
        let t_gemv = gemm_compute_ns(&nmp, flops, 1);
        let t_gemm = gemm_compute_ns(&nmp, flops, 64);
        assert!((t_gemv - t_gemm).abs() < 1e-9, "m must not change throughput");
        // 1e9 flops at 2 TFLOPS x 0.85 sustain ~ 0.59 ms.
        assert!((t_gemv - 1e9 / (2e3 * 0.85)).abs() < 1e-6);
    }

    #[test]
    fn rram_pe_wider_array() {
        let d = NmpConfig::dram_default();
        let r = NmpConfig::rram_default();
        // Same FLOPs, fully-fed: RRAM NMP is 16x faster (32 vs 2 TFLOPS).
        let td = gemm_compute_ns(&d, 1e9, 64);
        let tr = gemm_compute_ns(&r, 1e9, 64);
        assert!((td / tr - 16.0).abs() < 1e-6);
    }

    #[test]
    fn energy_scales_with_time_and_activity() {
        let nmp = NmpConfig::rram_default();
        let e1 = compute_energy_pj(&nmp, 1000.0, 0.5);
        let e2 = compute_energy_pj(&nmp, 2000.0, 0.5);
        let e3 = compute_energy_pj(&nmp, 1000.0, 1.0);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
        assert!((e3 / e1 - 2.0).abs() < 1e-9);
        // 2.584 W for 1000 ns at full activity = 2584 nJ.
        assert!((compute_energy_pj(&nmp, 1000.0, 1.0) - 2.584e6).abs() < 1.0);
    }
}
