//! Special-function PE (SFPE) timing: the 256-way SIMD lane that executes
//! online softmax, normalization, activation functions, and residual glue
//! (paper Table I's SFPE stages).

use crate::config::NmpConfig;

/// Elementwise/special-function time for `elems` elements, ns.
///
/// Special functions (exp, rsqrt) are multi-cycle; `cycles_per_elem`
/// captures the pipeline cost per element per lane.
pub fn sfpe_ns(nmp: &NmpConfig, elems: u64, cycles_per_elem: f64) -> f64 {
    if elems == 0 {
        return 0.0;
    }
    elems as f64 * cycles_per_elem / nmp.sfpe_elems_per_ns()
}

/// Cycles-per-element presets by operation class.
pub mod cost {
    /// Online softmax update: max, exp, scale, accumulate.
    pub const SOFTMAX: f64 = 4.0;
    /// LayerNorm: two reduction passes + normalize + scale/shift.
    pub const NORM: f64 = 3.0;
    /// GELU/SiLU activation.
    pub const ACTIVATION: f64 = 2.0;
    /// Residual add / bias add.
    pub const ADD: f64 = 1.0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_elems_free() {
        let nmp = NmpConfig::dram_default();
        assert_eq!(sfpe_ns(&nmp, 0, cost::SOFTMAX), 0.0);
    }

    #[test]
    fn dram_sfpe_throughput() {
        let nmp = NmpConfig::dram_default();
        // 256 lanes x 16 PUs @ 1 GHz = 4096 elems/ns at 1 cycle/elem.
        let t = sfpe_ns(&nmp, 4096 * 100, cost::ADD);
        assert!((t - 100.0).abs() < 1e-9);
    }

    #[test]
    fn softmax_costlier_than_add() {
        let nmp = NmpConfig::dram_default();
        assert!(sfpe_ns(&nmp, 1000, cost::SOFTMAX) > sfpe_ns(&nmp, 1000, cost::ADD));
    }

    #[test]
    fn rram_nmp_falls_back_to_pe_lanes() {
        let nmp = NmpConfig::rram_default();
        // No SFPE on the RRAM logic die; elementwise still executes.
        let t = sfpe_ns(&nmp, 1_000_000, cost::ACTIVATION);
        assert!(t > 0.0 && t.is_finite());
    }
}
