//! DRAM-chiplet execution model: fused kernels running on the DRAM NMP
//! fed by the tiered M3D DRAM stack.
//!
//! Kernel time = dispatch + max(weight/KV streaming, MAC compute, SFPE)
//! — the SFPE-PE pipeline with double-buffered PEs overlaps all three
//! (paper §III-B1: "compute on one tile while transferring the other").

use crate::config::NmpConfig;
use crate::sim::energy::Component;
use crate::sim::fabric::Fabric;
use crate::sim::kernels::{FusedKernel, KernelCost};
use crate::sim::memory::dram::WeightClass;
use crate::sim::memory::{DramMem, KvResidency, RramMem};
use crate::sim::nmp::{pe, sfpe};

/// Execute one fused kernel on the DRAM chiplet.
///
/// `rram`/`fabric` are needed because attention over very long contexts
/// may read cold KV blocks that tiering offloaded to the RRAM chiplet —
/// those cross the package's local UCIe link. The memories answer
/// stream-time queries at whichever fidelity they wrap (first-order
/// analytic or the cycle-accurate bank/row model).
pub fn execute(
    kernel: &FusedKernel,
    nmp: &NmpConfig,
    dram: &mut DramMem,
    rram: &mut RramMem,
    fabric: &mut Fabric,
) -> KernelCost {
    let mut cost = KernelCost::default();
    let mut stream_ns = 0.0;

    // --- weight streaming from the tiers ---------------------------------
    let wb = kernel.weight_bytes();
    if wb > 0 {
        stream_ns += dram.weight_stream_ns_classed(weight_class(kernel), wb);
        cost.energy.deposit(Component::DramArray, dram.array_energy_pj(wb));
    }

    // --- KV reads: priced per residency tier (mapping ❷) -----------------
    let kv_read = kernel.kv_read_bytes();
    if kv_read > 0 {
        let dist = dram.kv_distribution();
        let total: u64 = dist.iter().map(|(_, b)| b).sum();
        let mut dram_parts: Vec<(usize, u64)> = Vec::new();
        let mut rram_part: u64 = 0;
        if total == 0 {
            dram_parts.push((0, kv_read));
        } else {
            for (res, bytes) in dist {
                let share = ((kv_read as u128 * bytes as u128) / total as u128) as u64;
                match res {
                    KvResidency::Tier(t) => dram_parts.push((t, share)),
                    KvResidency::Rram => rram_part += share,
                }
            }
        }
        stream_ns += dram.kv_stream_ns(&dram_parts);
        let dram_kv_bytes: u64 = dram_parts.iter().map(|(_, b)| b).sum();
        cost.energy
            .deposit(Component::DramArray, dram.array_energy_pj(dram_kv_bytes));
        if rram_part > 0 {
            // Cold blocks stream out of RRAM and cross UCIe back to the PUs.
            stream_ns += rram.kv_stream_ns(rram_part);
            cost.energy
                .deposit(Component::RramArray, rram.read_energy_pj(rram_part));
            let (ns, pj) = fabric.local_transfer(rram_part);
            stream_ns += ns;
            cost.energy.deposit(Component::Ucie, pj);
        }
    }

    // --- KV append (write-back of this step's K/V) ------------------------
    let kv_write = kernel.kv_write_bytes();
    if kv_write > 0 {
        let offloaded = dram.append_kv(kv_write);
        cost.energy
            .deposit(Component::DramArray, dram.array_energy_pj(kv_write));
        if offloaded > 0 {
            // One-shot cold offload to RRAM (write-once policy).
            let wns = rram.offload_kv(offloaded);
            stream_ns += wns;
            cost.energy
                .deposit(Component::RramArray, rram.write_energy_pj(offloaded));
            let (ns, pj) = fabric.local_transfer(offloaded);
            stream_ns += ns;
            cost.energy.deposit(Component::Ucie, pj);
        }
        // Writes stream through the same row buffers.
        stream_ns += dram.kv_writeback_ns(kv_write);
    }

    // --- compute ----------------------------------------------------------
    let compute_ns = if kernel.flops() > 0.0 {
        pe::gemm_compute_ns(nmp, kernel.flops(), kernel.m_rows)
    } else {
        0.0
    };
    let sfpe_ns = sfpe::sfpe_ns(nmp, kernel.sfpe_elems(), sfpe_cycles(kernel));

    cost.stream_ns = stream_ns;
    cost.compute_ns = compute_ns;
    cost.sfpe_ns = sfpe_ns;
    cost.time_ns = nmp.kernel_dispatch_ns + stream_ns.max(compute_ns).max(sfpe_ns);

    // NMP energy: active portion at utilization, remainder at idle burn.
    let busy = compute_ns.max(sfpe_ns);
    let activity = if cost.time_ns > 0.0 { (busy / cost.time_ns).clamp(0.05, 1.0) } else { 0.0 };
    cost.energy.deposit(
        Component::DramNmp,
        pe::compute_energy_pj(nmp, cost.time_ns, activity),
    );
    cost
}

/// Which heat class a kernel's weights stream from (mirrors the layout's
/// placement priority; see `mapping::layout`).
fn weight_class(kernel: &FusedKernel) -> WeightClass {
    use crate::sim::kernels::FusedKind::*;
    match kernel.kind {
        FusedQkvProj | FusedAttnStream | FusedNorm | Elementwise => WeightClass::Attn,
        FusedFfnAct => WeightClass::Ffn, // DRAM-only ablation path
        LmHead => WeightClass::LmHead,
        Embed => WeightClass::Embed,
        VisionBlock | ConnectorBlock => WeightClass::VisionConn,
    }
}

fn sfpe_cycles(kernel: &FusedKernel) -> f64 {
    use crate::sim::kernels::FusedKind::*;
    match kernel.kind {
        FusedAttnStream => sfpe::cost::SOFTMAX,
        FusedNorm => sfpe::cost::NORM,
        FusedFfnAct => sfpe::cost::ACTIVATION,
        _ => sfpe::cost::ADD,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChimeHardware, MemoryFidelity, MllmConfig};
    use crate::model::{OpCost, OpKind, Stage};
    use crate::sim::kernels::{FusedKind, Placement};
    use crate::sim::memory::{DramState, RramState};

    fn setup_with(fidelity: MemoryFidelity) -> (ChimeHardware, DramMem, RramMem, Fabric) {
        let hw = ChimeHardware::default();
        let dram = DramMem::new(DramState::new(hw.dram.clone()), fidelity);
        let rram = RramMem::new(RramState::new(hw.rram.clone()), fidelity);
        let fabric = Fabric::single(hw.ucie.clone());
        (hw, dram, rram, fabric)
    }

    fn setup() -> (ChimeHardware, DramMem, RramMem, Fabric) {
        setup_with(MemoryFidelity::FirstOrder)
    }

    fn kernel_with(weight_bytes: u64, flops: f64, m: usize) -> FusedKernel {
        let mut op = OpCost::new("t", OpKind::Gemm, Stage::Backbone);
        op.weight_bytes = weight_bytes;
        op.flops = flops;
        FusedKernel {
            kind: FusedKind::FusedQkvProj,
            placement: Placement::DramChiplet,
            layer: Some(0),
            m_rows: m,
            ops: vec![op],
            cut_in: false,
            cut_out: false,
        }
    }

    #[test]
    fn memory_bound_gemv_dominated_by_streaming() {
        let (hw, mut dram, mut rram, mut fabric) = setup();
        dram.state_mut().place_weights(1_000_000_000).unwrap();
        // Decode GEMV: bytes dominate (weights 100 MB, flops tiny).
        let k = kernel_with(100_000_000, 1e6, 1);
        let c = execute(&k, &hw.dram_nmp, &mut dram, &mut rram, &mut fabric);
        assert_eq!(c.bottleneck(), "memory");
        assert!(c.time_ns > c.compute_ns);
        assert!(c.energy.get(Component::DramArray) > 0.0);
        assert!(c.energy.get(Component::DramNmp) > 0.0);
    }

    #[test]
    fn compute_bound_prefill_dominated_by_macs() {
        let (hw, mut dram, mut rram, mut fabric) = setup();
        // Prefill GEMM: heavy flops, light weights.
        let k = kernel_with(1_000, 1e12, 256);
        let c = execute(&k, &hw.dram_nmp, &mut dram, &mut rram, &mut fabric);
        assert_eq!(c.bottleneck(), "compute");
    }

    #[test]
    fn cold_kv_reads_cross_ucie() {
        let (hw, mut dram, mut rram, mut fabric) = setup();
        // Fill DRAM completely with weights, then append KV -> all offloads.
        dram.state_mut().place_weights(hw.dram.chip_capacity_bytes()).unwrap();
        dram.append_kv(10_000_000);
        assert!(dram.state().kv_offloaded > 0);
        let mut op = OpCost::new("attn", OpKind::Attention, Stage::Backbone);
        op.kv_read_bytes = 10_000_000;
        let k = FusedKernel {
            kind: FusedKind::FusedAttnStream,
            placement: Placement::DramChiplet,
            layer: Some(0),
            m_rows: 1,
            ops: vec![op],
            cut_in: false,
            cut_out: true,
        };
        let before = fabric.bytes_transferred;
        let c = execute(&k, &hw.dram_nmp, &mut dram, &mut rram, &mut fabric);
        assert!(fabric.bytes_transferred > before, "cold KV must cross the link");
        assert!(c.energy.get(Component::RramArray) > 0.0);
    }

    #[test]
    fn dispatch_floor_applies() {
        let (hw, mut dram, mut rram, mut fabric) = setup();
        let k = kernel_with(0, 0.0, 1);
        let c = execute(&k, &hw.dram_nmp, &mut dram, &mut rram, &mut fabric);
        assert!((c.time_ns - hw.dram_nmp.kernel_dispatch_ns).abs() < 1e-9);
    }

    #[test]
    fn cycle_fidelity_kernel_never_beats_first_order() {
        // Identical kernels on the two fidelities: the analytic model is
        // the idealized lower bound, so the cycle cost must dominate, and
        // the streamed-byte accounting must agree bit for bit.
        let run = |fidelity: MemoryFidelity| {
            let (hw, mut dram, mut rram, mut fabric) = setup_with(fidelity);
            dram.state_mut().place_weights(1_000_000_000).unwrap();
            let k = kernel_with(100_000_000, 1e6, 1);
            let c = execute(&k, &hw.dram_nmp, &mut dram, &mut rram, &mut fabric);
            (c, dram.state().bytes_read)
        };
        let (fo, fo_read) = run(MemoryFidelity::FirstOrder);
        let (cy, cy_read) = run(MemoryFidelity::CycleAccurate);
        assert!(
            cy.stream_ns > fo.stream_ns,
            "cycle stream {} must exceed first-order {}",
            cy.stream_ns,
            fo.stream_ns
        );
        assert!(cy.time_ns >= fo.time_ns);
        assert_eq!(fo_read, cy_read, "fidelity must not change byte accounting");
        // Shared energy model: array energy identical for identical bytes.
        assert_eq!(
            fo.energy.get(Component::DramArray).to_bits(),
            cy.energy.get(Component::DramArray).to_bits()
        );
    }

    #[test]
    fn paper_scale_attention_step_sane() {
        // One full decode-attention layer of FastVLM-0.6B should take
        // single-digit microseconds on the DRAM chiplet.
        let (hw, mut dram, mut rram, mut fabric) = setup();
        let m = MllmConfig::fastvlm_0_6b();
        dram.state_mut()
            .place_weights(m.llm.attn_weight_bytes_per_layer() * m.llm.n_layers as u64)
            .unwrap();
        let k = kernel_with(m.llm.attn_weight_bytes_per_layer(), 2.0 * 1.84e6, 1);
        let c = execute(&k, &hw.dram_nmp, &mut dram, &mut rram, &mut fabric);
        assert!(c.time_ns > 1_000.0 && c.time_ns < 100_000.0, "t = {} ns", c.time_ns);
    }
}
