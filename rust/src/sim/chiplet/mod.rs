//! Chiplet execution models (DRAM NMP + RRAM NMP).

pub mod dram_chiplet;
pub mod rram_chiplet;
