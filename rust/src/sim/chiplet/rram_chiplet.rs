//! RRAM-chiplet execution model: the FUSED_FFN_ACT kernel running on the
//! RRAM NMP with weights resident in the stacked arrays (paper §III-B2:
//! "weights are resident in the stacked arrays and later steps access
//! them directly without reload").

use crate::config::NmpConfig;
use crate::sim::energy::Component;
use crate::sim::kernels::{FusedKernel, KernelCost};
use crate::sim::memory::RramMem;
use crate::sim::nmp::{pe, sfpe};

/// Execute one fused kernel on the RRAM chiplet. The memory answers
/// stream-time queries at whichever fidelity it wraps (first-order
/// analytic or the cycle-accurate mat/pulse model).
pub fn execute(kernel: &FusedKernel, nmp: &NmpConfig, rram: &mut RramMem) -> KernelCost {
    let mut cost = KernelCost::default();
    let mut stream_ns = 0.0;

    // Resident weights stream from the arrays to the PE groups.
    let wb = kernel.weight_bytes();
    if wb > 0 {
        stream_ns += rram.weight_stream_ns(wb);
        cost.energy.deposit(Component::RramArray, rram.read_energy_pj(wb));
    }

    // (Cold-KV reads on the RRAM side are priced by the DRAM-chiplet
    // attention path; the FFN kernel touches only weights + activations.)

    let compute_ns = if kernel.flops() > 0.0 {
        pe::gemm_compute_ns(nmp, kernel.flops(), kernel.m_rows)
    } else {
        0.0
    };
    // RRAM NMP has no SFPE; activation tails run on PE accumulators.
    let sfpe_ns = sfpe::sfpe_ns(nmp, kernel.sfpe_elems(), sfpe::cost::ACTIVATION);

    cost.stream_ns = stream_ns;
    cost.compute_ns = compute_ns;
    cost.sfpe_ns = sfpe_ns;
    cost.time_ns = nmp.kernel_dispatch_ns + stream_ns.max(compute_ns).max(sfpe_ns);

    let busy = compute_ns.max(sfpe_ns);
    // Streaming resident weights keeps the wide H-tree datapaths, routers
    // and PE accumulators active even when MACs idle — the RRAM chiplet's
    // activity floor is high (paper Fig 7: "RRAM dominates because it
    // runs the data-intensive FFN").
    let activity = if cost.time_ns > 0.0 { (busy / cost.time_ns).clamp(0.35, 1.0) } else { 0.0 };
    cost.energy.deposit(
        Component::RramNmp,
        pe::compute_energy_pj(nmp, cost.time_ns, activity),
    );
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChimeHardware, MemoryFidelity, MllmConfig};
    use crate::model::{OpCost, OpKind, Stage};
    use crate::sim::kernels::{FusedKind, Placement};
    use crate::sim::memory::RramState;

    fn rram_with(hw: &ChimeHardware, fidelity: MemoryFidelity) -> RramMem {
        RramMem::new(RramState::new(hw.rram.clone()), fidelity)
    }

    fn ffn_kernel(weight_bytes: u64, flops: f64, m: usize) -> FusedKernel {
        let mut op = OpCost::new("ffn_act", OpKind::Gemm, Stage::Backbone);
        op.weight_bytes = weight_bytes;
        op.flops = flops;
        op.sfpe_elems = 1000;
        FusedKernel {
            kind: FusedKind::FusedFfnAct,
            placement: Placement::RramChiplet,
            layer: Some(0),
            m_rows: m,
            ops: vec![op],
            cut_in: true,
            cut_out: true,
        }
    }

    #[test]
    fn decode_ffn_memory_bound() {
        let hw = ChimeHardware::default();
        let mut rram = rram_with(&hw, MemoryFidelity::FirstOrder);
        let llm = MllmConfig::mobilevlm_3b().llm;
        rram.load_weights(llm.ffn_weight_bytes_per_layer() * llm.n_layers as u64)
            .unwrap();
        let k = ffn_kernel(
            llm.ffn_weight_bytes_per_layer(),
            2.0 * (llm.ffn_matrices * llm.d_model * llm.d_ffn) as f64,
            1,
        );
        let c = execute(&k, &hw.rram_nmp, &mut rram);
        assert_eq!(c.bottleneck(), "memory");
        // 106 MB @ ~1.7 TB/s -> tens of microseconds.
        assert!(c.time_ns > 10_000.0 && c.time_ns < 500_000.0, "t = {}", c.time_ns);
    }

    #[test]
    fn prefill_ffn_can_be_compute_bound() {
        let hw = ChimeHardware::default();
        let mut rram = rram_with(&hw, MemoryFidelity::FirstOrder);
        // Large-batch prefill: heavy flops over the same weights.
        let k = ffn_kernel(1_000_000, 1e13, 512);
        let c = execute(&k, &hw.rram_nmp, &mut rram);
        assert_eq!(c.bottleneck(), "compute");
    }

    #[test]
    fn energy_includes_array_and_nmp() {
        let hw = ChimeHardware::default();
        let mut rram = rram_with(&hw, MemoryFidelity::FirstOrder);
        let k = ffn_kernel(50_000_000, 1e9, 1);
        let c = execute(&k, &hw.rram_nmp, &mut rram);
        assert!(c.energy.get(Component::RramArray) > 0.0);
        assert!(c.energy.get(Component::RramNmp) > 0.0);
    }

    #[test]
    fn cycle_fidelity_ffn_never_beats_first_order() {
        let hw = ChimeHardware::default();
        let run = |fidelity: MemoryFidelity| {
            let mut rram = rram_with(&hw, fidelity);
            rram.load_weights(1_000_000_000).unwrap();
            let k = ffn_kernel(106_000_000, 1e9, 1);
            let c = execute(&k, &hw.rram_nmp, &mut rram);
            (c, rram.state().lifetime_read_bytes)
        };
        let (fo, fo_read) = run(MemoryFidelity::FirstOrder);
        let (cy, cy_read) = run(MemoryFidelity::CycleAccurate);
        assert!(cy.stream_ns >= fo.stream_ns);
        assert!(cy.time_ns >= fo.time_ns);
        assert_eq!(fo_read, cy_read, "fidelity must not change byte accounting");
    }
}
