//! M3D DRAM chiplet memory state: tiered capacity allocation, classed
//! weight placement, KV-block residency, and stream-timing/energy queries.
//!
//! The 200-layer stack is split into 5 tiers with the paper's
//! (3 + 0.8·L) ns staircase latency. The mapping framework places static
//! weights bottom-up *by access heat* (attention weights — touched every
//! token — in the fastest tiers; vision/connector weights — touched once
//! per inference — in the slowest; §III-B1 "hottest attention data in the
//! bottom tier"), then KV-cache blocks fill remaining capacity; when DRAM
//! runs out, the coldest blocks are offloaded one-shot to RRAM (§III-C ❷).

use std::collections::BTreeMap;

use crate::config::DramConfig;

use super::MemoryModel;

/// Heat-ordered weight classes (placement priority = enum order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum WeightClass {
    /// QKV/O projections + norms: streamed every token, hottest.
    Attn,
    /// FFN weights — only present in the DRAM-only ablation.
    Ffn,
    /// Unembedding GEMV: streamed every token.
    LmHead,
    /// Embedding table: one row gathered per token.
    Embed,
    /// Vision encoder + connector: once per inference, coldest.
    VisionConn,
}

impl WeightClass {
    pub fn all_in_priority_order() -> [WeightClass; 5] {
        [
            WeightClass::Attn,
            WeightClass::Ffn,
            WeightClass::LmHead,
            WeightClass::Embed,
            WeightClass::VisionConn,
        ]
    }
}

/// Byte-granular view of one tier's occupancy.
#[derive(Debug, Clone)]
pub struct TierState {
    pub capacity: u64,
    pub weights: u64,
    pub kv: u64,
}

impl TierState {
    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.weights + self.kv)
    }
}

/// Where a KV byte-range lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvResidency {
    /// DRAM tier index (0 = fastest).
    Tier(usize),
    /// Offloaded to the RRAM chiplet (write-once cold storage).
    Rram,
}

/// M3D DRAM state.
#[derive(Debug, Clone)]
pub struct DramState {
    pub cfg: DramConfig,
    pub tiers: Vec<TierState>,
    /// Per-class tier spans: class -> [(tier, bytes)].
    spans: BTreeMap<WeightClass, Vec<(usize, u64)>>,
    /// Total KV bytes offloaded to RRAM (cold tail).
    pub kv_offloaded: u64,
    /// Running counters for reporting.
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl DramState {
    pub fn new(cfg: DramConfig) -> Self {
        let tiers = (0..cfg.tiers)
            .map(|_| TierState { capacity: cfg.tier_capacity_bytes, weights: 0, kv: 0 })
            .collect();
        DramState {
            cfg,
            tiers,
            spans: BTreeMap::new(),
            kv_offloaded: 0,
            bytes_read: 0,
            bytes_written: 0,
        }
    }

    /// Statically place `bytes` of `class` weights bottom-up into the
    /// fastest remaining tiers (mapping ❶). Call in heat-priority order.
    /// Returns Err(overflow) if the stack cannot hold them.
    pub fn place_weights_classed(&mut self, class: WeightClass, mut bytes: u64)
        -> Result<(), u64> {
        let mut span = Vec::new();
        for (i, t) in self.tiers.iter_mut().enumerate() {
            let take = bytes.min(t.free());
            if take > 0 {
                t.weights += take;
                span.push((i, take));
                bytes -= take;
            }
            if bytes == 0 {
                break;
            }
        }
        self.spans.entry(class).or_default().extend(span);
        if bytes == 0 {
            Ok(())
        } else {
            Err(bytes)
        }
    }

    /// Un-classed placement (tests / simple callers): files under Attn.
    pub fn place_weights(&mut self, bytes: u64) -> Result<(), u64> {
        self.place_weights_classed(WeightClass::Attn, bytes)
    }

    /// Append `bytes` of fresh (hot) KV. New blocks go to the fastest tier
    /// with room; when DRAM is full, cold KV is evicted (or, if none, the
    /// fresh bytes overflow) to RRAM one-shot write-once. Returns bytes
    /// sent to RRAM.
    pub fn append_kv(&mut self, bytes: u64) -> u64 {
        let mut remaining = bytes;
        for t in &mut self.tiers {
            let take = remaining.min(t.free());
            t.kv += take;
            remaining -= take;
            if remaining == 0 {
                self.bytes_written += bytes;
                return 0;
            }
        }
        // DRAM full: offload the coldest `remaining` KV bytes (they sit in
        // the slowest tier that has KV) and append the fresh bytes there.
        let mut to_offload = remaining;
        for t in self.tiers.iter_mut().rev() {
            let evict = to_offload.min(t.kv);
            t.kv -= evict;
            to_offload -= evict;
            if to_offload == 0 {
                break;
            }
        }
        let evicted = remaining - to_offload;
        // Re-append the fresh bytes into the space we just freed.
        let mut still = remaining;
        for t in &mut self.tiers {
            let take = still.min(t.free());
            t.kv += take;
            still -= take;
            if still == 0 {
                break;
            }
        }
        // Fresh bytes that found no DRAM home (stack packed with weights,
        // no cold KV to evict) also go to RRAM.
        let offloaded = evicted + still;
        self.kv_offloaded += offloaded;
        self.bytes_written += bytes;
        offloaded
    }

    /// Distribution of the current KV bytes across residencies. Attention
    /// reads the *whole* prefix each step; the tier mix determines the
    /// effective stream bandwidth.
    pub fn kv_distribution(&self) -> Vec<(KvResidency, u64)> {
        let mut out: Vec<(KvResidency, u64)> = self
            .tiers
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kv > 0)
            .map(|(i, t)| (KvResidency::Tier(i), t.kv))
            .collect();
        if self.kv_offloaded > 0 {
            out.push((KvResidency::Rram, self.kv_offloaded));
        }
        out
    }

    pub fn total_kv_bytes(&self) -> u64 {
        self.tiers.iter().map(|t| t.kv).sum::<u64>() + self.kv_offloaded
    }

    /// Per-tier byte shares a `class` stream of `bytes` draws from, in
    /// span (placement) order; unplaced classes fall back to tier 0. Both
    /// memory fidelities price a class stream over exactly these shares,
    /// so the cycle-accurate model sees the same tier mix the first-order
    /// model amortizes over.
    pub fn class_stream_shares(&self, class: WeightClass, bytes: u64) -> Vec<(usize, f64)> {
        let span = self.spans.get(&class);
        let span_total: u64 = span
            .map(|s| s.iter().map(|(_, b)| b).sum())
            .unwrap_or(0);
        if span_total == 0 {
            // Unplaced class (tests): assume tier 0.
            return vec![(0, bytes as f64)];
        }
        span.unwrap()
            .iter()
            .map(|&(tier, tier_bytes)| {
                (tier, bytes as f64 * tier_bytes as f64 / span_total as f64)
            })
            .collect()
    }

    /// Time (ns) to stream `bytes` of `class` weights into the NMP, priced
    /// at the class's own tier mix (hot classes live low and stream fast).
    pub fn weight_stream_ns_classed(&mut self, class: WeightClass, bytes: u64) -> f64 {
        self.bytes_read += bytes;
        let freq = 1.0; // GHz; NMP clock == memory interface clock
        let mut ns = 0.0;
        for (tier, share) in self.class_stream_shares(class, bytes) {
            ns += share / self.cfg.tier_stream_bw_gbps(tier, freq);
        }
        ns
    }

    /// Back-compat helper: stream as the hottest class.
    pub fn weight_stream_ns(&mut self, bytes: u64) -> f64 {
        self.weight_stream_ns_classed(WeightClass::Attn, bytes)
    }

    /// Time (ns) to write this step's fresh K/V back through the tier-0
    /// row buffers. Single source of the first-order write-back price —
    /// the cycle model builds its extras on top of exactly this value.
    pub fn kv_writeback_ns(&self, bytes: u64) -> f64 {
        bytes as f64 / self.cfg.tier_stream_bw_gbps(0, 1.0)
    }

    /// Time (ns) to stream KV bytes by explicit tier mix.
    pub fn kv_stream_ns(&mut self, bytes_by_tier: &[(usize, u64)]) -> f64 {
        let freq = 1.0;
        let mut ns = 0.0;
        for &(tier, bytes) in bytes_by_tier {
            self.bytes_read += bytes;
            ns += bytes as f64 / self.cfg.tier_stream_bw_gbps(tier, freq);
        }
        ns
    }

    /// Array read/write energy for `bytes` (pJ), including the streaming
    /// row-reuse derate (see `DramConfig::array_energy_scale`).
    pub fn array_energy_pj(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 * self.cfg.energy_pj_per_bit * self.cfg.array_energy_scale
    }
}

impl MemoryModel for DramState {
    fn name(&self) -> &'static str {
        "m3d-dram"
    }

    fn capacity_bytes(&self) -> u64 {
        self.cfg.chip_capacity_bytes()
    }

    fn used_bytes(&self) -> u64 {
        self.tiers.iter().map(|t| t.weights + t.kv).sum()
    }

    fn stream_weights_ns(&mut self, bytes: u64) -> f64 {
        DramState::weight_stream_ns(self, bytes)
    }

    fn read_energy_pj(&self, bytes: u64) -> f64 {
        self.array_energy_pj(bytes)
    }

    fn write_energy_pj(&self, bytes: u64) -> f64 {
        // Symmetric array cost: DRAM SET/SENSE energy is direction-agnostic
        // at this granularity (unlike RRAM's asymmetric SET/RESET pulses).
        self.array_energy_pj(bytes)
    }

    fn lifetime_read_bytes(&self) -> u64 {
        self.bytes_read
    }

    fn lifetime_write_bytes(&self) -> u64 {
        self.bytes_written
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> DramConfig {
        let mut c = DramConfig::default();
        c.tier_capacity_bytes = 1000;
        c
    }

    #[test]
    fn weights_fill_bottom_up() {
        let mut d = DramState::new(small_cfg());
        d.place_weights(1500).unwrap();
        assert_eq!(d.tiers[0].weights, 1000);
        assert_eq!(d.tiers[1].weights, 500);
        assert_eq!(d.tiers[2].weights, 0);
    }

    #[test]
    fn weights_overflow_reported() {
        let mut d = DramState::new(small_cfg());
        let over = d.place_weights(6000).unwrap_err();
        assert_eq!(over, 1000);
    }

    #[test]
    fn hot_class_streams_faster_than_cold_class() {
        let mut d = DramState::new(small_cfg());
        d.place_weights_classed(WeightClass::Attn, 1000).unwrap(); // tier 0
        d.place_weights_classed(WeightClass::VisionConn, 1000).unwrap(); // tier 1
        let hot = d.weight_stream_ns_classed(WeightClass::Attn, 500);
        let cold = d.weight_stream_ns_classed(WeightClass::VisionConn, 500);
        assert!(cold > hot, "cold {cold} vs hot {hot}");
    }

    #[test]
    fn kv_appends_into_fastest_free_tier() {
        let mut d = DramState::new(small_cfg());
        d.place_weights(1000).unwrap(); // tier 0 full of weights
        let off = d.append_kv(300);
        assert_eq!(off, 0);
        assert_eq!(d.tiers[1].kv, 300);
    }

    #[test]
    fn kv_offloads_when_full() {
        let mut d = DramState::new(small_cfg());
        d.place_weights(4500).unwrap();
        assert_eq!(d.append_kv(400), 0); // fits in remaining 500
        let off = d.append_kv(400); // only 100 free -> 300 offloaded
        assert_eq!(off, 300);
        assert_eq!(d.kv_offloaded, 300);
        assert_eq!(d.total_kv_bytes(), 800);
        for t in &d.tiers {
            assert!(t.weights + t.kv <= t.capacity);
        }
    }

    #[test]
    fn kv_overflows_directly_when_nothing_to_evict() {
        let mut d = DramState::new(small_cfg());
        d.place_weights(5000).unwrap(); // every tier full of weights
        let off = d.append_kv(250);
        assert_eq!(off, 250);
        assert_eq!(d.kv_offloaded, 250);
    }

    #[test]
    fn faster_tier_streams_faster() {
        let mut a = DramState::new(DramConfig::default());
        let t0 = a.kv_stream_ns(&[(0, 1_000_000)]);
        let t4 = a.kv_stream_ns(&[(4, 1_000_000)]);
        assert!(t4 > t0);
    }

    #[test]
    fn weight_stream_time_positive_and_linear() {
        let mut d = DramState::new(DramConfig::default());
        d.place_weights(2_000_000_000).unwrap();
        let t1 = d.weight_stream_ns(100_000_000);
        let t2 = d.weight_stream_ns(200_000_000);
        assert!(t1 > 0.0);
        assert!((t2 / t1 - 2.0).abs() < 1e-6);
    }

    #[test]
    fn energy_matches_derated_pj_per_bit() {
        let d = DramState::new(DramConfig::default());
        let expect = 8.0 * d.cfg.energy_pj_per_bit * d.cfg.array_energy_scale;
        assert!((d.array_energy_pj(1) - expect).abs() < 1e-9);
    }
}
