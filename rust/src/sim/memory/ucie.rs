//! UCIe 2.5D die-to-die link model: DMA transfers between the DRAM and
//! RRAM chiplets. Only the two cut-point activations (AttnOut, FFNOut)
//! and one-shot KV offloads ever cross this link (paper §III-C ❶).

use crate::config::UcieConfig;

#[derive(Debug, Clone)]
pub struct UcieLink {
    pub cfg: UcieConfig,
    pub bytes_transferred: u64,
    pub transfers: u64,
}

impl UcieLink {
    pub fn new(cfg: UcieConfig) -> Self {
        UcieLink { cfg, bytes_transferred: 0, transfers: 0 }
    }

    /// DMA a payload across the link. Returns (latency_ns, energy_pj).
    ///
    /// Streaming payloads overlap with downstream compute (the paper's
    /// "immediately fused with preloaded weights" pipelining), so the
    /// non-overlappable cost is the DMA setup latency plus the serialized
    /// wire time of the payload.
    pub fn transfer(&mut self, bytes: u64) -> (f64, f64) {
        if bytes == 0 || self.cfg.bandwidth_gbps.is_infinite() {
            // DRAM-only ablation: no link.
            return (0.0, 0.0);
        }
        self.bytes_transferred += bytes;
        self.transfers += 1;
        let wire_ns = bytes as f64 / self.cfg.bandwidth_gbps;
        let latency = self.cfg.dma_latency_ns + wire_ns;
        let energy = bytes as f64 * 8.0 * self.cfg.energy_pj_per_bit;
        (latency, energy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_accounts_latency_and_energy() {
        let mut l = UcieLink::new(UcieConfig::default());
        let (ns, pj) = l.transfer(128_000); // 128 KB at 128 GB/s = 1000 ns
        assert!((ns - (80.0 + 1000.0)).abs() < 1e-9);
        assert!((pj - 128_000.0 * 8.0 * 0.6).abs() < 1e-6);
        assert_eq!(l.transfers, 1);
    }

    #[test]
    fn zero_bytes_free() {
        let mut l = UcieLink::new(UcieConfig::default());
        assert_eq!(l.transfer(0), (0.0, 0.0));
    }

    #[test]
    fn dram_only_link_is_free() {
        let mut cfg = UcieConfig::default();
        cfg.bandwidth_gbps = f64::INFINITY;
        let mut l = UcieLink::new(cfg);
        assert_eq!(l.transfer(1_000_000), (0.0, 0.0));
        assert_eq!(l.bytes_transferred, 0);
    }
}
