//! M3D RRAM chiplet memory state: resident-weight streaming, write-once
//! KV offload, and the endurance ledger behind the paper's
//! "endurance-aware management for device protection".

use crate::config::RramConfig;

use super::MemoryModel;

/// M3D RRAM state.
#[derive(Debug, Clone)]
pub struct RramState {
    pub cfg: RramConfig,
    /// Weight bytes resident in the arrays (written once at model load).
    pub weight_bytes: u64,
    /// Cold KV bytes offloaded from DRAM (write-once).
    pub kv_bytes: u64,
    /// Lifetime write bytes (endurance accounting).
    pub lifetime_write_bytes: u64,
    /// Lifetime read bytes.
    pub lifetime_read_bytes: u64,
    /// Writes are wear-leveled across the full capacity; this tracks the
    /// worst-case per-cell write count under ideal leveling.
    pub max_cell_writes: f64,
}

impl RramState {
    pub fn new(cfg: RramConfig) -> Self {
        RramState {
            cfg,
            weight_bytes: 0,
            kv_bytes: 0,
            lifetime_write_bytes: 0,
            lifetime_read_bytes: 0,
            max_cell_writes: 0.0,
        }
    }

    pub fn free_bytes(&self) -> u64 {
        self.cfg
            .chip_capacity_bytes
            .saturating_sub(self.weight_bytes + self.kv_bytes)
    }

    /// Load model weights (one-shot write at deployment). Returns the
    /// write time in ns. Errors if capacity is exceeded.
    pub fn load_weights(&mut self, bytes: u64) -> Result<f64, String> {
        if bytes > self.free_bytes() {
            return Err(format!(
                "RRAM capacity exceeded: need {} over {} free",
                bytes,
                self.free_bytes()
            ));
        }
        self.weight_bytes += bytes;
        Ok(self.record_write(bytes))
    }

    /// One-shot KV offload from DRAM (the paper's write-once policy for
    /// extremely long contexts). Returns write time in ns.
    pub fn offload_kv(&mut self, bytes: u64) -> f64 {
        let take = bytes.min(self.free_bytes());
        self.kv_bytes += take;
        self.record_write(take)
    }

    fn record_write(&mut self, bytes: u64) -> f64 {
        self.lifetime_write_bytes += bytes;
        // Ideal wear-leveling spreads writes uniformly over all cells.
        self.max_cell_writes =
            self.lifetime_write_bytes as f64 / self.cfg.chip_capacity_bytes as f64;
        bytes as f64 / self.cfg.write_stream_bw_gbps(1.0)
    }

    /// Stream resident weights to the PE groups. Returns ns.
    pub fn weight_stream_ns(&mut self, bytes: u64) -> f64 {
        self.lifetime_read_bytes += bytes;
        bytes as f64 / self.cfg.read_stream_bw_gbps(1.0)
    }

    /// Stream offloaded (cold) KV. Cold reads go over the plain interface
    /// (no near-layer parallel fan-out — the blocks live wherever the
    /// write-once allocator put them).
    pub fn kv_stream_ns(&mut self, bytes: u64) -> f64 {
        self.lifetime_read_bytes += bytes;
        bytes as f64 / (self.cfg.interface_bw_gbps(1.0) * self.cfg.stream_utilization)
    }

    pub fn read_energy_pj(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 * self.cfg.read_energy_pj_per_bit * self.cfg.array_energy_scale
    }

    pub fn write_energy_pj(&self, bytes: u64) -> f64 {
        // Writes pay the full per-bit cost (SET/RESET pulses do not
        // amortize the way synchronous wide reads do).
        bytes as f64 * 8.0 * self.cfg.write_energy_pj_per_bit
    }

    /// Fraction of rated endurance consumed (1.0 = worn out).
    pub fn endurance_consumed(&self) -> f64 {
        self.max_cell_writes / self.cfg.endurance_writes as f64
    }

    /// Projected device lifetime in inferences, given the per-inference
    /// write volume observed so far over `inferences` runs.
    pub fn projected_lifetime_inferences(&self, inferences: u64) -> f64 {
        if self.lifetime_write_bytes == 0 || inferences == 0 {
            return f64::INFINITY;
        }
        let writes_per_inference =
            self.max_cell_writes / inferences as f64;
        self.cfg.endurance_writes as f64 / writes_per_inference
    }
}

impl MemoryModel for RramState {
    fn name(&self) -> &'static str {
        "m3d-rram"
    }

    fn capacity_bytes(&self) -> u64 {
        self.cfg.chip_capacity_bytes
    }

    fn used_bytes(&self) -> u64 {
        self.weight_bytes + self.kv_bytes
    }

    fn stream_weights_ns(&mut self, bytes: u64) -> f64 {
        RramState::weight_stream_ns(self, bytes)
    }

    fn read_energy_pj(&self, bytes: u64) -> f64 {
        RramState::read_energy_pj(self, bytes)
    }

    fn write_energy_pj(&self, bytes: u64) -> f64 {
        RramState::write_energy_pj(self, bytes)
    }

    fn lifetime_read_bytes(&self) -> u64 {
        self.lifetime_read_bytes
    }

    fn lifetime_write_bytes(&self) -> u64 {
        self.lifetime_write_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_enforced() {
        let mut r = RramState::new(RramConfig::default());
        assert!(r.load_weights(17_000_000_000).is_err());
        assert!(r.load_weights(10_000_000_000).is_ok());
        assert_eq!(r.free_bytes(), 6_000_000_000);
    }

    #[test]
    fn writes_slower_than_reads() {
        let mut r = RramState::new(RramConfig::default());
        r.load_weights(1_000_000).unwrap();
        let read_ns = r.weight_stream_ns(1_000_000);
        let mut r2 = RramState::new(RramConfig::default());
        let write_ns = r2.load_weights(1_000_000).unwrap();
        assert!(write_ns > read_ns, "write {write_ns} vs read {read_ns}");
    }

    #[test]
    fn write_energy_exceeds_read_energy() {
        let r = RramState::new(RramConfig::default());
        assert!(r.write_energy_pj(100) > r.read_energy_pj(100));
    }

    #[test]
    fn endurance_accumulates_with_writes() {
        let mut r = RramState::new(RramConfig::default());
        r.load_weights(1_000_000_000).unwrap();
        let e1 = r.endurance_consumed();
        r.offload_kv(500_000_000);
        let e2 = r.endurance_consumed();
        assert!(e2 > e1);
        assert!(e2 < 1e-5, "write-once traffic must barely dent endurance");
    }

    #[test]
    fn lifetime_projection() {
        let mut r = RramState::new(RramConfig::default());
        // 2 MB of KV offload per inference over 10 inferences.
        for _ in 0..10 {
            r.offload_kv(2_000_000);
        }
        let life = r.projected_lifetime_inferences(10);
        // 1e6 endurance / (1e-3 cell-writes per inference) = 1e9.
        assert!(life > 1e8, "lifetime {life}");
        assert!(life.is_finite());
    }

    #[test]
    fn cold_kv_reads_slower_than_weight_stream() {
        let mut r = RramState::new(RramConfig::default());
        let w = r.weight_stream_ns(1_000_000);
        let k = r.kv_stream_ns(1_000_000);
        assert!(k > w);
    }
}
