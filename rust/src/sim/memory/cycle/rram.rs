//! Cycle-accurate M3D RRAM timing: mat/sense-amp pulse occupancy and
//! wear-aware write scheduling layered on the first-order [`RramState`].
//!
//! RRAM reads are wide and synchronous (H-tree fan-out across mats), so
//! the analytic stream bandwidth is close to reality; the discrete
//! effects are pulse quantization (a stream is an integer number of
//! array pulses), sense-amp occupancy when the pulse rate outruns the
//! mat groups, and a pipeline-refill pulse on stream switch. Writes add
//! SET/RESET *verify* pulses and the endurance machinery the paper's
//! "endurance-aware management" implies: write traffic is routed in
//! chunks to the least-worn region, and each chunk boundary pays a remap
//! bookkeeping latency.
//!
//! All capacity/lifetime/endurance accounting delegates to the wrapped
//! [`RramState`] — only time diverges (see `cycle` module docs).

use crate::config::RramConfig;

use super::super::rram::RramState;
use super::super::MemoryModel;

const TAG_WEIGHTS: u8 = 0;
const TAG_KV: u8 = 1;
const TAG_WRITE: u8 = 2;

/// Discrete RRAM timing parameters not carried by Table III.
#[derive(Debug, Clone)]
pub struct RramCycleTiming {
    /// Bytes fetched per parallel array pulse (one 1 Kb unit row across
    /// the internally parallel mats).
    pub pulse_bytes: f64,
    /// Independent mat groups a pulse train spreads over.
    pub mat_groups: f64,
    /// Write-verify overhead as a fraction of the write pulse.
    pub verify_frac: f64,
    /// Wear-aware scheduling granularity: bytes per region remap.
    pub remap_chunk_bytes: u64,
    /// Remap bookkeeping latency per chunk (map update + verify read).
    pub remap_ns: f64,
    /// Wear-leveling regions the write scheduler balances across.
    pub wear_regions: usize,
}

impl RramCycleTiming {
    /// Derive from the device organization (paper Table III).
    pub fn from_cfg(cfg: &RramConfig) -> RramCycleTiming {
        // One unit row is 1 Kb (1k x 1k unit) = 128 B; `internal_parallelism`
        // mats pulse together.
        let unit_row_bytes = 1024.0 / 8.0;
        RramCycleTiming {
            pulse_bytes: unit_row_bytes * cfg.internal_parallelism as f64,
            mat_groups: (cfg.controllers * cfg.channels_per_controller) as f64,
            verify_frac: 0.3,
            remap_chunk_bytes: 1 << 20,
            remap_ns: 220.0,
            wear_regions: 64,
        }
    }
}

/// Cycle-accurate M3D RRAM state: a [`RramState`] (capacity, endurance
/// ledger — bit-identical to first-order) plus pulse/wear timing state.
#[derive(Debug, Clone)]
pub struct CycleRramState {
    /// The wrapped first-order state; owns every byte of accounting.
    pub base: RramState,
    /// Discrete timing constants (derived from the device organization).
    pub timing: RramCycleTiming,
    /// Last stream tag (pipeline-refill lead on switch).
    last_tag: Option<u8>,
    /// Write bytes accumulated toward the next wear remap.
    write_cursor_bytes: u64,
    /// Per-region chunk-write counters (wear-aware scheduling ledger).
    region_writes: Vec<u64>,
    /// Diagnostics: wear remaps performed.
    pub remaps: u64,
    /// Diagnostics: total sense-amp occupancy stall (ns).
    pub pulse_stall_ns: f64,
    /// Diagnostics: total SET/RESET verify-pulse time (ns).
    pub verify_ns: f64,
    /// Diagnostics: total remap bookkeeping stall (ns).
    pub remap_stall_ns: f64,
}

impl CycleRramState {
    /// Wrap a first-order state (typically after weight load).
    pub fn new(base: RramState) -> CycleRramState {
        let timing = RramCycleTiming::from_cfg(&base.cfg);
        let regions = timing.wear_regions;
        CycleRramState {
            base,
            timing,
            last_tag: None,
            write_cursor_bytes: 0,
            region_writes: vec![0; regions],
            remaps: 0,
            pulse_stall_ns: 0.0,
            verify_ns: 0.0,
            remap_stall_ns: 0.0,
        }
    }

    /// Device configuration (shared with the wrapped state).
    pub fn cfg(&self) -> &RramConfig {
        &self.base.cfg
    }

    /// Remaining capacity (delegates).
    pub fn free_bytes(&self) -> u64 {
        self.base.free_bytes()
    }

    /// Fraction of rated endurance consumed (delegates).
    pub fn endurance_consumed(&self) -> f64 {
        self.base.endurance_consumed()
    }

    /// Projected lifetime in inferences (delegates).
    pub fn projected_lifetime_inferences(&self, inferences: u64) -> f64 {
        self.base.projected_lifetime_inferences(inferences)
    }

    /// Read extras: pulse quantization/occupancy + stream-switch lead.
    fn read_extras_ns(&mut self, bytes: u64, tag: u8, fo_ns: f64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let pulse_ns = self.base.cfg.read_latency_ns;
        let pulses = (bytes as f64 / self.timing.pulse_bytes).ceil().max(1.0);
        let occupancy_ns = pulses * pulse_ns / self.timing.mat_groups;
        let stall = (occupancy_ns - fo_ns).max(0.0);
        let lead = if self.last_tag == Some(tag) { 0.0 } else { pulse_ns };
        self.last_tag = Some(tag);
        self.pulse_stall_ns += stall;
        stall + lead
    }

    /// Write extras: verify-pulse occupancy + wear-aware chunk routing.
    fn write_extras_ns(&mut self, bytes: u64, fo_ns: f64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let pulse_ns = self.base.cfg.write_latency_ns * (1.0 + self.timing.verify_frac);
        let pulses = (bytes as f64 / self.timing.pulse_bytes).ceil().max(1.0);
        let occupancy_ns = pulses * pulse_ns / self.timing.mat_groups;
        let stall = (occupancy_ns - fo_ns).max(0.0);
        let lead = if self.last_tag == Some(TAG_WRITE) { 0.0 } else { self.base.cfg.write_latency_ns };
        self.last_tag = Some(TAG_WRITE);
        // Wear-aware scheduling: each full chunk routes to the currently
        // least-worn region and pays the remap bookkeeping latency.
        let mut remaps = 0u64;
        self.write_cursor_bytes += bytes;
        while self.write_cursor_bytes >= self.timing.remap_chunk_bytes {
            self.write_cursor_bytes -= self.timing.remap_chunk_bytes;
            let min_idx = self
                .region_writes
                .iter()
                .enumerate()
                .min_by_key(|&(_, &w)| w)
                .map(|(i, _)| i)
                .unwrap_or(0);
            self.region_writes[min_idx] += 1;
            remaps += 1;
        }
        self.remaps += remaps;
        self.pulse_stall_ns += stall;
        // Diagnostics only: attribute the verify share of the pulse train
        // and the remap bookkeeping latency to their causes (the returned
        // time is unchanged — these never feed back into timing).
        self.verify_ns +=
            pulses * self.base.cfg.write_latency_ns * self.timing.verify_frac / self.timing.mat_groups;
        self.remap_stall_ns += remaps as f64 * self.timing.remap_ns;
        stall + lead + remaps as f64 * self.timing.remap_ns
    }

    /// Worst-minus-best region wear under the chunked scheduler (<= 1
    /// chunk when balancing works).
    pub fn wear_spread_chunks(&self) -> u64 {
        let max = self.region_writes.iter().copied().max().unwrap_or(0);
        let min = self.region_writes.iter().copied().min().unwrap_or(0);
        max - min
    }

    /// Load model weights (one-shot deployment write). Returns cycle
    /// write time; errors delegate to the wrapped state.
    pub fn load_weights(&mut self, bytes: u64) -> Result<f64, String> {
        let fo = self.base.load_weights(bytes)?;
        Ok(fo + self.write_extras_ns(bytes, fo))
    }

    /// One-shot KV offload (write-once). Returns cycle write time.
    pub fn offload_kv(&mut self, bytes: u64) -> f64 {
        let take = bytes.min(self.base.free_bytes());
        let fo = self.base.offload_kv(bytes);
        fo + self.write_extras_ns(take, fo)
    }

    /// Cycle-accurate resident-weight stream.
    pub fn weight_stream_ns(&mut self, bytes: u64) -> f64 {
        let fo = self.base.weight_stream_ns(bytes);
        fo + self.read_extras_ns(bytes, TAG_WEIGHTS, fo)
    }

    /// Cycle-accurate cold-KV stream.
    pub fn kv_stream_ns(&mut self, bytes: u64) -> f64 {
        let fo = self.base.kv_stream_ns(bytes);
        fo + self.read_extras_ns(bytes, TAG_KV, fo)
    }

    /// Array read energy (delegates — shared energy model).
    pub fn read_energy_pj(&self, bytes: u64) -> f64 {
        self.base.read_energy_pj(bytes)
    }

    /// Array write energy (delegates — shared energy model).
    pub fn write_energy_pj(&self, bytes: u64) -> f64 {
        self.base.write_energy_pj(bytes)
    }
}

impl MemoryModel for CycleRramState {
    fn name(&self) -> &'static str {
        "m3d-rram-cycle"
    }

    fn capacity_bytes(&self) -> u64 {
        self.base.capacity_bytes()
    }

    fn used_bytes(&self) -> u64 {
        self.base.used_bytes()
    }

    fn stream_weights_ns(&mut self, bytes: u64) -> f64 {
        CycleRramState::weight_stream_ns(self, bytes)
    }

    fn read_energy_pj(&self, bytes: u64) -> f64 {
        self.base.read_energy_pj(bytes)
    }

    fn write_energy_pj(&self, bytes: u64) -> f64 {
        self.base.write_energy_pj(bytes)
    }

    fn lifetime_read_bytes(&self) -> u64 {
        self.base.lifetime_read_bytes()
    }

    fn lifetime_write_bytes(&self) -> u64 {
        self.base.lifetime_write_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RramConfig;

    fn pair() -> (RramState, CycleRramState) {
        let fo = RramState::new(RramConfig::default());
        let cy = CycleRramState::new(fo.clone());
        (fo, cy)
    }

    #[test]
    fn cycle_reads_and_writes_never_undercut_first_order() {
        let (mut fo, mut cy) = pair();
        let wf = fo.load_weights(1_000_000_000).unwrap();
        let wc = cy.load_weights(1_000_000_000).unwrap();
        assert!(wc >= wf, "write {wc} < analytic {wf}");
        for &bytes in &[100u64, 16_384, 1_000_000, 50_000_000] {
            let a = fo.weight_stream_ns(bytes);
            let b = cy.weight_stream_ns(bytes);
            assert!(b >= a, "{bytes} B read: cycle {b} < first-order {a}");
            let ka = fo.kv_stream_ns(bytes);
            let kb = cy.kv_stream_ns(bytes);
            assert!(kb >= ka, "{bytes} B kv: cycle {kb} < first-order {ka}");
        }
    }

    #[test]
    fn wear_scheduler_balances_regions() {
        let (_, mut cy) = pair();
        // 256 MB of chunked writes over 64 regions -> 4 chunks each.
        cy.load_weights(256 << 20).unwrap();
        assert_eq!(cy.remaps, 256);
        assert!(cy.wear_spread_chunks() <= 1, "spread {}", cy.wear_spread_chunks());
    }

    #[test]
    fn endurance_accounting_is_bit_identical() {
        let (mut fo, mut cy) = pair();
        fo.load_weights(2_000_000).unwrap();
        cy.load_weights(2_000_000).unwrap();
        fo.offload_kv(500_000);
        cy.offload_kv(500_000);
        assert_eq!(fo.lifetime_write_bytes, cy.base.lifetime_write_bytes);
        assert_eq!(fo.lifetime_read_bytes, cy.base.lifetime_read_bytes);
        assert_eq!(fo.endurance_consumed().to_bits(), cy.endurance_consumed().to_bits());
        assert_eq!(fo.used_bytes(), cy.used_bytes());
    }

    #[test]
    fn remap_latency_shows_up_on_chunk_boundaries() {
        let (mut fo, mut cy) = pair();
        let fo_t = fo.offload_kv(4 << 20);
        let cy_t = cy.offload_kv(4 << 20);
        assert_eq!(cy.remaps, 4);
        assert!(cy_t >= fo_t + 4.0 * cy.timing.remap_ns - 1e-9);
        // The stall-cause diagnostics attribute the same events.
        assert_eq!(cy.remap_stall_ns, 4.0 * cy.timing.remap_ns);
        assert!(cy.verify_ns > 0.0, "writes must log verify-pulse time");
    }
}
