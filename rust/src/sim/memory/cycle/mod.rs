//! Cycle-accurate chiplet-memory timing subsystem (DESIGN.md §9).
//!
//! The first-order states (`DramState`, `RramState`) price every stream
//! at an effective bandwidth — activation cost perfectly amortized,
//! strictly linear in bytes. This subsystem is the ROADMAP's
//! DRAMsim3-style alternative: event-driven device state machines that
//! price the *same* streams discretely, on top of the analytic time:
//!
//! * **DRAM** ([`CycleDramState`]) — per-tier bank/open-row tracking,
//!   whole-row activation quantization, precharge on row conflicts when
//!   weight and KV streams interleave on a tier, a four-activation-window
//!   (tFAW) issue limiter, and periodic refresh stalls (tREFI/tRFC).
//! * **RRAM** ([`CycleRramState`]) — mat/sense-amp pulse occupancy for
//!   reads, write-verify pulse overhead, and wear-aware write scheduling
//!   (chunked least-worn-region routing with remap bookkeeping).
//!
//! Two invariants the rest of the crate builds on:
//!
//! 1. **Lower bound** — for any request, cycle-accurate time >=
//!    first-order time. Every discrete effect is an *addition* to the
//!    analytic time of the same request (the analytic model is the
//!    idealized, perfectly-amortized limit), so the bound holds exactly,
//!    not just within float noise.
//! 2. **Bit-identical accounting** — capacity, occupancy, KV residency,
//!    and lifetime read/write/endurance ledgers are delegated to the
//!    wrapped first-order state, byte for byte. Only *time* diverges.
//!
//! Both states implement [`super::MemoryModel`], so they are
//! interchangeable with the first-order states behind
//! `&mut dyn MemoryModel`; `results::memcheck` cross-validates the two
//! fidelities over the Table II models and locks the per-phase divergence
//! inside a tolerance band.

pub mod dram;
pub mod rram;

pub use dram::{CycleDramState, DramCycleTiming};
pub use rram::{CycleRramState, RramCycleTiming};
