//! Cycle-accurate M3D DRAM timing: per-tier bank/open-row state machines
//! layered on top of the first-order [`DramState`].
//!
//! The first-order tier bandwidth (`DramConfig::tier_stream_bw_gbps`)
//! folds row activation into an amortized per-byte cost — fractional
//! rows, no precharge, no refresh, activations perfectly overlapped with
//! data. This model re-prices the same stream discretely:
//!
//! * activations are whole-row (ceil), issued round-robin over the
//!   tier's banks by `channels` parallel activation engines;
//! * a bank whose open row belongs to a *different* stream pays a
//!   precharge (tRP) before the activate — weight and KV streams
//!   interleaving on one tier thrash each other's rows;
//! * a stream switching onto a tier pays one un-overlapped activation
//!   (pipeline refill);
//! * at most four activations per engine per tFAW window;
//! * every tREFI of accumulated busy time stalls the device for tRFC.
//!
//! All occupancy and lifetime accounting delegates to the wrapped
//! [`DramState`] — only time diverges (see `cycle` module docs).

use crate::config::DramConfig;

use super::super::dram::{DramState, KvResidency, WeightClass};
use super::super::MemoryModel;

/// Stream tag for open-row / conflict tracking: one per weight class,
/// plus the KV read and KV write-back streams.
fn class_tag(class: WeightClass) -> u8 {
    class as u8
}
const TAG_KV_READ: u8 = 5;
const TAG_KV_WRITE: u8 = 6;
/// Stored-tag sentinel for "no open row" / "no prior stream". Stream
/// tags are stored shifted by one so the flat bank array needs no
/// per-bank `Option` discriminant.
const TAG_NONE: u8 = 0;

/// Timing parameters the staircase model does not carry. tFAW / tREFI /
/// tRFC are standard LPDDR-class constants; tRP is expressed as a
/// fraction of the tier's activate latency (precharge restores the same
/// wordline path the activation drove).
#[derive(Debug, Clone)]
pub struct DramCycleTiming {
    /// Four-activation window (ns) per activation engine.
    pub t_faw_ns: f64,
    /// Average refresh interval (ns).
    pub t_refi_ns: f64,
    /// Refresh cycle time (ns) — the stall every tREFI of busy time.
    pub t_rfc_ns: f64,
    /// Precharge latency as a fraction of the tier activate latency.
    pub t_rp_frac: f64,
}

impl Default for DramCycleTiming {
    fn default() -> Self {
        DramCycleTiming { t_faw_ns: 40.0, t_refi_ns: 3900.0, t_rfc_ns: 280.0, t_rp_frac: 0.5 }
    }
}

/// All tiers' bank state machines in a flat SoA layout (§Perf: the
/// per-tier struct-of-`Vec<Option<u8>>` layout cost a discriminant per
/// bank and a pointer chase per tier; the hot conflict loop now walks a
/// dense `u8` slice).
///
/// Conflicts are tracked at stream granularity: sequential streams
/// re-walk their own rows in order, so a bank held by the same stream is
/// a row hit and a bank held by a different stream always needs a
/// precharge.
#[derive(Debug, Clone)]
struct BankState {
    /// Open-row owner tag per (tier, bank): bank `b` of tier `t` lives at
    /// `t * banks + b`; [`TAG_NONE`] when no row is open. Stored tags are
    /// shifted by one (`tag + 1`).
    open: Vec<u8>,
    /// Round-robin activation pointer per tier.
    cursor: Vec<usize>,
    /// Shifted tag of the last stream on each tier (pipeline-refill
    /// lead); [`TAG_NONE`] before any stream.
    last_tag: Vec<u8>,
    /// Banks per tier.
    banks: usize,
}

impl BankState {
    fn new(tiers: usize, banks: usize) -> BankState {
        BankState {
            open: vec![TAG_NONE; tiers * banks],
            cursor: vec![0; tiers],
            last_tag: vec![TAG_NONE; tiers],
            banks,
        }
    }

    fn tiers(&self) -> usize {
        self.cursor.len()
    }
}

/// Cycle-accurate M3D DRAM state: a [`DramState`] (occupancy, placement,
/// lifetime ledgers — bit-identical to first-order) plus the per-tier
/// bank/row timing machinery.
#[derive(Debug, Clone)]
pub struct CycleDramState {
    /// The wrapped first-order state; owns every byte of accounting.
    pub base: DramState,
    /// Discrete timing constants.
    pub timing: DramCycleTiming,
    banks: BankState,
    /// Busy time accumulated toward the next refresh stall.
    refresh_debt_ns: f64,
    /// Diagnostics: total refresh stall time (ns).
    pub refresh_stall_ns: f64,
    /// Diagnostics: total tFAW stall time (ns).
    pub faw_stall_ns: f64,
    /// Diagnostics: total precharge (row-conflict) stall time (ns).
    pub precharge_stall_ns: f64,
    /// Diagnostics: whole-row activations issued.
    pub activations: u64,
    /// Diagnostics: row conflicts (precharge-before-activate events).
    pub row_conflicts: u64,
}

impl CycleDramState {
    /// Wrap a first-order state (typically after weight placement).
    pub fn new(base: DramState) -> CycleDramState {
        let banks = BankState::new(base.cfg.tiers, base.cfg.channels * base.cfg.banks_per_channel);
        CycleDramState {
            base,
            timing: DramCycleTiming::default(),
            banks,
            refresh_debt_ns: 0.0,
            refresh_stall_ns: 0.0,
            faw_stall_ns: 0.0,
            precharge_stall_ns: 0.0,
            activations: 0,
            row_conflicts: 0,
        }
    }

    /// Device configuration (shared with the wrapped state).
    pub fn cfg(&self) -> &DramConfig {
        &self.base.cfg
    }

    /// Discrete extras for one contiguous stream of `share` bytes out of
    /// `tier` under stream `tag`, given the analytic time `fo_ns` of that
    /// share. Every term is >= 0, so cycle time >= first-order time holds
    /// exactly (see module docs).
    fn stream_extras_ns(&mut self, tier: usize, tag: u8, share: f64, fo_ns: f64) -> f64 {
        if share <= 0.0 {
            return 0.0;
        }
        let row_bytes = self.base.cfg.row_buffer_bits as f64 / 8.0;
        let engines = self.base.cfg.channels as f64;
        let t_act = self.base.cfg.tier_latency_ns(tier);
        let rows_frac = share / row_bytes;
        let rows = rows_frac.ceil().max(1.0);

        // (a) whole-row activation quantization beyond the amortized cost
        // already folded into the first-order bandwidth.
        let quant_ns = (rows - rows_frac) * t_act / engines;

        // (b) bank/open-row machine: rows land round-robin on the tier's
        // banks; a bank holding a different stream's row precharges first.
        // The index is clamped so an out-of-range tier (which the
        // first-order model prices as an extra-slow stream) degrades the
        // same way here instead of panicking.
        if self.banks.tiers() == 0 {
            return quant_ns; // zero-tier config: no bank machinery
        }
        let bank_tier = tier.min(self.banks.tiers() - 1);
        let n_banks = self.banks.banks;
        let shifted = tag + 1; // stored tags are shifted; TAG_NONE = 0
        let open = &mut self.banks.open[bank_tier * n_banks..(bank_tier + 1) * n_banks];
        let touched = (rows as usize).min(n_banks);
        let cursor = self.banks.cursor[bank_tier];
        let mut conflicts = 0u64;
        for i in 0..touched {
            let b = (cursor + i) % n_banks;
            let g = open[b];
            if g != TAG_NONE && g != shifted {
                conflicts += 1;
            }
            open[b] = shifted;
        }
        self.banks.cursor[bank_tier] = (cursor + touched) % n_banks;

        // (c) pipeline refill: the first activation of a stream that just
        // switched onto this tier cannot hide behind prior data bursts.
        let lead_ns = if self.banks.last_tag[bank_tier] == shifted { 0.0 } else { t_act };
        self.banks.last_tag[bank_tier] = shifted;

        let conflict_ns = conflicts as f64 * (self.timing.t_rp_frac * t_act) / engines;

        // (d) tFAW: at most 4 activations per window per engine. With the
        // default staircase (t_act >= 19 ns > tFAW/4) serial issue already
        // satisfies the window and this contributes 0; it binds for
        // faster-activate configurations.
        let acts_per_engine = (rows / engines).ceil();
        let faw_ns =
            (acts_per_engine * (self.timing.t_faw_ns / 4.0) - acts_per_engine * t_act).max(0.0);

        // (e) refresh: every tREFI of accumulated busy time stalls tRFC.
        self.refresh_debt_ns += fo_ns + quant_ns + conflict_ns + lead_ns + faw_ns;
        let mut refresh_ns = 0.0;
        while self.refresh_debt_ns >= self.timing.t_refi_ns {
            self.refresh_debt_ns -= self.timing.t_refi_ns;
            refresh_ns += self.timing.t_rfc_ns;
        }

        self.activations += rows as u64;
        self.row_conflicts += conflicts;
        self.faw_stall_ns += faw_ns;
        self.refresh_stall_ns += refresh_ns;
        self.precharge_stall_ns += conflict_ns;
        quant_ns + conflict_ns + lead_ns + faw_ns + refresh_ns
    }

    /// Statically place `bytes` of `class` weights (delegates to the
    /// wrapped state; placement is timing-free at deployment).
    pub fn place_weights_classed(&mut self, class: WeightClass, bytes: u64) -> Result<(), u64> {
        self.base.place_weights_classed(class, bytes)
    }

    /// Un-classed placement (tests / simple callers).
    pub fn place_weights(&mut self, bytes: u64) -> Result<(), u64> {
        self.base.place_weights(bytes)
    }

    /// Cycle-accurate classed weight stream: the analytic time of the
    /// same tier shares plus the discrete extras per share. The shares
    /// are computed once; the analytic component and accounting mirror
    /// `DramState::weight_stream_ns_classed` over the same mix.
    pub fn weight_stream_ns_classed(&mut self, class: WeightClass, bytes: u64) -> f64 {
        let shares = self.base.class_stream_shares(class, bytes);
        self.base.bytes_read += bytes;
        let mut ns = 0.0;
        for (tier, share) in shares {
            let fo_share = share / self.base.cfg.tier_stream_bw_gbps(tier, 1.0);
            ns += fo_share + self.stream_extras_ns(tier, class_tag(class), share, fo_share);
        }
        ns
    }

    /// Cycle-accurate KV read stream by explicit tier mix.
    pub fn kv_stream_ns(&mut self, bytes_by_tier: &[(usize, u64)]) -> f64 {
        let fo = self.base.kv_stream_ns(bytes_by_tier);
        let mut extras = 0.0;
        for &(tier, bytes) in bytes_by_tier {
            let share = bytes as f64;
            let fo_share = share / self.base.cfg.tier_stream_bw_gbps(tier, 1.0);
            extras += self.stream_extras_ns(tier, TAG_KV_READ, share, fo_share);
        }
        fo + extras
    }

    /// Cycle-accurate KV write-back stream (this step's fresh K/V rows
    /// through the tier-0 row buffers).
    pub fn kv_writeback_ns(&mut self, bytes: u64) -> f64 {
        let fo = self.base.kv_writeback_ns(bytes);
        let extras = self.stream_extras_ns(0, TAG_KV_WRITE, bytes as f64, fo);
        fo + extras
    }

    /// Append fresh KV (occupancy bookkeeping delegates to the wrapped
    /// state; write timing is priced by [`Self::kv_writeback_ns`]).
    pub fn append_kv(&mut self, bytes: u64) -> u64 {
        self.base.append_kv(bytes)
    }

    /// KV residency distribution (delegates).
    pub fn kv_distribution(&self) -> Vec<(KvResidency, u64)> {
        self.base.kv_distribution()
    }

    /// Total resident + offloaded KV bytes (delegates).
    pub fn total_kv_bytes(&self) -> u64 {
        self.base.total_kv_bytes()
    }

    /// Array energy (delegates — the fidelities share one energy model;
    /// divergence is a *timing* question, see DESIGN.md §9).
    pub fn array_energy_pj(&self, bytes: u64) -> f64 {
        self.base.array_energy_pj(bytes)
    }
}

impl MemoryModel for CycleDramState {
    fn name(&self) -> &'static str {
        "m3d-dram-cycle"
    }

    fn capacity_bytes(&self) -> u64 {
        self.base.capacity_bytes()
    }

    fn used_bytes(&self) -> u64 {
        self.base.used_bytes()
    }

    fn stream_weights_ns(&mut self, bytes: u64) -> f64 {
        self.weight_stream_ns_classed(WeightClass::Attn, bytes)
    }

    fn read_energy_pj(&self, bytes: u64) -> f64 {
        self.base.read_energy_pj(bytes)
    }

    fn write_energy_pj(&self, bytes: u64) -> f64 {
        self.base.write_energy_pj(bytes)
    }

    fn lifetime_read_bytes(&self) -> u64 {
        self.base.lifetime_read_bytes()
    }

    fn lifetime_write_bytes(&self) -> u64 {
        self.base.lifetime_write_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DramConfig;

    fn placed(bytes: u64) -> (DramState, CycleDramState) {
        let mut fo = DramState::new(DramConfig::default());
        fo.place_weights(bytes).unwrap();
        let cy = CycleDramState::new(fo.clone());
        (fo, cy)
    }

    #[test]
    fn cycle_stream_never_undercuts_first_order() {
        let (mut fo, mut cy) = placed(2_000_000_000);
        for &bytes in &[1_000u64, 100_000, 4_096, 50_000_000, 3] {
            let a = fo.weight_stream_ns_classed(WeightClass::Attn, bytes);
            let b = cy.weight_stream_ns_classed(WeightClass::Attn, bytes);
            assert!(b >= a, "{bytes} B: cycle {b} < first-order {a}");
        }
    }

    #[test]
    fn refresh_makes_long_streams_super_linear() {
        // Linearity is the *first-order* contract; the cycle model is
        // legitimately super-linear once refresh stalls accrue.
        let (_, mut cy) = placed(2_000_000_000);
        let t1 = cy.weight_stream_ns_classed(WeightClass::Attn, 100_000_000);
        let t2 = cy.weight_stream_ns_classed(WeightClass::Attn, 200_000_000);
        assert!(t2 > t1, "monotone in bytes");
        assert!(cy.refresh_stall_ns > 0.0, "100 MB must cross several tREFI windows");
    }

    #[test]
    fn interleaved_streams_thrash_rows() {
        let (_, mut cy) = placed(1_000_000_000);
        // Same-stream re-streams keep rows open after the first pass...
        cy.weight_stream_ns_classed(WeightClass::Attn, 10_000_000);
        let before = cy.row_conflicts;
        cy.weight_stream_ns_classed(WeightClass::Attn, 10_000_000);
        assert_eq!(cy.row_conflicts, before, "same stream must not self-conflict");
        // ...while an interleaved KV stream on the same tier precharges them.
        cy.kv_stream_ns(&[(0, 10_000_000)]);
        assert!(cy.row_conflicts > before, "tag switch must conflict");
        assert!(
            cy.precharge_stall_ns > 0.0,
            "conflicts must show up in the precharge stall diagnostic"
        );
    }

    #[test]
    fn tiers_keep_independent_bank_state() {
        // Flat-SoA regression: rows opened on one tier must not leak into
        // another tier's slice of the flat bank array.
        let (_, mut cy) = placed(1_000_000_000);
        cy.kv_stream_ns(&[(1, 10_000_000)]); // open KV-read rows on tier 1
        let before = cy.row_conflicts;
        // A different stream tag on tier 0 lands on never-opened banks.
        cy.kv_writeback_ns(10_000_000);
        assert_eq!(cy.row_conflicts, before, "tier 0 banks were never opened");
    }

    #[test]
    fn accounting_is_bit_identical_to_first_order() {
        let (mut fo, mut cy) = placed(1_000_000);
        for m in [&mut fo as &mut dyn MemoryModel, &mut cy as &mut dyn MemoryModel] {
            m.stream_weights_ns(500_000);
        }
        fo.append_kv(4096);
        cy.append_kv(4096);
        assert_eq!(fo.used_bytes(), cy.used_bytes());
        assert_eq!(fo.bytes_read, cy.base.bytes_read);
        assert_eq!(fo.bytes_written, cy.base.bytes_written);
        assert_eq!(fo.kv_offloaded, cy.base.kv_offloaded);
    }

    #[test]
    fn out_of_range_tier_degrades_like_first_order() {
        // The first-order model prices an out-of-range tier as an
        // extra-slow stream; the cycle model must degrade the same way
        // (clamped bank state), not panic.
        let (mut fo, mut cy) = placed(1_000_000);
        let a = fo.kv_stream_ns(&[(7, 10_000)]);
        let b = cy.kv_stream_ns(&[(7, 10_000)]);
        assert!(b.is_finite() && b >= a, "cycle {b} vs first-order {a}");
    }

    #[test]
    fn writeback_is_bounded_below_by_the_tier0_stream() {
        let (_, mut cy) = placed(1_000_000);
        let fo = 65_536.0 / cy.cfg().tier_stream_bw_gbps(0, 1.0);
        let t = cy.kv_writeback_ns(65_536);
        assert!(t >= fo, "writeback {t} < analytic {fo}");
    }
}
