//! Memory subsystem models: M3D DRAM (tiered), M3D RRAM (endurance-aware),
//! and the cycle-accurate timing subsystem (`cycle`) behind the same
//! [`MemoryModel`] surface. (The UCIe die-to-die link moved to the routed
//! fabric subsystem, `sim::fabric`.)
//!
//! Two fidelities answer every stream-time/energy query (selected by
//! `config::MemoryFidelity`, threaded through `ChimeHardware`):
//!
//! * first-order — [`DramState`] / [`RramState`], the paper's analytic
//!   streaming model (effective bandwidth, linear in bytes);
//! * cycle-accurate — [`CycleDramState`] / [`CycleRramState`]
//!   (`cycle` module), event-driven bank/row/tier and mat/pulse state
//!   machines that price the same streams at or above the analytic time.
//!
//! The simulator holds them behind [`DramMem`] / [`RramMem`], so every
//! execution path (solo, DRAM-only, sharded serving) runs either model.

pub mod cycle;
pub mod dram;
pub mod rram;

pub use cycle::{CycleDramState, CycleRramState};
pub use dram::{DramState, KvResidency, TierState};
pub use rram::RramState;

use crate::config::MemoryFidelity;
use dram::WeightClass;

/// The streaming/energy surface a chiplet memory must answer. Object-safe
/// so heterogeneous memory stacks can be driven through `&mut dyn
/// MemoryModel` (validation harnesses, the cycle-accurate backend).
///
/// # Timing contract
///
/// `stream_weights_ns` must be monotone non-decreasing in `bytes` and
/// strictly positive for non-zero requests. **First-order** analytic
/// implementations ([`DramState`], [`RramState`]) additionally guarantee
/// *linearity in bytes* — they model an effective bandwidth with every
/// discrete cost perfectly amortized, which makes them an idealized
/// lower bound. **Cycle-accurate** implementations are *not* linear:
/// whole-row activation quantization, tFAW windows, refresh stalls, and
/// wear-remap boundaries make them legitimately super-linear (and
/// history-dependent), but never below the first-order time for the same
/// request. Occupancy (`used_bytes`) and the lifetime ledgers must agree
/// bit-for-bit across fidelities — fidelity is a timing question only.
pub trait MemoryModel {
    /// Short device name ("m3d-dram", "m3d-rram-cycle", ...).
    fn name(&self) -> &'static str;

    /// Total device capacity in bytes.
    fn capacity_bytes(&self) -> u64;

    /// Bytes currently resident (weights + KV).
    fn used_bytes(&self) -> u64;

    /// Remaining capacity in bytes.
    fn free_capacity_bytes(&self) -> u64 {
        self.capacity_bytes().saturating_sub(self.used_bytes())
    }

    /// Time (ns) to stream `bytes` of resident weights into the NMP.
    fn stream_weights_ns(&mut self, bytes: u64) -> f64;

    /// Array read energy for `bytes`, in picojoules.
    fn read_energy_pj(&self, bytes: u64) -> f64;

    /// Array write energy for `bytes`, in picojoules.
    fn write_energy_pj(&self, bytes: u64) -> f64;

    /// Lifetime bytes read from the device (reporting/validation).
    fn lifetime_read_bytes(&self) -> u64;

    /// Lifetime bytes written to the device (reporting/endurance).
    fn lifetime_write_bytes(&self) -> u64;
}

/// The DRAM chiplet memory at either fidelity. The simulator owns one of
/// these and calls the rich query surface; the `FirstOrder` arm forwards
/// verbatim to [`DramState`] (bit-identical to the pre-fidelity code
/// path), the `CycleAccurate` arm runs the bank/row timing machinery.
#[derive(Debug, Clone)]
pub enum DramMem {
    /// Analytic streaming model (the paper's simulator).
    FirstOrder(DramState),
    /// Event-driven bank/row/tier model (`cycle::dram`).
    CycleAccurate(CycleDramState),
}

impl DramMem {
    /// Wrap a placed state at the requested fidelity.
    pub fn new(state: DramState, fidelity: MemoryFidelity) -> DramMem {
        match fidelity {
            MemoryFidelity::FirstOrder => DramMem::FirstOrder(state),
            MemoryFidelity::CycleAccurate => DramMem::CycleAccurate(CycleDramState::new(state)),
        }
    }

    /// The fidelity this memory runs at.
    pub fn fidelity(&self) -> MemoryFidelity {
        match self {
            DramMem::FirstOrder(_) => MemoryFidelity::FirstOrder,
            DramMem::CycleAccurate(_) => MemoryFidelity::CycleAccurate,
        }
    }

    /// The underlying first-order state (occupancy, placement, ledgers —
    /// shared bit-for-bit by both fidelities).
    pub fn state(&self) -> &DramState {
        match self {
            DramMem::FirstOrder(s) => s,
            DramMem::CycleAccurate(c) => &c.base,
        }
    }

    /// Mutable access to the underlying first-order state.
    pub fn state_mut(&mut self) -> &mut DramState {
        match self {
            DramMem::FirstOrder(s) => s,
            DramMem::CycleAccurate(c) => &mut c.base,
        }
    }

    /// Classed weight stream time (ns) at this fidelity.
    pub fn weight_stream_ns_classed(&mut self, class: WeightClass, bytes: u64) -> f64 {
        match self {
            DramMem::FirstOrder(s) => s.weight_stream_ns_classed(class, bytes),
            DramMem::CycleAccurate(c) => c.weight_stream_ns_classed(class, bytes),
        }
    }

    /// KV read stream time (ns) by explicit tier mix at this fidelity.
    pub fn kv_stream_ns(&mut self, bytes_by_tier: &[(usize, u64)]) -> f64 {
        match self {
            DramMem::FirstOrder(s) => s.kv_stream_ns(bytes_by_tier),
            DramMem::CycleAccurate(c) => c.kv_stream_ns(bytes_by_tier),
        }
    }

    /// KV write-back stream time (ns) through the tier-0 row buffers.
    pub fn kv_writeback_ns(&mut self, bytes: u64) -> f64 {
        match self {
            DramMem::FirstOrder(s) => s.kv_writeback_ns(bytes),
            DramMem::CycleAccurate(c) => c.kv_writeback_ns(bytes),
        }
    }

    /// Append fresh KV; returns bytes overflowed to RRAM (occupancy is
    /// fidelity-independent).
    pub fn append_kv(&mut self, bytes: u64) -> u64 {
        self.state_mut().append_kv(bytes)
    }

    /// KV residency distribution (fidelity-independent).
    pub fn kv_distribution(&self) -> Vec<(KvResidency, u64)> {
        self.state().kv_distribution()
    }

    /// Array energy in pJ (shared energy model across fidelities).
    pub fn array_energy_pj(&self, bytes: u64) -> f64 {
        self.state().array_energy_pj(bytes)
    }
}

/// The RRAM chiplet memory at either fidelity (see [`DramMem`]).
#[derive(Debug, Clone)]
pub enum RramMem {
    /// Analytic streaming model (the paper's simulator).
    FirstOrder(RramState),
    /// Event-driven mat/pulse/wear model (`cycle::rram`).
    CycleAccurate(CycleRramState),
}

impl RramMem {
    /// Wrap a loaded state at the requested fidelity.
    pub fn new(state: RramState, fidelity: MemoryFidelity) -> RramMem {
        match fidelity {
            MemoryFidelity::FirstOrder => RramMem::FirstOrder(state),
            MemoryFidelity::CycleAccurate => RramMem::CycleAccurate(CycleRramState::new(state)),
        }
    }

    /// The fidelity this memory runs at.
    pub fn fidelity(&self) -> MemoryFidelity {
        match self {
            RramMem::FirstOrder(_) => MemoryFidelity::FirstOrder,
            RramMem::CycleAccurate(_) => MemoryFidelity::CycleAccurate,
        }
    }

    /// The underlying first-order state.
    pub fn state(&self) -> &RramState {
        match self {
            RramMem::FirstOrder(s) => s,
            RramMem::CycleAccurate(c) => &c.base,
        }
    }

    /// Mutable access to the underlying first-order state.
    pub fn state_mut(&mut self) -> &mut RramState {
        match self {
            RramMem::FirstOrder(s) => s,
            RramMem::CycleAccurate(c) => &mut c.base,
        }
    }

    /// Load model weights (one-shot deployment write); returns write ns.
    pub fn load_weights(&mut self, bytes: u64) -> Result<f64, String> {
        match self {
            RramMem::FirstOrder(s) => s.load_weights(bytes),
            RramMem::CycleAccurate(c) => c.load_weights(bytes),
        }
    }

    /// One-shot KV offload (write-once); returns write ns.
    pub fn offload_kv(&mut self, bytes: u64) -> f64 {
        match self {
            RramMem::FirstOrder(s) => s.offload_kv(bytes),
            RramMem::CycleAccurate(c) => c.offload_kv(bytes),
        }
    }

    /// Resident-weight stream time (ns) at this fidelity.
    pub fn weight_stream_ns(&mut self, bytes: u64) -> f64 {
        match self {
            RramMem::FirstOrder(s) => s.weight_stream_ns(bytes),
            RramMem::CycleAccurate(c) => c.weight_stream_ns(bytes),
        }
    }

    /// Cold-KV stream time (ns) at this fidelity.
    pub fn kv_stream_ns(&mut self, bytes: u64) -> f64 {
        match self {
            RramMem::FirstOrder(s) => s.kv_stream_ns(bytes),
            RramMem::CycleAccurate(c) => c.kv_stream_ns(bytes),
        }
    }

    /// Array read energy in pJ (shared energy model).
    pub fn read_energy_pj(&self, bytes: u64) -> f64 {
        self.state().read_energy_pj(bytes)
    }

    /// Array write energy in pJ (shared energy model).
    pub fn write_energy_pj(&self, bytes: u64) -> f64 {
        self.state().write_energy_pj(bytes)
    }

    /// Fraction of rated endurance consumed (fidelity-independent).
    pub fn endurance_consumed(&self) -> f64 {
        self.state().endurance_consumed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DramConfig, RramConfig};

    /// The relaxed polymorphic contract every implementation (both
    /// fidelities) must satisfy: positive monotone stream times, sane
    /// capacity arithmetic, energy ordering, and lifetime accounting.
    /// Linearity is asserted separately, for first-order models only —
    /// a cycle-accurate impl is legitimately super-linear near
    /// tFAW/refresh boundaries (see the `MemoryModel` timing contract).
    fn check_contract(m: &mut dyn MemoryModel) {
        assert!(m.capacity_bytes() > 0, "{}", m.name());
        assert_eq!(m.used_bytes(), 1_000_000, "{}", m.name());
        assert_eq!(
            m.free_capacity_bytes(),
            m.capacity_bytes() - 1_000_000,
            "{}",
            m.name()
        );
        let t1 = m.stream_weights_ns(500_000);
        let t2 = m.stream_weights_ns(1_000_000);
        assert!(t1 > 0.0, "{}", m.name());
        assert!(t2 >= t1, "{}: stream time must be monotone in bytes", m.name());
        assert!(m.read_energy_pj(1_000) > 0.0);
        assert!(m.write_energy_pj(1_000) >= m.read_energy_pj(1_000) * 0.5);
        assert!(m.lifetime_read_bytes() >= 1_500_000, "{}", m.name());
    }

    #[test]
    fn all_four_memories_answer_the_model_polymorphically() {
        let mut dram = DramState::new(DramConfig::default());
        dram.place_weights(1_000_000).unwrap();
        let mut cycle_dram = CycleDramState::new(dram.clone());
        let mut rram = RramState::new(RramConfig::default());
        rram.load_weights(1_000_000).unwrap();
        let mut cycle_rram = CycleRramState::new(rram.clone());

        let mut models: Vec<&mut dyn MemoryModel> =
            vec![&mut dram, &mut cycle_dram, &mut rram, &mut cycle_rram];
        for m in &mut models {
            check_contract(&mut **m);
        }
    }

    #[test]
    fn first_order_models_are_linear_in_bytes() {
        // The documented first-order contract: streaming is linear.
        let mut dram = DramState::new(DramConfig::default());
        dram.place_weights(1_000_000).unwrap();
        let mut rram = RramState::new(RramConfig::default());
        rram.load_weights(1_000_000).unwrap();
        let mut models: Vec<&mut dyn MemoryModel> = vec![&mut dram, &mut rram];
        for m in &mut models {
            let t1 = m.stream_weights_ns(500_000);
            let t2 = m.stream_weights_ns(1_000_000);
            assert!(
                (t2 / t1 - 2.0).abs() < 1e-6,
                "{}: first-order streaming must be linear in bytes",
                m.name()
            );
        }
    }

    #[test]
    fn cycle_models_bound_first_order_from_above() {
        let mut fo_d = DramState::new(DramConfig::default());
        fo_d.place_weights(1_000_000).unwrap();
        let mut cy_d = CycleDramState::new(fo_d.clone());
        let mut fo_r = RramState::new(RramConfig::default());
        fo_r.load_weights(1_000_000).unwrap();
        let mut cy_r = CycleRramState::new(fo_r.clone());
        for bytes in [1_000u64, 500_000, 5_000_000] {
            assert!(cy_d.stream_weights_ns(bytes) >= fo_d.stream_weights_ns(bytes));
            assert!(cy_r.stream_weights_ns(bytes) >= fo_r.stream_weights_ns(bytes));
        }
    }

    #[test]
    fn write_accounting_flows_through_the_trait() {
        let mut rram = RramState::new(RramConfig::default());
        rram.load_weights(2_000_000).unwrap();
        let m: &dyn MemoryModel = &rram;
        assert_eq!(m.lifetime_write_bytes(), 2_000_000);
        assert_eq!(m.name(), "m3d-rram");

        let mut dram = DramState::new(DramConfig::default());
        dram.append_kv(4096);
        let m: &dyn MemoryModel = &dram;
        assert_eq!(m.lifetime_write_bytes(), 4096);
        assert_eq!(m.name(), "m3d-dram");
    }

    #[test]
    fn fidelity_wrappers_dispatch_and_expose_state() {
        let mut d = DramMem::new(DramState::new(DramConfig::default()), MemoryFidelity::FirstOrder);
        assert_eq!(d.fidelity(), MemoryFidelity::FirstOrder);
        d.state_mut().place_weights(1_000).unwrap();
        assert_eq!(d.state().used_bytes(), 1_000);
        let mut dc =
            DramMem::new(DramState::new(DramConfig::default()), MemoryFidelity::CycleAccurate);
        assert_eq!(dc.fidelity(), MemoryFidelity::CycleAccurate);
        dc.state_mut().place_weights(1_000).unwrap();
        let bytes = 100_000;
        assert!(
            dc.weight_stream_ns_classed(WeightClass::Attn, bytes)
                >= d.weight_stream_ns_classed(WeightClass::Attn, bytes)
        );
        assert!(dc.kv_writeback_ns(4096) >= d.kv_writeback_ns(4096));

        let mut r = RramMem::new(RramState::new(RramConfig::default()), MemoryFidelity::FirstOrder);
        let mut rc =
            RramMem::new(RramState::new(RramConfig::default()), MemoryFidelity::CycleAccurate);
        r.load_weights(1_000_000).unwrap();
        rc.load_weights(1_000_000).unwrap();
        assert!(rc.weight_stream_ns(50_000) >= r.weight_stream_ns(50_000));
        assert_eq!(r.state().lifetime_write_bytes, rc.state().lifetime_write_bytes);
        assert_eq!(r.endurance_consumed(), rc.endurance_consumed());
    }
}
