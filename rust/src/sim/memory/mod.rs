//! Memory subsystem models: M3D DRAM (tiered), M3D RRAM (endurance-aware),
//! and the UCIe die-to-die link.

pub mod dram;
pub mod rram;
pub mod ucie;

pub use dram::{DramState, KvResidency, TierState};
pub use rram::RramState;
pub use ucie::UcieLink;
