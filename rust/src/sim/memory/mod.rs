//! Memory subsystem models: M3D DRAM (tiered), M3D RRAM (endurance-aware),
//! and the UCIe die-to-die link.
//!
//! Both chiplet memories implement [`MemoryModel`] — the first-order
//! streaming/energy surface the simulator prices against. The ROADMAP's
//! cycle-accurate backend (DRAMsim3-style) slots in behind this same
//! interface: a cycle-accurate state only has to answer the trait's
//! stream-time and energy queries to replace the analytic staircase model.

pub mod dram;
pub mod rram;
pub mod ucie;

pub use dram::{DramState, KvResidency, TierState};
pub use rram::RramState;
pub use ucie::UcieLink;

/// The streaming/energy surface a chiplet memory must answer. Object-safe
/// so heterogeneous memory stacks can be driven through `&mut dyn
/// MemoryModel` (validation harnesses, the future cycle-accurate backend).
pub trait MemoryModel {
    /// Short device name ("m3d-dram", "m3d-rram", ...).
    fn name(&self) -> &'static str;

    /// Total device capacity in bytes.
    fn capacity_bytes(&self) -> u64;

    /// Bytes currently resident (weights + KV).
    fn used_bytes(&self) -> u64;

    /// Remaining capacity in bytes.
    fn free_capacity_bytes(&self) -> u64 {
        self.capacity_bytes().saturating_sub(self.used_bytes())
    }

    /// Time (ns) to stream `bytes` of resident weights into the NMP.
    fn stream_weights_ns(&mut self, bytes: u64) -> f64;

    /// Array read energy for `bytes`, in picojoules.
    fn read_energy_pj(&self, bytes: u64) -> f64;

    /// Array write energy for `bytes`, in picojoules.
    fn write_energy_pj(&self, bytes: u64) -> f64;

    /// Lifetime bytes read from the device (reporting/validation).
    fn lifetime_read_bytes(&self) -> u64;

    /// Lifetime bytes written to the device (reporting/endurance).
    fn lifetime_write_bytes(&self) -> u64;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DramConfig, RramConfig};

    #[test]
    fn both_chiplet_memories_answer_the_model_polymorphically() {
        let mut dram = DramState::new(DramConfig::default());
        dram.place_weights(1_000_000).unwrap();
        let mut rram = RramState::new(RramConfig::default());
        rram.load_weights(1_000_000).unwrap();

        let mut models: Vec<&mut dyn MemoryModel> = vec![&mut dram, &mut rram];
        for m in &mut models {
            assert!(m.capacity_bytes() > 0, "{}", m.name());
            assert_eq!(m.used_bytes(), 1_000_000, "{}", m.name());
            assert_eq!(
                m.free_capacity_bytes(),
                m.capacity_bytes() - 1_000_000,
                "{}",
                m.name()
            );
            let t1 = m.stream_weights_ns(500_000);
            let t2 = m.stream_weights_ns(1_000_000);
            assert!(t1 > 0.0, "{}", m.name());
            assert!(
                (t2 / t1 - 2.0).abs() < 1e-6,
                "{}: streaming must be linear in bytes",
                m.name()
            );
            assert!(m.read_energy_pj(1_000) > 0.0);
            assert!(m.write_energy_pj(1_000) >= m.read_energy_pj(1_000) * 0.5);
            assert!(m.lifetime_read_bytes() >= 1_500_000, "{}", m.name());
        }
    }

    #[test]
    fn write_accounting_flows_through_the_trait() {
        let mut rram = RramState::new(RramConfig::default());
        rram.load_weights(2_000_000).unwrap();
        let m: &dyn MemoryModel = &rram;
        assert_eq!(m.lifetime_write_bytes(), 2_000_000);
        assert_eq!(m.name(), "m3d-rram");

        let mut dram = DramState::new(DramConfig::default());
        dram.append_kv(4096);
        let m: &dyn MemoryModel = &dram;
        assert_eq!(m.lifetime_write_bytes(), 4096);
        assert_eq!(m.name(), "m3d-dram");
    }
}
