//! Fabric topologies: which physical UCIe links exist between the
//! chiplets of a multi-package deployment, and how a `(src, dst)`
//! endpoint pair resolves to an explicit multi-hop link route.
//!
//! Every package is one DRAM + one RRAM chiplet joined by a *local*
//! UCIe link (the link the single-package simulator has always
//! modeled). Inter-package links connect DRAM dies — the DRAM chiplet
//! is the package's fabric port, matching the CHIME floorplan where the
//! LLM-side die fronts the package. The four topologies differ only in
//! which DRAM-to-DRAM links exist and how package paths are chosen:
//!
//! ```text
//! point-to-point        line                ring                mesh (w = ceil(sqrt(n)))
//!   p0 ─── p1           p0 ── p1            p0 ── p1            p0 ── p1
//!    │ ╲  ╱ │                  │             │      │            │      │
//!    │  ╳   │                  p2            p3 ── p2            p2 ── p3
//!    │ ╱  ╲ │                  │
//!   p3 ─── p2                  p3
//! ```
//!
//! Routes are canonical and deterministic: cross-package routes are
//! built for `src.package < dst.package` and the opposite direction is
//! the exact reversal, so `route(a, b)` always mirrors `route(b, a)`
//! (locked by a property test).

use crate::config::TopologyKind;

/// Which die of a package an endpoint lives on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Chiplet {
    /// The DDR die (LLM weights + KV; the package's fabric port).
    Dram,
    /// The RRAM CIM die (ViT weights).
    Rram,
}

/// One chiplet of one package — the unit the fabric routes between.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Endpoint {
    /// Package index (0-based).
    pub package: usize,
    /// Which die of that package.
    pub chiplet: Chiplet,
}

impl Endpoint {
    /// The DRAM die of package `package`.
    pub fn dram(package: usize) -> Endpoint {
        Endpoint { package, chiplet: Chiplet::Dram }
    }

    /// The RRAM die of package `package`.
    pub fn rram(package: usize) -> Endpoint {
        Endpoint { package, chiplet: Chiplet::Rram }
    }
}

/// One undirected physical UCIe link. `Inter` links are canonical
/// (`a < b`) so both traversal directions hit the same counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Link {
    /// The in-package DRAM↔RRAM link of `package`.
    Local {
        /// Package index.
        package: usize,
    },
    /// The inter-package DRAM-to-DRAM link between packages `a < b`.
    Inter {
        /// Lower package index.
        a: usize,
        /// Higher package index.
        b: usize,
    },
}

impl Link {
    /// Canonicalize an inter-package link (order-insensitive).
    fn inter(x: usize, y: usize) -> Link {
        Link::Inter { a: x.min(y), b: x.max(y) }
    }

    /// Short display label: `p2.local` / `p0-p1`.
    pub fn label(&self) -> String {
        match self {
            Link::Local { package } => format!("p{package}.local"),
            Link::Inter { a, b } => format!("p{a}-p{b}"),
        }
    }
}

/// A fabric topology over `packages()` DRAM+RRAM packages: which links
/// exist ([`Topology::links`]) and how endpoint pairs route over them
/// ([`Topology::route`]). Implementations only supply the package-level
/// path for `a < b`; endpoint routing, reversal symmetry, and local-leg
/// handling are provided.
pub trait Topology {
    /// The kind tag this topology was built from.
    fn kind(&self) -> TopologyKind;

    /// Number of packages spanned.
    fn packages(&self) -> usize;

    /// Ordered package sequence from `a` to `b`, both inclusive.
    /// Only called with `a < b`; every consecutive pair must be a
    /// physical inter-package link of the topology.
    fn package_path(&self, a: usize, b: usize) -> Vec<usize>;

    /// Upper bound on inter-package hops over all package pairs.
    fn package_diameter(&self) -> usize;

    /// Every inter-package link, canonical and deduplicated.
    fn inter_links(&self) -> Vec<Link>;

    /// Every physical link: one local link per package + inter links.
    fn links(&self) -> Vec<Link> {
        let mut v: Vec<Link> =
            (0..self.packages()).map(|p| Link::Local { package: p }).collect();
        v.extend(self.inter_links());
        v
    }

    /// Upper bound on hops for any endpoint route: the package
    /// diameter plus at most one local leg at each end.
    fn diameter(&self) -> usize {
        self.package_diameter() + 2
    }

    /// The explicit link route from `src` to `dst` (empty when they are
    /// the same endpoint). Cross-package routes enter/leave through the
    /// DRAM dies, with a local leg appended for RRAM endpoints;
    /// `route(a, b)` is always the exact reversal of `route(b, a)`.
    fn route(&self, src: Endpoint, dst: Endpoint) -> Vec<Link> {
        if src == dst {
            return Vec::new();
        }
        if src.package == dst.package {
            return vec![Link::Local { package: src.package }];
        }
        if src.package > dst.package {
            let mut rev = self.route(dst, src);
            rev.reverse();
            return rev;
        }
        let mut route = Vec::new();
        if src.chiplet == Chiplet::Rram {
            route.push(Link::Local { package: src.package });
        }
        let path = self.package_path(src.package, dst.package);
        debug_assert!(path.first() == Some(&src.package));
        debug_assert!(path.last() == Some(&dst.package));
        for w in path.windows(2) {
            route.push(Link::inter(w[0], w[1]));
        }
        if dst.chiplet == Chiplet::Rram {
            route.push(Link::Local { package: dst.package });
        }
        route
    }
}

/// Dedicated link between every package pair — the legacy model, where
/// every cross-package transfer is exactly one inter hop.
struct PointToPoint {
    n: usize,
}

impl Topology for PointToPoint {
    fn kind(&self) -> TopologyKind {
        TopologyKind::PointToPoint
    }

    fn packages(&self) -> usize {
        self.n
    }

    fn package_path(&self, a: usize, b: usize) -> Vec<usize> {
        vec![a, b]
    }

    fn package_diameter(&self) -> usize {
        if self.n > 1 { 1 } else { 0 }
    }

    fn inter_links(&self) -> Vec<Link> {
        let mut v = Vec::new();
        for a in 0..self.n {
            for b in a + 1..self.n {
                v.push(Link::Inter { a, b });
            }
        }
        v
    }
}

/// Open chain `p0 — p1 — … — p(n-1)`; routes walk the chain.
struct Line {
    n: usize,
}

impl Topology for Line {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Line
    }

    fn packages(&self) -> usize {
        self.n
    }

    fn package_path(&self, a: usize, b: usize) -> Vec<usize> {
        (a..=b).collect()
    }

    fn package_diameter(&self) -> usize {
        self.n.saturating_sub(1)
    }

    fn inter_links(&self) -> Vec<Link> {
        (1..self.n).map(|b| Link::Inter { a: b - 1, b }).collect()
    }
}

/// Closed chain with a wraparound link; routes take the shorter arc
/// (ascending on ties, so routes stay canonical).
struct Ring {
    n: usize,
}

impl Topology for Ring {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Ring
    }

    fn packages(&self) -> usize {
        self.n
    }

    fn package_path(&self, a: usize, b: usize) -> Vec<usize> {
        let fwd = b - a;
        if fwd <= self.n - fwd {
            (a..=b).collect()
        } else {
            let mut path = vec![a];
            let mut p = a;
            while p != b {
                p = (p + self.n - 1) % self.n;
                path.push(p);
            }
            path
        }
    }

    fn package_diameter(&self) -> usize {
        self.n / 2
    }

    fn inter_links(&self) -> Vec<Link> {
        // BTreeSet dedupes the n=2 case, where 0→1 and the wraparound
        // are the same canonical link.
        let set: std::collections::BTreeSet<Link> = (0..self.n)
            .filter(|_| self.n > 1)
            .map(|i| Link::inter(i, (i + 1) % self.n))
            .collect();
        set.into_iter().collect()
    }
}

/// Row-major 2D grid of width `w = ceil(sqrt(n))` (last row may be
/// partial); routes are dimension-ordered (X then Y), which never
/// leaves the populated region for `a < b` because rows fill top-down.
struct Mesh {
    n: usize,
    w: usize,
}

impl Mesh {
    fn new(n: usize) -> Mesh {
        let mut w = 1;
        while w * w < n {
            w += 1;
        }
        Mesh { n, w }
    }
}

impl Topology for Mesh {
    fn kind(&self) -> TopologyKind {
        TopologyKind::Mesh
    }

    fn packages(&self) -> usize {
        self.n
    }

    fn package_path(&self, a: usize, b: usize) -> Vec<usize> {
        let xb = b % self.w;
        let mut path = vec![a];
        let mut cur = a;
        while cur % self.w != xb {
            cur = if cur % self.w < xb { cur + 1 } else { cur - 1 };
            path.push(cur);
        }
        while cur / self.w != b / self.w {
            cur += self.w; // a < b row-major ⇒ rows only increase
            path.push(cur);
        }
        path
    }

    fn package_diameter(&self) -> usize {
        if self.n <= 1 {
            return 0;
        }
        let h = (self.n + self.w - 1) / self.w;
        (self.w - 1) + (h - 1)
    }

    fn inter_links(&self) -> Vec<Link> {
        let mut v = Vec::new();
        for p in 0..self.n {
            if p % self.w + 1 < self.w && p + 1 < self.n {
                v.push(Link::Inter { a: p, b: p + 1 });
            }
            if p + self.w < self.n {
                v.push(Link::Inter { a: p, b: p + self.w });
            }
        }
        v.sort();
        v
    }
}

impl TopologyKind {
    /// Construct the concrete topology over `packages` packages.
    pub fn build(self, packages: usize) -> Box<dyn Topology + Send + Sync> {
        match self {
            TopologyKind::PointToPoint => Box::new(PointToPoint { n: packages }),
            TopologyKind::Line => Box::new(Line { n: packages }),
            TopologyKind::Ring => Box::new(Ring { n: packages }),
            TopologyKind::Mesh => Box::new(Mesh::new(packages)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all(packages: usize) -> Vec<Box<dyn Topology + Send + Sync>> {
        TopologyKind::ALL.iter().map(|k| k.build(packages)).collect()
    }

    #[test]
    fn intra_package_routes_are_one_local_hop_on_every_topology() {
        for topo in all(4) {
            for p in 0..4 {
                let route = topo.route(Endpoint::dram(p), Endpoint::rram(p));
                assert_eq!(route, vec![Link::Local { package: p }], "{:?}", topo.kind());
                assert!(topo.route(Endpoint::dram(p), Endpoint::dram(p)).is_empty());
            }
        }
    }

    #[test]
    fn point_to_point_is_always_one_inter_hop() {
        let topo = TopologyKind::PointToPoint.build(8);
        for a in 0..8 {
            for b in 0..8 {
                if a == b {
                    continue;
                }
                let route = topo.route(Endpoint::dram(a), Endpoint::dram(b));
                assert_eq!(route, vec![Link::inter(a, b)]);
            }
        }
        assert_eq!(topo.inter_links().len(), 8 * 7 / 2);
    }

    #[test]
    fn line_routes_walk_the_chain() {
        let topo = TopologyKind::Line.build(4);
        let route = topo.route(Endpoint::dram(0), Endpoint::dram(3));
        assert_eq!(
            route,
            vec![Link::inter(0, 1), Link::inter(1, 2), Link::inter(2, 3)]
        );
        assert_eq!(topo.package_diameter(), 3);
        assert_eq!(topo.inter_links().len(), 3);
    }

    #[test]
    fn ring_takes_the_shorter_arc_including_the_wraparound() {
        let topo = TopologyKind::Ring.build(5);
        // 0→4 wraps (1 hop) instead of walking 4 ascending hops.
        assert_eq!(
            topo.route(Endpoint::dram(0), Endpoint::dram(4)),
            vec![Link::inter(0, 4)]
        );
        // 0→2 goes ascending (tie-free: 2 vs 3).
        assert_eq!(
            topo.route(Endpoint::dram(0), Endpoint::dram(2)),
            vec![Link::inter(0, 1), Link::inter(1, 2)]
        );
        assert_eq!(topo.package_diameter(), 2);
        assert_eq!(topo.inter_links().len(), 5);
        // n=2 dedupes the wraparound into a single link.
        assert_eq!(TopologyKind::Ring.build(2).inter_links().len(), 1);
    }

    #[test]
    fn ring_tie_prefers_the_ascending_arc() {
        let topo = TopologyKind::Ring.build(4);
        assert_eq!(
            topo.route(Endpoint::dram(0), Endpoint::dram(2)),
            vec![Link::inter(0, 1), Link::inter(1, 2)]
        );
    }

    #[test]
    fn mesh_routes_are_dimension_ordered_and_stay_in_the_grid() {
        // n=6, w=3: rows [0 1 2] / [3 4 5].
        let topo = TopologyKind::Mesh.build(6);
        assert_eq!(
            topo.route(Endpoint::dram(0), Endpoint::dram(5)),
            vec![Link::inter(0, 1), Link::inter(1, 2), Link::inter(2, 5)]
        );
        // Partial grids never route through a missing package.
        for n in 1..=9 {
            let topo = TopologyKind::Mesh.build(n);
            for a in 0..n {
                for b in a + 1..n {
                    for p in topo.package_path(a, b) {
                        assert!(p < n, "mesh n={n}: path {a}→{b} visits missing p{p}");
                    }
                }
            }
        }
    }

    #[test]
    fn rram_endpoints_add_local_legs_at_each_end() {
        let topo = TopologyKind::Ring.build(4);
        let route = topo.route(Endpoint::rram(0), Endpoint::rram(1));
        assert_eq!(
            route,
            vec![
                Link::Local { package: 0 },
                Link::inter(0, 1),
                Link::Local { package: 1 },
            ]
        );
    }

    #[test]
    fn routes_are_symmetric_and_bounded_by_the_diameter() {
        for n in 1..=9 {
            for topo in all(n) {
                for a in 0..n {
                    for b in 0..n {
                        for (src, dst) in [
                            (Endpoint::dram(a), Endpoint::dram(b)),
                            (Endpoint::rram(a), Endpoint::dram(b)),
                            (Endpoint::rram(a), Endpoint::rram(b)),
                        ] {
                            let fwd = topo.route(src, dst);
                            let mut bwd = topo.route(dst, src);
                            bwd.reverse();
                            assert_eq!(fwd, bwd, "{:?} n={n}", topo.kind());
                            assert!(
                                fwd.len() <= topo.diameter(),
                                "{:?} n={n}: {} hops > diameter {}",
                                topo.kind(),
                                fwd.len(),
                                topo.diameter()
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn links_cover_one_local_per_package_plus_inter() {
        for n in 1..=8 {
            for topo in all(n) {
                let links = topo.links();
                let locals =
                    links.iter().filter(|l| matches!(l, Link::Local { .. })).count();
                assert_eq!(locals, n, "{:?}", topo.kind());
                assert_eq!(links.len(), n + topo.inter_links().len());
            }
        }
    }
}
