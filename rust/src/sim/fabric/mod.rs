//! Routed UCIe fabric: hop-by-hop link simulation over a
//! [`Topology`], with per-link byte/transfer counters, per-link busy
//! time, and per-tick peak-bandwidth tracking (DESIGN.md §12).
//!
//! Two transfer paths share one config and one set of counters:
//!
//! * [`Fabric::local_transfer`] — the in-package DRAM↔RRAM DMA every
//!   `SimEngine` issues for the two cut-point activations and KV
//!   offloads. This is *verbatim* the legacy `UcieLink` formula (same
//!   guard, same latency, same energy), so the default configuration
//!   reproduces every pre-fabric number bit-identically.
//! * [`Fabric::transfer`] — a routed transfer between two arbitrary
//!   chiplet endpoints. The payload crosses every link of the route
//!   serially (store-and-forward at DRAM dies), but the *sender* stalls
//!   only for the local handoff — the first-hop DMA — matching the
//!   streaming-overlap semantics of the legacy link: downstream hops
//!   overlap with whatever the sender does next, and the receiver sees
//!   the payload at `delivery_ns`.
//!
//! Telemetry is side-effect-only: recording bytes on a link never
//! changes the returned latency/energy, which keeps the single-package
//! default bit-identical while still exposing per-link peak GB/s.

pub mod topology;

pub use topology::{Chiplet, Endpoint, Link, Topology};

use std::collections::BTreeMap;

use crate::config::{TopologyKind, UcieConfig};

/// Peak-tracking window (ns): per-link bytes are bucketed into 1 µs
/// ticks of fabric virtual time; the max bucket is the peak. 1 µs sits
/// well under kernel granularity (~10–100 µs) and well over single
/// transfers, so the peak reflects sustained, not instantaneous, load.
pub const TICK_NS: f64 = 1000.0;

/// Lifetime + per-tick counters for one physical link.
#[derive(Debug, Clone, Default)]
pub struct LinkState {
    /// Total payload bytes that crossed this link.
    pub bytes: u64,
    /// Number of transfers that crossed this link.
    pub transfers: u64,
    /// Total wire-serialization time on this link (ns).
    pub busy_ns: f64,
    /// Largest per-tick byte count observed ([`TICK_NS`] window).
    pub peak_tick_bytes: u64,
    tick_index: u64,
    tick_bytes: u64,
}

impl LinkState {
    /// Record one crossing at fabric time `clock_ns`.
    fn record(&mut self, bytes: u64, wire_ns: f64, clock_ns: f64) {
        let tick = (clock_ns / TICK_NS) as u64;
        if tick != self.tick_index {
            self.tick_index = tick;
            self.tick_bytes = 0;
        }
        self.bytes += bytes;
        self.transfers += 1;
        self.busy_ns += wire_ns;
        self.tick_bytes += bytes;
        self.peak_tick_bytes = self.peak_tick_bytes.max(self.tick_bytes);
    }

    /// Peak sustained bandwidth over any [`TICK_NS`] window, in GB/s
    /// (bytes/ns ≡ GB/s).
    pub fn peak_gbps(&self) -> f64 {
        self.peak_tick_bytes as f64 / TICK_NS
    }

    /// Fold another link's counters into this one (sum totals, max
    /// peaks) — used when merging per-engine fabrics into one view.
    pub fn merge(&mut self, other: &LinkState) {
        self.bytes += other.bytes;
        self.transfers += other.transfers;
        self.busy_ns += other.busy_ns;
        self.peak_tick_bytes = self.peak_tick_bytes.max(other.peak_tick_bytes);
    }
}

/// Cost of one routed transfer (see [`Fabric::transfer`]).
#[derive(Debug, Clone, Copy)]
pub struct Delivery {
    /// Sender-side stall: the first-hop DMA (setup + one wire pass).
    pub stall_ns: f64,
    /// When the receiver has the payload, relative to send: setup plus
    /// one serialized wire pass per hop.
    pub delivery_ns: f64,
    /// Total link energy (every hop re-drives the wires), pJ.
    pub energy_pj: f64,
    /// Number of links crossed.
    pub hops: usize,
}

impl Delivery {
    /// A free delivery (zero-byte, linkless, or unrouted transfer).
    pub fn free() -> Delivery {
        Delivery { stall_ns: 0.0, delivery_ns: 0.0, energy_pj: 0.0, hops: 0 }
    }
}

/// A routed UCIe fabric instance: one [`Topology`] plus per-link state
/// and aggregate counters. Engines own a single-package fabric (their
/// private local link); `ShardedServer` owns a fabric spanning all
/// packages for cross-package (steal) traffic.
pub struct Fabric {
    cfg: UcieConfig,
    kind: TopologyKind,
    packages: usize,
    home: usize,
    topo: Box<dyn Topology + Send + Sync>,
    links: BTreeMap<Link, LinkState>,
    clock_ns: f64,
    /// Aggregate payload bytes (counted once per transfer, like the
    /// legacy `UcieLink` — per-link counters count per crossing).
    pub bytes_transferred: u64,
    /// Aggregate transfer count.
    pub transfers: u64,
}

impl Fabric {
    /// A fabric over `packages` packages. `home` names the package
    /// whose local link [`Fabric::local_transfer`] charges.
    pub fn new(cfg: UcieConfig, kind: TopologyKind, packages: usize, home: usize) -> Fabric {
        assert!(home < packages.max(1), "home package out of range");
        let topo = kind.build(packages);
        let links = topo.links().into_iter().map(|l| (l, LinkState::default())).collect();
        Fabric {
            cfg,
            kind,
            packages,
            home,
            topo,
            links,
            clock_ns: 0.0,
            bytes_transferred: 0,
            transfers: 0,
        }
    }

    /// The single-package fabric a `SimEngine` owns: one local link,
    /// point-to-point (every topology is identical at one package).
    pub fn single(cfg: UcieConfig) -> Fabric {
        Fabric::new(cfg, TopologyKind::PointToPoint, 1, 0)
    }

    /// The link configuration (read-only).
    pub fn config(&self) -> &UcieConfig {
        &self.cfg
    }

    /// The topology kind this fabric routes over.
    pub fn kind(&self) -> TopologyKind {
        self.kind
    }

    /// Number of packages spanned.
    pub fn packages(&self) -> usize {
        self.packages
    }

    /// The topology (route inspection).
    pub fn topology(&self) -> &dyn Topology {
        self.topo.as_ref()
    }

    /// Current fabric virtual time (ns).
    pub fn clock_ns(&self) -> f64 {
        self.clock_ns
    }

    /// Advance fabric virtual time by `ns` (peak-tick bucketing only —
    /// never changes transfer costs).
    pub fn advance(&mut self, ns: f64) {
        self.clock_ns += ns;
    }

    /// Advance fabric virtual time to at least `ns`.
    pub fn advance_to(&mut self, ns: f64) {
        self.clock_ns = self.clock_ns.max(ns);
    }

    /// Zero every counter and the clock (new serving session); the
    /// topology is untouched.
    pub fn reset(&mut self) {
        for state in self.links.values_mut() {
            *state = LinkState::default();
        }
        self.clock_ns = 0.0;
        self.bytes_transferred = 0;
        self.transfers = 0;
    }

    /// Per-link telemetry, in canonical link order.
    pub fn link_states(&self) -> impl Iterator<Item = (&Link, &LinkState)> {
        self.links.iter()
    }

    /// In-package DMA on the home package's local link. Returns
    /// `(latency_ns, energy_pj)` — *verbatim* the legacy `UcieLink`
    /// formula: streaming payloads overlap with downstream compute, so
    /// the non-overlappable cost is the DMA setup latency plus the
    /// serialized wire time of the payload.
    pub fn local_transfer(&mut self, bytes: u64) -> (f64, f64) {
        if bytes == 0 || self.cfg.bandwidth_gbps.is_infinite() {
            // DRAM-only ablation: no link.
            return (0.0, 0.0);
        }
        self.bytes_transferred += bytes;
        self.transfers += 1;
        let wire_ns = bytes as f64 / self.cfg.bandwidth_gbps;
        let latency = self.cfg.dma_latency_ns + wire_ns;
        let energy = bytes as f64 * 8.0 * self.cfg.energy_pj_per_bit;
        let clock = self.clock_ns;
        self.links
            .entry(Link::Local { package: self.home })
            .or_default()
            .record(bytes, wire_ns, clock);
        (latency, energy)
    }

    /// Route a payload from `src` to `dst` hop-by-hop. Each hop
    /// re-serializes the payload on its link (store-and-forward) and
    /// re-drives the wires, so delivery time and energy scale with hop
    /// count; the sender stalls only for the first-hop handoff. A
    /// one-hop route costs exactly what [`Fabric::local_transfer`]
    /// charges.
    pub fn transfer(&mut self, src: Endpoint, dst: Endpoint, bytes: u64) -> Delivery {
        let route = self.topo.route(src, dst);
        let hops = route.len();
        if bytes == 0 || self.cfg.bandwidth_gbps.is_infinite() || hops == 0 {
            return Delivery::free();
        }
        self.bytes_transferred += bytes;
        self.transfers += 1;
        let wire_ns = bytes as f64 / self.cfg.bandwidth_gbps;
        let clock = self.clock_ns;
        for link in &route {
            self.links.entry(*link).or_default().record(bytes, wire_ns, clock);
        }
        Delivery {
            stall_ns: self.cfg.dma_latency_ns + wire_ns,
            delivery_ns: self.cfg.dma_latency_ns + wire_ns * hops as f64,
            energy_pj: bytes as f64 * 8.0 * self.cfg.energy_pj_per_bit * hops as f64,
            hops,
        }
    }
}

impl Clone for Fabric {
    fn clone(&self) -> Fabric {
        Fabric {
            cfg: self.cfg.clone(),
            kind: self.kind,
            packages: self.packages,
            home: self.home,
            topo: self.kind.build(self.packages),
            links: self.links.clone(),
            clock_ns: self.clock_ns,
            bytes_transferred: self.bytes_transferred,
            transfers: self.transfers,
        }
    }
}

impl std::fmt::Debug for Fabric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Fabric")
            .field("kind", &self.kind)
            .field("packages", &self.packages)
            .field("home", &self.home)
            .field("bytes_transferred", &self.bytes_transferred)
            .field("transfers", &self.transfers)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_transfer_matches_the_legacy_link_bit_for_bit() {
        // 128 KB at 128 GB/s = 1000 ns wire + 80 ns DMA; 0.6 pJ/bit.
        let mut f = Fabric::single(UcieConfig::default());
        let (ns, pj) = f.local_transfer(128_000);
        let wire = 128_000.0 / 128.0;
        assert_eq!(ns.to_bits(), (80.0 + wire).to_bits());
        assert_eq!(pj.to_bits(), (128_000.0 * 8.0 * 0.6).to_bits());
        assert_eq!((f.bytes_transferred, f.transfers), (128_000, 1));
    }

    #[test]
    fn zero_bytes_free() {
        let mut f = Fabric::single(UcieConfig::default());
        assert_eq!(f.local_transfer(0), (0.0, 0.0));
        let d = f.transfer(Endpoint::dram(0), Endpoint::rram(0), 0);
        assert_eq!((d.delivery_ns, d.energy_pj), (0.0, 0.0));
        assert_eq!(f.transfers, 0);
    }

    #[test]
    fn dram_only_link_is_free() {
        let mut cfg = UcieConfig::default();
        cfg.bandwidth_gbps = f64::INFINITY;
        let mut f = Fabric::new(cfg, TopologyKind::Ring, 4, 0);
        assert_eq!(f.local_transfer(1_000_000), (0.0, 0.0));
        let d = f.transfer(Endpoint::dram(0), Endpoint::dram(2), 1_000_000);
        assert_eq!((d.stall_ns, d.delivery_ns, d.energy_pj), (0.0, 0.0, 0.0));
        assert_eq!(f.bytes_transferred, 0);
    }

    #[test]
    fn one_hop_routed_transfer_costs_exactly_a_local_transfer() {
        let mut a = Fabric::single(UcieConfig::default());
        let mut b = Fabric::new(UcieConfig::default(), TopologyKind::Ring, 4, 0);
        let (ns, pj) = a.local_transfer(64_000);
        let d = b.transfer(Endpoint::dram(0), Endpoint::dram(1), 64_000);
        assert_eq!(d.hops, 1);
        assert_eq!(d.delivery_ns.to_bits(), ns.to_bits());
        assert_eq!(d.stall_ns.to_bits(), ns.to_bits());
        assert_eq!(d.energy_pj.to_bits(), pj.to_bits());
    }

    #[test]
    fn multi_hop_scales_delivery_and_energy_but_not_the_stall() {
        let mut f = Fabric::new(UcieConfig::default(), TopologyKind::Line, 4, 0);
        let bytes = 128_000u64;
        let wire = bytes as f64 / 128.0;
        let d = f.transfer(Endpoint::dram(0), Endpoint::dram(3), bytes);
        assert_eq!(d.hops, 3);
        assert_eq!(d.stall_ns.to_bits(), (80.0 + wire).to_bits());
        assert_eq!(d.delivery_ns.to_bits(), (80.0 + 3.0 * wire).to_bits());
        assert_eq!(d.energy_pj.to_bits(), (bytes as f64 * 8.0 * 0.6 * 3.0).to_bits());
        // Every link on the route counted the full payload.
        for hop in [(0, 1), (1, 2), (2, 3)] {
            let state = &f.links[&Link::Inter { a: hop.0, b: hop.1 }];
            assert_eq!((state.bytes, state.transfers), (bytes, 1));
        }
    }

    #[test]
    fn per_link_bytes_conserve_bytes_times_hops() {
        let mut f = Fabric::new(UcieConfig::default(), TopologyKind::Mesh, 6, 0);
        let mut expected = 0u64;
        for (a, b, bytes) in [(0, 5, 1000u64), (2, 3, 500), (4, 1, 2048), (5, 0, 64)] {
            let d = f.transfer(Endpoint::dram(a), Endpoint::rram(b), bytes);
            expected += bytes * d.hops as u64;
        }
        let counted: u64 = f.link_states().map(|(_, s)| s.bytes).sum();
        assert_eq!(counted, expected);
    }

    #[test]
    fn peak_tracks_the_busiest_tick_window() {
        let mut f = Fabric::single(UcieConfig::default());
        f.local_transfer(10_000);
        f.local_transfer(5_000); // same tick: accumulates
        assert_eq!(f.links[&Link::Local { package: 0 }].peak_tick_bytes, 15_000);
        f.advance(10.0 * TICK_NS); // next window is quieter
        f.local_transfer(7_000);
        let state = &f.links[&Link::Local { package: 0 }];
        assert_eq!(state.peak_tick_bytes, 15_000);
        assert_eq!(state.bytes, 22_000);
        assert_eq!(state.peak_gbps(), 15.0); // 15 KB / 1 µs = 15 GB/s
    }

    #[test]
    fn reset_zeroes_counters_but_keeps_the_topology() {
        let mut f = Fabric::new(UcieConfig::default(), TopologyKind::Ring, 4, 0);
        f.transfer(Endpoint::dram(0), Endpoint::dram(2), 4096);
        f.advance(5.0 * TICK_NS);
        f.reset();
        assert_eq!((f.bytes_transferred, f.transfers), (0, 0));
        assert_eq!(f.clock_ns(), 0.0);
        assert!(f.link_states().all(|(_, s)| s.bytes == 0 && s.peak_tick_bytes == 0));
        assert_eq!(f.kind(), TopologyKind::Ring);
        assert_eq!(f.link_states().count(), 4 + 4); // 4 local + 4 ring links
    }

    #[test]
    fn clone_preserves_counters_and_topology() {
        let mut f = Fabric::new(UcieConfig::default(), TopologyKind::Mesh, 4, 0);
        f.transfer(Endpoint::dram(0), Endpoint::dram(3), 9000);
        let c = f.clone();
        assert_eq!(c.bytes_transferred, f.bytes_transferred);
        assert_eq!(c.kind(), TopologyKind::Mesh);
        let (a, b): (Vec<_>, Vec<_>) = (
            f.link_states().map(|(l, s)| (*l, s.bytes)).collect(),
            c.link_states().map(|(l, s)| (*l, s.bytes)).collect(),
        );
        assert_eq!(a, b);
    }

    #[test]
    fn merge_sums_totals_and_maxes_peaks() {
        let mut a = LinkState::default();
        let mut b = LinkState::default();
        a.record(1000, 10.0, 0.0);
        b.record(3000, 30.0, 0.0);
        a.merge(&b);
        assert_eq!(a.bytes, 4000);
        assert_eq!(a.transfers, 2);
        assert_eq!(a.busy_ns, 40.0);
        assert_eq!(a.peak_tick_bytes, 3000);
    }
}
