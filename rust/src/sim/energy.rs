//! Component-level energy accounting (picojoule ledger).
//!
//! Every simulated action deposits energy against a component; the ledger
//! backs the paper's Fig 7(c)/(d) power breakdowns and the token/J numbers
//! in Fig 6 / Table V.

/// Energy-bearing component (paper Fig 7 categories).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Component {
    /// M3D DRAM array reads/writes (0.429 pJ/bit).
    DramArray,
    /// DRAM-chiplet NMP logic (PEs, SFPEs, routers, SRAM).
    DramNmp,
    /// M3D RRAM array reads/writes (0.4 / 1.33 pJ/bit).
    RramArray,
    /// RRAM-chiplet NMP logic.
    RramNmp,
    /// UCIe PHY + link transfers.
    Ucie,
    /// Idle/leakage burn of a waiting chiplet.
    Idle,
}

impl Component {
    pub fn name(self) -> &'static str {
        match self {
            Component::DramArray => "dram_array",
            Component::DramNmp => "dram_nmp",
            Component::RramArray => "rram_array",
            Component::RramNmp => "rram_nmp",
            Component::Ucie => "ucie",
            Component::Idle => "idle",
        }
    }

    pub fn all() -> [Component; 6] {
        [
            Component::DramArray,
            Component::DramNmp,
            Component::RramArray,
            Component::RramNmp,
            Component::Ucie,
            Component::Idle,
        ]
    }

    /// Dense index for the array-backed ledger (§Perf: the ledger sits on
    /// the simulator's innermost loop; a fixed array beats a BTreeMap).
    #[inline]
    pub const fn idx(self) -> usize {
        match self {
            Component::DramArray => 0,
            Component::DramNmp => 1,
            Component::RramArray => 2,
            Component::RramNmp => 3,
            Component::Ucie => 4,
            Component::Idle => 5,
        }
    }
}

/// Picojoule ledger keyed by component (array-backed; see Component::idx).
#[derive(Debug, Clone, Default)]
pub struct EnergyLedger {
    pj: [f64; 6],
}

impl EnergyLedger {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn deposit(&mut self, c: Component, pj: f64) {
        debug_assert!(pj >= 0.0, "negative energy {pj} for {c:?}");
        self.pj[c.idx()] += pj;
    }

    #[inline]
    pub fn get(&self, c: Component) -> f64 {
        self.pj[c.idx()]
    }

    pub fn total_pj(&self) -> f64 {
        self.pj.iter().sum()
    }

    pub fn total_joules(&self) -> f64 {
        self.total_pj() / 1e12
    }

    /// Fractional breakdown (component -> share of total).
    pub fn breakdown(&self) -> Vec<(Component, f64)> {
        let total = self.total_pj().max(1e-30);
        Component::all()
            .iter()
            .map(|&c| (c, self.get(c) / total))
            .collect()
    }

    #[inline]
    pub fn merge(&mut self, other: &EnergyLedger) {
        for i in 0..self.pj.len() {
            self.pj[i] += other.pj[i];
        }
    }

    /// Average power in watts over a duration.
    pub fn avg_power_w(&self, duration_ns: f64) -> f64 {
        if duration_ns <= 0.0 {
            return 0.0;
        }
        // pJ / ns = mW; /1000 -> W.
        self.total_pj() / duration_ns / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deposits_accumulate() {
        let mut l = EnergyLedger::new();
        l.deposit(Component::DramArray, 100.0);
        l.deposit(Component::DramArray, 50.0);
        l.deposit(Component::Ucie, 25.0);
        assert_eq!(l.get(Component::DramArray), 150.0);
        assert_eq!(l.total_pj(), 175.0);
    }

    #[test]
    fn breakdown_sums_to_one() {
        let mut l = EnergyLedger::new();
        l.deposit(Component::RramArray, 3.0);
        l.deposit(Component::RramNmp, 1.0);
        let total: f64 = l.breakdown().iter().map(|(_, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn power_conversion() {
        let mut l = EnergyLedger::new();
        // 2 mJ over 1 ms = 2 W.
        l.deposit(Component::RramNmp, 2e9);
        assert!((l.avg_power_w(1e6) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds() {
        let mut a = EnergyLedger::new();
        a.deposit(Component::Idle, 1.0);
        let mut b = EnergyLedger::new();
        b.deposit(Component::Idle, 2.0);
        a.merge(&b);
        assert_eq!(a.get(Component::Idle), 3.0);
    }
}
