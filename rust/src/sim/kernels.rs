//! Fused near-memory kernels (paper Table I) as the simulator's unit of
//! execution, plus their cost structure.
//!
//! The mapping framework (mapping::fusion) groups the model's operators
//! into these kernels; fusion boundaries coincide with chiplet boundaries
//! and never split within kernels of the same step (paper §III-C ❸).

use crate::model::OpCost;
use crate::sim::energy::EnergyLedger;

/// Which chiplet executes a fused kernel (mapping ❶: workload-aware
/// layout — FFN on RRAM, everything else on DRAM).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Placement {
    DramChiplet,
    RramChiplet,
}

/// Table I fused-kernel classes (+ the coarse encoder/connector blocks and
/// the lm_head GEMV, which the paper folds into "connector kernels" /
/// attention-side work on the DRAM chiplet).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FusedKind {
    FusedQkvProj,
    FusedAttnStream,
    FusedFfnAct,
    FusedNorm,
    VisionBlock,
    ConnectorBlock,
    LmHead,
    Embed,
    Elementwise,
}

impl FusedKind {
    /// Dense index (§Perf: per-kind time accumulates in a fixed array on
    /// the simulator inner loop, folded into the report map once per
    /// phase).
    #[inline]
    pub const fn idx(self) -> usize {
        match self {
            FusedKind::FusedQkvProj => 0,
            FusedKind::FusedAttnStream => 1,
            FusedKind::FusedFfnAct => 2,
            FusedKind::FusedNorm => 3,
            FusedKind::VisionBlock => 4,
            FusedKind::ConnectorBlock => 5,
            FusedKind::LmHead => 6,
            FusedKind::Embed => 7,
            FusedKind::Elementwise => 8,
        }
    }

    pub const COUNT: usize = 9;

    pub fn from_idx(i: usize) -> FusedKind {
        [
            FusedKind::FusedQkvProj,
            FusedKind::FusedAttnStream,
            FusedKind::FusedFfnAct,
            FusedKind::FusedNorm,
            FusedKind::VisionBlock,
            FusedKind::ConnectorBlock,
            FusedKind::LmHead,
            FusedKind::Embed,
            FusedKind::Elementwise,
        ][i]
    }

    pub fn name(self) -> &'static str {
        match self {
            FusedKind::FusedQkvProj => "FUSED_QKV_PROJ",
            FusedKind::FusedAttnStream => "FUSED_ATTN_STREAM",
            FusedKind::FusedFfnAct => "FUSED_FFN_ACT",
            FusedKind::FusedNorm => "FUSED_NORM",
            FusedKind::VisionBlock => "VISION_BLOCK",
            FusedKind::ConnectorBlock => "CONNECTOR_BLOCK",
            FusedKind::LmHead => "LM_HEAD",
            FusedKind::Embed => "EMBED",
            FusedKind::Elementwise => "ELEMENTWISE",
        }
    }
}

/// A fused kernel instance: a group of operators executing back-to-back
/// on one chiplet with intermediates pinned in on-die SRAM.
#[derive(Debug, Clone)]
pub struct FusedKernel {
    pub kind: FusedKind,
    pub placement: Placement,
    pub layer: Option<usize>,
    /// Activation row count (GEMM m-dim): prefill length or 1 for decode.
    pub m_rows: usize,
    pub ops: Vec<OpCost>,
    /// Consumes an activation that crossed UCIe (FFN input = AttnOut).
    pub cut_in: bool,
    /// Produces an activation that will cross UCIe (AttnOut / FFNOut).
    pub cut_out: bool,
}

impl FusedKernel {
    pub fn weight_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.weight_bytes).sum()
    }

    pub fn kv_read_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.kv_read_bytes).sum()
    }

    pub fn kv_write_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.kv_write_bytes).sum()
    }

    pub fn flops(&self) -> f64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    pub fn sfpe_elems(&self) -> u64 {
        self.ops.iter().map(|o| o.sfpe_elems).sum()
    }

    /// Activation bytes crossing the kernel's outbound boundary.
    pub fn act_out_bytes(&self) -> u64 {
        self.ops.last().map(|o| o.act_out_bytes).unwrap_or(0)
    }
}

/// The cost of executing one fused kernel.
#[derive(Debug, Clone, Default)]
pub struct KernelCost {
    pub time_ns: f64,
    /// Time attributable to memory streaming (for bottleneck reporting).
    pub stream_ns: f64,
    /// Time attributable to MAC compute.
    pub compute_ns: f64,
    /// Time attributable to SFPE work.
    pub sfpe_ns: f64,
    pub energy: EnergyLedger,
}

impl KernelCost {
    /// Which resource bounds this kernel?
    pub fn bottleneck(&self) -> &'static str {
        if self.stream_ns >= self.compute_ns && self.stream_ns >= self.sfpe_ns {
            "memory"
        } else if self.compute_ns >= self.sfpe_ns {
            "compute"
        } else {
            "sfpe"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{OpCost, OpKind, Stage};

    #[test]
    fn aggregation_over_ops() {
        let mut a = OpCost::new("x", OpKind::Gemm, Stage::Backbone);
        a.weight_bytes = 10;
        a.flops = 5.0;
        let mut b = OpCost::new("y", OpKind::Norm, Stage::Backbone);
        b.sfpe_elems = 7;
        b.act_out_bytes = 3;
        let k = FusedKernel {
            kind: FusedKind::FusedQkvProj,
            placement: Placement::DramChiplet,
            layer: Some(0),
            m_rows: 1,
            ops: vec![a, b],
            cut_in: false,
            cut_out: true,
        };
        assert_eq!(k.weight_bytes(), 10);
        assert_eq!(k.flops(), 5.0);
        assert_eq!(k.sfpe_elems(), 7);
        assert_eq!(k.act_out_bytes(), 3);
    }

    #[test]
    fn bottleneck_classification() {
        let mut c = KernelCost::default();
        c.stream_ns = 10.0;
        c.compute_ns = 5.0;
        assert_eq!(c.bottleneck(), "memory");
        c.compute_ns = 20.0;
        assert_eq!(c.bottleneck(), "compute");
        c.sfpe_ns = 30.0;
        assert_eq!(c.bottleneck(), "sfpe");
    }
}
