//! CHIME hardware simulator: executes mapping-framework plans over the
//! chiplet models, producing latency / energy / power / throughput.
//!
//! Methodology mirrors the paper's own in-house simulator (§IV-A3): device
//! constants from Tables III/IV drive first-order streaming/compute
//! models; the two-cut-point pipeline prices UCIe traffic; KV tiering and
//! RRAM endurance evolve as the context grows.

pub mod chiplet;
pub mod energy;
pub mod fabric;
pub mod kernels;
pub mod memory;
pub mod nmp;

use crate::config::{ChimeConfig, ChimeHardware, MllmConfig, WorkloadConfig};
use crate::mapping::Plan;
use crate::sim::energy::{Component, EnergyLedger};
use crate::sim::fabric::Fabric;
use crate::sim::kernels::{FusedKernel, FusedKind, Placement};
use crate::sim::memory::{DramMem, DramState, RramMem, RramState};

use std::collections::BTreeMap;

/// Aggregated execution statistics for one phase (encode / prefill /
/// decode) or a whole inference.
#[derive(Debug, Clone, Default)]
pub struct PhaseStats {
    pub time_ns: f64,
    pub energy: EnergyLedger,
    /// Time by fused-kernel kind (Fig 1(c)-style breakdown).
    pub time_by_kind: BTreeMap<&'static str, f64>,
    /// Time attributable to each chiplet (for utilization/power).
    pub dram_busy_ns: f64,
    pub rram_busy_ns: f64,
    pub ucie_ns: f64,
    pub kernels: u64,
    pub cut_transfers: u64,
}

impl PhaseStats {
    pub fn merge(&mut self, other: &PhaseStats) {
        self.time_ns += other.time_ns;
        self.energy.merge(&other.energy);
        for (k, v) in &other.time_by_kind {
            *self.time_by_kind.entry(*k).or_insert(0.0) += v;
        }
        self.dram_busy_ns += other.dram_busy_ns;
        self.rram_busy_ns += other.rram_busy_ns;
        self.ucie_ns += other.ucie_ns;
        self.kernels += other.kernels;
        self.cut_transfers += other.cut_transfers;
    }

    pub fn avg_power_w(&self) -> f64 {
        self.energy.avg_power_w(self.time_ns)
    }
}

/// Full-inference statistics (the quantities the paper reports).
#[derive(Debug, Clone)]
pub struct InferenceStats {
    pub model: String,
    pub encode: PhaseStats,
    pub prefill: PhaseStats,
    pub decode: PhaseStats,
    pub output_tokens: usize,
    /// Final KV residency snapshot (tiering analysis).
    pub kv_offloaded_bytes: u64,
    pub rram_endurance_consumed: f64,
}

impl InferenceStats {
    pub fn total_time_ns(&self) -> f64 {
        self.encode.time_ns + self.prefill.time_ns + self.decode.time_ns
    }

    pub fn total_energy_j(&self) -> f64 {
        self.encode.energy.total_joules()
            + self.prefill.energy.total_joules()
            + self.decode.energy.total_joules()
    }

    /// Time to first token (encode + prefill).
    pub fn ttft_ns(&self) -> f64 {
        self.encode.time_ns + self.prefill.time_ns
    }

    /// End-to-end tokens/second (the paper's TPS metric).
    pub fn tokens_per_s(&self) -> f64 {
        self.output_tokens as f64 / (self.total_time_ns() / 1e9)
    }

    /// Decode-only tokens/second.
    pub fn decode_tokens_per_s(&self) -> f64 {
        self.output_tokens as f64 / (self.decode.time_ns / 1e9)
    }

    /// Tokens per joule (the paper's energy-efficiency metric).
    pub fn tokens_per_j(&self) -> f64 {
        self.output_tokens as f64 / self.total_energy_j()
    }

    pub fn avg_power_w(&self) -> f64 {
        self.total_energy_j() / (self.total_time_ns() / 1e9)
    }

    /// Combined energy ledger.
    pub fn energy(&self) -> EnergyLedger {
        let mut e = EnergyLedger::new();
        e.merge(&self.encode.energy);
        e.merge(&self.prefill.energy);
        e.merge(&self.decode.energy);
        e
    }
}

/// The simulation engine: owns chiplet state across an inference.
///
/// The chiplet memories run at the fidelity `ChimeHardware::memory_fidelity`
/// selects — first-order analytic streaming (default, the paper's model)
/// or the cycle-accurate bank/row/tier subsystem (`memory::cycle`).
pub struct SimEngine {
    pub hw: ChimeHardware,
    pub dram: DramMem,
    pub rram: RramMem,
    /// The engine's private UCIe fabric: a single-package fabric whose
    /// local link is this package's DRAM↔RRAM DMA (`sim::fabric`).
    pub fabric: Fabric,
    /// DRAM-only ablation mode (Fig 9).
    pub dram_only: bool,
}

impl SimEngine {
    /// Build an engine with weights placed per the plan's layout.
    pub fn new(hw: &ChimeHardware, plan: &Plan) -> SimEngine {
        Self::with_mode(hw, plan, false)
    }

    pub fn new_dram_only(hw: &ChimeHardware, plan: &Plan) -> SimEngine {
        Self::with_mode(&hw.dram_only(), plan, true)
    }

    /// Serialized-control-plane penalty for the DRAM-only ablation: in the
    /// heterogeneous design, each chiplet's controller overlaps kernel
    /// dispatch/sequencing with the partner chiplet's execution (the
    /// paper's "next decoding step without idle cycles"); a single-chiplet
    /// design dispatches every kernel on one control plane with nothing to
    /// hide behind. Calibrated against Fig 9's 2.38-2.49x.
    pub const DRAM_ONLY_DISPATCH_MULT: f64 = 2.4;

    fn with_mode(hw: &ChimeHardware, plan: &Plan, dram_only: bool) -> SimEngine {
        let mut hw = hw.clone();
        if dram_only {
            hw.dram_nmp.kernel_dispatch_ns *= Self::DRAM_ONLY_DISPATCH_MULT;
        }
        let hw = &hw;
        let mut dram = DramState::new(hw.dram.clone());
        let mut rram = RramState::new(hw.rram.clone());
        for (class, bytes) in &plan.layout.dram_classes {
            dram.place_weights_classed(*class, *bytes)
                .expect("DRAM weight placement overflow");
        }
        if plan.layout.rram_weight_bytes > 0 {
            rram.load_weights(plan.layout.rram_weight_bytes)
                .expect("RRAM weight placement overflow");
        }
        SimEngine {
            hw: hw.clone(),
            dram: DramMem::new(dram, hw.memory_fidelity),
            rram: RramMem::new(rram, hw.memory_fidelity),
            fabric: Fabric::single(hw.ucie.clone()),
            dram_only,
        }
    }

    /// Execute one kernel list (a phase or one decode step) and return its
    /// stats. Cut-point activations are DMA'd between kernels.
    pub fn run_kernels(&mut self, kernels: &[FusedKernel]) -> PhaseStats {
        let mut stats = PhaseStats::default();
        // §Perf: accumulate per-kind time in a fixed array; fold into the
        // BTreeMap once at the end (one map op per kind, not per kernel).
        let mut by_kind = [0.0f64; FusedKind::COUNT];
        let mut prev_cut_out_bytes: u64 = 0;
        for k in kernels {
            // Inbound cut-point transfer (AttnOut -> RRAM side etc.).
            if k.cut_in && prev_cut_out_bytes > 0 && !self.dram_only {
                let (ns, pj) = self.fabric.local_transfer(prev_cut_out_bytes);
                self.fabric.advance(ns);
                stats.time_ns += ns;
                stats.ucie_ns += ns;
                stats.energy.deposit(Component::Ucie, pj);
                stats.cut_transfers += 1;
            }
            prev_cut_out_bytes = 0;

            let cost = match k.placement {
                Placement::DramChiplet => chiplet::dram_chiplet::execute(
                    k,
                    &self.hw.dram_nmp,
                    &mut self.dram,
                    &mut self.rram,
                    &mut self.fabric,
                ),
                Placement::RramChiplet => {
                    chiplet::rram_chiplet::execute(k, &self.hw.rram_nmp, &mut self.rram)
                }
            };
            // Keep the fabric's virtual clock in step with simulated time
            // so per-tick peak tracking reflects sustained link load
            // (telemetry only — never feeds back into costs).
            self.fabric.advance(cost.time_ns);
            stats.time_ns += cost.time_ns;
            match k.placement {
                Placement::DramChiplet => stats.dram_busy_ns += cost.time_ns,
                Placement::RramChiplet => stats.rram_busy_ns += cost.time_ns,
            }
            by_kind[k.kind.idx()] += cost.time_ns;
            stats.energy.merge(&cost.energy);
            stats.kernels += 1;

            if k.cut_out && !self.dram_only {
                // FFNOut/AttnOut return stream: the payload (m x d_model)
                // crosses UCIe to the partner chiplet.
                prev_cut_out_bytes = k.act_out_bytes();
                // When the *next* kernel lives on the same chiplet (e.g.
                // residual after FFNOut), the transfer is priced when the
                // placement actually changes; FFNOut back-transfers are
                // handled below via kind.
                if k.kind == FusedKind::FusedFfnAct {
                    let (ns, pj) = self.fabric.local_transfer(prev_cut_out_bytes);
                    self.fabric.advance(ns);
                    stats.time_ns += ns;
                    stats.ucie_ns += ns;
                    stats.energy.deposit(Component::Ucie, pj);
                    stats.cut_transfers += 1;
                    prev_cut_out_bytes = 0;
                }
            }
        }
        for (i, &t) in by_kind.iter().enumerate() {
            if t > 0.0 {
                *stats
                    .time_by_kind
                    .entry(FusedKind::from_idx(i).name())
                    .or_insert(0.0) += t;
            }
        }
        // Idle burn: while one chiplet works the other leaks.
        self.deposit_idle(&mut stats);
        stats
    }

    fn deposit_idle(&self, stats: &mut PhaseStats) {
        let d_idle_ns = (stats.time_ns - stats.dram_busy_ns).max(0.0);
        let r_idle_ns = (stats.time_ns - stats.rram_busy_ns).max(0.0);
        let d = self.hw.dram_nmp.peak_power_w * self.hw.dram_nmp.idle_power_frac;
        let r = if self.dram_only {
            0.0 // RRAM chiplet absent in the ablation
        } else {
            self.hw.rram_nmp.peak_power_w * self.hw.rram_nmp.idle_power_frac
        };
        stats
            .energy
            .deposit(Component::Idle, (d * d_idle_ns + r * r_idle_ns) * 1000.0);
        // UCIe PHY static burn (paper Fig 7: "the UCIe link draws about
        // 1 W" while the package is active). Absent in the DRAM-only
        // ablation (no link).
        if !self.dram_only && self.hw.ucie.active_power_w > 0.0 {
            stats.energy.deposit(
                Component::Ucie,
                self.hw.ucie.active_power_w * stats.time_ns * 1000.0,
            );
        }
    }

    /// Run a complete VQA inference per the plan.
    pub fn run_inference(&mut self, plan: &Plan) -> InferenceStats {
        let encode = self.run_kernels(&plan.encode_kernels);
        let prefill = if self.dram_only {
            let mut ks = plan.prefill_kernels.clone();
            for k in &mut ks {
                k.placement = Placement::DramChiplet;
                k.cut_in = false;
                k.cut_out = false;
            }
            self.run_kernels(&ks)
        } else {
            self.run_kernels(&plan.prefill_kernels)
        };
        let mut decode = PhaseStats::default();
        let start = plan.trace.prefill_len();
        // §Perf: reuse one fused-kernel template per inference, patching
        // only the kv-length-dependent attention fields per step (see
        // Plan::decode_template; EXPERIMENTS.md §Perf for before/after).
        let mut tmpl = if self.dram_only {
            plan.decode_template_dram_only()
        } else {
            plan.decode_template()
        };
        for i in 0..plan.trace.output_tokens {
            plan.patch_decode_template(&mut tmpl, start + i);
            let step = self.run_kernels(&tmpl.kernels);
            decode.merge(&step);
        }
        InferenceStats {
            model: plan.model.name.clone(),
            encode,
            prefill,
            decode,
            output_tokens: plan.trace.output_tokens,
            kv_offloaded_bytes: self.dram.state().kv_offloaded,
            rram_endurance_consumed: self.rram.endurance_consumed(),
        }
    }
}

/// Convenience: simulate one model end-to-end on CHIME.
pub fn simulate(model: &MllmConfig, cfg: &ChimeConfig) -> InferenceStats {
    let plan = Plan::build(model, &cfg.hardware, &cfg.workload);
    let mut engine = SimEngine::new(&cfg.hardware, &plan);
    engine.run_inference(&plan)
}

/// Convenience: simulate the DRAM-only ablation (Fig 9 baseline).
pub fn simulate_dram_only(model: &MllmConfig, cfg: &ChimeConfig) -> InferenceStats {
    let plan = Plan::build_dram_only(model, &cfg.hardware, &cfg.workload);
    let mut engine = SimEngine::new_dram_only(&cfg.hardware, &plan);
    engine.run_inference(&plan)
}

/// Simulate with a custom workload (sequence-length sweeps etc.).
pub fn simulate_with_workload(
    model: &MllmConfig,
    cfg: &ChimeConfig,
    w: &WorkloadConfig,
) -> InferenceStats {
    let plan = Plan::build(model, &cfg.hardware, w);
    let mut engine = SimEngine::new(&cfg.hardware, &plan);
    engine.run_inference(&plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChimeConfig;

    fn small_workload() -> ChimeConfig {
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 16; // keep unit tests fast
        cfg
    }

    #[test]
    fn inference_produces_sane_stats() {
        let cfg = small_workload();
        let stats = simulate(&MllmConfig::fastvlm_0_6b(), &cfg);
        assert!(stats.total_time_ns() > 0.0);
        assert!(stats.total_energy_j() > 0.0);
        assert!(stats.tokens_per_s() > 0.0);
        assert!(stats.ttft_ns() < stats.total_time_ns());
        assert_eq!(stats.output_tokens, 16);
    }

    #[test]
    fn larger_model_slower_and_hungrier() {
        let cfg = small_workload();
        let small = simulate(&MllmConfig::fastvlm_0_6b(), &cfg);
        let big = simulate(&MllmConfig::mobilevlm_3b(), &cfg);
        assert!(big.decode.time_ns > small.decode.time_ns);
        assert!(big.total_energy_j() > small.total_energy_j());
    }

    #[test]
    fn dram_only_slower_than_heterogeneous() {
        let cfg = small_workload();
        for m in [MllmConfig::fastvlm_0_6b(), MllmConfig::mobilevlm_3b()] {
            let het = simulate(&m, &cfg);
            let solo = simulate_dram_only(&m, &cfg);
            assert!(
                solo.decode.time_ns > het.decode.time_ns,
                "{}: dram-only {} vs chime {}",
                m.name,
                solo.decode.time_ns,
                het.decode.time_ns
            );
        }
    }

    #[test]
    fn decode_dominated_by_rram_ffn_or_dram_attn() {
        let cfg = small_workload();
        let stats = simulate(&MllmConfig::mobilevlm_3b(), &cfg);
        // FFN is the single largest decode kernel class for the big model.
        let ffn = stats.decode.time_by_kind.get("FUSED_FFN_ACT").copied().unwrap_or(0.0);
        assert!(ffn > 0.0);
        let total: f64 = stats.decode.time_by_kind.values().sum();
        assert!(ffn / total > 0.3, "ffn share {}", ffn / total);
    }

    #[test]
    fn ucie_traffic_only_cut_points() {
        let cfg = small_workload();
        let m = MllmConfig::fastvlm_0_6b();
        let plan = Plan::build(&m, &cfg.hardware, &cfg.workload);
        let mut engine = SimEngine::new(&cfg.hardware, &plan);
        let pos = plan.trace.prefill_len();
        let ks = plan.decode_kernels(pos);
        let before = engine.fabric.bytes_transferred;
        engine.run_kernels(&ks);
        let moved = engine.fabric.bytes_transferred - before;
        // Two cut points per layer, each m=1 x d_model FP16.
        let expect = (2 * m.llm.n_layers * m.llm.d_model * 2) as u64;
        assert_eq!(moved, expect);
    }

    #[test]
    fn power_in_edge_envelope() {
        let cfg = ChimeConfig::default();
        let stats = simulate(&MllmConfig::fastvlm_1_7b(), &cfg);
        let p = stats.avg_power_w();
        assert!(p > 0.5 && p < 6.0, "power {p} W out of edge envelope");
    }

    #[test]
    fn cycle_fidelity_runs_end_to_end_and_bounds_first_order() {
        use crate::config::MemoryFidelity;
        let mut cfg = small_workload();
        let fo = simulate(&MllmConfig::fastvlm_0_6b(), &cfg);
        cfg.hardware.memory_fidelity = MemoryFidelity::CycleAccurate;
        let cy = simulate(&MllmConfig::fastvlm_0_6b(), &cfg);
        // The analytic model is an idealized lower bound per phase...
        assert!(cy.encode.time_ns >= fo.encode.time_ns);
        assert!(cy.prefill.time_ns >= fo.prefill.time_ns);
        // ...and strictly below the cycle model where streams bind (decode).
        assert!(
            cy.decode.time_ns > fo.decode.time_ns,
            "cycle decode {} must exceed first-order {}",
            cy.decode.time_ns,
            fo.decode.time_ns
        );
        // Fidelity is a timing question only: token and KV accounting agree.
        assert_eq!(cy.output_tokens, fo.output_tokens);
        assert_eq!(cy.kv_offloaded_bytes, fo.kv_offloaded_bytes);

        // The DRAM-only ablation runs at cycle fidelity too.
        let solo = simulate_dram_only(&MllmConfig::fastvlm_0_6b(), &cfg);
        assert!(solo.decode.time_ns > cy.decode.time_ns);
    }

    #[test]
    fn long_context_offloads_kv_for_big_model() {
        let mut cfg = ChimeConfig::default();
        cfg.workload.text_tokens = 4096;
        cfg.workload.output_tokens = 64;
        let stats = simulate(&MllmConfig::mobilevlm_3b(), &cfg);
        // 4k context x 320 KB/token KV ~ 1.3 GB; DRAM still has room after
        // ~1.8 GB of weights, but tiers beyond 0 get used. Offload happens
        // only under real pressure — assert the accounting is consistent
        // rather than forcing a specific outcome.
        assert!(stats.kv_offloaded_bytes < 2_000_000_000);
        assert!(stats.rram_endurance_consumed < 1e-3);
    }
}
