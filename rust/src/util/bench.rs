//! Criterion-style micro-benchmark harness (criterion is not vendored in
//! this offline environment). Used by `cargo bench` targets
//! (rust/benches/*.rs with `harness = false`).
//!
//! Methodology: warmup iterations, then timed batches until both a
//! minimum iteration count and a minimum measurement window are reached;
//! reports mean / stddev / min / throughput.

use std::time::Instant;

use super::stats::{fmt_ns, Summary};

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: u64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter (±{:>10}, min {:>10}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
            fmt_ns(self.min_ns),
            self.iterations
        )
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup_iters: u64,
    pub min_iters: u64,
    pub min_time_ns: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 3, min_iters: 10, min_time_ns: 2e8, results: Vec::new() }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fast settings for CI-ish runs.
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, min_iters: 3, min_time_ns: 5e7, results: Vec::new() }
    }

    /// Time `f`, preventing the optimizer from discarding its result.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut s = Summary::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while iters < self.min_iters || (start.elapsed().as_nanos() as f64) < self.min_time_ns {
            let t0 = Instant::now();
            std::hint::black_box(f());
            s.push(t0.elapsed().as_nanos() as f64);
            iters += 1;
            if iters > 1_000_000 {
                break; // pathological fast function; enough samples
            }
        }
        let r = BenchResult {
            name: name.to_string(),
            iterations: s.count(),
            mean_ns: s.mean(),
            stddev_ns: s.stddev(),
            min_ns: s.min(),
        };
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render a closing summary table.
    pub fn summary(&self) -> String {
        let mut out = String::from("\n== bench summary ==\n");
        for r in &self.results {
            out.push_str(&r.report_line());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench { warmup_iters: 1, min_iters: 5, min_time_ns: 0.0, results: vec![] };
        let r = b.bench("noop-ish", || std::hint::black_box(2 + 2));
        assert!(r.iterations >= 5);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.mean_ns);
    }

    #[test]
    fn ordering_detects_slow_functions() {
        let mut b = Bench { warmup_iters: 1, min_iters: 5, min_time_ns: 0.0, results: vec![] };
        let fast = b.bench("fast", || 1 + 1).mean_ns;
        // black_box the loop bound so release builds cannot const-fold it.
        let n = std::hint::black_box(200_000u64);
        let slow = b
            .bench("slow", || {
                let mut acc = 0u64;
                let mut i = std::hint::black_box(0u64);
                while i < n {
                    acc = acc.wrapping_add(std::hint::black_box(i).wrapping_mul(31));
                    i += 1;
                }
                acc
            })
            .mean_ns;
        assert!(slow > fast, "slow {slow} fast {fast}");
    }
}
