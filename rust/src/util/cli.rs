//! Tiny CLI argument parser (clap is not vendored in this environment).
//!
//! Supports the subcommand + `--flag` / `--key value` / `--key=value`
//! grammar used by the `chime` binary and the examples.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare word (subcommand), if any.
    pub command: Option<String>,
    /// Remaining bare words.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }

    /// Flags/options present on the command line but not in `allowed`,
    /// each paired with the closest accepted spelling (when one is
    /// plausibly intended). Lets every subcommand reject typos like
    /// `--routee` with a suggestion instead of silently ignoring them.
    pub fn unknown(&self, allowed: &[&str]) -> Vec<(String, Option<String>)> {
        self.options
            .keys()
            .map(|s| s.as_str())
            .chain(self.flags.iter().map(|s| s.as_str()))
            .filter(|name| !allowed.contains(name))
            .map(|name| (name.to_string(), suggest(name, allowed)))
            .collect()
    }
}

/// The closest `allowed` spelling to `flag` within an edit distance that
/// plausibly indicates a typo (≤ 2, and strictly less than the flag's own
/// length so short flags don't match everything).
pub fn suggest(flag: &str, allowed: &[&str]) -> Option<String> {
    let mut best: Option<(usize, &str)> = None;
    for &a in allowed {
        let d = edit_distance(flag, a);
        if best.map(|(bd, _)| d < bd).unwrap_or(true) {
            best = Some((d, a));
        }
    }
    let (d, name) = best?;
    if d <= 2 && d < flag.chars().count().max(1) {
        Some(name.to_string())
    } else {
        None
    }
}

/// Levenshtein distance (small DP; flag names are short).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for i in 1..=a.len() {
        cur[0] = i;
        for j in 1..=b.len() {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["simulate", "fastvlm-0.6b"]);
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.positional, vec!["fastvlm-0.6b"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["sweep", "--steps", "488", "--model=tiny"]);
        assert_eq!(a.get_usize("steps", 0), 488);
        assert_eq!(a.get("model"), Some("tiny"));
    }

    #[test]
    fn flags_vs_options() {
        let a = parse(&["results", "--json", "--fig", "6"]);
        assert!(a.flag("json"));
        assert_eq!(a.get("fig"), Some("6"));
        assert!(a.flag("fig")); // options count as present
        assert!(!a.flag("nope"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["x", "--verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--models", "a, b,c"]);
        assert_eq!(a.get_list("models").unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn unknown_flags_are_reported_with_suggestions() {
        let allowed = ["route", "packages", "queue", "model"];
        let a = parse(&["serve", "--routee", "ll", "--packages", "2"]);
        let unknown = a.unknown(&allowed);
        assert_eq!(unknown.len(), 1);
        assert_eq!(unknown[0].0, "routee");
        assert_eq!(unknown[0].1.as_deref(), Some("route"));
    }

    #[test]
    fn unknown_catches_bare_flags_too() {
        let a = parse(&["results", "--jsno"]);
        let unknown = a.unknown(&["json", "all", "fig"]);
        assert_eq!(unknown.len(), 1);
        assert_eq!(unknown[0].0, "jsno");
        assert_eq!(unknown[0].1.as_deref(), Some("json"));
    }

    #[test]
    fn known_flags_pass_validation() {
        let a = parse(&["serve", "--route", "ll", "--queue=4", "--model", "tiny"]);
        assert!(a.unknown(&["route", "queue", "model"]).is_empty());
    }

    #[test]
    fn far_off_flags_get_no_suggestion() {
        let a = parse(&["x", "--zzzzzz"]);
        let unknown = a.unknown(&["route", "model"]);
        assert_eq!(unknown.len(), 1);
        assert_eq!(unknown[0].1, None);
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("route", "route"), 0);
        assert_eq!(edit_distance("routee", "route"), 1);
        assert_eq!(edit_distance("jsno", "json"), 2);
        assert_eq!(edit_distance("", "abc"), 3);
        assert_eq!(suggest("batc", &["batch", "backend"]).as_deref(), Some("batch"));
        assert_eq!(suggest("x", &["batch"]), None, "short flags never match far names");
    }
}
