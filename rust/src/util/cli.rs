//! Tiny CLI argument parser (clap is not vendored in this environment).
//!
//! Supports the subcommand + `--flag` / `--key value` / `--key=value`
//! grammar used by the `chime` binary and the examples.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare word (subcommand), if any.
    pub command: Option<String>,
    /// Remaining bare words.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list option.
    pub fn get_list(&self, name: &str) -> Option<Vec<String>> {
        self.get(name)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["simulate", "fastvlm-0.6b"]);
        assert_eq!(a.command.as_deref(), Some("simulate"));
        assert_eq!(a.positional, vec!["fastvlm-0.6b"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["sweep", "--steps", "488", "--model=tiny"]);
        assert_eq!(a.get_usize("steps", 0), 488);
        assert_eq!(a.get("model"), Some("tiny"));
    }

    #[test]
    fn flags_vs_options() {
        let a = parse(&["results", "--json", "--fig", "6"]);
        assert!(a.flag("json"));
        assert_eq!(a.get("fig"), Some("6"));
        assert!(a.flag("fig")); // options count as present
        assert!(!a.flag("nope"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["x", "--verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("verbose"), None);
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--models", "a, b,c"]);
        assert_eq!(a.get_list("models").unwrap(), vec!["a", "b", "c"]);
    }
}
