//! Self-built substrates: JSON, CLI parsing, PRNG, statistics, tables.
//!
//! This offline environment vendors only the `xla` crate's build closure,
//! so serde / clap / rand / prettytable equivalents live here (DESIGN.md
//! §2 substitution table).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod stats;
pub mod table;

pub use cli::Args;
pub use json::Json;
pub use prng::Prng;
pub use stats::Summary;
pub use table::Table;
