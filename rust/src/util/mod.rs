//! Self-built substrates: JSON, CLI parsing, PRNG, statistics, tables.
//!
//! This offline environment cannot fetch registry crates, so serde /
//! clap / rand / criterion / prettytable equivalents live here, and the
//! two external names the runtime consumes (`anyhow`, `xla`) are vendored
//! as path crates under rust/vendor/ (DESIGN.md §2 substitution table).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod stats;
pub mod table;

pub use cli::Args;
pub use json::Json;
pub use prng::Prng;
pub use stats::Summary;
pub use table::Table;
