//! Plain-text table rendering for the paper-results harness (`chime
//! results`) — prints the same rows/series the paper's tables and figures
//! report.

/// A simple column-aligned text table with a title and header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {} in table {:?}",
            cells.len(),
            self.header.len(),
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for i in 0..ncol {
                if i > 0 {
                    s.push_str("  ");
                }
                // Left-align first column, right-align numerics.
                if i == 0 {
                    s.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    s.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
                }
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        let total = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&format!("{}\n", "-".repeat(total)));
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }
}

/// Convenience: format with fixed decimals.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Convenience: format a multiplicative factor like "41.4x".
pub fn x(v: f64) -> String {
    if v >= 100.0 {
        format!("{:.0}x", v)
    } else {
        format!("{:.1}x", v)
    }
}

/// Convenience: format a percentage.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["model", "tps"]);
        t.row(vec!["fastvlm-0.6b".into(), f(533.0, 1)]);
        t.row(vec!["mv-3b".into(), f(23.0, 1)]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("fastvlm-0.6b"));
        // Right-aligned numeric column: both numbers end at same offset.
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(x(41.44), "41.4x");
        assert_eq!(x(246.0), "246x");
        assert_eq!(pct(0.515), "51.5%");
        assert_eq!(f(1.23456, 2), "1.23");
    }
}
