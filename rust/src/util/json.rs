//! Minimal JSON parser/serializer.
//!
//! serde/serde_json are unavailable in this offline environment (DESIGN.md
//! §2), so the crate carries its own small, strict JSON implementation. It
//! supports the full JSON grammar needed by `artifacts/manifest.json`,
//! config files, and the results emitters: objects, arrays, strings with
//! escapes, numbers (f64 + i64 fast path), booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so
/// serialization is deterministic — results files diff cleanly run-to-run.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns Null for missing keys (chainable).
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; returns Null when out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num_arr(items: &[f64]) -> Json {
        Json::Arr(items.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize with 2-space indentation (stable key order).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Serialize compactly.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        indent(out, depth + 1);
                    }
                    v.write(out, depth + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    indent(out, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        indent(out, depth + 1);
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, depth + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    indent(out, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs for non-BMP characters.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid unicode escape"))?);
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.src.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let chunk = std::str::from_utf8(&self.src[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").idx(0).as_i64(), Some(1));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" é 😀"));
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"nums": [1, 2.5, -3], "s": "x\ny", "t": true, "n": null}"#;
        let v = Json::parse(src).unwrap();
        let again = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, again);
        let compact = Json::parse(&v.compact()).unwrap();
        assert_eq!(v, compact);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn missing_keys_chain_to_null() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("a").get("b").idx(3).is_null());
    }

    #[test]
    fn integer_fidelity() {
        let v = Json::parse("9007199254740991").unwrap();
        assert_eq!(v.as_i64(), None); // beyond the safe window we refuse
        let v = Json::parse("488").unwrap();
        assert_eq!(v.as_usize(), Some(488));
    }
}
