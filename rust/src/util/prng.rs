//! Deterministic PRNG (SplitMix64) + sampling helpers.
//!
//! The `rand` crate is not vendored in this offline environment, so the
//! crate carries its own small generator. SplitMix64 is statistically
//! strong enough for workload generation, property-test case generation,
//! and synthetic tensors, and — critically — is reproducible across runs
//! and platforms (all simulator experiments are seeded).

/// SplitMix64 generator (public-domain algorithm by Sebastiano Vigna).
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Prng { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — panics if the range is empty.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick one element uniformly.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.range(0, items.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Exponential inter-arrival sample with the given rate (per unit time).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let x = p.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut p = Prng::new(3);
        for _ in 0..10_000 {
            let x = p.range(5, 17);
            assert!((5..17).contains(&x));
        }
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut p = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut p = Prng::new(13);
        let rate = 4.0;
        let n = 50_000;
        let mean = (0..n).map(|_| p.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }
}
