//! Summary statistics and unit formatting used across metrics, benches,
//! and the results harness.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.max }
    }
}

/// Percentile over a sample (linear interpolation, p in [0, 100]).
///
/// Non-finite handling: NaN and ±∞ samples are **dropped** before ranking,
/// so a single `INFINITY` TPOT (the documented zero-decode-span contract)
/// cannot poison p95/p99. The slice is sorted with `total_cmp` (never
/// panics on NaN); callers who need the dropped count use
/// [`count_non_finite`]. Returns NaN when no finite sample remains.
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    samples.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(samples, p)
}

/// Percentile over an already `total_cmp`-sorted sample. Pays the
/// O(n log n) sort once when several percentiles are taken from one
/// buffer. Same non-finite drop policy as [`percentile`].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    // Under total order, -NaN/-inf sort to the front and +inf/+NaN to the
    // back, so the finite samples form one contiguous run in the middle.
    let lo = match sorted.iter().position(|x| x.is_finite()) {
        Some(i) => i,
        None => return f64::NAN,
    };
    let hi = sorted.iter().rposition(|x| x.is_finite()).unwrap();
    let finite = &sorted[lo..=hi];
    let rank = (p / 100.0) * (finite.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        finite[lo]
    } else {
        let frac = rank - lo as f64;
        finite[lo] * (1.0 - frac) + finite[hi] * frac
    }
}

/// How many samples the percentile helpers would drop (NaN or ±∞).
pub fn count_non_finite(samples: &[f64]) -> usize {
    samples.iter().filter(|x| !x.is_finite()).count()
}

/// Format a duration in nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if !ns.is_finite() {
        return format!("{ns}");
    }
    let abs = ns.abs();
    if abs >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if abs >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if abs >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{:.1} ns", ns)
    }
}

/// Format an energy in picojoules with an adaptive unit.
pub fn fmt_pj(pj: f64) -> String {
    let abs = pj.abs();
    if abs >= 1e12 {
        format!("{:.3} J", pj / 1e12)
    } else if abs >= 1e9 {
        format!("{:.3} mJ", pj / 1e9)
    } else if abs >= 1e6 {
        format!("{:.3} µJ", pj / 1e6)
    } else if abs >= 1e3 {
        format!("{:.3} nJ", pj / 1e3)
    } else {
        format!("{:.1} pJ", pj)
    }
}

/// Format a byte count with an adaptive binary unit.
pub fn fmt_bytes(b: f64) -> String {
    let abs = b.abs();
    if abs >= 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    } else if abs >= 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else if abs >= 1024.0 {
        format!("{:.2} KiB", b / 1024.0)
    } else {
        format!("{:.0} B", b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentile_interpolates() {
        let mut xs = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 100.0), 4.0);
        assert!((percentile(&mut xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_survives_nan_and_does_not_let_infinity_poison_the_tail() {
        // Regression: `partial_cmp(..).unwrap()` panicked on NaN, and a
        // single INFINITY sample (zero-decode-span TPOT) dragged p95/p99
        // to infinity. Both are now dropped before ranking.
        let mut xs = vec![f64::NAN, 3.0, 1.0, f64::INFINITY, 2.0, 4.0, f64::NEG_INFINITY];
        assert_eq!(count_non_finite(&xs), 3);
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        assert_eq!(percentile(&mut xs, 100.0), 4.0);
        assert!((percentile(&mut xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&mut xs, 99.0).is_finite());

        let mut none = vec![f64::NAN, f64::INFINITY];
        assert!(percentile(&mut none, 50.0).is_nan());
        let mut empty: Vec<f64> = Vec::new();
        assert!(percentile(&mut empty, 50.0).is_nan());
    }

    #[test]
    fn percentile_sorted_matches_unsorted_entry_point() {
        let mut xs = vec![9.0, 1.0, 5.0, 3.0, 7.0];
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(percentile(&mut xs, p), percentile_sorted(&sorted, p));
        }
    }

    #[test]
    fn formatting_picks_units() {
        assert_eq!(fmt_ns(1.5e9), "1.500 s");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(512.0), "512.0 ns");
        assert_eq!(fmt_pj(3.2e9), "3.200 mJ");
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
    }
}
