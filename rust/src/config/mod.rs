//! Configuration system: hardware (Tables III/IV), model zoo (Table II),
//! and workload defaults, with JSON file overrides for experiments.

pub mod hardware;
pub mod models;

pub use hardware::{
    AreaModel, ChimeHardware, DramConfig, FacilSpec, JetsonSpec, MemoryFidelity, NmpConfig,
    RramConfig, TopologyConfig, TopologyKind, UcieConfig,
};
pub use models::{Connector, ConnectorKind, LlmConfig, MllmConfig, VisionEncoder, VisionKind};

use crate::util::Json;

/// Default VQA workload (paper §IV-A1): 512x512 image, 128 text tokens in,
/// 488 output tokens.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub image_size: usize,
    pub text_tokens: usize,
    pub output_tokens: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig { image_size: 512, text_tokens: 128, output_tokens: 488 }
    }
}

/// Root configuration for a simulation run.
#[derive(Debug, Clone, Default)]
pub struct ChimeConfig {
    pub hardware: ChimeHardware,
    pub workload: WorkloadConfig,
}

impl ChimeConfig {
    /// Apply a JSON override file. Only recognized scalar knobs are applied;
    /// unknown keys raise an error so typos do not silently no-op.
    pub fn apply_overrides(&mut self, json: &Json) -> Result<(), String> {
        let obj = json.as_obj().ok_or("config overrides must be a JSON object")?;
        for (k, v) in obj {
            let num = || {
                v.as_f64()
                    .ok_or_else(|| format!("override {k:?} must be a number"))
            };
            match k.as_str() {
                "dram.miv_internal_bw_mult" => self.hardware.dram.miv_internal_bw_mult = num()?,
                "dram.stream_utilization" => self.hardware.dram.stream_utilization = num()?,
                "rram.near_layer_bw_mult" => self.hardware.rram.near_layer_bw_mult = num()?,
                "rram.stream_utilization" => self.hardware.rram.stream_utilization = num()?,
                "rram.endurance_writes" => {
                    self.hardware.rram.endurance_writes = num()? as u64
                }
                "ucie.bandwidth_gbps" => self.hardware.ucie.bandwidth_gbps = num()?,
                "ucie.energy_pj_per_bit" => self.hardware.ucie.energy_pj_per_bit = num()?,
                "ucie.dma_latency_ns" => self.hardware.ucie.dma_latency_ns = num()?,
                "ucie.active_power_w" => self.hardware.ucie.active_power_w = num()?,
                "nmp.kernel_dispatch_ns" => {
                    let x = num()?;
                    self.hardware.dram_nmp.kernel_dispatch_ns = x;
                    self.hardware.rram_nmp.kernel_dispatch_ns = x;
                }
                "memory.fidelity" => {
                    let s = v
                        .as_str()
                        .ok_or_else(|| format!("override {k:?} must be a string"))?;
                    self.hardware.memory_fidelity =
                        MemoryFidelity::parse(s).ok_or_else(|| {
                            format!("unknown memory fidelity {s:?} (first-order | cycle)")
                        })?;
                }
                "topology.kind" => {
                    let s = v
                        .as_str()
                        .ok_or_else(|| format!("override {k:?} must be a string"))?;
                    self.hardware.topology.kind = TopologyKind::parse(s).ok_or_else(|| {
                        format!("unknown topology {s:?} (point-to-point | line | ring | mesh)")
                    })?;
                }
                "workload.image_size" => self.workload.image_size = num()? as usize,
                "workload.text_tokens" => self.workload.text_tokens = num()? as usize,
                "workload.output_tokens" => self.workload.output_tokens = num()? as usize,
                other => return Err(format!("unknown config override {other:?}")),
            }
        }
        Ok(())
    }

    /// Load overrides from a JSON file path.
    pub fn with_override_file(mut self, path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read config {path}: {e}"))?;
        let json = Json::parse(&text).map_err(|e| e.to_string())?;
        self.apply_overrides(&json)?;
        Ok(self)
    }

    /// Serialize the effective calibration knobs (for EXPERIMENTS.md).
    /// Every UCIe knob that participates in the link formula (and the
    /// fabric topology) is part of the effective calibration, so all of
    /// them round-trip through [`ChimeConfig::apply_overrides`].
    pub fn calibration_json(&self) -> Json {
        Json::obj(vec![
            ("dram.miv_internal_bw_mult", self.hardware.dram.miv_internal_bw_mult.into()),
            ("dram.stream_utilization", self.hardware.dram.stream_utilization.into()),
            ("rram.near_layer_bw_mult", self.hardware.rram.near_layer_bw_mult.into()),
            ("rram.stream_utilization", self.hardware.rram.stream_utilization.into()),
            ("ucie.bandwidth_gbps", self.hardware.ucie.bandwidth_gbps.into()),
            ("ucie.energy_pj_per_bit", self.hardware.ucie.energy_pj_per_bit.into()),
            ("ucie.dma_latency_ns", self.hardware.ucie.dma_latency_ns.into()),
            ("ucie.active_power_w", self.hardware.ucie.active_power_w.into()),
            ("nmp.kernel_dispatch_ns", self.hardware.dram_nmp.kernel_dispatch_ns.into()),
            ("topology.kind", self.hardware.topology.kind.name().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_workload_matches_paper() {
        let w = WorkloadConfig::default();
        assert_eq!((w.image_size, w.text_tokens, w.output_tokens), (512, 128, 488));
    }

    #[test]
    fn overrides_apply() {
        let mut c = ChimeConfig::default();
        let j = Json::parse(
            r#"{"dram.miv_internal_bw_mult": 8.0, "workload.output_tokens": 64}"#,
        )
        .unwrap();
        c.apply_overrides(&j).unwrap();
        assert_eq!(c.hardware.dram.miv_internal_bw_mult, 8.0);
        assert_eq!(c.workload.output_tokens, 64);
    }

    #[test]
    fn memory_fidelity_override_applies_and_validates() {
        let mut c = ChimeConfig::default();
        let j = Json::parse(r#"{"memory.fidelity": "cycle"}"#).unwrap();
        c.apply_overrides(&j).unwrap();
        assert_eq!(c.hardware.memory_fidelity, MemoryFidelity::CycleAccurate);
        let bad = Json::parse(r#"{"memory.fidelity": "cyccle"}"#).unwrap();
        assert!(c.apply_overrides(&bad).is_err());
        let not_str = Json::parse(r#"{"memory.fidelity": 1}"#).unwrap();
        assert!(c.apply_overrides(&not_str).is_err());
    }

    #[test]
    fn topology_override_applies_and_validates() {
        let mut c = ChimeConfig::default();
        let j = Json::parse(r#"{"topology.kind": "ring"}"#).unwrap();
        c.apply_overrides(&j).unwrap();
        assert_eq!(c.hardware.topology.kind, TopologyKind::Ring);
        let bad = Json::parse(r#"{"topology.kind": "rign"}"#).unwrap();
        assert!(c.apply_overrides(&bad).is_err());
        let not_str = Json::parse(r#"{"topology.kind": 1}"#).unwrap();
        assert!(c.apply_overrides(&not_str).is_err());
    }

    #[test]
    fn calibration_json_round_trips_every_ucie_knob() {
        // Pre-fix, ucie.energy_pj_per_bit / ucie.dma_latency_ns were not
        // accepted as overrides and calibration_json dropped everything
        // but the bandwidth: a saved calibration silently lost the link
        // formula's other knobs. The effective calibration now
        // round-trips exactly.
        let mut tuned = ChimeConfig::default();
        tuned.hardware.ucie.bandwidth_gbps = 256.0;
        tuned.hardware.ucie.energy_pj_per_bit = 0.45;
        tuned.hardware.ucie.dma_latency_ns = 120.0;
        tuned.hardware.ucie.active_power_w = 1.5;
        tuned.hardware.topology.kind = TopologyKind::Mesh;
        let mut restored = ChimeConfig::default();
        restored.apply_overrides(&tuned.calibration_json()).unwrap();
        assert_eq!(restored.hardware.ucie.bandwidth_gbps, 256.0);
        assert_eq!(restored.hardware.ucie.energy_pj_per_bit, 0.45);
        assert_eq!(restored.hardware.ucie.dma_latency_ns, 120.0);
        assert_eq!(restored.hardware.ucie.active_power_w, 1.5);
        assert_eq!(restored.hardware.topology.kind, TopologyKind::Mesh);
    }

    #[test]
    fn unknown_override_is_error() {
        let mut c = ChimeConfig::default();
        let j = Json::parse(r#"{"dram.typo": 1}"#).unwrap();
        assert!(c.apply_overrides(&j).is_err());
    }
}
