//! Hardware configuration: the paper's Tables III & IV (M3D DRAM / M3D
//! RRAM device, system, and NMP parameters), the UCIe link, plus the
//! baseline platform envelopes (Jetson Orin NX, FACIL) used by Table V.
//!
//! Every number that comes straight from the paper is marked `// paper:`.
//! A small set of *calibration* factors (utilization, per-layer sync
//! overhead) is needed because the paper publishes device constants and
//! endpoint results but not its simulator internals; these are documented
//! inline and recorded in EXPERIMENTS.md (DESIGN.md §6).

/// Memory-timing fidelity for the chiplet memories.
///
/// The paper's own simulator (and every headline number) prices memory
/// through the *first-order* analytic streaming model: effective
/// bandwidth per tier, linear in bytes, activation cost perfectly
/// amortized. The ROADMAP's DRAMsim3-style backend is the
/// *cycle-accurate* alternative (`sim::memory::cycle`): per-tier bank /
/// open-row state machines, whole-row activation quantization, tFAW
/// windows, refresh stalls, RRAM pulse occupancy and wear-aware write
/// scheduling. The analytic model is an idealized lower bound; the
/// cycle model prices the same streams at or above it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MemoryFidelity {
    /// First-order analytic streaming model (default; the paper's model).
    #[default]
    FirstOrder,
    /// Event-driven bank/row/tier timing model (`sim::memory::cycle`).
    CycleAccurate,
}

impl MemoryFidelity {
    /// Parse a CLI spelling (`first-order`, `fo`, `analytic`; `cycle`,
    /// `cycle-accurate`, `ca`). Returns `None` for unknown names.
    pub fn parse(s: &str) -> Option<MemoryFidelity> {
        match s {
            "first-order" | "firstorder" | "first_order" | "fo" | "analytic" => {
                Some(MemoryFidelity::FirstOrder)
            }
            "cycle" | "cycle-accurate" | "cycleaccurate" | "cycle_accurate" | "ca" => {
                Some(MemoryFidelity::CycleAccurate)
            }
            _ => None,
        }
    }

    /// Canonical CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            MemoryFidelity::FirstOrder => "first-order",
            MemoryFidelity::CycleAccurate => "cycle",
        }
    }
}

/// UCIe fabric topology over the DRAM+RRAM packages of a deployment
/// (`sim::fabric`). The in-package DRAM↔RRAM link always exists; the
/// kind chooses which inter-package (DRAM-to-DRAM) links exist and how
/// multi-hop routes are formed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TopologyKind {
    /// Dedicated link between every package pair (default; the legacy
    /// flat model — every cross-package transfer is one hop).
    #[default]
    PointToPoint,
    /// Open chain p0—p1—…—p(n-1).
    Line,
    /// Closed chain with a wraparound link; routes take the shorter arc.
    Ring,
    /// Row-major 2D grid of width ceil(sqrt(n)) with XY routing.
    Mesh,
}

impl TopologyKind {
    /// Every kind, in canonical order (CLI sweeps, results grids).
    pub const ALL: [TopologyKind; 4] = [
        TopologyKind::PointToPoint,
        TopologyKind::Line,
        TopologyKind::Ring,
        TopologyKind::Mesh,
    ];

    /// Parse a CLI spelling (`point-to-point`/`p2p`, `line`, `ring`,
    /// `mesh`). Returns `None` for unknown names.
    pub fn parse(s: &str) -> Option<TopologyKind> {
        match s {
            "point-to-point" | "pointtopoint" | "point_to_point" | "p2p" => {
                Some(TopologyKind::PointToPoint)
            }
            "line" | "chain" => Some(TopologyKind::Line),
            "ring" => Some(TopologyKind::Ring),
            "mesh" | "grid" => Some(TopologyKind::Mesh),
            _ => None,
        }
    }

    /// Canonical CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            TopologyKind::PointToPoint => "point-to-point",
            TopologyKind::Line => "line",
            TopologyKind::Ring => "ring",
            TopologyKind::Mesh => "mesh",
        }
    }
}

/// Fabric topology configuration (`--topology`, `topology.kind`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TopologyConfig {
    /// Which inter-package link graph the fabric routes over.
    pub kind: TopologyKind,
}

/// M3D DRAM device + system parameters (paper Table IV).
#[derive(Debug, Clone)]
pub struct DramConfig {
    /// paper: 200 vertically stacked layers.
    pub layers: usize,
    /// paper: 5 in-memory tiers (L1..L5 / Tier-0..Tier-4).
    pub tiers: usize,
    /// paper: read/write latency = (3 + 0.8 * L) ns for layer L.
    pub latency_base_ns: f64,
    pub latency_per_layer_ns: f64,
    /// paper: 0.429 pJ/bit read/write energy.
    pub energy_pj_per_bit: f64,
    /// paper: 32 Kb row buffer per bank.
    pub row_buffer_bits: usize,
    /// paper: 1k x 1k MATs, 200 MATs/bank -> 200 Mb bank.
    pub mat_bits: usize,
    pub mats_per_bank: usize,
    /// paper: 16 channels/chip, 16 banks/channel, 64b data I/O per channel.
    pub channels: usize,
    pub banks_per_channel: usize,
    pub channel_io_bits: usize,
    /// paper: 1.25 GB capacity per tier (5 tiers -> 6.25 GB chip).
    pub tier_capacity_bytes: u64,
    /// paper: 121 mm^2 chip area.
    pub chip_area_mm2: f64,
    /// CALIBRATION: monolithic inter-tier vias expose far more internal
    /// bandwidth to the on-logic-die NMP than the 64b/channel external
    /// interface — the central claim of M3D DRAM (paper §II-C). This
    /// multiplier scales the external channel I/O to the internal MIV
    /// streaming bandwidth seen by the PU cluster.
    pub miv_internal_bw_mult: f64,
    /// CALIBRATION: sustained fraction of peak streaming bandwidth for
    /// GEMV-style weight/KV streams (row activation gaps, bank conflicts).
    pub stream_utilization: f64,
    /// CALIBRATION: per-bit streaming energy derate vs the Table IV
    /// random-access pJ/bit — one row activation amortizes over the full
    /// 32 Kb row buffer under sequential weight/KV streaming. Needed to
    /// reconcile the published pJ/bit with the paper's ~2 W endpoint.
    pub array_energy_scale: f64,
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            layers: 200,
            tiers: 5,
            latency_base_ns: 3.0,
            latency_per_layer_ns: 0.8,
            energy_pj_per_bit: 0.429,
            row_buffer_bits: 32 * 1024,
            mat_bits: 1024 * 1024,
            mats_per_bank: 200,
            channels: 16,
            banks_per_channel: 16,
            channel_io_bits: 64,
            tier_capacity_bytes: 1_250_000_000,
            chip_area_mm2: 121.0,
            miv_internal_bw_mult: 33.5,
            stream_utilization: 0.88,
            array_energy_scale: 0.25,
        }
    }
}

impl DramConfig {
    /// Layers per tier (tier 0 = bottom = fastest).
    pub fn layers_per_tier(&self) -> usize {
        self.layers / self.tiers
    }

    /// Representative access latency of a tier: mid-layer of the tier's
    /// layer range under the paper's (3 + 0.8 L) ns staircase model.
    pub fn tier_latency_ns(&self, tier: usize) -> f64 {
        let lpt = self.layers_per_tier();
        let mid_layer = tier * lpt + lpt / 2;
        self.latency_base_ns + self.latency_per_layer_ns * mid_layer as f64
    }

    /// External channel-I/O bandwidth (GB/s): channels * 64b * 1 GHz.
    pub fn external_bw_gbps(&self, freq_ghz: f64) -> f64 {
        self.channels as f64 * self.channel_io_bits as f64 / 8.0 * freq_ghz
    }

    /// Internal MIV streaming bandwidth to the NMP (GB/s), before the
    /// tier-latency occupancy penalty.
    pub fn internal_bw_gbps(&self, freq_ghz: f64) -> f64 {
        self.external_bw_gbps(freq_ghz) * self.miv_internal_bw_mult * self.stream_utilization
    }

    /// Effective streaming bandwidth out of a given tier (GB/s): row-buffer
    /// refills from slower (higher) tiers eat into stream occupancy.
    /// time(N bytes) = N / BW_int + rows(N) * t_access(tier) / banks —
    /// folded here into an equivalent bandwidth.
    pub fn tier_stream_bw_gbps(&self, tier: usize, freq_ghz: f64) -> f64 {
        let bw = self.internal_bw_gbps(freq_ghz); // GB/s == bytes/ns
        let row_bytes = self.row_buffer_bits as f64 / 8.0;
        // Row activations overlap across the banks of a channel but the
        // channels' streams serialize at the PU ingest ports, so the
        // activation penalty amortizes over channels, not channels*banks.
        let chans = self.channels as f64;
        let act_ns_per_byte = self.tier_latency_ns(tier) / (row_bytes * chans);
        1.0 / (1.0 / bw + act_ns_per_byte)
    }

    pub fn chip_capacity_bytes(&self) -> u64 {
        self.tier_capacity_bytes * self.tiers as u64
    }
}

/// M3D RRAM device + system parameters (paper Table III).
#[derive(Debug, Clone)]
pub struct RramConfig {
    /// paper: 8 stacked RRAM layers above the logic die.
    pub layers: usize,
    /// paper: read 2.3 ns, write 11 ns.
    pub read_latency_ns: f64,
    pub write_latency_ns: f64,
    /// paper: read 0.4 pJ/bit, write 1.33 pJ/bit.
    pub read_energy_pj_per_bit: f64,
    pub write_energy_pj_per_bit: f64,
    /// paper: 1k x 1k units, 256 units/tile, 64 H-trees/tile.
    pub unit_bits: usize,
    pub units_per_tile: usize,
    pub htrees_per_tile: usize,
    /// paper: 8 controllers, 16 channels/controller, 4 tiles/channel.
    pub controllers: usize,
    pub channels_per_controller: usize,
    pub tiles_per_channel: usize,
    /// Table III says "Chip Capacity 2 GB", but its own organization row
    /// (8 controllers x 16 channels x 4 tiles x 256 units x 1 Mb
    /// = 2 GB) describes ONE layer; the narrative requires MobileVLM-3B's
    /// 3.4 GB of FFN weights resident in RRAM, so we read the capacity as
    /// per-layer: 8 layers x 2 GB = 16 GB (see DESIGN.md §2).
    pub chip_capacity_bytes: u64,
    pub internal_parallelism: usize,
    /// paper: peak interface BW = 512 GB/s (8 controllers x 512 bit x 1 GHz).
    pub interface_bits_per_controller: usize,
    /// Typical 1T1R endurance budget (writes/cell). The paper manages RRAM
    /// with a write-once KV offload policy precisely because endurance is
    /// limited; 1e6 is the consensus figure for HfO2 1T1R at this node.
    pub endurance_writes: u64,
    /// CALIBRATION: near-layer parallel reads (each pair of PUs owns one
    /// RRAM layer; weights stream to the PE groups without serializing on
    /// one shared bus) scale the single-interface peak.
    pub near_layer_bw_mult: f64,
    /// CALIBRATION: sustained fraction of peak for resident-weight streams.
    pub stream_utilization: f64,
    /// CALIBRATION: per-bit streaming energy derate (synchronous wide
    /// H-tree reads amortize peripheral energy; see DramConfig).
    pub array_energy_scale: f64,
}

impl Default for RramConfig {
    fn default() -> Self {
        RramConfig {
            layers: 8,
            read_latency_ns: 2.3,
            write_latency_ns: 11.0,
            read_energy_pj_per_bit: 0.4,
            write_energy_pj_per_bit: 1.33,
            unit_bits: 1024 * 1024,
            units_per_tile: 256,
            htrees_per_tile: 64,
            controllers: 8,
            channels_per_controller: 16,
            tiles_per_channel: 4,
            chip_capacity_bytes: 16_000_000_000,
            internal_parallelism: 128,
            interface_bits_per_controller: 512,
            endurance_writes: 1_000_000,
            near_layer_bw_mult: 5.5,
            stream_utilization: 0.85,
            array_energy_scale: 0.25,
        }
    }
}

impl RramConfig {
    /// Interface peak bandwidth (GB/s) = controllers * 512b * freq.
    pub fn interface_bw_gbps(&self, freq_ghz: f64) -> f64 {
        self.controllers as f64 * self.interface_bits_per_controller as f64 / 8.0 * freq_ghz
    }

    /// Effective read-stream bandwidth to the PE groups (GB/s).
    pub fn read_stream_bw_gbps(&self, freq_ghz: f64) -> f64 {
        self.interface_bw_gbps(freq_ghz) * self.near_layer_bw_mult * self.stream_utilization
    }

    /// Effective write bandwidth (GB/s): writes are slower (11 ns vs 2.3 ns)
    /// and not parallelized across layers for a single stream.
    pub fn write_stream_bw_gbps(&self, freq_ghz: f64) -> f64 {
        self.interface_bw_gbps(freq_ghz) * self.stream_utilization
            * (self.read_latency_ns / self.write_latency_ns)
    }
}

/// Near-memory-processor parameters (paper Tables III & IV, NMP sections).
#[derive(Debug, Clone)]
pub struct NmpConfig {
    /// paper: 16 PUs on each logic die.
    pub pus: usize,
    /// paper: 16 PEs per PU.
    pub pes_per_pu: usize,
    /// paper: tensor core 2x2 MACs (DRAM NMP) / 4x4 MACs (RRAM NMP).
    pub mac_rows: usize,
    pub mac_cols: usize,
    /// paper: SFPE 256-way SIMD (DRAM NMP); RRAM NMP has none.
    pub sfpe_simd_lanes: usize,
    /// paper: double-buffered SRAM per PE (1 KB DRAM / 8 KB RRAM).
    pub pe_sram_bytes: usize,
    /// paper: PU shared memory (20 KB DRAM / 80 KB RRAM).
    pub pu_shared_bytes: usize,
    /// paper: peak performance (2 TFLOPS DRAM NMP / 32 TFLOPS RRAM NMP).
    pub peak_tflops: f64,
    /// paper: peak power (0.671 W DRAM NMP / 2.584 W RRAM NMP).
    pub peak_power_w: f64,
    /// paper: die area (121 mm^2 DRAM stack footprint / 33.6 mm^2 RRAM).
    pub die_area_mm2: f64,
    /// paper: 1 GHz, 7 nm logic, FP16.
    pub freq_ghz: f64,
    /// CALIBRATION: fixed per-fused-kernel dispatch cost on the NMP
    /// (controller sequencing + SFPE/PE pipeline fill). The paper's
    /// 233–533 TPS envelope implies a per-step floor beyond pure
    /// streaming; see DESIGN.md §6.
    pub kernel_dispatch_ns: f64,
    /// CALIBRATION: idle fraction of peak power burned while the chiplet
    /// waits on its partner (leakage + clocking).
    pub idle_power_frac: f64,
}

impl NmpConfig {
    /// DRAM-chiplet NMP (paper Table IV).
    pub fn dram_default() -> Self {
        NmpConfig {
            pus: 16,
            pes_per_pu: 16,
            mac_rows: 2,
            mac_cols: 2,
            sfpe_simd_lanes: 256,
            pe_sram_bytes: 1024,
            pu_shared_bytes: 20 * 1024,
            peak_tflops: 2.0,
            peak_power_w: 0.671,
            die_area_mm2: 28.71,
            freq_ghz: 1.0,
            kernel_dispatch_ns: 9_000.0,
            idle_power_frac: 0.2,
        }
    }

    /// RRAM-chiplet NMP (paper Table III).
    pub fn rram_default() -> Self {
        NmpConfig {
            pus: 16,
            pes_per_pu: 16,
            mac_rows: 4,
            mac_cols: 4,
            sfpe_simd_lanes: 0,
            pe_sram_bytes: 8 * 1024,
            pu_shared_bytes: 80 * 1024,
            peak_tflops: 32.0,
            peak_power_w: 2.584,
            die_area_mm2: 24.85,
            freq_ghz: 1.0,
            kernel_dispatch_ns: 9_000.0,
            idle_power_frac: 0.2,
        }
    }

    /// Peak MAC throughput in FLOP/ns (2 flops per MAC).
    pub fn peak_flops_per_ns(&self) -> f64 {
        self.peak_tflops * 1e3
    }

    /// SFPE elementwise throughput in elements/ns (all PUs).
    pub fn sfpe_elems_per_ns(&self) -> f64 {
        if self.sfpe_simd_lanes == 0 {
            // RRAM NMP routes elementwise tails through PE accumulators.
            (self.pus * self.pes_per_pu) as f64 * self.freq_ghz
        } else {
            (self.sfpe_simd_lanes * self.pus) as f64 * self.freq_ghz
        }
    }
}

/// UCIe 2.5D link parameters (paper §III-A and the ISSCC'25 reference:
/// 32 GB/s per module, 0.6 pJ/bit; the package integrates several modules).
#[derive(Debug, Clone)]
pub struct UcieConfig {
    /// Aggregate link bandwidth between the two chiplets (GB/s).
    pub bandwidth_gbps: f64,
    /// paper ref [23]: 0.6 pJ/bit.
    pub energy_pj_per_bit: f64,
    /// Fixed DMA transaction latency (ns) per transfer.
    pub dma_latency_ns: f64,
    /// paper Fig 7: "the UCIe link draws about 1 W" while active.
    pub active_power_w: f64,
}

impl Default for UcieConfig {
    fn default() -> Self {
        UcieConfig {
            bandwidth_gbps: 128.0,
            energy_pj_per_bit: 0.6,
            dma_latency_ns: 80.0,
            active_power_w: 1.0,
        }
    }
}

/// Logic-die area breakdown fractions (paper Fig 7(a)/(b)).
#[derive(Debug, Clone)]
pub struct AreaModel {
    pub dram_peripheral_frac: f64, // paper: 51.5%
    pub dram_ucie_frac: f64,       // paper: 22.3%
    pub dram_pu_frac: f64,         // paper: 26.2%
    pub rram_pu_frac: f64,         // paper: 34.0%
    pub dram_logic_die_mm2: f64,   // paper: 28.71 mm^2
    pub rram_logic_die_mm2: f64,   // paper: 24.85 mm^2
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            dram_peripheral_frac: 0.515,
            dram_ucie_frac: 0.223,
            dram_pu_frac: 0.262,
            rram_pu_frac: 0.340,
            dram_logic_die_mm2: 28.71,
            rram_logic_die_mm2: 24.85,
        }
    }
}

/// Full CHIME platform description.
#[derive(Debug, Clone)]
pub struct ChimeHardware {
    pub dram: DramConfig,
    pub rram: RramConfig,
    pub dram_nmp: NmpConfig,
    pub rram_nmp: NmpConfig,
    pub ucie: UcieConfig,
    pub area: AreaModel,
    /// Memory-timing fidelity every `SimEngine` built from this hardware
    /// runs at (default: the paper's first-order streaming model).
    pub memory_fidelity: MemoryFidelity,
    /// UCIe fabric topology every fabric built from this hardware routes
    /// over (default: the legacy point-to-point model).
    pub topology: TopologyConfig,
}

impl Default for ChimeHardware {
    fn default() -> Self {
        ChimeHardware {
            dram: DramConfig::default(),
            rram: RramConfig::default(),
            dram_nmp: NmpConfig::dram_default(),
            rram_nmp: NmpConfig::rram_default(),
            ucie: UcieConfig::default(),
            area: AreaModel::default(),
            memory_fidelity: MemoryFidelity::default(),
            topology: TopologyConfig::default(),
        }
    }
}

impl ChimeHardware {
    /// Total logic-die area (Table V: 28.71 & 24.85 mm^2).
    pub fn total_die_area_mm2(&self) -> f64 {
        self.area.dram_logic_die_mm2 + self.area.rram_logic_die_mm2
    }

    /// DRAM-only ablation platform (Fig 9): the RRAM chiplet is removed and
    /// FFN weights live in (and stream from) M3D DRAM, contending with
    /// attention for the same internal bandwidth. KV tiering still applies.
    /// Same-silicon-budget comparison: the single logic die re-provisions
    /// the combined PU budget (it must run the FFN too), so its NMP power
    /// envelope is the sum of both chiplets' NMPs.
    pub fn dram_only(&self) -> ChimeHardware {
        let mut hw = self.clone();
        // No second chiplet: no UCIe hop, but FFN streams share DRAM.
        hw.ucie.bandwidth_gbps = f64::INFINITY;
        hw.ucie.active_power_w = 0.0;
        hw.ucie.dma_latency_ns = 0.0;
        hw.dram_nmp.peak_power_w = self.dram_nmp.peak_power_w + self.rram_nmp.peak_power_w;
        hw
    }
}

/// Jetson Orin NX envelope (paper Table V + [31]); see
/// `baselines::jetson` for the performance model that consumes this.
#[derive(Debug, Clone)]
pub struct JetsonSpec {
    /// LPDDR5 bandwidth: 102.4 GB/s.
    pub dram_bw_gbps: f64,
    /// Dense FP16 peak (GPU, sparsity off) ~ 50 TOPS -> ~25 TFLOPS FP16;
    /// usable dense FP16 on Ampere mobile ~ 17 TFLOPS.
    pub peak_fp16_tflops: f64,
    /// paper Table V: power 10-40 W envelope; measured MLLM inference draw.
    pub power_low_w: f64,
    pub power_high_w: f64,
    /// paper Table V: ~200 mm^2 die at 8 nm, <= 0.92 GHz.
    pub die_area_mm2: f64,
    pub freq_ghz: f64,
    /// CALIBRATION: sustained fraction of DRAM bandwidth for small-batch
    /// decode (GEMV-heavy, launch-gapped).
    pub bw_utilization: f64,
    /// CALIBRATION: sustained fraction of peak FLOPs (prefill/encoder).
    pub flops_utilization: f64,
    /// CALIBRATION: fixed per-decode-step overhead (kernel launches,
    /// framework scheduling) that flattens Jetson TPS across model sizes
    /// (paper Fig 6(b): 7–11 TPS on 0.5B..2.7B alike).
    pub step_overhead_ms: f64,
}

impl Default for JetsonSpec {
    fn default() -> Self {
        JetsonSpec {
            dram_bw_gbps: 102.4,
            peak_fp16_tflops: 17.0,
            power_low_w: 10.0,
            power_high_w: 40.0,
            die_area_mm2: 200.0,
            freq_ghz: 0.92,
            bw_utilization: 0.85,
            flops_utilization: 0.35,
            step_overhead_ms: 75.0,
        }
    }
}

/// FACIL (HPCA'25) near-bank DRAM PIM envelope (paper Table V).
#[derive(Debug, Clone)]
pub struct FacilSpec {
    /// Near-bank LPDDR PIM: internal bandwidth available to bank-level MACs.
    pub internal_bw_gbps: f64,
    /// SoC side handles non-GEMV kernels over the external interface.
    pub external_bw_gbps: f64,
    /// paper Table V envelope: 5.7-38.5 W, <= 3.2 GHz, ~200 mm^2, 15 nm.
    pub power_low_w: f64,
    pub power_high_w: f64,
    pub die_area_mm2: f64,
    pub freq_ghz: f64,
    /// CALIBRATION: fraction of decode bytes eligible for in-bank execution
    /// (FACIL accelerates FC/GEMV; attention softmax & co stay on the SoC).
    pub pim_coverage: f64,
    /// CALIBRATION: per-step overhead for SoC<->PIM orchestration.
    pub step_overhead_ms: f64,
    /// CALIBRATION: sustained utilization of internal bandwidth.
    pub bw_utilization: f64,
}

impl Default for FacilSpec {
    fn default() -> Self {
        FacilSpec {
            internal_bw_gbps: 512.0,
            external_bw_gbps: 68.0,
            power_low_w: 5.7,
            power_high_w: 38.5,
            die_area_mm2: 200.0,
            freq_ghz: 3.2,
            pim_coverage: 0.6,
            step_overhead_ms: 40.0,
            bw_utilization: 0.55,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_tier_latency_staircase() {
        let d = DramConfig::default();
        // Tier 0 mid-layer = 20 -> 3 + 0.8*20 = 19 ns.
        assert!((d.tier_latency_ns(0) - 19.0).abs() < 1e-9);
        // Tier 4 mid-layer = 180 -> 147 ns.
        assert!((d.tier_latency_ns(4) - 147.0).abs() < 1e-9);
        // Monotone in tier.
        for t in 1..d.tiers {
            assert!(d.tier_latency_ns(t) > d.tier_latency_ns(t - 1));
        }
    }

    #[test]
    fn dram_bandwidths_ordered() {
        let d = DramConfig::default();
        let ext = d.external_bw_gbps(1.0);
        assert!((ext - 128.0).abs() < 1e-9); // 16 ch x 8 B x 1 GHz
        let int = d.internal_bw_gbps(1.0);
        assert!(int > ext, "MIV internal must exceed external I/O");
        // Faster tiers stream faster.
        assert!(d.tier_stream_bw_gbps(0, 1.0) > d.tier_stream_bw_gbps(4, 1.0));
        // All tiers stay below the pure internal bandwidth.
        for t in 0..d.tiers {
            assert!(d.tier_stream_bw_gbps(t, 1.0) <= int);
        }
    }

    #[test]
    fn rram_interface_bw_matches_paper() {
        let r = RramConfig::default();
        // paper: 8 controllers x 512 bit x 1 GHz = 512 GB/s.
        assert!((r.interface_bw_gbps(1.0) - 512.0).abs() < 1e-9);
        assert!(r.read_stream_bw_gbps(1.0) > r.interface_bw_gbps(1.0));
        assert!(r.write_stream_bw_gbps(1.0) < r.read_stream_bw_gbps(1.0));
    }

    #[test]
    fn nmp_defaults_match_tables() {
        let d = NmpConfig::dram_default();
        assert_eq!(d.peak_tflops, 2.0);
        assert_eq!(d.peak_power_w, 0.671);
        assert_eq!((d.mac_rows, d.mac_cols), (2, 2));
        let r = NmpConfig::rram_default();
        assert_eq!(r.peak_tflops, 32.0);
        assert_eq!(r.peak_power_w, 2.584);
        assert_eq!((r.mac_rows, r.mac_cols), (4, 4));
        assert_eq!(r.sfpe_simd_lanes, 0);
        assert!(r.sfpe_elems_per_ns() > 0.0);
    }

    #[test]
    fn area_fractions_sum_to_one() {
        let a = AreaModel::default();
        let total = a.dram_peripheral_frac + a.dram_ucie_frac + a.dram_pu_frac;
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dram_only_removes_link() {
        let hw = ChimeHardware::default();
        let d = hw.dram_only();
        assert_eq!(d.ucie.active_power_w, 0.0);
        assert!(d.ucie.bandwidth_gbps.is_infinite());
    }

    #[test]
    fn memory_fidelity_spellings_round_trip() {
        for f in [MemoryFidelity::FirstOrder, MemoryFidelity::CycleAccurate] {
            assert_eq!(MemoryFidelity::parse(f.name()), Some(f));
        }
        assert_eq!(MemoryFidelity::parse("fo"), Some(MemoryFidelity::FirstOrder));
        assert_eq!(MemoryFidelity::parse("cycle-accurate"), Some(MemoryFidelity::CycleAccurate));
        assert_eq!(MemoryFidelity::parse("cyccle"), None);
        assert_eq!(MemoryFidelity::default(), MemoryFidelity::FirstOrder);
        assert_eq!(ChimeHardware::default().memory_fidelity, MemoryFidelity::FirstOrder);
    }

    #[test]
    fn topology_spellings_round_trip() {
        for k in TopologyKind::ALL {
            assert_eq!(TopologyKind::parse(k.name()), Some(k));
        }
        assert_eq!(TopologyKind::parse("p2p"), Some(TopologyKind::PointToPoint));
        assert_eq!(TopologyKind::parse("grid"), Some(TopologyKind::Mesh));
        assert_eq!(TopologyKind::parse("rign"), None);
        assert_eq!(TopologyKind::default(), TopologyKind::PointToPoint);
        assert_eq!(
            ChimeHardware::default().topology.kind,
            TopologyKind::PointToPoint
        );
    }

    #[test]
    fn total_die_area_matches_table_v() {
        let hw = ChimeHardware::default();
        assert!((hw.total_die_area_mm2() - 53.56).abs() < 0.01);
    }
}
