//! Model zoo: the paper's Table II MLLM configurations plus the tiny
//! functional-path model that matches `artifacts/manifest.json`.
//!
//! Timing/energy depend only on tensor shapes and byte counts, so each
//! model is described by its public architecture dimensions (FP16 weights,
//! per the paper's "FP16 format" NMP configuration).

/// Vision-encoder family (paper Fig 5(a)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VisionKind {
    /// ViT without downsampling: N patch tokens out (MobileVLM; CLIP-L/14).
    Vit,
    /// Pyramid ViT, four-stage downsampling.
    Pvt,
    /// FastViT-HD: five-stage downsampling, M << N tokens out (FastVLM).
    FastVitHd,
}

/// Vision-encoder cost model: token count + aggregate compute/weights.
#[derive(Debug, Clone)]
pub struct VisionEncoder {
    pub kind: VisionKind,
    /// Output visual tokens fed to the connector.
    pub out_tokens: usize,
    /// Hidden width of the final stage (for activation sizing).
    pub d_out: usize,
    /// Total encoder parameters (bytes = params * 2, FP16).
    pub params: u64,
    /// Forward GFLOPs at the paper's 512x512 (or native) input.
    pub gflops: f64,
}

impl VisionEncoder {
    pub fn weight_bytes(&self) -> u64 {
        self.params * 2
    }
}

/// Connector family (paper Fig 5(a)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnectorKind {
    /// Lightweight MLP projector (FastVLM).
    Mlp,
    /// Lightweight Downsample Projector (MobileVLM): conv + 2x2 downsample.
    Ldp,
    /// Cross-attention connector (visual KV, text Q).
    CrossAttn,
}

#[derive(Debug, Clone)]
pub struct Connector {
    pub kind: ConnectorKind,
    /// Token count after the connector (LDP downsamples 4x).
    pub out_tokens: usize,
    pub params: u64,
    pub gflops: f64,
}

impl Connector {
    pub fn weight_bytes(&self) -> u64 {
        self.params * 2
    }
}

/// LLM backbone architecture (decoder-only transformer).
#[derive(Debug, Clone)]
pub struct LlmConfig {
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// GQA: number of KV heads (== n_heads for MHA).
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ffn: usize,
    /// 2 for GELU MLP (up+down), 3 for SwiGLU (gate+up+down).
    pub ffn_matrices: usize,
    pub vocab: usize,
    /// Tied input/output embeddings (Qwen2-0.5B/1.5B tie; LLaMA does not).
    pub tied_embeddings: bool,
    /// FP16 = 2 bytes.
    pub bytes_per_param: usize,
}

impl LlmConfig {
    pub fn d_q(&self) -> usize {
        self.n_heads * self.d_head
    }

    pub fn d_kv(&self) -> usize {
        self.n_kv_heads * self.d_head
    }

    /// QKV + output-projection weight bytes for one layer.
    pub fn attn_weight_bytes_per_layer(&self) -> u64 {
        let q = self.d_model * self.d_q();
        let k = self.d_model * self.d_kv();
        let v = self.d_model * self.d_kv();
        let o = self.d_q() * self.d_model;
        ((q + k + v + o) * self.bytes_per_param) as u64
    }

    /// FFN weight bytes for one layer.
    pub fn ffn_weight_bytes_per_layer(&self) -> u64 {
        (self.ffn_matrices * self.d_model * self.d_ffn * self.bytes_per_param) as u64
    }

    /// LayerNorm/RMSNorm parameter bytes for one layer (two norms).
    pub fn norm_weight_bytes_per_layer(&self) -> u64 {
        (2 * self.d_model * self.bytes_per_param) as u64
    }

    /// Unembedding (lm_head) weight bytes — streamed every decode step.
    pub fn lm_head_bytes(&self) -> u64 {
        (self.vocab * self.d_model * self.bytes_per_param) as u64
    }

    /// Embedding-table bytes (same array as lm_head when tied).
    pub fn embedding_bytes(&self) -> u64 {
        (self.vocab * self.d_model * self.bytes_per_param) as u64
    }

    /// KV-cache bytes appended per token per layer (K + V).
    pub fn kv_bytes_per_token_per_layer(&self) -> u64 {
        (2 * self.d_kv() * self.bytes_per_param) as u64
    }

    /// KV-cache bytes per token across all layers.
    pub fn kv_bytes_per_token(&self) -> u64 {
        self.kv_bytes_per_token_per_layer() * self.n_layers as u64
    }

    /// Total backbone parameters (weights only, excl. embeddings).
    pub fn backbone_params(&self) -> u64 {
        let per_layer = (self.attn_weight_bytes_per_layer()
            + self.ffn_weight_bytes_per_layer()
            + self.norm_weight_bytes_per_layer()) / self.bytes_per_param as u64;
        per_layer * self.n_layers as u64
    }

    /// Total parameters including embeddings (and untied lm_head).
    pub fn total_params(&self) -> u64 {
        let emb = (self.vocab * self.d_model) as u64;
        let emb_total = if self.tied_embeddings { emb } else { 2 * emb };
        self.backbone_params() + emb_total
    }
}

/// A full MLLM (Table II row).
#[derive(Debug, Clone)]
pub struct MllmConfig {
    pub name: String,
    pub family: String,
    pub vision: VisionEncoder,
    pub connector: Connector,
    pub llm: LlmConfig,
}

impl MllmConfig {
    /// Visual tokens entering the LLM (post-connector).
    pub fn visual_tokens(&self) -> usize {
        self.connector.out_tokens
    }

    /// Total model parameters (encoder + connector + backbone).
    pub fn total_params(&self) -> u64 {
        self.vision.params + self.connector.params + self.llm.total_params()
    }

    // ---- Table II presets --------------------------------------------------

    /// FastVLM 0.6B = FastViT-HD + lightweight MLP + Qwen2-0.5B.
    pub fn fastvlm_0_6b() -> Self {
        MllmConfig {
            name: "fastvlm-0.6b".into(),
            family: "FastVLM".into(),
            vision: VisionEncoder {
                kind: VisionKind::FastVitHd,
                // FastViT-HD downsamples 64x: (512/64)^2 = 64 tokens.
                out_tokens: 64,
                d_out: 1536,
                params: 125_000_000,
                gflops: 28.0,
            },
            connector: Connector {
                kind: ConnectorKind::Mlp,
                out_tokens: 64,
                params: 3_000_000,
                gflops: 0.4,
            },
            llm: LlmConfig {
                d_model: 896,
                n_layers: 24,
                n_heads: 14,
                n_kv_heads: 2,
                d_head: 64,
                d_ffn: 4864,
                ffn_matrices: 3, // SwiGLU
                vocab: 151_936,
                tied_embeddings: true,
                bytes_per_param: 2,
            },
        }
    }

    /// FastVLM 1.7B = FastViT-HD + lightweight MLP + Qwen2-1.5B.
    pub fn fastvlm_1_7b() -> Self {
        MllmConfig {
            name: "fastvlm-1.7b".into(),
            family: "FastVLM".into(),
            vision: VisionEncoder {
                kind: VisionKind::FastVitHd,
                out_tokens: 64,
                d_out: 1536,
                params: 125_000_000,
                gflops: 28.0,
            },
            connector: Connector {
                kind: ConnectorKind::Mlp,
                out_tokens: 64,
                params: 5_000_000,
                gflops: 0.6,
            },
            llm: LlmConfig {
                d_model: 1536,
                n_layers: 28,
                n_heads: 12,
                n_kv_heads: 2,
                d_head: 128,
                d_ffn: 8960,
                ffn_matrices: 3,
                vocab: 151_936,
                tied_embeddings: true,
                bytes_per_param: 2,
            },
        }
    }

    /// MobileVLM 1.7B = CLIP ViT-L/14 + LDP + MobileLLaMA-1.4B.
    pub fn mobilevlm_1_7b() -> Self {
        MllmConfig {
            name: "mobilevlm-1.7b".into(),
            family: "MobileVLM".into(),
            vision: VisionEncoder {
                kind: VisionKind::Vit,
                // ViT-L/14 @ 336: 576 patch tokens, no downsampling.
                out_tokens: 576,
                d_out: 1024,
                params: 304_000_000,
                gflops: 162.0,
            },
            connector: Connector {
                kind: ConnectorKind::Ldp,
                // LDP downsamples 2x2 -> 144 pseudo tokens.
                out_tokens: 144,
                params: 12_000_000,
                gflops: 1.4,
            },
            llm: LlmConfig {
                d_model: 2048,
                n_layers: 24,
                n_heads: 16,
                n_kv_heads: 16,
                d_head: 128,
                d_ffn: 5632,
                ffn_matrices: 3,
                vocab: 32_000,
                tied_embeddings: false,
                bytes_per_param: 2,
            },
        }
    }

    /// MobileVLM 3B = CLIP ViT-L/14 + LDP + MobileLLaMA-2.7B.
    pub fn mobilevlm_3b() -> Self {
        MllmConfig {
            name: "mobilevlm-3b".into(),
            family: "MobileVLM".into(),
            vision: VisionEncoder {
                kind: VisionKind::Vit,
                out_tokens: 576,
                d_out: 1024,
                params: 304_000_000,
                gflops: 162.0,
            },
            connector: Connector {
                kind: ConnectorKind::Ldp,
                out_tokens: 144,
                params: 17_000_000,
                gflops: 1.9,
            },
            llm: LlmConfig {
                d_model: 2560,
                n_layers: 32,
                n_heads: 20,
                n_kv_heads: 20,
                d_head: 128,
                d_ffn: 6912,
                ffn_matrices: 3,
                vocab: 32_000,
                tied_embeddings: false,
                bytes_per_param: 2,
            },
        }
    }

    /// The tiny functional-path model (must mirror python/compile/model.py
    /// and artifacts/manifest.json).
    pub fn tiny() -> Self {
        MllmConfig {
            name: "tiny".into(),
            family: "Tiny".into(),
            vision: VisionEncoder {
                kind: VisionKind::Vit,
                out_tokens: 16,
                d_out: 64,
                params: 120_000,
                gflops: 0.0005,
            },
            connector: Connector {
                kind: ConnectorKind::Mlp,
                out_tokens: 16,
                params: 16_384,
                gflops: 0.0001,
            },
            llm: LlmConfig {
                d_model: 64,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads: 4,
                d_head: 16,
                d_ffn: 256,
                ffn_matrices: 2, // GELU MLP in the functional model
                vocab: 256,
                tied_embeddings: true,
                bytes_per_param: 2,
            },
        }
    }

    /// All four Table II evaluation models, paper order.
    pub fn paper_models() -> Vec<MllmConfig> {
        vec![
            Self::fastvlm_0_6b(),
            Self::fastvlm_1_7b(),
            Self::mobilevlm_1_7b(),
            Self::mobilevlm_3b(),
        ]
    }

    /// Look up by name (accepts the `chime` CLI spellings).
    pub fn by_name(name: &str) -> Option<MllmConfig> {
        match name.to_ascii_lowercase().as_str() {
            "fastvlm-0.6b" | "fastvlm0.6b" | "fastvlm-0.6" => Some(Self::fastvlm_0_6b()),
            "fastvlm-1.7b" | "fastvlm1.7b" | "fastvlm-1.7" => Some(Self::fastvlm_1_7b()),
            "mobilevlm-1.7b" | "mobilevlm1.7b" => Some(Self::mobilevlm_1_7b()),
            "mobilevlm-3b" | "mobilevlm3b" => Some(Self::mobilevlm_3b()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_near_nameplate() {
        // Backbone + embeddings should land near the advertised sizes.
        let m = MllmConfig::fastvlm_0_6b();
        let p = m.llm.total_params() as f64 / 1e9;
        assert!((0.4..0.6).contains(&p), "qwen2-0.5b params {p}B");

        let m = MllmConfig::fastvlm_1_7b();
        let p = m.llm.total_params() as f64 / 1e9;
        assert!((1.3..1.8).contains(&p), "qwen2-1.5b params {p}B");

        let m = MllmConfig::mobilevlm_1_7b();
        let p = m.llm.total_params() as f64 / 1e9;
        assert!((1.2..1.6).contains(&p), "mobilellama-1.4b params {p}B");

        let m = MllmConfig::mobilevlm_3b();
        let p = m.llm.total_params() as f64 / 1e9;
        assert!((2.4..3.0).contains(&p), "mobilellama-2.7b params {p}B");
    }

    #[test]
    fn gqa_shrinks_kv() {
        let qwen = MllmConfig::fastvlm_0_6b().llm;
        let llama = MllmConfig::mobilevlm_1_7b().llm;
        // Qwen2 GQA: kv width 2*64=128 << q width 896.
        assert_eq!(qwen.d_kv(), 128);
        assert_eq!(qwen.d_q(), 896);
        // MHA: kv == q width.
        assert_eq!(llama.d_kv(), llama.d_q());
        assert!(qwen.kv_bytes_per_token() < llama.kv_bytes_per_token());
    }

    #[test]
    fn weight_accounting_consistent() {
        let llm = MllmConfig::mobilevlm_3b().llm;
        // SwiGLU: 3 matrices.
        assert_eq!(
            llm.ffn_weight_bytes_per_layer(),
            (3 * 2560 * 6912 * 2) as u64
        );
        // MHA QKVO: 4 * d^2.
        assert_eq!(
            llm.attn_weight_bytes_per_layer(),
            (4 * 2560 * 2560 * 2) as u64
        );
    }

    #[test]
    fn connector_downsampling() {
        let mv = MllmConfig::mobilevlm_1_7b();
        assert_eq!(mv.vision.out_tokens, 576);
        assert_eq!(mv.visual_tokens(), 144); // LDP 4x reduction
        let fv = MllmConfig::fastvlm_0_6b();
        assert_eq!(fv.visual_tokens(), 64); // encoder-side compression
    }

    #[test]
    fn lookup_by_name() {
        for m in MllmConfig::paper_models() {
            assert_eq!(MllmConfig::by_name(&m.name).unwrap().name, m.name);
        }
        assert!(MllmConfig::by_name("nonexistent").is_none());
    }

    #[test]
    fn tiny_matches_functional_model() {
        let t = MllmConfig::tiny();
        assert_eq!(t.llm.d_model, 64);
        assert_eq!(t.llm.n_layers, 2);
        assert_eq!(t.llm.vocab, 256);
        assert_eq!(t.visual_tokens(), 16);
    }
}
