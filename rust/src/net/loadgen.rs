//! `chime loadgen`: open-loop wall-clock load generator for a running
//! `chime serve --listen` target.
//!
//! One worker thread per request sleeps until its [`ArrivalProcess`]
//! point, POSTs `/v1/submit`, opens the request's SSE stream, and
//! timestamps first-token / per-token / completion frames with the host
//! monotonic clock. The report renders the same p50/p95/p99 TTFT / TPOT
//! / latency table as `results::tail` — but measured over the wire in
//! wall-clock time rather than inside the simulator's virtual timeline.
//! Pair it with a `--listen` server built with `--threads N` (the
//! parallel executor, DESIGN.md §15) to measure how wire-visible
//! throughput scales with host worker threads.
//!
//! The client side is std-only like the server: a blocking
//! `TcpStream` + the [`super::http`] caps-checked parser in reverse
//! (status line + headers + Content-Length body).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use crate::api::{ArrivalProcess, ChimeError};
use crate::results::tail::tail_percentiles;
use crate::util::{table, Json, Table};

use super::server::resolve_addr;

/// One loadgen run: target, demand shape, and per-request budgets.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// `HOST:PORT` of a running `chime serve --listen`.
    pub target: String,
    /// Requests to fire (ignored for `trace:` — the file dictates it).
    pub requests: usize,
    /// Open-loop arrival schedule (burst / poisson / trace).
    pub arrival: ArrivalProcess,
    /// Seed for the Poisson schedule.
    pub seed: u64,
    /// Decode budget per request (traces may override per point).
    pub max_new_tokens: usize,
    /// Synthetic prompt length submitted with each request.
    pub prompt_tokens: usize,
    /// Finish + shut the server down after the run (smoke-test mode).
    pub shutdown: bool,
    /// Per-connection I/O timeout.
    pub timeout: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            target: String::new(),
            requests: 16,
            arrival: ArrivalProcess::Burst,
            seed: 7,
            max_new_tokens: 16,
            prompt_tokens: 8,
            shutdown: false,
            timeout: Duration::from_secs(120),
        }
    }
}

/// Wall-clock measurements for one completed request.
#[derive(Debug, Clone)]
pub struct RequestSample {
    pub id: u64,
    /// Submit → first-token frame, ns (None for zero-token requests).
    pub ttft_ns: Option<f64>,
    /// Mean first-token → completion spacing per decode token, ns.
    pub tpot_ns: Option<f64>,
    /// Submit → completed frame, ns.
    pub latency_ns: f64,
    /// Tokens the server reported in the completion frame.
    pub tokens: u64,
}

/// The run's outcome: samples, failures, and the rendered tail table.
pub struct LoadgenReport {
    pub samples: Vec<RequestSample>,
    /// Per-request failures (connect errors, rejected/shed terminals).
    pub errors: Vec<String>,
    /// First submit → last terminal frame, seconds.
    pub wall_s: f64,
    /// Rendered p50/p95/p99 table (the `results::tail` format).
    pub table: String,
    /// The server's canonical `ServeOutcome` JSON (shutdown mode only).
    pub outcome: Option<Json>,
}

impl LoadgenReport {
    /// The canonical JSON body behind `chime loadgen --json FILE`. The
    /// tail statistics come from the same [`metric_rows`] computation the
    /// rendered table prints, so the two report identical numbers
    /// (`loadgen_json_report_matches_the_table` locks this). Metrics with
    /// no samples (e.g. TTFT on a zero-token run) serialize as `null`,
    /// mirroring the table's placeholder row.
    pub fn to_json(&self) -> Json {
        let metrics = metric_rows(&self.samples)
            .into_iter()
            .map(|(name, stats)| {
                let value = match stats {
                    None => Json::Null,
                    Some(s) => Json::obj(vec![
                        ("p50_ns", s.p50_ns.into()),
                        ("p95_ns", s.p95_ns.into()),
                        ("p99_ns", s.p99_ns.into()),
                        ("mean_ns", s.mean_ns.into()),
                        ("samples", s.samples.into()),
                    ]),
                };
                (name, value)
            })
            .collect();
        let tokens: u64 = self.samples.iter().map(|s| s.tokens).sum();
        Json::obj(vec![
            ("metrics", Json::obj(metrics)),
            (
                "achieved",
                Json::obj(vec![
                    ("requests", self.samples.len().into()),
                    ("errors", self.errors.len().into()),
                    ("wall_s", self.wall_s.into()),
                    (
                        "req_per_s",
                        (self.samples.len() as f64 / self.wall_s.max(1e-9)).into(),
                    ),
                    ("tokens", (tokens as f64).into()),
                ]),
            ),
        ])
    }
}

/// Summary statistics for one wall-clock tail metric. Computed once and
/// consumed by both the rendered table and the `--json` report.
#[derive(Debug, Clone, Copy)]
struct MetricStats {
    p50_ns: f64,
    p95_ns: f64,
    p99_ns: f64,
    mean_ns: f64,
    samples: usize,
}

fn metric_stats(xs: Vec<f64>) -> Option<MetricStats> {
    if xs.is_empty() {
        return None;
    }
    let mean_ns = xs.iter().sum::<f64>() / xs.len() as f64;
    let samples = xs.len();
    let (p50_ns, p95_ns, p99_ns) = tail_percentiles(xs);
    Some(MetricStats { p50_ns, p95_ns, p99_ns, mean_ns, samples })
}

/// The three reported tail metrics, in table row order.
fn metric_rows(samples: &[RequestSample]) -> [(&'static str, Option<MetricStats>); 3] {
    [
        ("TTFT", metric_stats(samples.iter().filter_map(|s| s.ttft_ns).collect())),
        ("TPOT", metric_stats(samples.iter().filter_map(|s| s.tpot_ns).collect())),
        ("latency", metric_stats(samples.iter().map(|s| s.latency_ns).collect())),
    ]
}

/// Fire the configured request set at the target and collect the report.
/// A malformed `--target` is a usage error (exit 2); an unreachable or
/// non-chime target is a runtime error (exit 1).
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport, ChimeError> {
    let addr = resolve_addr("target", &cfg.target)?;
    probe(addr, cfg.timeout)?;
    let points = cfg.arrival.points(cfg.seed, cfg.requests)?;
    let t0 = Instant::now();
    let mut results: Vec<Result<RequestSample, String>> = Vec::with_capacity(points.len());
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(points.len());
        for (i, point) in points.iter().enumerate() {
            let cfg = &*cfg;
            handles.push(scope.spawn(move || {
                let at = t0 + Duration::from_nanos(point.arrival_ns as u64);
                std::thread::sleep(at.saturating_duration_since(Instant::now()));
                drive_request(
                    addr,
                    i as u64,
                    cfg.prompt_tokens,
                    point.max_new_tokens.unwrap_or(cfg.max_new_tokens),
                    cfg.timeout,
                )
            }));
        }
        for h in handles {
            results.push(h.join().unwrap_or_else(|_| Err("worker panicked".to_string())));
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let mut samples = Vec::new();
    let mut errors = Vec::new();
    for r in results {
        match r {
            Ok(s) => samples.push(s),
            Err(e) => errors.push(e),
        }
    }
    samples.sort_by_key(|s| s.id);
    let outcome = if cfg.shutdown {
        let (status, body) = http_call(addr, "POST", "/v1/finish", None, cfg.timeout)?;
        let text = String::from_utf8_lossy(&body).into_owned();
        if status != 200 {
            return Err(ChimeError::Runtime(format!("finish returned {status}: {text}")));
        }
        let json = Json::parse(&text)
            .map_err(|e| ChimeError::Runtime(format!("finish body is not JSON: {e}")))?;
        let (status, _) = http_call(addr, "POST", "/v1/shutdown", None, cfg.timeout)?;
        if status != 200 {
            return Err(ChimeError::Runtime(format!("shutdown returned {status}")));
        }
        Some(json)
    } else {
        None
    };
    let table = render_table(&cfg.arrival, &samples, wall_s);
    Ok(LoadgenReport { samples, errors, wall_s, table, outcome })
}

/// Preflight: the target must answer `/v1/metrics` like a chime server.
fn probe(addr: SocketAddr, timeout: Duration) -> Result<(), ChimeError> {
    let (status, body) = http_call(addr, "GET", "/v1/metrics", None, timeout)
        .map_err(|e| ChimeError::Runtime(format!("--target {addr} unreachable: {e}")))?;
    if status != 200 {
        return Err(ChimeError::Runtime(format!(
            "--target {addr} is not a chime server (/v1/metrics returned {status})"
        )));
    }
    let json = Json::parse(&String::from_utf8_lossy(&body))
        .map_err(|e| ChimeError::Runtime(format!("--target {addr} metrics not JSON: {e}")))?;
    if json.get("server").get("deterministic").as_bool() == Some(true) {
        eprintln!(
            "warning: target runs --deterministic (tokens stream only at finish); \
             wall-clock TTFT/TPOT will be degenerate"
        );
    }
    Ok(())
}

/// Submit one request and follow its SSE stream to the terminal frame.
fn drive_request(
    addr: SocketAddr,
    id: u64,
    prompt_tokens: usize,
    max_new_tokens: usize,
    timeout: Duration,
) -> Result<RequestSample, String> {
    let body = Json::obj(vec![
        ("id", (id as i64).into()),
        ("prompt_tokens", prompt_tokens.into()),
        ("max_new_tokens", max_new_tokens.into()),
    ]);
    let submitted = Instant::now();
    let (status, reply) = http_call(addr, "POST", "/v1/submit", Some(&body), timeout)
        .map_err(|e| format!("request {id}: submit: {e}"))?;
    if status != 200 {
        return Err(format!(
            "request {id}: submit returned {status}: {}",
            String::from_utf8_lossy(&reply)
        ));
    }
    let mut sse = SseStream::open(addr, &format!("/v1/stream/{id}"), timeout)
        .map_err(|e| format!("request {id}: stream: {e}"))?;
    let mut first_token: Option<Instant> = None;
    loop {
        let Some((event, data)) =
            sse.next_frame().map_err(|e| format!("request {id}: stream: {e}"))?
        else {
            return Err(format!("request {id}: stream ended before a terminal event"));
        };
        match event.as_str() {
            "first-token" => first_token = Some(Instant::now()),
            "token" => {}
            "completed" => {
                let done = Instant::now();
                let frame = Json::parse(&data)
                    .map_err(|e| format!("request {id}: completed frame not JSON: {e}"))?;
                let tokens = frame.get("tokens").as_i64().unwrap_or(0).max(0) as u64;
                let latency_ns = done.duration_since(submitted).as_nanos() as f64;
                let ttft_ns =
                    first_token.map(|t| t.duration_since(submitted).as_nanos() as f64);
                let tpot_ns = match (first_token, tokens) {
                    (Some(t), n) if n > 0 => {
                        Some(done.duration_since(t).as_nanos() as f64 / n as f64)
                    }
                    _ => None,
                };
                return Ok(RequestSample { id, ttft_ns, tpot_ns, latency_ns, tokens });
            }
            "rejected" | "shed" => {
                return Err(format!("request {id}: server terminated it as {event:?}"))
            }
            // `admitted`, `stolen`, and the final `done` marker carry no
            // timing we sample; `done` is followed by stream EOF.
            _ => {}
        }
    }
}

/// The wall-clock tail table (same shape as `results::tail`).
fn render_table(arrival: &ArrivalProcess, samples: &[RequestSample], wall_s: f64) -> String {
    let mut t = Table::new(
        &format!("Loadgen wall-clock tail — arrival {}, {} completed", arrival.spec(),
                 samples.len()),
        &["metric", "p50 (ms)", "p95 (ms)", "p99 (ms)", "mean (ms)", "samples"],
    );
    for (name, stats) in metric_rows(samples) {
        let Some(s) = stats else {
            t.row(vec![name.to_string(), "-".into(), "-".into(), "-".into(), "-".into(),
                       "0".into()]);
            continue;
        };
        t.row(vec![
            name.to_string(),
            table::f(s.p50_ns / 1e6, 2),
            table::f(s.p95_ns / 1e6, 2),
            table::f(s.p99_ns / 1e6, 2),
            table::f(s.mean_ns / 1e6, 2),
            s.samples.to_string(),
        ]);
    }
    let tokens: u64 = samples.iter().map(|s| s.tokens).sum();
    let mut out = t.render();
    out.push_str(&format!(
        "achieved: {} requests in {:.2}s ({:.1} req/s, {} tokens)\n",
        samples.len(),
        wall_s,
        samples.len() as f64 / wall_s.max(1e-9),
        tokens,
    ));
    out
}

/// One blocking HTTP exchange: write the request, read status line +
/// headers + body (Content-Length, or to EOF when absent).
pub(crate) fn http_call(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&Json>,
    timeout: Duration,
) -> Result<(u16, Vec<u8>), String> {
    let stream = TcpStream::connect_timeout(&addr, timeout).map_err(|e| e.to_string())?;
    stream.set_read_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    stream.set_write_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    let mut writer = stream.try_clone().map_err(|e| e.to_string())?;
    let payload = body.map(|b| b.compact().into_bytes()).unwrap_or_default();
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n");
    if body.is_some() {
        head.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            payload.len()
        ));
    }
    head.push_str("\r\n");
    writer.write_all(head.as_bytes()).map_err(|e| e.to_string())?;
    writer.write_all(&payload).map_err(|e| e.to_string())?;
    writer.flush().map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(stream);
    let (status, content_length) = read_response_head(&mut reader)?;
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            body.resize(n, 0);
            reader.read_exact(&mut body).map_err(|e| format!("body: {e}"))?;
        }
        None => {
            reader.read_to_end(&mut body).map_err(|e| format!("body: {e}"))?;
        }
    }
    Ok((status, body))
}

/// Parse `HTTP/1.1 <status> ...` + headers; return (status, CL if any).
fn read_response_head<R: BufRead>(reader: &mut R) -> Result<(u16, Option<usize>), String> {
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| format!("status line: {e}"))?;
    let mut parts = line.split_whitespace();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(format!("not an HTTP response: {:?}", line.trim_end()));
    }
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("bad status line: {:?}", line.trim_end()))?;
    let mut content_length = None;
    loop {
        let mut header = String::new();
        let n = reader.read_line(&mut header).map_err(|e| format!("headers: {e}"))?;
        let header = header.trim_end();
        if n == 0 || header.is_empty() {
            return Ok((status, content_length));
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse::<usize>().ok();
            }
        }
    }
}

/// A live SSE subscription: frames come back as (event, data) pairs.
pub(crate) struct SseStream {
    reader: BufReader<TcpStream>,
}

impl SseStream {
    pub(crate) fn open(
        addr: SocketAddr,
        path: &str,
        timeout: Duration,
    ) -> Result<SseStream, String> {
        let stream = TcpStream::connect_timeout(&addr, timeout).map_err(|e| e.to_string())?;
        stream.set_read_timeout(Some(timeout)).map_err(|e| e.to_string())?;
        stream.set_write_timeout(Some(timeout)).map_err(|e| e.to_string())?;
        let mut writer = stream.try_clone().map_err(|e| e.to_string())?;
        writer
            .write_all(
                format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")
                    .as_bytes(),
            )
            .map_err(|e| e.to_string())?;
        writer.flush().map_err(|e| e.to_string())?;
        let mut reader = BufReader::new(stream);
        let (status, _) = read_response_head(&mut reader)?;
        if status != 200 {
            return Err(format!("stream returned {status}"));
        }
        Ok(SseStream { reader })
    }

    /// The next `event:`/`data:` frame, or `None` at end of stream.
    pub(crate) fn next_frame(&mut self) -> Result<Option<(String, String)>, String> {
        let mut event = None;
        let mut data = None;
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
            if n == 0 {
                return Ok(None);
            }
            let line = line.trim_end_matches(['\r', '\n']);
            if line.is_empty() {
                if let (Some(e), Some(d)) = (event.take(), data.take()) {
                    return Ok(Some((e, d)));
                }
                continue;
            }
            if let Some(v) = line.strip_prefix("event: ") {
                event = Some(v.to_string());
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = Some(v.to_string());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_table_renders_tail_rows_and_achieved_rate() {
        let samples = vec![
            RequestSample {
                id: 0,
                ttft_ns: Some(2e6),
                tpot_ns: Some(0.5e6),
                latency_ns: 10e6,
                tokens: 16,
            },
            RequestSample {
                id: 1,
                ttft_ns: Some(4e6),
                tpot_ns: Some(0.7e6),
                latency_ns: 20e6,
                tokens: 16,
            },
        ];
        let text = render_table(&ArrivalProcess::Burst, &samples, 0.5);
        for needle in ["TTFT", "TPOT", "latency", "p50 (ms)", "p99 (ms)", "achieved: 2 requests",
                       "32 tokens"] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Zero-token runs render placeholder rows instead of panicking.
        let bare = vec![RequestSample {
            id: 0,
            ttft_ns: None,
            tpot_ns: None,
            latency_ns: 1e6,
            tokens: 0,
        }];
        let text = render_table(&ArrivalProcess::Burst, &bare, 0.1);
        assert!(text.contains("TTFT") && text.contains('-'));
    }

    #[test]
    fn loadgen_json_report_matches_the_table() {
        let samples = vec![
            RequestSample {
                id: 0,
                ttft_ns: Some(2e6),
                tpot_ns: Some(0.5e6),
                latency_ns: 10e6,
                tokens: 16,
            },
            RequestSample {
                id: 1,
                ttft_ns: Some(4e6),
                tpot_ns: Some(0.7e6),
                latency_ns: 20e6,
                tokens: 16,
            },
        ];
        let table = render_table(&ArrivalProcess::Burst, &samples, 0.5);
        let report = LoadgenReport {
            samples,
            errors: vec![],
            wall_s: 0.5,
            table: table.clone(),
            outcome: None,
        };
        let json = report.to_json();
        // Every tail cell the table prints is the JSON number rendered
        // through the same formatter — one computation, two views.
        for name in ["TTFT", "TPOT", "latency"] {
            let m = json.get("metrics").get(name);
            for key in ["p50_ns", "p95_ns", "p99_ns", "mean_ns"] {
                let v = m.get(key).as_f64().unwrap_or_else(|| panic!("{name}.{key} missing"));
                let cell = table::f(v / 1e6, 2);
                assert!(table.contains(&cell), "{name}.{key} = {cell} not in table:\n{table}");
            }
        }
        assert_eq!(json.get("achieved").get("requests").as_i64(), Some(2));
        assert_eq!(json.get("achieved").get("tokens").as_i64(), Some(32));
        assert_eq!(json.get("achieved").get("req_per_s").as_f64(), Some(4.0));
        // Same report serializes byte-identically (canonical writer).
        assert_eq!(report.to_json().pretty(), json.pretty());
        // Sample-less metrics are null, mirroring the placeholder rows.
        let bare = LoadgenReport {
            samples: vec![RequestSample {
                id: 0,
                ttft_ns: None,
                tpot_ns: None,
                latency_ns: 1e6,
                tokens: 0,
            }],
            errors: vec![],
            wall_s: 0.1,
            table: String::new(),
            outcome: None,
        };
        let j = bare.to_json();
        assert!(j.get("metrics").get("TTFT").is_null());
        assert!(j.get("metrics").get("TPOT").is_null());
        assert!(!j.get("metrics").get("latency").is_null());
    }

    #[test]
    fn dead_targets_are_runtime_errors_not_usage_errors() {
        // Bind-then-drop guarantees a dead port.
        let dead = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let cfg = LoadgenConfig {
            target: dead.to_string(),
            requests: 1,
            timeout: Duration::from_millis(500),
            ..LoadgenConfig::default()
        };
        let err = run(&cfg).unwrap_err();
        assert_eq!(err.exit_code(), 1, "{err}");
        assert!(err.to_string().contains("unreachable"), "{err}");
        let bad = LoadgenConfig { target: "not-an-addr".to_string(), ..LoadgenConfig::default() };
        assert_eq!(run(&bad).unwrap_err().exit_code(), 2);
    }
}
