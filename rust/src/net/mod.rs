//! Network serving front end (DESIGN.md §13): the std-only HTTP/SSE
//! ingress that turns the embeddable serving API into a system real
//! traffic can hit, plus the open-loop wall-clock load generator that
//! benchmarks it.
//!
//! * [`http`] — a minimal HTTP/1.1 layer over `std::net`: request-line /
//!   header / body parsing with hard size caps, and the
//!   `ChimeError` → status mapping that mirrors the CLI's exit-code
//!   philosophy (4xx ⇔ usage/exit 2, 5xx ⇔ runtime/exit 1).
//! * [`server`] — `chime serve --listen <addr>`: `POST /v1/submit`,
//!   `GET /v1/stream/<id>` (typed `ServeEvent`s as SSE),
//!   `GET /v1/metrics`, `POST /v1/finish`, `POST /v1/shutdown`, with
//!   graceful drain on SIGINT. The simulator stays virtual-time; only
//!   arrival timestamps come from the wire, and `--deterministic` pins
//!   them from the request body so a served run is bit-identical to the
//!   in-process batch path.
//! * [`loadgen`] — `chime loadgen --target <addr>`: fires N requests
//!   open-loop per an `ArrivalProcess` schedule from worker threads and
//!   renders the `results::tail` p50/p95/p99 table from wall-clock
//!   TTFT/TPOT/latency samples.
//!
//! No new dependencies: sockets are `std::net`, JSON is
//! `util::json::Json`, signals are a raw `signal(2)` declaration.

pub mod http;
pub mod loadgen;
pub mod server;

pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use server::{outcome_to_json, NetServer, ServeOpts, ServeSummary};
