//! `chime serve --listen`: the HTTP/SSE ingress over the streaming
//! serving protocol (DESIGN.md §13).
//!
//! One engine thread owns the [`Session`] and its `ServingSession` and
//! multiplexes three duties in a poll loop: accept new connections
//! (non-blocking listener), drain handler commands (mpsc), and tick the
//! engine. Each accepted connection gets a short-lived handler thread
//! that parses the request ([`super::http`]), sends one [`EngineCmd`]
//! with a reply channel, and writes the response; SSE subscribers hold
//! a frame receiver and stream until the request completes or the
//! client disconnects.
//!
//! ## Endpoints
//!
//! * `POST /v1/submit` — body `{"id": 0, "prompt_tokens": 8,
//!   "max_new_tokens": 16, "arrival_offset_s": 0.25}` (every field
//!   optional; `prompt` may spell the token ids explicitly). Replies
//!   with the assigned id and any immediate events.
//! * `GET /v1/stream/<id>` — Server-Sent Events: each engine event for
//!   that request as `event: <kind>\ndata: <json>\n\n`, replayed from
//!   the start for late subscribers, terminated by `event: done`.
//! * `GET /v1/metrics` — server config echo + live counters + the
//!   outcome once finished.
//! * `POST /v1/finish` — drain the engine and return the canonical
//!   [`ServeOutcome`] JSON ([`outcome_to_json`]); idempotent.
//! * `POST /v1/shutdown` — finish (if needed) and stop the listener.
//!
//! ## Determinism boundary
//!
//! The simulator under the server always runs virtual time; the wire
//! only contributes arrival timestamps. In live mode (default) a
//! request with no `arrival_offset_s` arrives at the wall-clock offset
//! since server start, and the engine ticks eagerly so SSE frames flow
//! as the virtual timeline advances. With [`ServeOpts::deterministic`]
//! the engine never ticks between submits — exactly the submit-all +
//! finish discipline of the batch `Session::serve` — so a fixed request
//! set with pinned `arrival_offset_s` values produces a bit-identical
//! [`ServeOutcome`] over the wire (the loopback golden test in
//! `tests/net_serving.rs`).

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::{Backend as _, ChimeError, ServeEvent, ServeRequest, ServingSession, Session};
use crate::coordinator::ServeOutcome;
use crate::obs::prom::PromText;
use crate::util::Json;

use super::http::{self, HttpCaps, HttpError, HttpRequest, HttpResponse};

/// Engine-loop poll period while idle (connections, commands, ticks).
const POLL: Duration = Duration::from_millis(2);

/// SSE terminator frame: the stream is complete, no more events follow.
const DONE_FRAME: &str = "event: done\ndata: {}\n\n";

/// Server behavior knobs (`chime serve --listen` flags).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Replay mode: never tick between submits, so the outcome is
    /// bit-identical to batch `Session::serve` of the same requests
    /// (tokens stream only at finish). Default: live eager ticking.
    pub deterministic: bool,
    /// `max_new_tokens` for submits that do not spell one.
    pub default_max_new_tokens: usize,
    /// Request body size cap, bytes.
    pub max_body_bytes: usize,
    /// Install a SIGINT/SIGTERM handler that drains gracefully (the CLI
    /// path sets this; library users and tests keep their own handlers).
    pub handle_signals: bool,
    /// Record the virtual-time trace of the served session and write it
    /// as Chrome trace-event JSON here when the server drains
    /// (`chime serve --listen ... --trace-out FILE`).
    pub trace_out: Option<std::path::PathBuf>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            deterministic: false,
            default_max_new_tokens: 64,
            max_body_bytes: HttpCaps::default().max_body,
            handle_signals: false,
            trace_out: None,
        }
    }
}

/// What the engine loop served, reported after shutdown.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeSummary {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub shed: u64,
    pub tokens: u64,
}

/// Canonical JSON for a [`ServeOutcome`] — the single serializer behind
/// `POST /v1/finish`, `GET /v1/metrics`, and the loopback golden test
/// (both sides of the bit-identity assertion go through this function).
pub fn outcome_to_json(out: &ServeOutcome) -> Json {
    let responses = out
        .responses
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("id", (r.id as i64).into()),
                ("tokens", r.tokens.len().into()),
                ("queue_ns", r.queue_ns.into()),
                ("ttft_ns", r.ttft_ns.into()),
                ("service_ns", r.service_ns.into()),
                ("energy_j", r.energy_j.into()),
            ])
        })
        .collect();
    let m = &out.metrics;
    Json::obj(vec![
        ("responses", Json::Arr(responses)),
        ("shed", Json::arr(out.shed.iter().map(|r| Json::from(r.id as i64)))),
        (
            "metrics",
            Json::obj(vec![
                ("completed", (m.completed as i64).into()),
                ("admitted", (m.admitted as i64).into()),
                ("rejected", (m.rejected as i64).into()),
                ("shed", (m.shed as i64).into()),
                ("tokens", (m.tokens as i64).into()),
                ("steals", (m.steals as i64).into()),
                ("stolen_bytes", (m.stolen_bytes as i64).into()),
                ("steal_delay_ns", m.steal_delay_ns.into()),
                ("energy_j", m.energy_j.into()),
                ("tokens_per_s", m.tokens_per_s().into()),
            ]),
        ),
    ])
}

/// Resolve `HOST:PORT` for `--listen`/`--target`. Malformed spellings
/// are usage errors (exit 2 on the CLI); a well-formed address that is
/// simply dead surfaces later as a Runtime (exit 1) connect/bind error.
pub fn resolve_addr(flag: &str, spec: &str) -> Result<SocketAddr, ChimeError> {
    if let Ok(addr) = spec.parse::<SocketAddr>() {
        return Ok(addr);
    }
    match spec.to_socket_addrs() {
        Ok(mut addrs) => addrs.next().ok_or_else(|| {
            ChimeError::Invalid(format!("--{flag} {spec:?} resolves to no address"))
        }),
        Err(e) => Err(ChimeError::Invalid(format!(
            "--{flag} expects HOST:PORT (e.g. 127.0.0.1:8080), got {spec:?}: {e}"
        ))),
    }
}

/// A running listener: spawned engine thread + bound address. Request a
/// stop with [`NetServer::request_shutdown`] (or `POST /v1/shutdown`,
/// or SIGINT under [`ServeOpts::handle_signals`]), then [`NetServer::join`].
pub struct NetServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    thread: Option<JoinHandle<Result<ServeSummary, ChimeError>>>,
}

impl NetServer {
    /// Bind `listen` (port 0 picks an ephemeral port) and start the
    /// engine loop. `make_session` runs on the engine thread because
    /// backends are not `Send`; a build failure is reported here
    /// synchronously. Returns once the server is accepting.
    pub fn spawn<F>(listen: &str, make_session: F, opts: ServeOpts) -> Result<NetServer, ChimeError>
    where
        F: FnOnce() -> Result<Session, ChimeError> + Send + 'static,
    {
        let addr = resolve_addr("listen", listen)?;
        let listener = TcpListener::bind(addr)
            .map_err(|e| ChimeError::Runtime(format!("binding {listen}: {e}")))?;
        let addr = listener
            .local_addr()
            .map_err(|e| ChimeError::Runtime(format!("reading bound address: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| ChimeError::Runtime(format!("non-blocking listener: {e}")))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let (ready_tx, ready_rx) = channel::<Result<(), ChimeError>>();
        let thread = std::thread::Builder::new()
            .name("chime-net-engine".to_string())
            .spawn(move || {
                let mut session = match make_session() {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.clone()));
                        return Err(e);
                    }
                };
                engine_loop(listener, &mut session, &opts, &flag)
            })
            .map_err(|e| ChimeError::Runtime(format!("spawning engine thread: {e}")))?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(NetServer { addr, shutdown, thread: Some(thread) }),
            Ok(Err(e)) => {
                let _ = thread.join();
                Err(e)
            }
            // Channel closed without a message: the thread died before
            // building; surface its error (or the panic).
            Err(_) => match thread.join() {
                Ok(r) => Err(r.err().unwrap_or_else(|| {
                    ChimeError::Runtime("engine thread exited before ready".to_string())
                })),
                Err(_) => Err(ChimeError::Runtime("engine thread panicked".to_string())),
            },
        }
    }

    /// The bound listen address (resolves `--listen 127.0.0.1:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the engine loop to drain and exit (observed within [`POLL`]).
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Wait for the engine loop to exit and return its summary.
    pub fn join(mut self) -> Result<ServeSummary, ChimeError> {
        let thread = self.thread.take().expect("join consumes the only handle");
        thread
            .join()
            .map_err(|_| ChimeError::Runtime("engine thread panicked".to_string()))?
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        // A dropped-without-join server must not pin the process: the
        // loop notices the flag at its next poll and exits.
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

/// A parsed `POST /v1/submit` body.
struct SubmitBody {
    id: Option<u64>,
    prompt: Option<Vec<i32>>,
    prompt_tokens: Option<usize>,
    max_new_tokens: Option<usize>,
    arrival_offset_s: Option<f64>,
    image_seed: Option<u64>,
}

const SUBMIT_FIELDS: [&str; 6] =
    ["id", "prompt", "prompt_tokens", "max_new_tokens", "arrival_offset_s", "image_seed"];

fn parse_submit(body: &[u8]) -> Result<SubmitBody, HttpError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| HttpError::new(400, "submit body is not UTF-8"))?;
    let json = Json::parse(text)
        .map_err(|e| HttpError::new(400, format!("submit body is not valid JSON: {e}")))?;
    let obj = json
        .as_obj()
        .ok_or_else(|| HttpError::new(400, "submit body must be a JSON object"))?;
    for key in obj.keys() {
        if !SUBMIT_FIELDS.contains(&key.as_str()) {
            return Err(HttpError::new(
                400,
                format!("unknown submit field {key:?} (accepted: {})", SUBMIT_FIELDS.join(", ")),
            ));
        }
    }
    let uint = |key: &str| -> Result<Option<u64>, HttpError> {
        match json.get(key) {
            Json::Null => Ok(None),
            v => v
                .as_i64()
                .and_then(|n| u64::try_from(n).ok())
                .map(Some)
                .ok_or_else(|| {
                    HttpError::new(400, format!("{key:?} must be a non-negative integer"))
                }),
        }
    };
    let prompt = match json.get("prompt") {
        Json::Null => None,
        v => {
            let arr = v.as_arr().ok_or_else(|| {
                HttpError::new(400, "\"prompt\" must be an array of token ids")
            })?;
            let mut tokens = Vec::with_capacity(arr.len());
            for t in arr {
                let id = t.as_i64().and_then(|n| i32::try_from(n).ok()).ok_or_else(|| {
                    HttpError::new(400, "\"prompt\" entries must be integer token ids")
                })?;
                tokens.push(id);
            }
            Some(tokens)
        }
    };
    let prompt_tokens = uint("prompt_tokens")?.map(|n| n as usize);
    if prompt.is_some() && prompt_tokens.is_some() {
        return Err(HttpError::new(
            400,
            "pass either \"prompt\" (explicit ids) or \"prompt_tokens\" (a length), not both",
        ));
    }
    let arrival_offset_s = match json.get("arrival_offset_s") {
        Json::Null => None,
        v => Some(v.as_f64().ok_or_else(|| {
            HttpError::new(400, "\"arrival_offset_s\" must be a number (seconds)")
        })?),
    };
    Ok(SubmitBody {
        id: uint("id")?,
        prompt,
        prompt_tokens,
        max_new_tokens: uint("max_new_tokens")?.map(|n| n as usize),
        arrival_offset_s,
        image_seed: uint("image_seed")?,
    })
}

/// One handler→engine command, with a reply channel.
enum EngineCmd {
    Submit(SubmitBody, Sender<Result<Json, HttpError>>),
    Subscribe(u64, Sender<Result<Receiver<String>, HttpError>>),
    Metrics(Sender<Json>),
    /// `GET /v1/metrics?format=prometheus`: the text exposition.
    MetricsProm(Sender<String>),
    /// Drain + finish (idempotent); replies with the canonical outcome
    /// JSON body. Shutdown sends this first, then sets the stop flag.
    Finish(Sender<Result<Vec<u8>, HttpError>>),
}

#[derive(Default)]
struct Counts {
    submitted: u64,
    admitted: u64,
    completed: u64,
    rejected: u64,
    shed: u64,
    tokens: u64,
    steals: u64,
}

/// Engine-thread state: the serving session plus request logs, SSE
/// subscriber channels, and live counters.
struct Engine<'s> {
    serving: Option<ServingSession<'s>>,
    deterministic: bool,
    default_tokens: usize,
    epoch: Instant,
    /// Config echo included in `/v1/metrics`.
    info: Json,
    /// Ids ever submitted (pre-guards the protocol's duplicate panic).
    ids: BTreeSet<u64>,
    next_auto_id: u64,
    /// Full per-request event history, for SSE replay to late (or
    /// deterministic-mode) subscribers.
    log: BTreeMap<u64, Vec<ServeEvent>>,
    /// Live SSE subscribers by request id.
    subs: BTreeMap<u64, Vec<Sender<String>>>,
    counts: Counts,
    /// The canonical outcome JSON once finished.
    outcome: Option<Json>,
    fatal: Option<ChimeError>,
}

impl<'s> Engine<'s> {
    fn handle(&mut self, cmd: EngineCmd) {
        match cmd {
            EngineCmd::Submit(body, reply) => {
                let result = self.submit(body);
                let _ = reply.send(result);
            }
            EngineCmd::Subscribe(id, reply) => {
                let result = self.subscribe(id);
                let _ = reply.send(result);
            }
            EngineCmd::Metrics(reply) => {
                let _ = reply.send(self.metrics());
            }
            EngineCmd::MetricsProm(reply) => {
                let _ = reply.send(self.prometheus());
            }
            EngineCmd::Finish(reply) => {
                let result = self.finish();
                let _ = reply.send(result);
            }
        }
    }

    fn submit(&mut self, body: SubmitBody) -> Result<Json, HttpError> {
        if let Some(e) = &self.fatal {
            return Err(HttpError::new(500, format!("serving engine failed: {e}")));
        }
        if self.outcome.is_some() {
            return Err(HttpError::new(
                400,
                "session already finished (POST /v1/finish); restart the server to serve more",
            ));
        }
        let id = body.id.unwrap_or(self.next_auto_id);
        if !self.ids.insert(id) {
            return Err(HttpError::new(400, format!("duplicate request id {id}")));
        }
        self.next_auto_id = self.next_auto_id.max(id + 1);
        let prompt = match (body.prompt, body.prompt_tokens) {
            (Some(tokens), _) => tokens,
            (None, Some(n)) => vec![0; n],
            (None, None) => Vec::new(),
        };
        // Live mode stamps wire time; deterministic mode pins t=0 so an
        // offset-less replay matches a burst. Non-finite offsets flow
        // through: the engine sheds them (its malformed-arrival path).
        let arrival_ns = match body.arrival_offset_s {
            Some(s) => s * 1e9,
            None if self.deterministic => 0.0,
            None => self.epoch.elapsed().as_nanos() as f64,
        };
        let req = ServeRequest {
            id,
            prompt,
            image_seed: body.image_seed.unwrap_or(id),
            max_new_tokens: body.max_new_tokens.unwrap_or(self.default_tokens),
            arrival_ns,
        };
        self.counts.submitted += 1;
        let serving = self.serving.as_mut().expect("present until finished");
        let events = serving.submit(req);
        let immediate: Vec<Json> = events.iter().map(|e| e.to_json()).collect();
        self.publish(events);
        Ok(Json::obj(vec![
            ("id", (id as i64).into()),
            ("status", "submitted".into()),
            ("events", Json::Arr(immediate)),
        ]))
    }

    /// Advance the engine one event in live mode. Returns whether any
    /// work happened (idle loops back off to [`POLL`]).
    fn tick_once(&mut self) -> bool {
        if self.deterministic || self.fatal.is_some() {
            return false;
        }
        let Some(serving) = self.serving.as_mut() else { return false };
        match serving.tick() {
            Ok(events) if events.is_empty() => false,
            Ok(events) => {
                self.publish(events);
                true
            }
            Err(e) => {
                self.fatal = Some(e);
                false
            }
        }
    }

    /// Record events in the per-request log, bump counters, and fan
    /// frames out to live SSE subscribers.
    fn publish(&mut self, events: Vec<ServeEvent>) {
        for ev in events {
            let id = ev.id();
            match &ev {
                ServeEvent::Admitted { .. } => self.counts.admitted += 1,
                ServeEvent::Rejected { .. } => self.counts.rejected += 1,
                ServeEvent::Shed { .. } => self.counts.shed += 1,
                ServeEvent::Stolen { .. } => self.counts.steals += 1,
                ServeEvent::Completed { response, .. } => {
                    self.counts.completed += 1;
                    self.counts.tokens += response.tokens.len() as u64;
                }
                ServeEvent::FirstToken { .. } | ServeEvent::Token { .. } => {}
            }
            let terminal = matches!(
                ev,
                ServeEvent::Completed { .. } | ServeEvent::Rejected { .. } | ServeEvent::Shed { .. }
            );
            if let Some(senders) = self.subs.get_mut(&id) {
                let frame = sse_frame(&ev);
                // A send error means the subscriber hung up; forget it.
                senders.retain(|tx| tx.send(frame.clone()).is_ok());
                if terminal {
                    for tx in senders.iter() {
                        let _ = tx.send(DONE_FRAME.to_string());
                    }
                    self.subs.remove(&id);
                }
            }
            self.log.entry(id).or_default().push(ev);
        }
    }

    fn subscribe(&mut self, id: u64) -> Result<Receiver<String>, HttpError> {
        if !self.ids.contains(&id) {
            return Err(HttpError::new(
                404,
                format!("unknown request id {id} (POST /v1/submit first)"),
            ));
        }
        let (tx, rx) = channel();
        let mut terminal = false;
        if let Some(history) = self.log.get(&id) {
            for ev in history {
                let _ = tx.send(sse_frame(ev));
                terminal |= matches!(
                    ev,
                    ServeEvent::Completed { .. }
                        | ServeEvent::Rejected { .. }
                        | ServeEvent::Shed { .. }
                );
            }
        }
        if terminal {
            // Replay-only: the done frame ends the stream; dropping tx
            // closes the channel after the buffered frames drain.
            let _ = tx.send(DONE_FRAME.to_string());
        } else {
            self.subs.entry(id).or_default().push(tx);
        }
        Ok(rx)
    }

    fn state(&self) -> &'static str {
        if self.fatal.is_some() {
            "failed"
        } else if self.outcome.is_some() {
            "finished"
        } else {
            "serving"
        }
    }

    fn metrics(&self) -> Json {
        let state = self.state();
        let c = &self.counts;
        let mut pairs = vec![
            ("server", self.info.clone()),
            ("state", state.into()),
            (
                "counts",
                Json::obj(vec![
                    ("submitted", (c.submitted as i64).into()),
                    ("admitted", (c.admitted as i64).into()),
                    ("completed", (c.completed as i64).into()),
                    ("rejected", (c.rejected as i64).into()),
                    ("shed", (c.shed as i64).into()),
                    ("tokens", (c.tokens as i64).into()),
                    ("steals", (c.steals as i64).into()),
                ]),
            ),
            ("outcome", self.outcome.clone().unwrap_or(Json::Null)),
        ];
        if let Some(e) = &self.fatal {
            pairs.push(("error", e.to_string().into()));
        }
        Json::obj(pairs)
    }

    /// Prometheus text exposition of the same counters `/v1/metrics`
    /// serves as JSON, plus live engine telemetry (fabric links, memory
    /// stall causes) while the session is open. The request counters are
    /// the ones the finish outcome reconciles against.
    fn prometheus(&self) -> String {
        let mut p = PromText::new();
        let c = &self.counts;
        p.counter(
            "chime_requests_submitted_total",
            "Requests received over the wire.",
            c.submitted as f64,
        );
        p.counter(
            "chime_requests_admitted_total",
            "Requests admitted by the serving engine.",
            c.admitted as f64,
        );
        p.counter(
            "chime_requests_completed_total",
            "Requests that ran to completion.",
            c.completed as f64,
        );
        p.counter(
            "chime_requests_rejected_total",
            "Requests rejected at admission.",
            c.rejected as f64,
        );
        p.counter("chime_requests_shed_total", "Requests shed under load.", c.shed as f64);
        p.counter(
            "chime_tokens_total",
            "Tokens generated across completed requests.",
            c.tokens as f64,
        );
        p.counter("chime_steals_total", "Cross-package work steals.", c.steals as f64);
        p.header("chime_server_state", "Engine state (1 on the active state).", "gauge");
        let state = self.state();
        for s in ["serving", "finished", "failed"] {
            p.sample("chime_server_state", &[("state", s)], if s == state { 1.0 } else { 0.0 });
        }
        if let Some(t) = self.serving.as_ref().and_then(|s| s.telemetry()) {
            p.header(
                "chime_fabric_link_bytes_total",
                "Payload bytes that crossed each fabric link.",
                "counter",
            );
            for l in &t.links {
                p.sample(
                    "chime_fabric_link_bytes_total",
                    &[("link", l.link.as_str())],
                    l.bytes as f64,
                );
            }
            p.header(
                "chime_fabric_link_transfers_total",
                "Transfers that crossed each fabric link.",
                "counter",
            );
            for l in &t.links {
                p.sample(
                    "chime_fabric_link_transfers_total",
                    &[("link", l.link.as_str())],
                    l.transfers as f64,
                );
            }
            p.header(
                "chime_fabric_link_busy_seconds_total",
                "Wire-serialization time per fabric link.",
                "counter",
            );
            for l in &t.links {
                p.sample(
                    "chime_fabric_link_busy_seconds_total",
                    &[("link", l.link.as_str())],
                    l.busy_ns / 1e9,
                );
            }
            p.header(
                "chime_fabric_link_peak_gbps",
                "Peak sustained bandwidth per link over any tick window.",
                "gauge",
            );
            for l in &t.links {
                p.sample(
                    "chime_fabric_link_peak_gbps",
                    &[("link", l.link.as_str())],
                    l.peak_gbps,
                );
            }
            let st = &t.stalls;
            p.header("chime_dram_stall_seconds_total", "DRAM stall time by cause.", "counter");
            p.sample(
                "chime_dram_stall_seconds_total",
                &[("cause", "precharge")],
                st.dram_precharge_ns / 1e9,
            );
            p.sample("chime_dram_stall_seconds_total", &[("cause", "tfaw")], st.dram_faw_ns / 1e9);
            p.sample(
                "chime_dram_stall_seconds_total",
                &[("cause", "refresh")],
                st.dram_refresh_ns / 1e9,
            );
            p.counter(
                "chime_dram_activations_total",
                "DRAM whole-row activations issued.",
                st.dram_activations as f64,
            );
            p.counter(
                "chime_dram_row_conflicts_total",
                "DRAM row conflicts (precharge before activate).",
                st.dram_row_conflicts as f64,
            );
            p.header("chime_rram_stall_seconds_total", "RRAM stall time by cause.", "counter");
            p.sample(
                "chime_rram_stall_seconds_total",
                &[("cause", "pulse")],
                st.rram_pulse_ns / 1e9,
            );
            p.sample(
                "chime_rram_stall_seconds_total",
                &[("cause", "verify")],
                st.rram_verify_ns / 1e9,
            );
            p.sample(
                "chime_rram_stall_seconds_total",
                &[("cause", "remap")],
                st.rram_remap_ns / 1e9,
            );
            p.counter(
                "chime_rram_remaps_total",
                "RRAM wear remaps performed.",
                st.rram_remaps as f64,
            );
        }
        p.render()
    }

    /// Drain (publishing the drained events) and finish. Idempotent:
    /// repeated calls return the cached outcome body byte-for-byte.
    fn finish(&mut self) -> Result<Vec<u8>, HttpError> {
        if let Some(e) = &self.fatal {
            return Err(HttpError::new(500, format!("serving engine failed: {e}")));
        }
        if let Some(done) = &self.outcome {
            return Ok(done.pretty().into_bytes());
        }
        let mut serving = self.serving.take().expect("present until finished");
        match serving.drain() {
            Ok(events) => self.publish(events),
            Err(e) => {
                self.fatal = Some(e.clone());
                return Err(HttpError::new(500, format!("draining serving engine: {e}")));
            }
        }
        match serving.finish() {
            Ok(out) => {
                let json = outcome_to_json(&out);
                let body = json.pretty().into_bytes();
                self.outcome = Some(json);
                Ok(body)
            }
            Err(e) => {
                self.fatal = Some(e.clone());
                Err(HttpError::new(500, format!("finishing serving engine: {e}")))
            }
        }
    }

    fn summary(&self) -> ServeSummary {
        let c = &self.counts;
        ServeSummary {
            submitted: c.submitted,
            completed: c.completed,
            rejected: c.rejected,
            shed: c.shed,
            tokens: c.tokens,
        }
    }
}

fn sse_frame(ev: &ServeEvent) -> String {
    format!("event: {}\ndata: {}\n\n", ev.kind(), ev.to_json().compact())
}

/// The `format` query parameter of a request target, if any.
fn format_param(target: &str) -> Option<&str> {
    let (_, query) = target.split_once('?')?;
    query.split('&').find_map(|kv| kv.strip_prefix("format="))
}

/// Config echo in `/v1/metrics`, so a loadgen can report what it hit.
fn server_info(session: &Session, opts: &ServeOpts) -> Json {
    Json::obj(vec![
        ("protocol", "chime-serve/1".into()),
        ("backend", session.backend_name().into()),
        ("model", session.model().name.as_str().into()),
        ("memory", session.memory_fidelity().name().into()),
        ("topology", session.topology().name().into()),
        ("threads", (session.threads() as i64).into()),
        ("deterministic", opts.deterministic.into()),
        ("tracing", opts.trace_out.is_some().into()),
    ])
}

/// The engine loop: accept + dispatch + tick until a shutdown request
/// (flag, `/v1/shutdown`, or SIGINT under `handle_signals`), then drain
/// gracefully and report the summary.
fn engine_loop(
    listener: TcpListener,
    session: &mut Session,
    opts: &ServeOpts,
    shutdown: &Arc<AtomicBool>,
) -> Result<ServeSummary, ChimeError> {
    if opts.handle_signals {
        signals::install();
    }
    if opts.trace_out.is_some() {
        // Before open_serving, so the session starts with a fresh trace.
        session.backend_mut().set_tracing(true);
    }
    let info = server_info(session, opts);
    let caps = HttpCaps { max_body: opts.max_body_bytes, ..HttpCaps::default() };
    let mut engine = Engine {
        serving: Some(session.open_serving()?),
        deterministic: opts.deterministic,
        default_tokens: opts.default_max_new_tokens,
        epoch: Instant::now(),
        info,
        ids: BTreeSet::new(),
        next_auto_id: 0,
        log: BTreeMap::new(),
        subs: BTreeMap::new(),
        counts: Counts::default(),
        outcome: None,
        fatal: None,
    };
    let (cmd_tx, cmd_rx) = channel::<EngineCmd>();
    let summary = loop {
        // New connections → handler threads (short-lived; SSE handlers
        // live for the stream).
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let tx = cmd_tx.clone();
                    let caps = caps.clone();
                    let stop = Arc::clone(shutdown);
                    std::thread::spawn(move || handle_connection(stream, &tx, &caps, &stop));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                // Transient accept failure (e.g. aborted handshake):
                // back off one poll period and keep serving.
                Err(_) => {
                    std::thread::sleep(POLL);
                    break;
                }
            }
        }
        let mut worked = false;
        while let Ok(cmd) = cmd_rx.try_recv() {
            engine.handle(cmd);
            worked = true;
        }
        worked |= engine.tick_once();
        if shutdown.load(Ordering::SeqCst) || signals::requested() {
            // Graceful drain: every in-flight request completes (into
            // the log/metrics) before the listener goes away.
            let _ = engine.finish();
            break engine.summary();
        }
        if !worked {
            std::thread::sleep(POLL);
        }
    };
    drop(engine);
    if let Some(path) = &opts.trace_out {
        let tracer = session.backend_mut().take_trace().unwrap_or_default();
        std::fs::write(path, format!("{}\n", tracer.chrome_trace().pretty()))
            .map_err(|e| ChimeError::Runtime(format!("writing trace {}: {e}", path.display())))?;
    }
    Ok(summary)
}

/// What the router decided to do with one parsed request.
enum Routed {
    Respond(HttpResponse),
    Stream(Receiver<String>),
    /// Respond, then raise the shutdown flag (after the reply is on the
    /// wire, so the client sees a clean 200).
    Shutdown(HttpResponse),
}

fn dispatch(req: &HttpRequest, tx: &Sender<EngineCmd>) -> Result<Routed, HttpError> {
    // Engine gone ⇒ the server is between drain and exit.
    let closed = || HttpError::new(503, "server is shutting down");
    let path = req.path();
    match (req.method.as_str(), path) {
        ("POST", "/v1/submit") => {
            let body = parse_submit(&req.body)?;
            let (reply_tx, reply_rx) = channel();
            tx.send(EngineCmd::Submit(body, reply_tx)).map_err(|_| closed())?;
            let json = reply_rx.recv().map_err(|_| closed())??;
            Ok(Routed::Respond(HttpResponse::json(200, &json)))
        }
        ("GET", p) if p.starts_with("/v1/stream/") => {
            let raw = &p["/v1/stream/".len()..];
            let id: u64 = raw.parse().map_err(|_| {
                HttpError::new(400, format!("stream id must be a request id, got {raw:?}"))
            })?;
            let (reply_tx, reply_rx) = channel();
            tx.send(EngineCmd::Subscribe(id, reply_tx)).map_err(|_| closed())?;
            let frames = reply_rx.recv().map_err(|_| closed())??;
            Ok(Routed::Stream(frames))
        }
        ("GET", "/v1/metrics") => match format_param(&req.target) {
            Some("prometheus") => {
                let (reply_tx, reply_rx) = channel();
                tx.send(EngineCmd::MetricsProm(reply_tx)).map_err(|_| closed())?;
                let text = reply_rx.recv().map_err(|_| closed())?;
                Ok(Routed::Respond(HttpResponse {
                    status: 200,
                    content_type: "text/plain; version=0.0.4",
                    body: text.into_bytes(),
                    allow: None,
                }))
            }
            Some("json") | None => {
                let (reply_tx, reply_rx) = channel();
                tx.send(EngineCmd::Metrics(reply_tx)).map_err(|_| closed())?;
                let json = reply_rx.recv().map_err(|_| closed())?;
                Ok(Routed::Respond(HttpResponse::json(200, &json)))
            }
            Some(other) => Err(HttpError::new(
                400,
                format!("unknown metrics format {other:?} (accepted: json, prometheus)"),
            )),
        },
        ("POST", "/v1/finish") | ("POST", "/v1/shutdown") => {
            let (reply_tx, reply_rx) = channel();
            tx.send(EngineCmd::Finish(reply_tx)).map_err(|_| closed())?;
            let body = reply_rx.recv().map_err(|_| closed())??;
            let resp = HttpResponse {
                status: 200,
                content_type: "application/json",
                body,
                allow: None,
            };
            if path == "/v1/shutdown" {
                Ok(Routed::Shutdown(resp))
            } else {
                Ok(Routed::Respond(resp))
            }
        }
        // Known routes with the wrong method get a 405 + Allow.
        (_, "/v1/submit") | (_, "/v1/finish") | (_, "/v1/shutdown") => Err(HttpError::new(
            405,
            format!("{path} accepts POST, not {}", req.method),
        )),
        (_, "/v1/metrics") => {
            Err(HttpError::new(405, format!("{path} accepts GET, not {}", req.method)))
        }
        (_, p) if p.starts_with("/v1/stream/") => {
            Err(HttpError::new(405, format!("{path} accepts GET, not {}", req.method)))
        }
        _ => Err(HttpError::new(
            404,
            format!(
                "no route {path:?} (endpoints: POST /v1/submit, GET /v1/stream/<id>, \
                 GET /v1/metrics, POST /v1/finish, POST /v1/shutdown)"
            ),
        )),
    }
}

fn handle_connection(
    stream: TcpStream,
    tx: &Sender<EngineCmd>,
    caps: &HttpCaps,
    shutdown: &AtomicBool,
) {
    // A peer that opens a connection and goes silent would otherwise pin
    // this handler forever.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let Ok(reader_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(reader_half);
    let mut writer = stream;
    let routed = http::read_request(&mut reader, caps)
        .and_then(|req| allowed_methods_guard(&req).and_then(|()| dispatch(&req, tx)));
    match routed {
        Ok(Routed::Respond(resp)) => {
            let _ = writer.write_all(&resp.to_bytes());
        }
        Ok(Routed::Shutdown(resp)) => {
            let _ = writer.write_all(&resp.to_bytes());
            let _ = writer.flush();
            shutdown.store(true, Ordering::SeqCst);
        }
        Ok(Routed::Stream(frames)) => {
            if writer.write_all(http::SSE_PREAMBLE.as_bytes()).is_err() {
                return;
            }
            let _ = writer.flush();
            // Blocks between events; ends when the engine sends `done`
            // and drops the sender, or when the client hangs up (the
            // write fails, we drop the receiver, the engine forgets us
            // on its next send).
            for frame in frames {
                if writer.write_all(frame.as_bytes()).and_then(|()| writer.flush()).is_err() {
                    break;
                }
            }
        }
        Err(err) => {
            let mut resp = HttpResponse::error(&err);
            if err.status == 405 {
                resp.allow = Some(if err.message.contains("accepts GET") { "GET" } else { "POST" });
            }
            let _ = writer.write_all(&resp.to_bytes());
        }
    }
    let _ = writer.flush();
}

/// Methods the server understands at all; anything else is 405 before
/// routing (e.g. `BREW /v1/metrics`).
fn allowed_methods_guard(req: &HttpRequest) -> Result<(), HttpError> {
    match req.method.as_str() {
        "GET" | "POST" | "HEAD" | "PUT" | "DELETE" => Ok(()),
        other => Err(HttpError::new(405, format!("method {other:?} is not supported"))),
    }
}

/// SIGINT/SIGTERM → graceful drain, without a signal-handling crate:
/// libc is always linked, so declare `signal(2)` directly and flip an
/// atomic the engine loop polls (nothing async-signal-unsafe runs in
/// the handler).
#[cfg(unix)]
mod signals {
    use std::sync::atomic::{AtomicBool, Ordering};

    static REQUESTED: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_sig: i32) {
        REQUESTED.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    pub fn requested() -> bool {
        REQUESTED.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod signals {
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_bodies_parse_and_validate() {
        let ok = parse_submit(
            br#"{"id": 3, "prompt_tokens": 8, "max_new_tokens": 16, "arrival_offset_s": 0.5}"#,
        )
        .unwrap();
        assert_eq!(ok.id, Some(3));
        assert_eq!(ok.prompt_tokens, Some(8));
        assert_eq!(ok.max_new_tokens, Some(16));
        assert_eq!(ok.arrival_offset_s, Some(0.5));
        assert!(ok.prompt.is_none() && ok.image_seed.is_none());
        let explicit = parse_submit(br#"{"prompt": [5, 6, 7]}"#).unwrap();
        assert_eq!(explicit.prompt, Some(vec![5, 6, 7]));
        // Empty object: everything defaulted downstream.
        assert!(parse_submit(b"{}").unwrap().id.is_none());
        for bad in [
            &b"not json"[..],
            br#"[1, 2]"#,
            br#"{"id": -1}"#,
            br#"{"id": 1.5}"#,
            br#"{"prompt": "hi"}"#,
            br#"{"prompt": [1], "prompt_tokens": 4}"#,
            br#"{"arrival_offset_s": "soon"}"#,
            br#"{"max_new_tokenz": 4}"#,
        ] {
            let err = parse_submit(bad).unwrap_err();
            assert_eq!(err.status, 400, "{:?}", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn listen_addrs_resolve_or_reject_as_usage_errors() {
        let ok = resolve_addr("listen", "127.0.0.1:0").unwrap();
        assert_eq!(ok.port(), 0);
        for bad in ["", "not-an-addr", "127.0.0.1", "127.0.0.1:notaport", ":::::"] {
            let err = resolve_addr("listen", bad).unwrap_err();
            assert_eq!(err.exit_code(), 2, "{bad:?}: {err}");
        }
    }

    #[test]
    fn outcome_serializer_covers_every_metric_field() {
        let out = ServeOutcome {
            responses: vec![],
            shed: vec![],
            metrics: Default::default(),
        };
        let json = outcome_to_json(&out);
        for key in
            ["completed", "admitted", "rejected", "shed", "tokens", "steals", "stolen_bytes",
             "steal_delay_ns", "energy_j", "tokens_per_s"]
        {
            assert!(!json.get("metrics").get(key).is_null(), "missing metrics.{key}");
        }
        assert_eq!(json.get("responses").as_arr().unwrap().len(), 0);
    }
}
