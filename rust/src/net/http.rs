//! Minimal HTTP/1.1 layer for the network serving front end.
//!
//! std-only (DESIGN.md §13): `std::net` sockets plus hand-rolled
//! request parsing — no hyper, no tokio. The layer is deliberately
//! narrow: exactly what the `/v1/*` endpoints of [`crate::net::server`]
//! need, with hard size caps so a hostile peer cannot balloon memory,
//! and every malformed input mapped to a 4xx the same way the CLI maps
//! usage mistakes to exit 2 ([`status_for`] is the HTTP spelling of
//! `ChimeError::exit_code`: 4xx ↔ exit 2, 5xx ↔ exit 1).
//!
//! Unsupported-by-design: chunked transfer encoding (clients must send
//! `Content-Length`), HTTP/2, keep-alive (every response closes the
//! connection — the loadgen opens one connection per call, and SSE
//! streams are one long-lived response by construction).

use std::io::{BufRead, Read};

use crate::api::ChimeError;
use crate::util::Json;

/// Size caps applied while reading one request. Defaults are generous
/// for the JSON bodies the protocol uses and small enough that a
/// garbage peer cannot make the server buffer unbounded input.
#[derive(Debug, Clone)]
pub struct HttpCaps {
    /// Longest accepted request/header line, bytes (without CRLF).
    pub max_line: usize,
    /// Most header lines accepted per request.
    pub max_headers: usize,
    /// Largest accepted declared body, bytes.
    pub max_body: usize,
}

impl Default for HttpCaps {
    fn default() -> Self {
        HttpCaps { max_line: 8 * 1024, max_headers: 64, max_body: 1024 * 1024 }
    }
}

/// One parsed request: method + target + lowercased headers + raw body.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    /// Request target as sent (path + optional query string).
    pub target: String,
    /// Headers in arrival order, names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First value of a header, by lowercase name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// The target's path component (query string stripped).
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or("")
    }
}

/// A protocol-level failure while reading or routing a request: an HTTP
/// status plus a one-line message the server echoes back as JSON.
#[derive(Debug, Clone)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    pub fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError { status, message: message.into() }
    }

    /// Lift a typed [`ChimeError`] onto the wire (see [`status_for`]).
    pub fn from_chime(e: &ChimeError) -> HttpError {
        HttpError { status: status_for(e), message: e.to_string() }
    }
}

/// HTTP status for a [`ChimeError`], mirroring the exit-code taxonomy:
/// caller-fixable mistakes (exit 2) become 4xx, environment/runtime
/// failures (exit 1) become 5xx.
pub fn status_for(e: &ChimeError) -> u16 {
    match e {
        ChimeError::Unknown { .. } => 404,
        ChimeError::Unsupported { .. } => 405,
        ChimeError::Config(_) | ChimeError::UnknownFlag { .. } | ChimeError::Invalid(_) => 400,
        ChimeError::BackendUnavailable { .. } => 503,
        ChimeError::Runtime(_) => 500,
    }
}

/// Reason phrase for the status line.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Response header block opening an SSE stream (the one response shape
/// that is not a fixed-length [`HttpResponse`]).
pub const SSE_PREAMBLE: &str = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
                                Cache-Control: no-cache\r\nConnection: close\r\n\r\n";

/// One fixed-length response (the SSE stream writes [`SSE_PREAMBLE`] +
/// frames instead).
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// `Allow:` header value for 405 responses.
    pub allow: Option<&'static str>,
}

impl HttpResponse {
    /// A JSON response (the body is the value's pretty serialization, so
    /// shapes like the finish outcome stay bit-identical to the
    /// library-side serializer).
    pub fn json(status: u16, value: &Json) -> HttpResponse {
        HttpResponse {
            status,
            content_type: "application/json",
            body: value.pretty().into_bytes(),
            allow: None,
        }
    }

    /// The canonical error body: `{"error": <message>, "status": N}`.
    pub fn error(err: &HttpError) -> HttpResponse {
        HttpResponse::json(
            err.status,
            &Json::obj(vec![
                ("error", err.message.as_str().into()),
                ("status", (err.status as i64).into()),
            ]),
        )
    }

    /// Serialize status line + headers + body.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len()
        );
        if let Some(allow) = self.allow {
            head.push_str(&format!("Allow: {allow}\r\n"));
        }
        head.push_str("\r\n");
        let mut out = head.into_bytes();
        out.extend_from_slice(&self.body);
        out
    }
}

/// Read one CRLF/LF-terminated line, rejecting lines over `cap` bytes
/// (the cap is what makes a garbage peer cheap: we never buffer more
/// than `cap + 2` bytes looking for the terminator).
fn read_line_capped<R: BufRead>(r: &mut R, cap: usize) -> Result<String, HttpError> {
    let mut buf = Vec::new();
    let n = (&mut *r)
        .take(cap as u64 + 2)
        .read_until(b'\n', &mut buf)
        .map_err(|e| HttpError::new(400, format!("reading request: {e}")))?;
    if n == 0 {
        return Err(HttpError::new(400, "connection closed before a full request"));
    }
    if !buf.ends_with(b"\n") {
        return Err(HttpError::new(
            400,
            format!("request line exceeds {cap} bytes or is truncated"),
        ));
    }
    while matches!(buf.last(), Some(b'\n') | Some(b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf).map_err(|_| HttpError::new(400, "request contains non-UTF-8 bytes"))
}

/// Read and validate one request under `caps`. POST/PUT bodies require
/// `Content-Length` (411 without one, 413 over the cap); chunked
/// transfer encoding is rejected up front.
pub fn read_request<R: BufRead>(r: &mut R, caps: &HttpCaps) -> Result<HttpRequest, HttpError> {
    let line = read_line_capped(r, caps.max_line)?;
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) => (m.to_string(), t.to_string(), v),
        _ => {
            return Err(HttpError::new(
                400,
                format!("malformed request line {line:?} (want \"METHOD /path HTTP/1.1\")"),
            ))
        }
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(400, format!("unsupported protocol {version:?}")));
    }
    if !method.chars().all(|c| c.is_ascii_uppercase()) {
        return Err(HttpError::new(400, format!("malformed method {method:?}")));
    }
    let mut headers = Vec::new();
    loop {
        let line = read_line_capped(r, caps.max_line)?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= caps.max_headers {
            return Err(HttpError::new(
                400,
                format!("more than {} header lines", caps.max_headers),
            ));
        }
        let (name, value) = line.split_once(':').ok_or_else(|| {
            HttpError::new(400, format!("malformed header line {line:?}"))
        })?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let req = HttpRequest { method, target, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        return Err(HttpError::new(
            400,
            "chunked transfer encoding is not supported; send Content-Length",
        ));
    }
    let declared = match req.header("content-length") {
        None => None,
        Some(v) => Some(v.parse::<usize>().map_err(|_| {
            HttpError::new(400, format!("malformed Content-Length {v:?}"))
        })?),
    };
    let body = match declared {
        None if matches!(req.method.as_str(), "POST" | "PUT") => {
            return Err(HttpError::new(
                411,
                format!("{} {} requires Content-Length", req.method, req.path()),
            ))
        }
        None | Some(0) => Vec::new(),
        Some(n) if n > caps.max_body => {
            return Err(HttpError::new(
                413,
                format!("declared body of {n} bytes exceeds the {}-byte cap", caps.max_body),
            ))
        }
        Some(n) => {
            let mut body = vec![0u8; n];
            r.read_exact(&mut body)
                .map_err(|_| HttpError::new(400, "connection closed before the declared body"))?;
            body
        }
    };
    Ok(HttpRequest { body, ..req })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str) -> Result<HttpRequest, HttpError> {
        read_request(&mut BufReader::new(raw.as_bytes()), &HttpCaps::default())
    }

    #[test]
    fn parses_a_post_with_body_and_query_target() {
        let req = parse(
            "POST /v1/submit?x=1 HTTP/1.1\r\nHost: h\r\nContent-Length: 7\r\n\r\n{\"a\":1}",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.target, "/v1/submit?x=1");
        assert_eq!(req.path(), "/v1/submit");
        assert_eq!(req.header("host"), Some("h"));
        assert_eq!(req.body, b"{\"a\":1}");
        // Bare-LF line endings are tolerated too.
        let lf = parse("GET /v1/metrics HTTP/1.1\nHost: h\n\n").unwrap();
        assert_eq!(lf.method, "GET");
        assert!(lf.body.is_empty());
    }

    #[test]
    fn malformed_inputs_map_to_400_411_413() {
        for (raw, want) in [
            ("TOTAL GARBAGE\r\n\r\n", 400),                                  // no version
            ("GET /x HTTP/2.0\r\n\r\n", 400),                               // wrong protocol
            ("get /x HTTP/1.1\r\n\r\n", 400),                               // lowercase method
            ("GET /x HTTP/1.1\r\nbroken header\r\n\r\n", 400),              // no colon
            ("POST /x HTTP/1.1\r\n\r\n", 411),                              // no length
            ("POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n", 400),      // bad length
            ("POST /x HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n", 413),  // over cap
            ("POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 400),
            ("POST /x HTTP/1.1\r\nContent-Length: 9\r\n\r\nshort", 400),    // truncated body
            ("", 400),                                                      // closed early
        ] {
            let err = parse(raw).unwrap_err();
            assert_eq!(err.status, want, "{raw:?}: {}", err.message);
        }
    }

    #[test]
    fn line_and_header_caps_bound_hostile_input() {
        let caps = HttpCaps { max_line: 64, max_headers: 2, max_body: 64 };
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(200));
        let err = read_request(&mut BufReader::new(long.as_bytes()), &caps).unwrap_err();
        assert_eq!(err.status, 400);
        let many = "GET / HTTP/1.1\r\nA: 1\r\nB: 2\r\nC: 3\r\n\r\n";
        let err = read_request(&mut BufReader::new(many.as_bytes()), &caps).unwrap_err();
        assert_eq!(err.status, 400);
        assert!(err.message.contains("header lines"), "{}", err.message);
    }

    #[test]
    fn chime_errors_map_like_exit_codes() {
        // 4xx ↔ exit 2 (caller-fixable), 5xx ↔ exit 1 (environment).
        let cases: Vec<(ChimeError, u16)> = vec![
            (ChimeError::Unknown { what: "route", name: "x".into(), hint: None }, 404),
            (ChimeError::Unsupported { backend: "sim", what: "x" }, 405),
            (ChimeError::Invalid("x".into()), 400),
            (ChimeError::Config("x".into()), 400),
            (ChimeError::UnknownFlag { flag: "x".into(), suggestion: None }, 400),
            (ChimeError::BackendUnavailable { backend: "functional", reason: "x".into() }, 503),
            (ChimeError::Runtime("x".into()), 500),
        ];
        for (e, status) in cases {
            assert_eq!(status_for(&e), status, "{e}");
            let wire_is_usage = status < 500;
            assert_eq!(wire_is_usage, e.exit_code() == 2, "{e}");
            let resp = HttpResponse::error(&HttpError::from_chime(&e));
            assert_eq!(resp.status, status);
        }
    }

    #[test]
    fn responses_serialize_with_length_and_close() {
        let resp = HttpResponse::json(200, &Json::obj(vec![("ok", true.into())]));
        let text = String::from_utf8(resp.to_bytes()).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: "), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.ends_with('}'), "{text}");
        let with_allow = HttpResponse {
            allow: Some("POST"),
            ..HttpResponse::error(&HttpError::new(405, "nope"))
        };
        let text = String::from_utf8(with_allow.to_bytes()).unwrap();
        assert!(text.contains("Allow: POST\r\n"), "{text}");
    }
}
