//! The [`Backend`] trait: one polymorphic surface over every execution
//! path — the CHIME simulator (solo, DRAM-only ablation, multi-package
//! sharded), the functional PJRT runtime, and the Jetson/FACIL baseline
//! models. A backend answers two questions: *what does one inference
//! cost* ([`Backend::infer`]) and *what does a request stream look like
//! end to end* ([`Backend::open_serving`]).
//!
//! Serving is event-driven (DESIGN.md §10): every backend opens a
//! streaming [`ServingSession`] — submit requests at any virtual time,
//! tick for typed [`crate::coordinator::ServeEvent`]s, finish for the
//! outcome. The batch [`Backend::serve`] is a *provided* method: a thin
//! submit-everything-then-drain wrapper over the session, identical for
//! every backend by construction.

use std::collections::BTreeMap;

use crate::baselines::{facil, jetson, BaselineStats};
use crate::config::{ChimeConfig, FacilSpec, JetsonSpec, MllmConfig, WorkloadConfig};
use crate::coordinator::streaming::PendingQueue;
use crate::coordinator::{
    BatchPolicy, FunctionalServer, RoutePolicy, SequentialTimeline, ServeEvent, ServeOutcome,
    ServeProtocol, ServeRequest, ServeResponse, ServingMetrics, ServingSession, ShardedServer,
    SimulatedServer,
};
use crate::sim::energy::Component;
use crate::sim::memory::{DramState, RramState};
use crate::sim::{InferenceStats, PhaseStats};

use super::ChimeError;

/// Which execution engine a [`crate::api::Session`] drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Single-package CHIME simulator (virtual time, paper-scale models).
    Sim,
    /// The M3D DRAM-only ablation (Fig 9 baseline) on the simulator.
    DramOnly,
    /// Multi-package sharded CHIME simulator (N DRAM+RRAM pairs).
    Sharded,
    /// Functional PJRT runtime over the AOT artifacts (real tokens,
    /// wall-clock time).
    Functional,
    /// Jetson Orin NX analytic baseline model.
    Jetson,
    /// FACIL near-bank DRAM PIM analytic baseline model.
    Facil,
}

impl BackendKind {
    /// Parse a CLI spelling. Returns `None` for unknown names.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "sim" | "simulated" => Some(BackendKind::Sim),
            "dram-only" | "dramonly" | "dram_only" => Some(BackendKind::DramOnly),
            "sharded" => Some(BackendKind::Sharded),
            "functional" | "pjrt" => Some(BackendKind::Functional),
            "jetson" | "jetson-orin-nx" => Some(BackendKind::Jetson),
            "facil" => Some(BackendKind::Facil),
            _ => None,
        }
    }

    /// Canonical CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::DramOnly => "dram-only",
            BackendKind::Sharded => "sharded",
            BackendKind::Functional => "functional",
            BackendKind::Jetson => "jetson",
            BackendKind::Facil => "facil",
        }
    }

    /// Every kind, in display order.
    pub fn all() -> [BackendKind; 6] {
        [
            BackendKind::Sim,
            BackendKind::DramOnly,
            BackendKind::Sharded,
            BackendKind::Functional,
            BackendKind::Jetson,
            BackendKind::Facil,
        ]
    }
}

/// Read-only view of a simulator backend's memory state after the most
/// recent [`Backend::infer`] (KV residency, endurance ledgers).
pub struct MemoryView<'a> {
    /// Tiered M3D DRAM state (weights, KV residency, stream counters).
    pub dram: &'a DramState,
    /// M3D RRAM state (resident weights, offloaded KV, endurance).
    pub rram: &'a RramState,
}

/// Request-stream sizing a backend dictates (the functional artifacts fix
/// both the prompt length and the vocabulary).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestProfile {
    /// Prompt length every request must carry.
    pub prompt_len: usize,
    /// Vocabulary size to sample prompt token ids from.
    pub vocab: usize,
}

/// One polymorphic execution surface: simulator, ablation, sharded
/// deployment, functional runtime, and analytic baselines all answer the
/// same two calls. Object-safe — [`crate::api::Session`] owns a
/// `Box<dyn Backend>`.
pub trait Backend {
    /// Short human-readable backend name ("sim", "sharded", "jetson", ...).
    fn name(&self) -> &'static str;

    /// The [`BackendKind`] this backend executes as.
    fn kind(&self) -> BackendKind;

    /// Run one VQA inference under workload `w` and return its statistics.
    fn infer(&mut self, w: &WorkloadConfig) -> Result<InferenceStats, ChimeError>;

    /// Open an event-driven streaming serving session: `submit` requests
    /// at any virtual time, `tick` to advance the engine and receive
    /// typed events, `finish` for the [`ServeOutcome`].
    fn open_serving(&mut self) -> Result<ServingSession<'_>, ChimeError>;

    /// Serve a request stream. Every offered request comes back either
    /// completed ([`ServeOutcome::responses`]) or shed
    /// ([`ServeOutcome::shed`]) — never silently dropped.
    ///
    /// Provided: the legacy batch call is a thin drain-everything wrapper
    /// over [`Backend::open_serving`] — submit all, drain, finish — so
    /// closed-loop callers and streaming callers share one engine path.
    fn serve(&mut self, requests: Vec<ServeRequest>) -> Result<ServeOutcome, ChimeError> {
        let mut session = self.open_serving()?;
        for r in requests {
            session.submit(r);
        }
        session.finish()
    }

    /// Serve a request stream in free-running wall-clock mode on up to
    /// `threads` executor worker threads (`chime serve --wall`,
    /// DESIGN.md §15): host events/s scales with threads; the outcome
    /// promises conservation (every offered request completed, rejected,
    /// or shed exactly once), not bit-reproducibility.
    ///
    /// Provided as `Unsupported`: only the simulator-backed sharded
    /// deployments have independent per-package engines to race.
    fn serve_wall_clock(
        &mut self,
        _requests: Vec<ServeRequest>,
        _threads: usize,
    ) -> Result<crate::exec::WallReport, ChimeError> {
        Err(ChimeError::Unsupported {
            backend: self.name(),
            what: "wall-clock parallel execution (sim/sharded/dram-only only)",
        })
    }

    /// Request sizing this backend dictates, when it does (the functional
    /// artifacts fix prompt length and vocabulary).
    fn request_profile(&self) -> Option<RequestProfile> {
        None
    }

    /// Completions per package, for multi-package backends.
    fn package_completed(&self) -> Option<Vec<u64>> {
        None
    }

    /// Per-package KV headroom in bytes, for multi-package backends.
    fn kv_budget_bytes_per_package(&self) -> Option<u64> {
        None
    }

    /// Memory state retained from the most recent [`Backend::infer`],
    /// for simulator-backed backends.
    fn memory(&self) -> Option<MemoryView<'_>> {
        None
    }

    /// Enable/disable span tracing for subsequent runs (DESIGN.md §14).
    /// Provided as a no-op: backends without an instrumented engine
    /// (functional runtime, analytic baselines) ignore it and
    /// [`Backend::take_trace`] stays `None`.
    fn set_tracing(&mut self, _on: bool) {}

    /// Enable tracing with wall-clock self-profiling on top
    /// (`chime bench --profile`). Provided as a no-op, like
    /// [`Backend::set_tracing`].
    fn set_profiling(&mut self, _on: bool) {}

    /// Detach the recorded trace, if tracing was enabled and this backend
    /// records one (tracing turns off on take).
    fn take_trace(&mut self) -> Option<crate::obs::Tracer> {
        None
    }
}

/// Lift a [`BaselineStats`] (Jetson/FACIL analytic models) into the
/// simulator's [`InferenceStats`] shape so baselines compare on the same
/// axes. The baseline models report one board-level average power rather
/// than a per-component ledger, so the whole draw lands in the ledger's
/// `Idle` bucket (headline totals — time, energy, tokens/J — are exact).
pub fn baseline_inference_stats(b: &BaselineStats) -> InferenceStats {
    let phase = |time_ns: f64| -> PhaseStats {
        let mut p = PhaseStats::default();
        p.time_ns = time_ns;
        // W x ns = 1e-9 J = 1e3 pJ.
        p.energy.deposit(Component::Idle, b.avg_power_w * time_ns * 1000.0);
        p
    };
    InferenceStats {
        model: b.model.clone(),
        encode: phase(b.encode_ns),
        prefill: phase(b.prefill_ns),
        decode: phase(b.decode_ns),
        output_tokens: b.output_tokens,
        kv_offloaded_bytes: 0,
        rram_endurance_consumed: 0.0,
    }
}

/// Streaming session over an analytic per-inference price: the baseline
/// boards run one request at a time, so queueing is exactly the backlog
/// on a [`SequentialTimeline`]. `price(tokens)` returns the baseline
/// stats for one inference generating `tokens` tokens. Requests are
/// processed in arrival order (ties by id); like the other sequential
/// engines, all of a request's `Token` events carry its completion
/// timestamp (the analytic models price whole phases, not tokens).
struct BaselineSession<'a> {
    price: Box<dyn FnMut(usize) -> BaselineStats + 'a>,
    pending: PendingQueue,
    seen: std::collections::BTreeSet<u64>,
    /// One price per distinct token budget (the analytic models are
    /// deterministic in it).
    cache: BTreeMap<usize, (f64, f64, f64)>,
    timeline: SequentialTimeline,
    responses: Vec<ServeResponse>,
    shed: Vec<ServeRequest>,
    metrics: ServingMetrics,
}

impl<'a> BaselineSession<'a> {
    fn new(price: Box<dyn FnMut(usize) -> BaselineStats + 'a>) -> BaselineSession<'a> {
        BaselineSession {
            price,
            pending: PendingQueue::new(),
            seen: std::collections::BTreeSet::new(),
            cache: BTreeMap::new(),
            timeline: SequentialTimeline::new(),
            responses: Vec::new(),
            shed: Vec::new(),
            metrics: ServingMetrics::new(),
        }
    }
}

impl ServeProtocol for BaselineSession<'_> {
    fn submit(&mut self, req: ServeRequest) -> Vec<ServeEvent> {
        // Shared guard: duplicate ids panic, non-finite arrivals shed —
        // the same submission contract as the sharded coordinator.
        let req = match crate::coordinator::streaming::guard_submission(
            &mut self.seen,
            &mut self.metrics,
            &mut self.shed,
            req,
        ) {
            Ok(req) => req,
            Err(events) => return events,
        };
        self.pending.push(req, req.id);
        Vec::new()
    }

    fn tick(&mut self) -> Result<Vec<ServeEvent>, ChimeError> {
        let Some(req) = self.pending.pop() else {
            return Ok(Vec::new());
        };
        self.metrics.record_admitted();
        let price = &mut self.price;
        let (ttft_ns, total_ns, energy_j) =
            *self.cache.entry(req.max_new_tokens).or_insert_with(|| {
                if req.max_new_tokens == 0 {
                    (0.0, 0.0, 0.0)
                } else {
                    let b = price(req.max_new_tokens);
                    (b.encode_ns + b.prefill_ns, b.total_ns(), b.energy_j())
                }
            });
        let queue_ns = self.timeline.begin(req.arrival_ns);
        self.timeline.finish(req.arrival_ns, total_ns);
        let resp = ServeResponse {
            id: req.id,
            tokens: vec![0; req.max_new_tokens],
            queue_ns,
            ttft_ns,
            service_ns: total_ns,
            energy_j,
        };
        self.metrics.record(req.arrival_ns, &resp);
        let events = crate::coordinator::streaming::sequential_request_events(&req, &resp);
        self.responses.push(resp);
        Ok(events)
    }

    fn finish(&mut self) -> ServeOutcome {
        ServeOutcome {
            responses: std::mem::take(&mut self.responses),
            shed: std::mem::take(&mut self.shed),
            metrics: std::mem::take(&mut self.metrics),
        }
    }
}

impl Backend for SimulatedServer {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Sim
    }

    fn infer(&mut self, w: &WorkloadConfig) -> Result<InferenceStats, ChimeError> {
        Ok(self.run_inference_with(w))
    }

    fn open_serving(&mut self) -> Result<ServingSession<'_>, ChimeError> {
        Ok(ServingSession::new(Box::new(SimulatedServer::open_serving(self))))
    }

    fn serve_wall_clock(
        &mut self,
        requests: Vec<ServeRequest>,
        threads: usize,
    ) -> Result<crate::exec::WallReport, ChimeError> {
        Ok(SimulatedServer::serve_wall_clock(self, requests, threads))
    }

    fn memory(&self) -> Option<MemoryView<'_>> {
        self.last_infer_memory().map(|(dram, rram)| MemoryView { dram, rram })
    }

    fn set_tracing(&mut self, on: bool) {
        SimulatedServer::set_tracing(self, on);
    }

    fn set_profiling(&mut self, on: bool) {
        SimulatedServer::set_profiling(self, on);
    }

    fn take_trace(&mut self) -> Option<crate::obs::Tracer> {
        SimulatedServer::take_trace(self)
    }
}

impl Backend for ShardedServer {
    fn name(&self) -> &'static str {
        if self.is_dram_only() {
            "dram-only"
        } else {
            "sharded"
        }
    }

    fn kind(&self) -> BackendKind {
        if self.is_dram_only() {
            BackendKind::DramOnly
        } else {
            BackendKind::Sharded
        }
    }

    fn infer(&mut self, w: &WorkloadConfig) -> Result<InferenceStats, ChimeError> {
        Ok(self.run_inference_with(w))
    }

    fn open_serving(&mut self) -> Result<ServingSession<'_>, ChimeError> {
        Ok(ServingSession::new(Box::new(ShardedServer::open_serving(self))))
    }

    fn serve_wall_clock(
        &mut self,
        requests: Vec<ServeRequest>,
        threads: usize,
    ) -> Result<crate::exec::WallReport, ChimeError> {
        Ok(crate::exec::serve_wall_clock(self, requests, threads))
    }

    fn package_completed(&self) -> Option<Vec<u64>> {
        Some(ShardedServer::package_completed(self))
    }

    fn kv_budget_bytes_per_package(&self) -> Option<u64> {
        Some(ShardedServer::kv_budget_bytes_per_package(self))
    }

    fn memory(&self) -> Option<MemoryView<'_>> {
        self.last_infer_memory().map(|(dram, rram)| MemoryView { dram, rram })
    }

    fn set_tracing(&mut self, on: bool) {
        ShardedServer::set_tracing(self, on);
    }

    fn set_profiling(&mut self, on: bool) {
        ShardedServer::set_profiling(self, on);
    }

    fn take_trace(&mut self) -> Option<crate::obs::Tracer> {
        ShardedServer::take_trace(self)
    }
}

impl Backend for FunctionalServer {
    fn name(&self) -> &'static str {
        "functional"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Functional
    }

    fn infer(&mut self, _w: &WorkloadConfig) -> Result<InferenceStats, ChimeError> {
        Err(ChimeError::Unsupported {
            backend: "functional",
            what: "single-inference simulation (the functional path measures \
                   wall clock per request; use serve)",
        })
    }

    fn open_serving(&mut self) -> Result<ServingSession<'_>, ChimeError> {
        Ok(ServingSession::new(Box::new(FunctionalServer::open_serving(self))))
    }

    fn request_profile(&self) -> Option<RequestProfile> {
        let c = &self.mllm.manifest.config;
        Some(RequestProfile { prompt_len: c.prompt_len, vocab: c.vocab })
    }
}

/// The DRAM-only ablation as its own backend: a sharded coordinator whose
/// packages run the single-chiplet plan (`Plan::build_dram_only` +
/// `SimEngine::new_dram_only`), i.e. Fig 9's baseline made servable.
pub struct DramOnlyBackend {
    inner: ShardedServer,
}

impl DramOnlyBackend {
    /// Build a DRAM-only deployment of `packages` single-chiplet packages.
    pub fn new(
        model: &MllmConfig,
        cfg: &ChimeConfig,
        policy: BatchPolicy,
        packages: usize,
        route: RoutePolicy,
    ) -> DramOnlyBackend {
        DramOnlyBackend {
            inner: ShardedServer::new_dram_only(model, cfg, policy, packages, route),
        }
    }

    /// Enable/disable cross-package work stealing (forwarded to the
    /// underlying coordinator).
    pub fn set_work_stealing(&mut self, on: bool) {
        self.inner.set_work_stealing(on);
    }

    /// Set the executor worker-thread count for serving drains
    /// (forwarded to the underlying coordinator; DESIGN.md §15).
    pub fn set_threads(&mut self, n: usize) {
        self.inner.set_threads(n);
    }
}

// Pure forwarding to `<ShardedServer as Backend>`: the dram-only
// behavior (name/kind flip, ablation plan, memory view) is defined once
// on the coordinator's impl and merely re-surfaced here.
impl Backend for DramOnlyBackend {
    fn name(&self) -> &'static str {
        Backend::name(&self.inner)
    }

    fn kind(&self) -> BackendKind {
        Backend::kind(&self.inner)
    }

    fn infer(&mut self, w: &WorkloadConfig) -> Result<InferenceStats, ChimeError> {
        Backend::infer(&mut self.inner, w)
    }

    fn open_serving(&mut self) -> Result<ServingSession<'_>, ChimeError> {
        Backend::open_serving(&mut self.inner)
    }

    fn serve_wall_clock(
        &mut self,
        requests: Vec<ServeRequest>,
        threads: usize,
    ) -> Result<crate::exec::WallReport, ChimeError> {
        Backend::serve_wall_clock(&mut self.inner, requests, threads)
    }

    fn package_completed(&self) -> Option<Vec<u64>> {
        Backend::package_completed(&self.inner)
    }

    fn kv_budget_bytes_per_package(&self) -> Option<u64> {
        Backend::kv_budget_bytes_per_package(&self.inner)
    }

    fn memory(&self) -> Option<MemoryView<'_>> {
        Backend::memory(&self.inner)
    }

    fn set_tracing(&mut self, on: bool) {
        Backend::set_tracing(&mut self.inner, on);
    }

    fn set_profiling(&mut self, on: bool) {
        Backend::set_profiling(&mut self.inner, on);
    }

    fn take_trace(&mut self) -> Option<crate::obs::Tracer> {
        Backend::take_trace(&mut self.inner)
    }
}

/// The Jetson Orin NX analytic baseline as a backend (Fig 6(b)'s measured
/// comparison point, servable through the same surface).
pub struct JetsonBackend {
    model: MllmConfig,
    workload: WorkloadConfig,
    spec: JetsonSpec,
}

impl JetsonBackend {
    /// Build with the paper's calibrated [`JetsonSpec`].
    pub fn new(model: MllmConfig, workload: WorkloadConfig) -> JetsonBackend {
        JetsonBackend { model, workload, spec: JetsonSpec::default() }
    }

    /// Override the board spec (calibration experiments).
    pub fn with_spec(mut self, spec: JetsonSpec) -> JetsonBackend {
        self.spec = spec;
        self
    }
}

impl Backend for JetsonBackend {
    fn name(&self) -> &'static str {
        "jetson"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Jetson
    }

    fn infer(&mut self, w: &WorkloadConfig) -> Result<InferenceStats, ChimeError> {
        Ok(baseline_inference_stats(&jetson::run(&self.model, w, &self.spec)))
    }

    fn open_serving(&mut self) -> Result<ServingSession<'_>, ChimeError> {
        let (model, spec, base) = (self.model.clone(), self.spec.clone(), self.workload.clone());
        Ok(ServingSession::new(Box::new(BaselineSession::new(Box::new(move |tokens| {
            let mut w = base.clone();
            w.output_tokens = tokens;
            jetson::run(&model, &w, &spec)
        })))))
    }
}

/// The FACIL near-bank PIM analytic baseline as a backend (Table V's
/// PIM comparison point, servable through the same surface).
pub struct FacilBackend {
    model: MllmConfig,
    workload: WorkloadConfig,
    spec: FacilSpec,
}

impl FacilBackend {
    /// Build with the paper's calibrated [`FacilSpec`].
    pub fn new(model: MllmConfig, workload: WorkloadConfig) -> FacilBackend {
        FacilBackend { model, workload, spec: FacilSpec::default() }
    }

    /// Override the platform spec (calibration experiments).
    pub fn with_spec(mut self, spec: FacilSpec) -> FacilBackend {
        self.spec = spec;
        self
    }
}

impl Backend for FacilBackend {
    fn name(&self) -> &'static str {
        "facil"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Facil
    }

    fn infer(&mut self, w: &WorkloadConfig) -> Result<InferenceStats, ChimeError> {
        Ok(baseline_inference_stats(&facil::run(&self.model, w, &self.spec)))
    }

    fn open_serving(&mut self) -> Result<ServingSession<'_>, ChimeError> {
        let (model, spec, base) = (self.model.clone(), self.spec.clone(), self.workload.clone());
        Ok(ServingSession::new(Box::new(BaselineSession::new(Box::new(move |tokens| {
            let mut w = base.clone();
            w.output_tokens = tokens;
            facil::run(&model, &w, &spec)
        })))))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (MllmConfig, WorkloadConfig) {
        let mut w = WorkloadConfig::default();
        w.output_tokens = 8;
        (MllmConfig::fastvlm_0_6b(), w)
    }

    #[test]
    fn baseline_conversion_preserves_headline_metrics() {
        let (model, w) = small();
        let b = jetson::run(&model, &w, &JetsonSpec::default());
        let s = baseline_inference_stats(&b);
        assert_eq!(s.output_tokens, b.output_tokens);
        assert!((s.total_time_ns() - b.total_ns()).abs() < 1e-6);
        assert!((s.tokens_per_s() - b.tokens_per_s()).abs() / b.tokens_per_s() < 1e-9);
        assert!((s.tokens_per_j() - b.tokens_per_j()).abs() / b.tokens_per_j() < 1e-9);
        assert!((s.avg_power_w() - b.avg_power_w).abs() < 1e-9);
    }

    #[test]
    fn baseline_backends_serve_a_burst_conserving_requests() {
        let (model, w) = small();
        let mut backends: Vec<Box<dyn Backend>> = vec![
            Box::new(JetsonBackend::new(model.clone(), w.clone())),
            Box::new(FacilBackend::new(model.clone(), w.clone())),
        ];
        for b in &mut backends {
            let out = b.serve(ServeRequest::burst(5, 4)).unwrap();
            assert_eq!(out.responses.len() + out.shed.len(), 5, "{}", b.name());
            assert!(out.shed.is_empty(), "{}: sequential stream never sheds", b.name());
            assert_eq!(out.metrics.completed, 5);
            assert_eq!(out.metrics.tokens, 20);
            // Simultaneous arrivals on a single stream must queue.
            let queued = out.responses.iter().filter(|r| r.queue_ns > 0.0).count();
            assert_eq!(queued, 4, "{}", b.name());
        }
    }

    #[test]
    fn baseline_serve_sheds_non_finite_arrivals() {
        let (model, w) = small();
        let mut b = JetsonBackend::new(model, w);
        let mut reqs = ServeRequest::burst(3, 4);
        reqs[1].arrival_ns = f64::NAN;
        let out = b.serve(reqs).unwrap();
        assert_eq!(out.responses.len(), 2);
        assert_eq!(out.shed.len(), 1);
        assert_eq!(out.shed[0].id, 1);
        assert_eq!(out.metrics.shed, 1, "non-finite arrival counts as shed, not rejected");
        assert_eq!(out.metrics.rejected, 0);
        assert_eq!(out.metrics.offered(), 3);
    }

    #[test]
    fn baseline_streaming_sessions_emit_the_event_lifecycle() {
        let (model, w) = small();
        let mut b = JetsonBackend::new(model, w);
        let mut session = b.open_serving().unwrap();
        let mut reqs = ServeRequest::burst(3, 2);
        reqs[2].max_new_tokens = 0;
        for r in reqs {
            assert!(session.submit(r).is_empty());
        }
        let events = session.drain().unwrap();
        let kinds = |id: u64| -> Vec<&'static str> {
            events.iter().filter(|e| e.id() == id).map(|e| e.kind()).collect()
        };
        assert_eq!(kinds(0), ["admitted", "first-token", "token", "token", "completed"]);
        assert_eq!(kinds(1), ["admitted", "first-token", "token", "token", "completed"]);
        // Zero-token requests complete inline with no token events.
        assert_eq!(kinds(2), ["admitted", "completed"]);
        let out = session.finish().unwrap();
        assert_eq!(out.responses.len(), 3);
        assert_eq!(out.metrics.tokens, 4);
    }

    #[test]
    fn backend_kind_spellings_round_trip() {
        for k in BackendKind::all() {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("routee"), None);
    }

    #[test]
    fn zero_token_requests_are_free_on_baselines() {
        let (model, w) = small();
        let mut b = FacilBackend::new(model, w);
        let mut reqs = ServeRequest::burst(2, 4);
        reqs[1].max_new_tokens = 0;
        let out = b.serve(reqs).unwrap();
        let zero = out.responses.iter().find(|r| r.id == 1).unwrap();
        assert_eq!(zero.tokens.len(), 0);
        assert_eq!(zero.service_ns, 0.0);
        assert_eq!(out.metrics.tokens, 4);
    }
}
