//! [`Session`]: the builder-style front door to every execution path.
//!
//! A session owns config resolution (defaults + JSON override file +
//! workload knobs), model lookup, and backend construction, and then
//! drives the chosen [`Backend`] polymorphically. The `chime` CLI and all
//! repo examples are thin shells over this type.
//!
//! ```text
//! Session::builder()                 // defaults: fastvlm-0.6b, sim, 1 package
//!     .model("fastvlm-1.7b")         // or .model_config(MllmConfig)
//!     .backend(BackendKind::Sharded) // sim | dram-only | sharded | functional | jetson | facil
//!     .packages(4)
//!     .route(RoutePolicy::LeastLoaded)
//!     .config_file("calib.json")     // optional JSON knob overrides
//!     .output_tokens(64)
//!     .build()?                      // validates, resolves, constructs
//!     .serve(requests)?              // or .infer() / .infer_with(&w)
//! ```

use std::path::PathBuf;

use crate::config::{ChimeConfig, MemoryFidelity, MllmConfig, TopologyKind, WorkloadConfig};
use crate::coordinator::{
    ArrivalProcess, BatchPolicy, FunctionalServer, RoutePolicy, ServeOutcome, ServeRequest,
    ServingSession, ShardedServer, SimulatedServer,
};
use crate::model::workload::RequestStream;
use crate::runtime::Manifest;
use crate::sim::InferenceStats;

use super::backend::{
    Backend, BackendKind, DramOnlyBackend, FacilBackend, JetsonBackend, MemoryView,
    RequestProfile,
};
use super::ChimeError;

/// Accepted model spellings, surfaced in unknown-model errors.
const MODEL_HINT: &str = "fastvlm-0.6b fastvlm-1.7b mobilevlm-1.7b mobilevlm-3b tiny";

/// Model selection: unset (backend-appropriate default), by CLI name
/// (resolved at build), or an explicit config.
enum ModelSel {
    Default,
    Name(String),
    Config(MllmConfig),
}

/// Builder for [`Session`] — see the module docs for the lifecycle.
pub struct SessionBuilder {
    model: ModelSel,
    backend: BackendKind,
    packages: usize,
    route: RoutePolicy,
    batch: BatchPolicy,
    steal: bool,
    threads: usize,
    memory: Option<MemoryFidelity>,
    topology: Option<TopologyKind>,
    config_file: Option<String>,
    text_tokens: Option<usize>,
    output_tokens: Option<usize>,
    image_size: Option<usize>,
    artifacts_dir: Option<PathBuf>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            model: ModelSel::Default,
            backend: BackendKind::Sim,
            packages: 1,
            route: RoutePolicy::RoundRobin,
            batch: BatchPolicy::default(),
            steal: false,
            threads: 1,
            memory: None,
            topology: None,
            config_file: None,
            text_tokens: None,
            output_tokens: None,
            image_size: None,
            artifacts_dir: None,
        }
    }
}

impl SessionBuilder {
    /// Select the model by CLI name (resolved against the Table II zoo at
    /// build time; unknown names fail with an actionable hint).
    pub fn model(mut self, name: &str) -> Self {
        self.model = ModelSel::Name(name.to_string());
        self
    }

    /// Select the model by explicit configuration (skips name lookup).
    pub fn model_config(mut self, model: MllmConfig) -> Self {
        self.model = ModelSel::Config(model);
        self
    }

    /// Choose the execution backend (default: [`BackendKind::Sim`]).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = kind;
        self
    }

    /// Number of DRAM+RRAM packages for sharded backends (default 1).
    pub fn packages(mut self, n: usize) -> Self {
        self.packages = n;
        self
    }

    /// Routing policy for multi-package backends (default round-robin).
    pub fn route(mut self, route: RoutePolicy) -> Self {
        self.route = route;
        self
    }

    /// Full batch policy (max concurrent decode streams + queue depth).
    pub fn batch(mut self, policy: BatchPolicy) -> Self {
        self.batch = policy;
        self
    }

    /// Max concurrent decode streams per package (default 4).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.batch.max_batch = n;
        self
    }

    /// Admission-queue depth per package (default 1024).
    pub fn queue_capacity(mut self, n: usize) -> Self {
        self.batch.queue_capacity = n;
        self
    }

    /// Enable cross-package work stealing (default off): an idle package
    /// takes queued decode work from the most-loaded one — the serving
    /// tail-latency knob (`chime serve --steal on`, DESIGN.md §10). Only
    /// meaningful on the sharded simulator backends; requesting it
    /// elsewhere is a build error rather than a silent no-op.
    pub fn work_stealing(mut self, on: bool) -> Self {
        self.steal = on;
        self
    }

    /// Executor worker threads for serving drains (default 1, the
    /// classic single-thread event loop; `chime serve --threads N`,
    /// DESIGN.md §15). With `n > 1` the simulator backends drain
    /// arrival-free windows on up to `n` scoped worker threads — the
    /// outcome stays bit-identical to the sequential path. Only
    /// meaningful on the simulator backends (sim/sharded/dram-only);
    /// requesting it elsewhere is a build error rather than a silent
    /// no-op, and `0` is rejected (a zero-worker executor can never
    /// drain).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Select the chiplet-memory timing fidelity (default: the
    /// first-order analytic model; `MemoryFidelity::CycleAccurate` runs
    /// the bank/row/tier subsystem — the CLI's `--memory` flag).
    /// Overrides a `memory.fidelity` key from [`Self::config_file`].
    pub fn memory_fidelity(mut self, fidelity: MemoryFidelity) -> Self {
        self.memory = Some(fidelity);
        self
    }

    /// Select the inter-package UCIe fabric topology steals route over
    /// (default: `point-to-point`, the legacy 0-cost baseline; `line`,
    /// `ring`, and `mesh` charge each cross-package steal a routed
    /// multi-hop delivery — the CLI's `--topology` flag, DESIGN.md §12).
    /// Overrides a `topology.kind` key from [`Self::config_file`].
    pub fn topology(mut self, kind: TopologyKind) -> Self {
        self.topology = Some(kind);
        self
    }

    /// Apply a JSON calibration-override file on top of the defaults
    /// (same knobs as `chime --config`; unknown keys are errors).
    pub fn config_file(mut self, path: &str) -> Self {
        self.config_file = Some(path.to_string());
        self
    }

    /// Override the workload's input text length (tokens).
    pub fn text_tokens(mut self, n: usize) -> Self {
        self.text_tokens = Some(n);
        self
    }

    /// Override the workload's generated output length (tokens).
    pub fn output_tokens(mut self, n: usize) -> Self {
        self.output_tokens = Some(n);
        self
    }

    /// Override the workload's input image side length (pixels).
    pub fn image_size(mut self, n: usize) -> Self {
        self.image_size = Some(n);
        self
    }

    /// Artifacts directory for the functional backend (default:
    /// `Manifest::default_dir()`).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = Some(dir.into());
        self
    }

    /// Resolve configuration, look up the model, validate the policy, and
    /// construct the backend. Every failure is a typed [`ChimeError`].
    pub fn build(self) -> Result<Session, ChimeError> {
        let mut cfg = ChimeConfig::default();
        if let Some(path) = &self.config_file {
            cfg = cfg.with_override_file(path).map_err(ChimeError::Config)?;
        }
        if let Some(n) = self.text_tokens {
            cfg.workload.text_tokens = n;
        }
        if let Some(n) = self.output_tokens {
            cfg.workload.output_tokens = n;
        }
        if let Some(n) = self.image_size {
            cfg.workload.image_size = n;
        }
        // Memory fidelity only exists on the simulator backends; an
        // explicit cycle request elsewhere would be silently ignored, so
        // it is rejected instead (config-file defaults pass through the
        // same check when set to cycle).
        if let Some(f) = self.memory {
            cfg.hardware.memory_fidelity = f;
        }
        if cfg.hardware.memory_fidelity == MemoryFidelity::CycleAccurate
            && matches!(
                self.backend,
                BackendKind::Functional | BackendKind::Jetson | BackendKind::Facil
            )
        {
            return Err(ChimeError::Invalid(format!(
                "backend {} has no simulated chiplet memory; --memory cycle applies \
                 to the sim/sharded/dram-only backends",
                self.backend.name()
            )));
        }
        // The fabric topology only exists on the chiplet simulator
        // backends; a routed topology anywhere else would be silently
        // ignored, so it is rejected instead (config-file `topology.kind`
        // passes through the same check).
        if let Some(t) = self.topology {
            cfg.hardware.topology.kind = t;
        }
        if cfg.hardware.topology.kind != TopologyKind::PointToPoint
            && matches!(
                self.backend,
                BackendKind::Functional | BackendKind::Jetson | BackendKind::Facil
            )
        {
            return Err(ChimeError::Invalid(format!(
                "backend {} has no simulated chiplet fabric; --topology applies \
                 to the sim/sharded/dram-only backends",
                self.backend.name()
            )));
        }
        // Resolve the model. The functional backend always runs the
        // AOT-compiled tiny model — an explicitly selected paper model
        // would be silently ignored, so it is rejected instead, and
        // `Session::model()` reports the model that actually runs.
        let requested = match self.model {
            ModelSel::Default => None,
            ModelSel::Config(m) => Some(m),
            ModelSel::Name(name) => {
                Some(MllmConfig::by_name(&name).ok_or(ChimeError::Unknown {
                    what: "model",
                    name,
                    hint: Some(MODEL_HINT.to_string()),
                })?)
            }
        };
        let model = if self.backend == BackendKind::Functional {
            if let Some(m) = &requested {
                if m.name != "tiny" {
                    return Err(ChimeError::Invalid(format!(
                        "backend functional always runs the AOT-compiled tiny model; \
                         omit .model() or pass \"tiny\" (got {:?})",
                        m.name
                    )));
                }
            }
            MllmConfig::tiny()
        } else {
            requested.unwrap_or_else(MllmConfig::fastvlm_0_6b)
        };
        if self.packages == 0 {
            return Err(ChimeError::Invalid(
                "a deployment needs at least one package".to_string(),
            ));
        }
        // Sequential single-stream backends have no package/routing
        // dimension; a multi-package request would silently run as one
        // stream, so it is rejected instead.
        if self.packages > 1
            && matches!(
                self.backend,
                BackendKind::Functional | BackendKind::Jetson | BackendKind::Facil
            )
        {
            return Err(ChimeError::Invalid(format!(
                "backend {} is a single sequential stream; packages > 1 applies \
                 to the sharded simulator backends",
                self.backend.name()
            )));
        }
        if self.batch.max_batch == 0 {
            return Err(ChimeError::Invalid(
                "max_batch 0 can never serve a request".to_string(),
            ));
        }
        if self.batch.queue_capacity == 0 {
            return Err(ChimeError::Invalid(
                "queue_capacity 0 can never admit a request".to_string(),
            ));
        }
        if self.threads == 0 {
            return Err(ChimeError::Invalid(
                "threads 0 can never drain a session; the executor needs at least \
                 one worker thread"
                    .to_string(),
            ));
        }
        // Executor threads drive the simulator event loop; a sequential
        // single-stream backend has no event loop to parallelize, so a
        // multi-thread request there is rejected rather than silently
        // running single-threaded.
        if self.threads > 1
            && matches!(
                self.backend,
                BackendKind::Functional | BackendKind::Jetson | BackendKind::Facil
            )
        {
            return Err(ChimeError::Invalid(format!(
                "backend {} is a single sequential stream; threads > 1 applies \
                 to the sim/sharded/dram-only backends",
                self.backend.name()
            )));
        }
        // Work stealing moves queued work between sibling packages; on a
        // backend with no package dimension the knob would be silently
        // ignored, so it is rejected instead.
        if self.steal && !matches!(self.backend, BackendKind::Sharded | BackendKind::DramOnly) {
            return Err(ChimeError::Invalid(format!(
                "backend {} has no sibling packages to steal between; work stealing \
                 applies to the sharded simulator backends",
                self.backend.name()
            )));
        }
        let backend: Box<dyn Backend> = match self.backend {
            BackendKind::Sim => {
                if self.packages > 1 {
                    return Err(ChimeError::Invalid(
                        "backend sim is single-package; use BackendKind::Sharded \
                         for multi-package deployments"
                            .to_string(),
                    ));
                }
                let mut srv = SimulatedServer::new(&model, &cfg, self.batch.clone());
                srv.set_threads(self.threads);
                Box::new(srv)
            }
            BackendKind::Sharded => {
                let mut srv = ShardedServer::new(
                    &model,
                    &cfg,
                    self.batch.clone(),
                    self.packages,
                    self.route,
                );
                srv.set_work_stealing(self.steal);
                srv.set_threads(self.threads);
                Box::new(srv)
            }
            BackendKind::DramOnly => {
                let mut srv = DramOnlyBackend::new(
                    &model,
                    &cfg,
                    self.batch.clone(),
                    self.packages,
                    self.route,
                );
                srv.set_work_stealing(self.steal);
                srv.set_threads(self.threads);
                Box::new(srv)
            }
            BackendKind::Functional => {
                let dir = self.artifacts_dir.clone().unwrap_or_else(Manifest::default_dir);
                Box::new(FunctionalServer::load(&dir)?)
            }
            BackendKind::Jetson => {
                Box::new(JetsonBackend::new(model.clone(), cfg.workload.clone()))
            }
            BackendKind::Facil => {
                Box::new(FacilBackend::new(model.clone(), cfg.workload.clone()))
            }
        };
        Ok(Session { model, cfg, backend, threads: self.threads })
    }
}

/// One configured execution context: a resolved model + configuration and
/// a boxed [`Backend`]. Construct through [`Session::builder`].
pub struct Session {
    model: MllmConfig,
    cfg: ChimeConfig,
    backend: Box<dyn Backend>,
    threads: usize,
}

impl Session {
    /// Start building a session (see [`SessionBuilder`]).
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// The resolved model configuration.
    pub fn model(&self) -> &MllmConfig {
        &self.model
    }

    /// The effective configuration (defaults + file overrides + knobs).
    pub fn config(&self) -> &ChimeConfig {
        &self.cfg
    }

    /// The session's default workload (from [`Session::config`]).
    pub fn workload(&self) -> &WorkloadConfig {
        &self.cfg.workload
    }

    /// The memory-timing fidelity the session's simulator runs at.
    pub fn memory_fidelity(&self) -> MemoryFidelity {
        self.cfg.hardware.memory_fidelity
    }

    /// The inter-package fabric topology the session's simulator routes
    /// steals over.
    pub fn topology(&self) -> TopologyKind {
        self.cfg.hardware.topology.kind
    }

    /// The backend's short name ("sim", "sharded", "jetson", ...).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The backend's [`BackendKind`].
    pub fn backend_kind(&self) -> BackendKind {
        self.backend.kind()
    }

    /// Executor worker-thread count serving drains run on
    /// ([`SessionBuilder::threads`]; 1 = the sequential event loop).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run one VQA inference under the session's default workload.
    pub fn infer(&mut self) -> Result<InferenceStats, ChimeError> {
        let w = self.cfg.workload.clone();
        self.backend.infer(&w)
    }

    /// Run one VQA inference under an explicit workload (sweeps).
    pub fn infer_with(&mut self, w: &WorkloadConfig) -> Result<InferenceStats, ChimeError> {
        self.backend.infer(w)
    }

    /// Serve a request stream through the backend. Every offered request
    /// comes back completed or shed — never silently dropped. (A thin
    /// drain-everything wrapper over [`Session::open_serving`].)
    pub fn serve(&mut self, requests: Vec<ServeRequest>) -> Result<ServeOutcome, ChimeError> {
        self.backend.serve(requests)
    }

    /// Serve a request stream in free-running wall-clock mode on up to
    /// `threads` executor worker threads (`chime serve --wall`,
    /// DESIGN.md §15). Host events/s scales with threads; the outcome
    /// promises conservation, not bit-reproducibility — use
    /// [`Session::serve`] with [`SessionBuilder::threads`] for the
    /// deterministic parallel path. Simulator backends only.
    pub fn serve_wall_clock(
        &mut self,
        requests: Vec<ServeRequest>,
        threads: usize,
    ) -> Result<crate::exec::WallReport, ChimeError> {
        self.backend.serve_wall_clock(requests, threads)
    }

    /// Open an event-driven streaming serving session on the backend:
    /// `submit` requests at any virtual time, `tick` to advance and
    /// receive typed [`crate::coordinator::ServeEvent`]s, `finish` for
    /// the [`ServeOutcome`] (DESIGN.md §10).
    pub fn open_serving(&mut self) -> Result<ServingSession<'_>, ChimeError> {
        self.backend.open_serving()
    }

    /// Synthesize a deterministic Poisson request stream sized for this
    /// session's backend: prompt length and vocabulary come from the
    /// backend's [`RequestProfile`] when it dictates one (functional
    /// artifacts), else from the session's workload + model.
    pub fn poisson_requests(
        &self,
        seed: u64,
        rate_per_s: f64,
        n: usize,
        max_new_tokens: usize,
    ) -> Vec<ServeRequest> {
        let profile = self.backend.request_profile().unwrap_or(RequestProfile {
            prompt_len: self.cfg.workload.text_tokens,
            vocab: self.model.llm.vocab,
        });
        let mut stream =
            RequestStream::new(seed, rate_per_s, profile.prompt_len, max_new_tokens, profile.vocab);
        stream
            .take(n)
            .into_iter()
            .map(|r| ServeRequest {
                id: r.id,
                prompt: r.prompt,
                image_seed: r.image_seed,
                max_new_tokens: r.max_new_tokens,
                arrival_ns: r.arrival_ns,
            })
            .collect()
    }

    /// Synthesize a request stream from an [`ArrivalProcess`], sized for
    /// this session's backend (same prompt/vocabulary profile as
    /// [`Session::poisson_requests`]):
    ///
    /// * `Burst` — `n` requests, all arriving at t=0;
    /// * `Poisson` — `n` requests with seeded exponential inter-arrivals
    ///   (identical to [`Session::poisson_requests`] at the same seed);
    /// * `Trace` — one request per trace entry (`n` is ignored; the file
    ///   dictates the count), with per-request token budgets where the
    ///   trace specifies them.
    pub fn requests_for(
        &self,
        process: &ArrivalProcess,
        seed: u64,
        n: usize,
        max_new_tokens: usize,
    ) -> Result<Vec<ServeRequest>, ChimeError> {
        match process {
            ArrivalProcess::Poisson { rate_per_s } => {
                Ok(self.poisson_requests(seed, *rate_per_s, n, max_new_tokens))
            }
            ArrivalProcess::Burst => {
                let mut reqs = self.poisson_requests(seed, 1.0, n, max_new_tokens);
                for r in &mut reqs {
                    r.arrival_ns = 0.0;
                }
                Ok(reqs)
            }
            ArrivalProcess::Trace { path } => {
                let points = ArrivalProcess::trace_points(path)?;
                let mut reqs = self.poisson_requests(seed, 1.0, points.len(), max_new_tokens);
                for (r, p) in reqs.iter_mut().zip(&points) {
                    r.arrival_ns = p.arrival_ns;
                    if let Some(tokens) = p.max_new_tokens {
                        r.max_new_tokens = tokens;
                    }
                }
                Ok(reqs)
            }
        }
    }

    /// Completions per package (multi-package backends; `None` otherwise).
    pub fn package_completed(&self) -> Option<Vec<u64>> {
        self.backend.package_completed()
    }

    /// Per-package KV headroom in bytes (multi-package backends).
    pub fn kv_budget_bytes_per_package(&self) -> Option<u64> {
        self.backend.kv_budget_bytes_per_package()
    }

    /// Memory state retained from the most recent [`Session::infer`]
    /// (simulator-backed backends; `None` before the first inference).
    pub fn memory(&self) -> Option<MemoryView<'_>> {
        self.backend.memory()
    }

    /// Mutable access to the backend for trait-level drivers.
    pub fn backend_mut(&mut self) -> &mut dyn Backend {
        self.backend.as_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn tiny_builder() -> SessionBuilder {
        Session::builder().model("tiny").text_tokens(8).output_tokens(4).image_size(64)
    }

    #[test]
    fn sim_session_infers_and_serves() {
        let mut s = tiny_builder().build().unwrap();
        assert_eq!(s.backend_kind(), BackendKind::Sim);
        assert_eq!(s.backend_name(), "sim");
        let stats = s.infer().unwrap();
        assert_eq!(stats.output_tokens, 4);
        assert!(stats.total_time_ns() > 0.0);
        // Memory state of the inference is retained for introspection.
        let mem = s.memory().expect("sim backend retains memory state");
        assert!(mem.dram.bytes_read > 0);
        let out = s.serve(ServeRequest::burst(3, 4)).unwrap();
        assert_eq!(out.responses.len(), 3);
        assert!(out.shed.is_empty());
    }

    #[test]
    fn unknown_model_is_a_usage_error() {
        let err = Session::builder().model("fastvlm-9b").build().unwrap_err();
        assert_eq!(err.exit_code(), 2);
        match err {
            ChimeError::Unknown { what, name, hint } => {
                assert_eq!(what, "model");
                assert_eq!(name, "fastvlm-9b");
                assert!(hint.unwrap().contains("fastvlm-0.6b"));
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn garbage_config_file_is_a_config_error_not_a_panic() {
        let path = std::env::temp_dir().join("chime_garbage_config_test.json");
        std::fs::write(&path, "{ not json at all ]").unwrap();
        let err = tiny_builder()
            .config_file(path.to_str().unwrap())
            .build()
            .unwrap_err();
        std::fs::remove_file(&path).ok();
        assert_eq!(err.exit_code(), 2);
        assert!(matches!(err, ChimeError::Config(_)), "wrong variant: {err:?}");
    }

    #[test]
    fn missing_config_file_is_a_config_error() {
        let err = tiny_builder()
            .config_file("/nonexistent/chime/config.json")
            .build()
            .unwrap_err();
        assert_eq!(err.exit_code(), 2);
        assert!(matches!(err, ChimeError::Config(_)));
    }

    #[test]
    fn unknown_config_knob_is_a_config_error() {
        let path = std::env::temp_dir().join("chime_unknown_knob_test.json");
        std::fs::write(&path, r#"{"dram.typo_knob": 1.0}"#).unwrap();
        let err = tiny_builder()
            .config_file(path.to_str().unwrap())
            .build()
            .unwrap_err();
        std::fs::remove_file(&path).ok();
        assert!(matches!(err, ChimeError::Config(_)));
        assert!(err.to_string().contains("typo_knob"), "{err}");
    }

    #[test]
    fn invalid_policies_are_rejected_with_typed_errors() {
        assert!(matches!(
            tiny_builder().packages(0).backend(BackendKind::Sharded).build(),
            Err(ChimeError::Invalid(_))
        ));
        assert!(matches!(
            tiny_builder().max_batch(0).build(),
            Err(ChimeError::Invalid(_))
        ));
        assert!(matches!(
            tiny_builder().queue_capacity(0).build(),
            Err(ChimeError::Invalid(_))
        ));
        assert!(matches!(
            tiny_builder().packages(2).build(), // sim is single-package
            Err(ChimeError::Invalid(_))
        ));
    }

    #[test]
    fn single_stream_backends_reject_multi_package_configs() {
        // Pre-fix, .packages(4) on a baseline/functional builder silently
        // built a single sequential stream.
        for kind in [BackendKind::Jetson, BackendKind::Facil, BackendKind::Functional] {
            let err = Session::builder().backend(kind).packages(4).build().unwrap_err();
            assert!(
                matches!(err, ChimeError::Invalid(_)),
                "{kind:?}: expected Invalid, got {err:?}"
            );
            assert_eq!(err.exit_code(), 2);
        }
    }

    #[test]
    fn functional_backend_rejects_paper_models_and_reports_tiny() {
        // The functional artifacts are the tiny model; a paper-model
        // selection would be silently ignored, so it is rejected (this
        // check runs before artifact loading, so it needs no artifacts).
        let err = Session::builder()
            .model("fastvlm-1.7b")
            .backend(BackendKind::Functional)
            .build()
            .unwrap_err();
        assert!(matches!(err, ChimeError::Invalid(_)), "{err:?}");
        // Explicitly selecting tiny is fine: the build proceeds to the
        // artifact-loading stage (unavailable in stub environments).
        match Session::builder().model("tiny").backend(BackendKind::Functional).build() {
            Ok(s) => assert_eq!(s.model().name, "tiny"),
            Err(ChimeError::BackendUnavailable { .. }) => {}
            Err(other) => panic!("unexpected error: {other:?}"),
        }
    }

    #[test]
    fn sharded_session_exposes_package_diagnostics() {
        let mut s = tiny_builder()
            .backend(BackendKind::Sharded)
            .packages(2)
            .route(RoutePolicy::LeastLoaded)
            .build()
            .unwrap();
        assert_eq!(s.backend_kind(), BackendKind::Sharded);
        let out = s.serve(ServeRequest::burst(6, 4)).unwrap();
        assert_eq!(out.responses.len(), 6);
        let per_pkg = s.package_completed().unwrap();
        assert_eq!(per_pkg.len(), 2);
        assert_eq!(per_pkg.iter().sum::<u64>(), 6);
        assert!(s.kv_budget_bytes_per_package().unwrap() > 0);
    }

    #[test]
    fn baseline_sessions_share_the_surface() {
        for kind in [BackendKind::Jetson, BackendKind::Facil] {
            let mut s = Session::builder()
                .model("fastvlm-0.6b")
                .backend(kind)
                .output_tokens(8)
                .build()
                .unwrap();
            let stats = s.infer().unwrap();
            assert_eq!(stats.output_tokens, 8);
            assert!(stats.tokens_per_s() > 0.0, "{kind:?}");
            assert!(s.memory().is_none(), "baselines have no simulator memory");
        }
    }

    #[test]
    fn poisson_requests_match_the_session_workload() {
        let s = tiny_builder().build().unwrap();
        let reqs = s.poisson_requests(7, 100.0, 5, 3);
        assert_eq!(reqs.len(), 5);
        for r in &reqs {
            assert_eq!(r.prompt.len(), 8, "prompt sized from workload.text_tokens");
            assert_eq!(r.max_new_tokens, 3);
            assert!(r.arrival_ns.is_finite());
        }
        // Deterministic: same seed, same stream.
        let again = s.poisson_requests(7, 100.0, 5, 3);
        for (a, b) in reqs.iter().zip(&again) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.arrival_ns, b.arrival_ns);
        }
    }

    #[test]
    fn cycle_fidelity_threads_through_the_session() {
        let mut fo = tiny_builder().build().unwrap();
        let mut cy = tiny_builder()
            .memory_fidelity(MemoryFidelity::CycleAccurate)
            .build()
            .unwrap();
        assert_eq!(fo.memory_fidelity(), MemoryFidelity::FirstOrder);
        assert_eq!(cy.memory_fidelity(), MemoryFidelity::CycleAccurate);
        let a = fo.infer().unwrap();
        let b = cy.infer().unwrap();
        // The analytic model is the idealized lower bound...
        assert!(b.total_time_ns() >= a.total_time_ns());
        assert!(b.decode.time_ns > a.decode.time_ns, "decode must diverge");
        // ...and fidelity never changes accounting: the retained memory
        // view reports identical streamed bytes.
        let (ra, rb) = (fo.memory().unwrap(), cy.memory().unwrap());
        assert_eq!(ra.dram.bytes_read, rb.dram.bytes_read);
        assert_eq!(ra.dram.bytes_written, rb.dram.bytes_written);
        // Serving runs at cycle fidelity end to end.
        let out = cy.serve(ServeRequest::burst(3, 4)).unwrap();
        assert_eq!(out.responses.len(), 3);
    }

    #[test]
    fn cycle_fidelity_works_on_sharded_and_dram_only() {
        for kind in [BackendKind::Sharded, BackendKind::DramOnly] {
            let mut s = tiny_builder()
                .backend(kind)
                .packages(2)
                .memory_fidelity(MemoryFidelity::CycleAccurate)
                .build()
                .unwrap();
            let out = s.serve(ServeRequest::burst(4, 4)).unwrap();
            assert_eq!(out.responses.len(), 4, "{kind:?}");
        }
    }

    #[test]
    fn memoryless_backends_reject_cycle_fidelity() {
        for kind in [BackendKind::Functional, BackendKind::Jetson, BackendKind::Facil] {
            let err = Session::builder()
                .backend(kind)
                .memory_fidelity(MemoryFidelity::CycleAccurate)
                .build()
                .unwrap_err();
            assert!(matches!(err, ChimeError::Invalid(_)), "{kind:?}: {err:?}");
            assert_eq!(err.exit_code(), 2);
            // The default (first-order) is fine — nothing to ignore.
            assert!(!matches!(
                Session::builder()
                    .backend(kind)
                    .memory_fidelity(MemoryFidelity::FirstOrder)
                    .build(),
                Err(ChimeError::Invalid(_))
            ));
        }
    }

    #[test]
    fn topology_threads_through_to_the_sharded_fabric() {
        // Default is the legacy point-to-point baseline.
        let s = tiny_builder().build().unwrap();
        assert_eq!(s.topology(), TopologyKind::PointToPoint);
        // A routed topology reaches the sharded deployment's steal
        // fabric and costs the steals a session serves.
        let mut s = tiny_builder()
            .backend(BackendKind::Sharded)
            .packages(4)
            .max_batch(2)
            .work_stealing(true)
            .topology(TopologyKind::Ring)
            .build()
            .unwrap();
        assert_eq!(s.topology(), TopologyKind::Ring);
        let mut reqs = ServeRequest::burst(16, 1);
        for (i, r) in reqs.iter_mut().enumerate() {
            if i % 2 == 0 {
                r.max_new_tokens = 64;
            }
        }
        let out = s.serve(reqs).unwrap();
        assert_eq!(out.responses.len(), 16);
        assert!(out.metrics.steals > 0, "the skewed burst must steal");
        assert!(out.metrics.stolen_bytes > 0);
        assert!(
            out.metrics.steal_delay_ns > 0.0,
            "ring steals must pay a routed delivery"
        );
    }

    #[test]
    fn fabricless_backends_reject_routed_topologies() {
        for kind in [BackendKind::Functional, BackendKind::Jetson, BackendKind::Facil] {
            let err = Session::builder()
                .backend(kind)
                .topology(TopologyKind::Ring)
                .build()
                .unwrap_err();
            assert!(matches!(err, ChimeError::Invalid(_)), "{kind:?}: {err:?}");
            assert_eq!(err.exit_code(), 2);
            // The point-to-point default is fine — nothing to ignore.
            assert!(!matches!(
                Session::builder()
                    .backend(kind)
                    .topology(TopologyKind::PointToPoint)
                    .build(),
                Err(ChimeError::Invalid(_))
            ));
        }
    }

    #[test]
    fn work_stealing_requires_a_sharded_backend() {
        // Pre-guard, .work_stealing(true) on a packageless backend would
        // be silently ignored; it is a typed usage error instead.
        for kind in [
            BackendKind::Sim,
            BackendKind::Jetson,
            BackendKind::Facil,
            BackendKind::Functional,
        ] {
            let err = tiny_builder().backend(kind).work_stealing(true).build().unwrap_err();
            assert!(matches!(err, ChimeError::Invalid(_)), "{kind:?}: {err:?}");
            assert_eq!(err.exit_code(), 2);
        }
        for kind in [BackendKind::Sharded, BackendKind::DramOnly] {
            let mut s = tiny_builder()
                .backend(kind)
                .packages(2)
                .work_stealing(true)
                .build()
                .unwrap();
            let out = s.serve(ServeRequest::burst(4, 4)).unwrap();
            assert_eq!(out.responses.len(), 4, "{kind:?}");
        }
    }

    #[test]
    fn executor_threads_validate_and_stay_bit_identical() {
        // 0 workers can never drain: typed usage error, exit 2.
        let err = tiny_builder().threads(0).build().unwrap_err();
        assert!(matches!(err, ChimeError::Invalid(_)), "{err:?}");
        assert_eq!(err.exit_code(), 2);
        // Sequential single-stream backends have no event loop to
        // parallelize; threads > 1 there is a usage error, threads(1)
        // (the default, nothing to ignore) is fine.
        for kind in [BackendKind::Jetson, BackendKind::Facil, BackendKind::Functional] {
            let err = Session::builder().backend(kind).threads(4).build().unwrap_err();
            assert!(matches!(err, ChimeError::Invalid(_)), "{kind:?}: {err:?}");
            assert_eq!(err.exit_code(), 2);
            assert!(!matches!(
                Session::builder().backend(kind).threads(1).build(),
                Err(ChimeError::Invalid(_))
            ));
        }
        // The deterministic contract end to end: a multi-thread sharded
        // session serves bit-identically to the single-thread one.
        let serve = |threads: usize| {
            let mut s = tiny_builder()
                .backend(BackendKind::Sharded)
                .packages(2)
                .route(RoutePolicy::LeastLoaded)
                .threads(threads)
                .build()
                .unwrap();
            s.serve(ServeRequest::burst(6, 4)).unwrap()
        };
        let (seq, par) = (serve(1), serve(4));
        assert_eq!(seq.responses.len(), par.responses.len());
        for (a, b) in seq.responses.iter().zip(&par.responses) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.service_ns.to_bits(), b.service_ns.to_bits());
            assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        }
    }

    #[test]
    fn requests_for_covers_every_arrival_process() {
        let s = tiny_builder().build().unwrap();
        let burst = s.requests_for(&ArrivalProcess::Burst, 7, 5, 3).unwrap();
        assert_eq!(burst.len(), 5);
        assert!(burst.iter().all(|r| r.arrival_ns == 0.0 && r.max_new_tokens == 3));
        // poisson:<rps> is exactly the legacy seeded stream.
        let poisson =
            s.requests_for(&ArrivalProcess::Poisson { rate_per_s: 100.0 }, 7, 5, 3).unwrap();
        let direct = s.poisson_requests(7, 100.0, 5, 3);
        for (a, b) in poisson.iter().zip(&direct) {
            assert_eq!(a.arrival_ns, b.arrival_ns);
            assert_eq!(a.prompt, b.prompt);
        }
        // A trace dictates count, arrivals, and optional token budgets.
        let path = std::env::temp_dir().join("chime_session_trace_test.json");
        std::fs::write(&path, r#"[0, {"arrival_s": 0.25, "tokens": 7}]"#).unwrap();
        let process = ArrivalProcess::Trace { path: path.to_str().unwrap().to_string() };
        let trace = s.requests_for(&process, 7, 99, 3).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(trace.len(), 2, "the file dictates the request count");
        assert_eq!(trace[0].max_new_tokens, 3, "entries without tokens use the default");
        assert_eq!(trace[1].arrival_ns, 0.25e9);
        assert_eq!(trace[1].max_new_tokens, 7);
    }

    #[test]
    fn streaming_session_through_the_api_matches_batch_serve() {
        let burst = ServeRequest::burst(5, 4);
        let mut batch = tiny_builder().build().unwrap();
        let batch_out = batch.serve(burst.clone()).unwrap();
        let mut streaming = tiny_builder().build().unwrap();
        let mut session = streaming.open_serving().unwrap();
        for r in burst {
            session.submit(r);
        }
        let events = session.drain().unwrap();
        assert!(events.iter().any(|e| e.kind() == "completed"));
        let out = session.finish().unwrap();
        assert_eq!(out.responses.len(), batch_out.responses.len());
        for (a, b) in out.responses.iter().zip(&batch_out.responses) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.service_ns.to_bits(), b.service_ns.to_bits());
        }
    }

    #[test]
    fn dram_only_session_is_slower_than_sim() {
        let w = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 4 };
        let mut het = tiny_builder().build().unwrap();
        let mut solo = tiny_builder().backend(BackendKind::DramOnly).build().unwrap();
        let a = het.infer_with(&w).unwrap();
        let b = solo.infer_with(&w).unwrap();
        assert!(
            b.decode.time_ns > a.decode.time_ns,
            "dram-only {} vs chime {}",
            b.decode.time_ns,
            a.decode.time_ns
        );
        assert_eq!(solo.backend_name(), "dram-only");
    }
}
