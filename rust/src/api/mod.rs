//! `chime::api` — the crate's public execution API.
//!
//! Three pieces compose into one polymorphic surface over every execution
//! path (DESIGN.md §8):
//!
//! * [`ChimeError`] — the typed error taxonomy. Usage/configuration
//!   mistakes map to exit code 2, environment/runtime failures to 1;
//!   nothing on the public path panics or hand-threads raw `i32`s.
//! * [`Backend`] — `infer` (one VQA inference → [`crate::sim::InferenceStats`])
//!   and `open_serving` (an event-driven [`ServingSession`]: submit
//!   requests at any virtual time, tick for typed [`ServeEvent`]s,
//!   finish for a [`crate::coordinator::ServeOutcome`]) implemented by
//!   the CHIME simulator (solo, DRAM-only ablation, multi-package
//!   sharded with optional work stealing), the functional PJRT runtime,
//!   and the Jetson/FACIL analytic baselines — FACIL-style comparisons
//!   are "another backend", not a parallel code path. The batch `serve`
//!   is a provided drain-everything wrapper over the session.
//! * [`Session`] — the builder that owns config resolution (defaults +
//!   JSON override file + workload knobs), model lookup, policy
//!   validation, and backend selection. The `chime` CLI and all repo
//!   examples construct execution exclusively through it.
//!
//! ```text
//! let mut session = Session::builder()
//!     .model("fastvlm-1.7b")
//!     .backend(BackendKind::Sharded)
//!     .packages(4)
//!     .route(RoutePolicy::LeastLoaded)
//!     .build()?;
//! let outcome = session.serve(session.poisson_requests(7, 2.0, 16, 64))?;
//! ```
#![deny(missing_docs)]

mod backend;
mod error;
mod session;

pub use backend::{
    baseline_inference_stats, Backend, BackendKind, DramOnlyBackend, FacilBackend, JetsonBackend,
    MemoryView, RequestProfile,
};
pub use error::ChimeError;
pub use session::{Session, SessionBuilder};

// Re-exported so downstream servers can drive the builder without
// importing coordinator internals.
pub use crate::config::MemoryFidelity;
pub use crate::coordinator::{
    ArrivalPoint, ArrivalProcess, BatchPolicy, RoutePolicy, ServeEvent, ServeOutcome,
    ServeProtocol, ServeRequest, ServeResponse, ServingSession,
};
