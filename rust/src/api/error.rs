//! The crate's typed error taxonomy.
//!
//! `ChimeError` replaces ad-hoc `panic!`s, `anyhow` errors, and raw `i32`
//! exit codes on every public execution path. Each variant carries enough
//! context to print a one-line actionable message, and maps to a process
//! exit code through [`ChimeError::exit_code`]: usage/configuration
//! mistakes exit 2 (the caller can fix the invocation), environment and
//! runtime failures exit 1.

use std::fmt;

/// Everything that can go wrong while building or driving a [`crate::api::Session`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChimeError {
    /// A configuration override file could not be read, parsed, or applied
    /// (unknown knob, non-numeric value, unreadable path).
    Config(String),
    /// A name failed to resolve: model, backend, route policy, experiment
    /// id, or subcommand. `hint` lists the accepted spellings.
    Unknown {
        /// What kind of name failed to resolve ("model", "backend", ...).
        what: &'static str,
        /// The name as the caller spelled it.
        name: String,
        /// Accepted spellings, when enumerable.
        hint: Option<String>,
    },
    /// A CLI flag is not accepted by the subcommand it was passed to.
    UnknownFlag {
        /// The unrecognized flag (without the leading `--`).
        flag: String,
        /// The closest accepted flag, when one is plausibly intended.
        suggestion: Option<String>,
    },
    /// A builder or argument invariant was violated (zero packages, zero
    /// batch, conflicting options).
    Invalid(String),
    /// A backend cannot be constructed in this environment (e.g. the
    /// functional PJRT backend without AOT artifacts).
    BackendUnavailable {
        /// The backend that failed to come up.
        backend: &'static str,
        /// Why it is unavailable.
        reason: String,
    },
    /// The chosen backend does not implement the requested operation.
    Unsupported {
        /// The backend that declined.
        backend: &'static str,
        /// The operation it does not implement.
        what: &'static str,
    },
    /// A runtime failure while executing (PJRT execution, serving).
    Runtime(String),
}

impl ChimeError {
    /// Process exit code for this error: 2 for usage/configuration
    /// mistakes the caller can fix in the invocation, 1 for environment
    /// and runtime failures.
    pub fn exit_code(&self) -> i32 {
        match self {
            ChimeError::Config(_)
            | ChimeError::Unknown { .. }
            | ChimeError::UnknownFlag { .. }
            | ChimeError::Invalid(_) => 2,
            ChimeError::BackendUnavailable { .. }
            | ChimeError::Unsupported { .. }
            | ChimeError::Runtime(_) => 1,
        }
    }
}

impl fmt::Display for ChimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ChimeError::Config(msg) => write!(f, "config: {msg}"),
            ChimeError::Unknown { what, name, hint } => {
                write!(f, "unknown {what} {name:?}")?;
                if let Some(h) = hint {
                    write!(f, " (use {h})")?;
                }
                Ok(())
            }
            ChimeError::UnknownFlag { flag, suggestion } => {
                write!(f, "unknown option --{flag}")?;
                if let Some(s) = suggestion {
                    write!(f, " (did you mean --{s}?)")?;
                }
                Ok(())
            }
            ChimeError::Invalid(msg) => write!(f, "invalid arguments: {msg}"),
            ChimeError::BackendUnavailable { backend, reason } => {
                write!(f, "backend {backend} unavailable: {reason}")
            }
            ChimeError::Unsupported { backend, what } => {
                write!(f, "backend {backend} does not support {what}")
            }
            ChimeError::Runtime(msg) => write!(f, "runtime error: {msg}"),
        }
    }
}

impl std::error::Error for ChimeError {}

impl From<anyhow::Error> for ChimeError {
    fn from(e: anyhow::Error) -> ChimeError {
        ChimeError::Runtime(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_codes_split_usage_vs_runtime() {
        assert_eq!(ChimeError::Config("x".into()).exit_code(), 2);
        assert_eq!(
            ChimeError::Unknown { what: "model", name: "nope".into(), hint: None }.exit_code(),
            2
        );
        assert_eq!(
            ChimeError::UnknownFlag { flag: "routee".into(), suggestion: None }.exit_code(),
            2
        );
        assert_eq!(ChimeError::Invalid("x".into()).exit_code(), 2);
        assert_eq!(
            ChimeError::BackendUnavailable { backend: "functional", reason: "no artifacts".into() }
                .exit_code(),
            1
        );
        assert_eq!(ChimeError::Runtime("boom".into()).exit_code(), 1);
    }

    #[test]
    fn display_is_actionable() {
        let e = ChimeError::UnknownFlag {
            flag: "routee".into(),
            suggestion: Some("route".into()),
        };
        let msg = e.to_string();
        assert!(msg.contains("--routee"), "{msg}");
        assert!(msg.contains("did you mean --route?"), "{msg}");

        let e = ChimeError::Unknown {
            what: "model",
            name: "fastvlm-9b".into(),
            hint: Some("fastvlm-0.6b fastvlm-1.7b".into()),
        };
        assert!(e.to_string().contains("unknown model"));
    }

    #[test]
    fn anyhow_interop_round_trips_the_chain() {
        let root = anyhow::anyhow!("root cause").context("while loading");
        let e = ChimeError::from(root);
        let msg = e.to_string();
        assert!(msg.contains("while loading"), "{msg}");
        assert!(msg.contains("root cause"), "{msg}");
        assert_eq!(e.exit_code(), 1);
        // And back: ChimeError implements std::error::Error, so `?` can
        // lift it into the vendored anyhow in downstream code.
        let back: anyhow::Error = e.into();
        assert!(format!("{back:#}").contains("root cause"));
    }
}
