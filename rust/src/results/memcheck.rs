//! Memory-fidelity cross-validation: run every Table II model at both
//! memory fidelities and report the per-phase divergence of the
//! cycle-accurate bank/row/tier subsystem (`sim::memory::cycle`) from
//! the paper's first-order streaming model.
//!
//! The first-order model is the idealized lower bound (activation cost
//! perfectly amortized, no refresh, no row thrash), so every ratio must
//! be >= 1; the cycle model's discrete effects — refresh duty cycle,
//! whole-row activation quantization, weight/KV row conflicts, pipeline
//! refills, RRAM verify/remap — bound it from above. The golden test
//! (`golden_memcheck_fidelity_divergence`) locks every per-phase ratio
//! inside [`RATIO_MIN`, `RATIO_MAX`] and requires the memory-bound
//! decode phase to diverge strictly.

use crate::config::{ChimeConfig, MemoryFidelity, MllmConfig};
use crate::sim;
use crate::util::{table, Json, Table};

use super::Experiment;

/// Lower edge of the tolerance band: the analytic model is a lower
/// bound, exactly (float-exact by construction — the cycle model adds
/// non-negative terms to the same analytic time).
pub const RATIO_MIN: f64 = 1.0;
/// Upper edge of the tolerance band: refresh duty cycle (~7%), row
/// conflicts and pipeline refills against the per-kernel dispatch floor
/// keep realistic divergence well under 35% per phase.
pub const RATIO_MAX: f64 = 1.35;

/// Decode-only output length for the cross-validation workload: long
/// enough for steady-state KV/refresh behavior, short enough that the
/// 8-simulation sweep stays cheap in debug test runs.
pub const OUTPUT_TOKENS: usize = 128;

/// One model's phase timing under one fidelity.
#[derive(Debug, Clone)]
pub struct PhaseDivergence {
    pub model: String,
    pub phase: &'static str,
    pub first_order_ns: f64,
    pub cycle_ns: f64,
    /// `cycle_ns / first_order_ns`.
    pub ratio: f64,
}

fn cfg_with(fidelity: MemoryFidelity) -> ChimeConfig {
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = OUTPUT_TOKENS;
    cfg.hardware.memory_fidelity = fidelity;
    cfg
}

/// Run both fidelities over the Table II zoo; 4 rows per model
/// (encode / prefill / decode / total).
pub fn compute() -> Vec<PhaseDivergence> {
    let mut out = Vec::new();
    for m in MllmConfig::paper_models() {
        let fo = sim::simulate(&m, &cfg_with(MemoryFidelity::FirstOrder));
        let cy = sim::simulate(&m, &cfg_with(MemoryFidelity::CycleAccurate));
        let phases: [(&'static str, f64, f64); 4] = [
            ("encode", fo.encode.time_ns, cy.encode.time_ns),
            ("prefill", fo.prefill.time_ns, cy.prefill.time_ns),
            ("decode", fo.decode.time_ns, cy.decode.time_ns),
            ("total", fo.total_time_ns(), cy.total_time_ns()),
        ];
        for (phase, first_order_ns, cycle_ns) in phases {
            out.push(PhaseDivergence {
                model: m.name.clone(),
                phase,
                first_order_ns,
                cycle_ns,
                ratio: cycle_ns / first_order_ns,
            });
        }
    }
    out
}

pub fn run() -> Experiment {
    let rows = compute();
    let mut t = Table::new(
        "Memcheck — first-order vs cycle-accurate memory timing (Table II models)",
        &["model", "phase", "first-order (ms)", "cycle (ms)", "cycle/first-order"],
    );
    let mut json_rows = Vec::new();
    for r in &rows {
        t.row(vec![
            r.model.clone(),
            r.phase.to_string(),
            table::f(r.first_order_ns / 1e6, 3),
            table::f(r.cycle_ns / 1e6, 3),
            table::f(r.ratio, 4),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", r.model.as_str().into()),
            ("phase", r.phase.into()),
            ("first_order_ns", r.first_order_ns.into()),
            ("cycle_ns", r.cycle_ns.into()),
            ("ratio", r.ratio.into()),
        ]));
    }
    Experiment {
        id: "memcheck",
        text: t.render(),
        json: Json::obj(vec![
            ("rows", Json::Arr(json_rows)),
            (
                "band",
                Json::obj(vec![
                    ("ratio_min", RATIO_MIN.into()),
                    ("ratio_max", RATIO_MAX.into()),
                ]),
            ),
            ("output_tokens", OUTPUT_TOKENS.into()),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divergence_inside_the_band_and_decode_strict() {
        // The golden test locks the snapshot; this unit test asserts the
        // band over the cheapest model so the invariant lives next to
        // the code too.
        let fo = sim::simulate(
            &MllmConfig::fastvlm_0_6b(),
            &cfg_with(MemoryFidelity::FirstOrder),
        );
        let cy = sim::simulate(
            &MllmConfig::fastvlm_0_6b(),
            &cfg_with(MemoryFidelity::CycleAccurate),
        );
        for (phase, a, b) in [
            ("encode", fo.encode.time_ns, cy.encode.time_ns),
            ("prefill", fo.prefill.time_ns, cy.prefill.time_ns),
            ("decode", fo.decode.time_ns, cy.decode.time_ns),
        ] {
            let ratio = b / a;
            assert!(
                (RATIO_MIN..=RATIO_MAX).contains(&ratio),
                "{phase}: ratio {ratio} outside [{RATIO_MIN}, {RATIO_MAX}]"
            );
        }
        assert!(cy.decode.time_ns / fo.decode.time_ns > 1.0001, "decode must diverge");
    }
}
