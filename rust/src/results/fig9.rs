//! Fig 9: memory-configuration ablation — CHIME (heterogeneous) vs the
//! M3D DRAM-only design. (a) speedup, (b) energy-efficiency gain.
//!
//! Paper claims: 2.38–2.49x speedup and 1.04–1.07x energy-efficiency
//! gain; the speedup is most pronounced for MobileVLM 3B whose FFN
//! weights overwhelm the DRAM-centric design.

use crate::config::{ChimeConfig, MllmConfig};
use crate::sim;
use crate::util::{table, Json, Table};

use super::Experiment;

pub struct Fig9Row {
    pub model: String,
    pub chime_tps: f64,
    pub dram_only_tps: f64,
    pub speedup: f64,
    pub chime_tok_j: f64,
    pub dram_only_tok_j: f64,
    pub energy_gain: f64,
}

pub fn compute() -> Vec<Fig9Row> {
    let cfg = ChimeConfig::default();
    MllmConfig::paper_models()
        .iter()
        .map(|m| {
            let het = sim::simulate(m, &cfg);
            let solo = sim::simulate_dram_only(m, &cfg);
            Fig9Row {
                model: m.name.clone(),
                chime_tps: het.tokens_per_s(),
                dram_only_tps: solo.tokens_per_s(),
                speedup: het.tokens_per_s() / solo.tokens_per_s(),
                chime_tok_j: het.tokens_per_j(),
                dram_only_tok_j: solo.tokens_per_j(),
                energy_gain: het.tokens_per_j() / solo.tokens_per_j(),
            }
        })
        .collect()
}

pub fn run() -> Experiment {
    let rows = compute();
    let mut t = Table::new(
        "Fig 9 — CHIME vs M3D DRAM-only (memory-configuration ablation)",
        &["model", "chime TPS", "dram-only TPS", "speedup", "chime tok/J",
          "dram-only tok/J", "energy gain"],
    );
    let mut json_rows = Vec::new();
    for r in &rows {
        t.row(vec![
            r.model.clone(),
            table::f(r.chime_tps, 1),
            table::f(r.dram_only_tps, 1),
            table::x(r.speedup),
            table::f(r.chime_tok_j, 1),
            table::f(r.dram_only_tok_j, 1),
            table::x(r.energy_gain),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", r.model.as_str().into()),
            ("speedup", r.speedup.into()),
            ("energy_gain", r.energy_gain.into()),
            ("chime_tps", r.chime_tps.into()),
            ("dram_only_tps", r.dram_only_tps.into()),
        ]));
    }
    Experiment {
        id: "fig9",
        text: t.render(),
        json: Json::obj(vec![
            ("rows", Json::Arr(json_rows)),
            ("paper", Json::obj(vec![
                ("speedup_range", "2.38-2.49x".into()),
                ("energy_range", "1.04-1.07x".into()),
            ])),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heterogeneous_always_wins() {
        for r in compute() {
            assert!(r.speedup > 1.5, "{}: speedup {}", r.model, r.speedup);
            assert!(r.speedup < 4.0, "{}: speedup {} implausibly high", r.model, r.speedup);
        }
    }

    #[test]
    fn energy_gain_modest() {
        // Paper: only 1.04-1.07x — the ablation saves time, not much
        // energy (same bytes move either way).
        for r in compute() {
            assert!(
                (0.8..1.8).contains(&r.energy_gain),
                "{}: energy gain {}",
                r.model,
                r.energy_gain
            );
        }
    }

    #[test]
    fn big_ffn_model_benefits_most() {
        // Paper: "the speedup is significant for larger models, especially
        // MobileVLM 3B whose FFN weights overwhelm [the DRAM-only design]".
        let rows = compute();
        let get = |n: &str| rows.iter().find(|r| r.model == n).unwrap().speedup;
        assert!(get("mobilevlm-3b") >= get("mobilevlm-1.7b") * 0.95);
    }
}
