//! Fig 1(b): MLLM execution-time breakdown by stage (encoder / connector /
//! backbone) and Fig 1(c): backbone op-class breakdown, both on the GPU
//! baseline (the paper's motivation profile).
//!
//! Paper claims: backbone 85.4–95.7% of time across connectors; within
//! the backbone, MHA 44%, FFN 29.36%, elementwise 26.41%.

use crate::baselines::jetson;
use crate::config::{JetsonSpec, MllmConfig, WorkloadConfig};
use crate::model::Stage;
use crate::util::{table, Json, Table};

use super::Experiment;

pub fn run() -> Experiment {
    let w = WorkloadConfig::default();
    let spec = JetsonSpec::default();

    let mut t = Table::new(
        "Fig 1(b) — execution-time breakdown by stage (GPU baseline)",
        &["model", "encoder", "connector", "backbone"],
    );
    let mut rows = Vec::new();
    for m in MllmConfig::paper_models() {
        let b = jetson::stage_breakdown(&m, &w, &spec);
        let get = |s: Stage| b.iter().find(|(x, _)| *x == s).map(|(_, f)| *f).unwrap_or(0.0);
        t.row(vec![
            m.name.clone(),
            table::pct(get(Stage::VisionEncoder)),
            table::pct(get(Stage::Connector)),
            table::pct(get(Stage::Backbone)),
        ]);
        rows.push(Json::obj(vec![
            ("model", m.name.as_str().into()),
            ("encoder", get(Stage::VisionEncoder).into()),
            ("connector", get(Stage::Connector).into()),
            ("backbone", get(Stage::Backbone).into()),
        ]));
    }

    // Fig 1(c): decode-time op breakdown on a GPT-2-class backbone.
    let m = MllmConfig::mobilevlm_1_7b();
    let stats = jetson::run(&m, &w, &spec);
    let total: f64 = stats.decode_breakdown.iter().map(|(_, ns)| ns).sum();
    let mut t2 = Table::new(
        "Fig 1(c) — backbone op-class breakdown (GPU decode)",
        &["op class", "share"],
    );
    let mut ops = Vec::new();
    for (label, ns) in &stats.decode_breakdown {
        t2.row(vec![label.to_string(), table::pct(ns / total)]);
        ops.push(Json::obj(vec![
            ("op", (*label).into()),
            ("share", (ns / total).into()),
        ]));
    }

    let text = format!("{}\n{}", t.render(), t2.render());
    Experiment {
        id: "fig1",
        text,
        json: Json::obj(vec![
            ("stages", Json::Arr(rows)),
            ("backbone_ops", Json::Arr(ops)),
            ("paper", Json::obj(vec![
                ("backbone_share", "85.4-95.7%".into()),
                ("mha", (0.44).into()),
                ("ffn", (0.2936).into()),
                ("elementwise", (0.2641).into()),
            ])),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backbone_dominates() {
        let e = run();
        for row in e.json.get("stages").as_arr().unwrap() {
            let b = row.get("backbone").as_f64().unwrap();
            assert!(b > 0.8, "backbone share {b}");
        }
    }

    #[test]
    fn op_shares_sum_to_one() {
        let e = run();
        let total: f64 = e
            .json
            .get("backbone_ops")
            .as_arr()
            .unwrap()
            .iter()
            .map(|o| o.get("share").as_f64().unwrap())
            .sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
