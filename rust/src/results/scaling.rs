//! Package-scaling table (ROADMAP "multi-package sharding" item): system
//! tokens/s and token/J as the deployment grows from 1 to 8 DRAM+RRAM
//! packages, serving a saturating burst through the sharded coordinator.
//!
//! Expected shape: near-linear tokens/s scaling while the burst saturates
//! every package (each package is an independent flow shop; the
//! event-ordered merge adds no cross-package stalls), and roughly flat
//! token/J (sharding divides time, not the per-token energy).

use crate::config::{ChimeConfig, MllmConfig};
use crate::coordinator::{BatchPolicy, RoutePolicy, ServeRequest, ShardedServer};
use crate::util::{table, Json, Table};

use super::Experiment;

pub const PACKAGES: [usize; 4] = [1, 2, 4, 8];
/// Saturating burst: all requests arrive at t=0.
pub const BURST_REQUESTS: usize = 32;
pub const TOKENS_PER_REQUEST: usize = 64;

pub struct ScalePoint {
    pub model: String,
    pub packages: usize,
    pub tokens_per_s: f64,
    pub tokens_per_j: f64,
    pub p99_latency_ms: f64,
    pub completed: u64,
}

pub fn compute() -> Vec<ScalePoint> {
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = TOKENS_PER_REQUEST;
    let mut out = Vec::new();
    for m in [MllmConfig::fastvlm_0_6b(), MllmConfig::mobilevlm_3b()] {
        for &packages in &PACKAGES {
            let mut srv = ShardedServer::new(
                &m,
                &cfg,
                BatchPolicy::default(),
                packages,
                RoutePolicy::RoundRobin,
            );
            let o = srv.serve(ServeRequest::burst(BURST_REQUESTS, TOKENS_PER_REQUEST));
            let mut metrics = o.metrics;
            assert_eq!(
                o.responses.len(),
                BURST_REQUESTS,
                "scaling burst must fully drain"
            );
            out.push(ScalePoint {
                model: m.name.clone(),
                packages,
                tokens_per_s: metrics.tokens_per_s(),
                tokens_per_j: metrics.tokens_per_j(),
                p99_latency_ms: metrics.latency_percentile_ns(99.0) / 1e6,
                completed: metrics.completed,
            });
        }
    }
    out
}

pub fn run() -> Experiment {
    let points = compute();
    let mut t = Table::new(
        "Package scaling — sharded serving, 32-request saturating burst, 64 tok/req",
        &["model", "packages", "tok/s", "speedup", "tok/J", "p99 latency (ms)"],
    );
    let mut json_rows = Vec::new();
    let mut base_tps = 0.0;
    for p in &points {
        if p.packages == 1 {
            base_tps = p.tokens_per_s;
        }
        let speedup = if base_tps > 0.0 { p.tokens_per_s / base_tps } else { 0.0 };
        t.row(vec![
            p.model.clone(),
            p.packages.to_string(),
            table::f(p.tokens_per_s, 1),
            format!("{:.2}x", speedup),
            table::f(p.tokens_per_j, 1),
            table::f(p.p99_latency_ms, 1),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", p.model.as_str().into()),
            ("packages", p.packages.into()),
            ("tokens_per_s", p.tokens_per_s.into()),
            ("speedup_vs_1", speedup.into()),
            ("tokens_per_j", p.tokens_per_j.into()),
            ("p99_latency_ms", p.p99_latency_ms.into()),
        ]));
    }
    Experiment {
        id: "scaling",
        text: t.render(),
        json: Json::obj(vec![
            ("points", Json::Arr(json_rows)),
            (
                "claim",
                Json::obj(vec![
                    ("tokens_per_s", "near-linear in packages under saturation".into()),
                    ("tokens_per_j", "roughly flat (sharding divides time, not energy)".into()),
                ]),
            ),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series<'a>(points: &'a [ScalePoint], model: &str) -> Vec<&'a ScalePoint> {
        points.iter().filter(|p| p.model == model).collect()
    }

    #[test]
    fn throughput_scales_with_packages() {
        let pts = compute();
        for m in ["fastvlm-0.6b", "mobilevlm-3b"] {
            let s = series(&pts, m);
            assert_eq!(s.len(), PACKAGES.len());
            // 2 packages must deliver a real scaling win on saturation.
            assert!(
                s[1].tokens_per_s >= s[0].tokens_per_s * 1.5,
                "{m}: 2 packages {} vs 1 package {}",
                s[1].tokens_per_s,
                s[0].tokens_per_s
            );
            // Monotone non-decreasing through 8 packages (small slack for
            // partial last waves).
            for w in s.windows(2) {
                assert!(
                    w[1].tokens_per_s >= w[0].tokens_per_s * 0.98,
                    "{m}: tok/s regressed {} -> {}",
                    w[0].tokens_per_s,
                    w[1].tokens_per_s
                );
            }
            // Sharding divides time, not energy: token/J roughly flat.
            for p in &s {
                assert!(
                    (p.tokens_per_j / s[0].tokens_per_j - 1.0).abs() < 0.25,
                    "{m}: tok/J drifted {} vs {}",
                    p.tokens_per_j,
                    s[0].tokens_per_j
                );
            }
        }
    }

    #[test]
    fn every_point_completes_the_burst() {
        for p in compute() {
            assert_eq!(p.completed as usize, BURST_REQUESTS);
            assert!(p.p99_latency_ms > 0.0);
        }
    }
}
