//! Serving tail-latency table (ROADMAP work-stealing item): p50/p95/p99
//! TTFT, TPOT, and total latency under a seeded open-loop Poisson
//! arrival process, with cross-package work stealing off vs on, as the
//! deployment grows from 1 to 8 packages.
//!
//! The workload is deliberately skewed: requests `i % 8 < 2` carry a
//! heavy 240-token decode budget, the rest a light 8-token one, so
//! round-robin routing concentrates the heavy work on a fixed subset of
//! packages. Under the arrival rate the heavy packages overload while
//! the light ones drain and go idle — exactly the regime where an idle
//! package stealing queued decode work from the most-loaded one cuts
//! the tail.
//!
//! Expected shape (locked by `golden_tail_work_stealing`): stealing is a
//! bitwise no-op at 1 package, strictly improves p99 total latency at
//! ≥ 4 packages, never changes the token count, and leaves tok/J within
//! 1% of `--steal off` (stealing relocates work; it does not re-price
//! the tokens).

use crate::config::{ChimeConfig, MllmConfig};
use crate::coordinator::{BatchPolicy, RoutePolicy, ServeRequest, ShardedServer};
use crate::util::stats::percentile_sorted;
use crate::util::{table, Json, Prng, Table};

use super::Experiment;

pub const PACKAGES: [usize; 4] = [1, 2, 4, 8];
pub const REQUESTS: usize = 48;
/// Open-loop offered load, requests/s (overloads the heavy packages at
/// every deployment size).
pub const RATE_PER_S: f64 = 40.0;
pub const HEAVY_TOKENS: usize = 240;
pub const LIGHT_TOKENS: usize = 8;
pub const SEED: u64 = 11;
/// Small per-package batch so queues (the thing stealing rebalances)
/// actually form.
pub const MAX_BATCH: usize = 2;

/// One (packages, steal) measurement.
pub struct TailPoint {
    pub model: String,
    pub packages: usize,
    pub steal: bool,
    pub p50_ttft_ms: f64,
    pub p95_ttft_ms: f64,
    pub p99_ttft_ms: f64,
    pub p50_tpot_ms: f64,
    pub p95_tpot_ms: f64,
    pub p99_tpot_ms: f64,
    pub p50_latency_ms: f64,
    pub p95_latency_ms: f64,
    pub p99_latency_ms: f64,
    pub tokens_per_s: f64,
    pub tokens_per_j: f64,
    pub tokens: u64,
    pub steals: u64,
    pub completed: u64,
}

/// The seeded open-loop arrival stream: Poisson arrivals at
/// [`RATE_PER_S`], heavy/light token skew by request index. Shared with
/// the fabric figure (`results::fabric`) so its per-topology tail rows
/// are directly comparable with this table.
pub fn tail_requests() -> Vec<ServeRequest> {
    let mut prng = Prng::new(SEED);
    let mut clock_ns = 0.0;
    (0..REQUESTS)
        .map(|i| {
            clock_ns += prng.exponential(RATE_PER_S) * 1e9;
            ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: if i % 8 < 2 { HEAVY_TOKENS } else { LIGHT_TOKENS },
                arrival_ns: clock_ns,
            }
        })
        .collect()
}

/// p50/p95/p99 of a sample buffer (sorted once, read three times) —
/// shared between this virtual-time table and the wall-clock tail table
/// `net::loadgen` renders from wire measurements.
pub fn tail_percentiles(mut samples: Vec<f64>) -> (f64, f64, f64) {
    samples.sort_by(|a, b| a.total_cmp(b));
    (
        percentile_sorted(&samples, 50.0),
        percentile_sorted(&samples, 95.0),
        percentile_sorted(&samples, 99.0),
    )
}

pub fn compute() -> Vec<TailPoint> {
    let model = MllmConfig::fastvlm_0_6b();
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = HEAVY_TOKENS;
    let policy = BatchPolicy { max_batch: MAX_BATCH, queue_capacity: 1024 };
    let mut out = Vec::new();
    for &packages in &PACKAGES {
        for steal in [false, true] {
            let mut srv =
                ShardedServer::new(&model, &cfg, policy.clone(), packages, RoutePolicy::RoundRobin);
            srv.set_work_stealing(steal);
            // Drive the streaming session directly so steal events are
            // observable (the batch wrapper discards the event stream).
            let mut session = srv.open_serving();
            for r in tail_requests() {
                session.submit(r);
            }
            let events = session.drain();
            let steals = events.iter().filter(|e| e.kind() == "stolen").count() as u64;
            let outcome = session.finish();
            assert_eq!(outcome.responses.len(), REQUESTS, "tail stream must fully drain");
            assert!(outcome.shed.is_empty(), "queue depth 1024 must not shed 48 requests");

            let (p50_ttft, p95_ttft, p99_ttft) = tail_percentiles(
                outcome.responses.iter().map(|r| r.queue_ns + r.ttft_ns).collect(),
            );
            let (p50_tpot, p95_tpot, p99_tpot) =
                tail_percentiles(outcome.responses.iter().map(|r| r.tpot_ns()).collect());
            let (p50_lat, p95_lat, p99_lat) =
                tail_percentiles(outcome.responses.iter().map(|r| r.total_latency_ns()).collect());
            let metrics = outcome.metrics;
            out.push(TailPoint {
                model: model.name.clone(),
                packages,
                steal,
                p50_ttft_ms: p50_ttft / 1e6,
                p95_ttft_ms: p95_ttft / 1e6,
                p99_ttft_ms: p99_ttft / 1e6,
                p50_tpot_ms: p50_tpot / 1e6,
                p95_tpot_ms: p95_tpot / 1e6,
                p99_tpot_ms: p99_tpot / 1e6,
                p50_latency_ms: p50_lat / 1e6,
                p95_latency_ms: p95_lat / 1e6,
                p99_latency_ms: p99_lat / 1e6,
                tokens_per_s: metrics.tokens_per_s(),
                tokens_per_j: metrics.tokens_per_j(),
                tokens: metrics.tokens,
                steals,
                completed: metrics.completed,
            });
        }
    }
    out
}

pub fn run() -> Experiment {
    let points = compute();
    let mut t = Table::new(
        "Serving tail latency — poisson:40 open-loop, 48 skewed requests, steal off vs on",
        &["model", "pkgs", "steal", "p50 TTFT (ms)", "p99 TTFT (ms)", "p50 TPOT (ms)",
          "p99 TPOT (ms)", "p50 lat (ms)", "p95 lat (ms)", "p99 lat (ms)", "tok/s", "tok/J",
          "steals"],
    );
    let mut json_rows = Vec::new();
    for p in &points {
        t.row(vec![
            p.model.clone(),
            p.packages.to_string(),
            if p.steal { "on" } else { "off" }.to_string(),
            table::f(p.p50_ttft_ms, 1),
            table::f(p.p99_ttft_ms, 1),
            table::f(p.p50_tpot_ms, 2),
            table::f(p.p99_tpot_ms, 2),
            table::f(p.p50_latency_ms, 1),
            table::f(p.p95_latency_ms, 1),
            table::f(p.p99_latency_ms, 1),
            table::f(p.tokens_per_s, 1),
            table::f(p.tokens_per_j, 1),
            p.steals.to_string(),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", p.model.as_str().into()),
            ("packages", p.packages.into()),
            ("steal", Json::Bool(p.steal)),
            ("p50_ttft_ms", p.p50_ttft_ms.into()),
            ("p95_ttft_ms", p.p95_ttft_ms.into()),
            ("p99_ttft_ms", p.p99_ttft_ms.into()),
            ("p50_tpot_ms", p.p50_tpot_ms.into()),
            ("p95_tpot_ms", p.p95_tpot_ms.into()),
            ("p99_tpot_ms", p.p99_tpot_ms.into()),
            ("p50_latency_ms", p.p50_latency_ms.into()),
            ("p95_latency_ms", p.p95_latency_ms.into()),
            ("p99_latency_ms", p.p99_latency_ms.into()),
            ("tokens_per_s", p.tokens_per_s.into()),
            ("tokens_per_j", p.tokens_per_j.into()),
            ("tokens", (p.tokens as i64).into()),
            ("steals", (p.steals as i64).into()),
            ("completed", (p.completed as i64).into()),
        ]));
    }
    Experiment {
        id: "tail",
        text: t.render(),
        json: Json::obj(vec![
            ("points", Json::Arr(json_rows)),
            (
                "claim",
                Json::obj(vec![
                    (
                        "p99_latency",
                        "work stealing strictly improves p99 at >= 4 packages".into(),
                    ),
                    ("tokens_per_j", "within 1% of steal-off (stealing relocates work)".into()),
                    ("tokens", "bit-identical across steal modes".into()),
                ]),
            ),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point<'a>(pts: &'a [TailPoint], packages: usize, steal: bool) -> &'a TailPoint {
        pts.iter().find(|p| p.packages == packages && p.steal == steal).unwrap()
    }

    #[test]
    fn stealing_cuts_the_tail_without_repricing_tokens() {
        let pts = compute();
        assert_eq!(pts.len(), PACKAGES.len() * 2);
        for &packages in &PACKAGES {
            let (off, on) = (point(&pts, packages, false), point(&pts, packages, true));
            assert_eq!(off.completed, REQUESTS as u64);
            assert_eq!(on.completed, REQUESTS as u64);
            // Stealing never changes what is generated, only where/when.
            assert_eq!(on.tokens, off.tokens, "{packages} pkgs: token count moved");
            assert!(
                (on.tokens_per_j / off.tokens_per_j - 1.0).abs() < 0.01,
                "{packages} pkgs: tok/J drifted {} vs {}",
                on.tokens_per_j,
                off.tokens_per_j
            );
            if packages == 1 {
                assert_eq!(on.steals, 0, "one package cannot steal from itself");
                assert_eq!(
                    on.p99_latency_ms.to_bits(),
                    off.p99_latency_ms.to_bits(),
                    "stealing must be a bitwise no-op on one package"
                );
            }
            if packages >= 4 {
                assert!(on.steals > 0, "{packages} pkgs: skewed overload must trigger steals");
                assert!(
                    on.p99_latency_ms < off.p99_latency_ms,
                    "{packages} pkgs: p99 {} (on) must strictly beat {} (off)",
                    on.p99_latency_ms,
                    off.p99_latency_ms
                );
            }
        }
    }

    #[test]
    fn arrival_stream_is_deterministic_and_skewed() {
        let (a, b) = (tail_requests(), tail_requests());
        assert_eq!(a.len(), REQUESTS);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_ns, y.arrival_ns);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
        let heavy = a.iter().filter(|r| r.max_new_tokens == HEAVY_TOKENS).count();
        assert_eq!(heavy, REQUESTS / 4, "2 of every 8 requests are heavy");
        for w in a.windows(2) {
            assert!(w[1].arrival_ns > w[0].arrival_ns, "arrivals must be strictly increasing");
        }
    }
}
