//! Fabric figure (DESIGN.md §12): per-link peak bandwidth and the
//! steal-vs-locality tail across {1, 2, 4, 8} packages × the four UCIe
//! fabric topologies, with work stealing always on, under the same
//! seeded skewed open-loop stream as the tail-latency table.
//!
//! What the grid shows:
//!
//! * `point-to-point` is the legacy 0-cost steal baseline — steals move
//!   payloads (`stolen KB` is counted) but pay no routed delivery, so
//!   its rows reproduce the pre-fabric tail numbers bit for bit;
//! * `line`/`ring`/`mesh` charge every steal a multi-hop DRAM-to-DRAM
//!   delivery, so `steal delay` turns strictly positive and the steal
//!   traffic becomes visible as per-link peak GB/s on the inter-package
//!   links ([`ShardedServer::fabric_links`]);
//! * at 1 package every topology is identical by construction (there is
//!   no inter-package link to route over), which the first four rows
//!   demonstrate.
//!
//! Reachable via `chime results --fig fabric` (and `make fabric`), never
//! from `--all`: the `--all` output is locked byte for byte by the
//! `golden_paper` suite from before this figure existed.

use crate::config::{ChimeConfig, MllmConfig, TopologyKind};
use crate::coordinator::{BatchPolicy, RoutePolicy, ShardedServer};
use crate::sim::fabric::Link;
use crate::util::stats::percentile_sorted;
use crate::util::{table, Json, Table};

use super::tail::{tail_requests, HEAVY_TOKENS, MAX_BATCH, PACKAGES, REQUESTS};
use super::Experiment;

/// One (packages, topology) measurement, stealing on.
pub struct FabricPoint {
    pub model: String,
    pub packages: usize,
    pub topology: TopologyKind,
    pub steals: u64,
    pub stolen_kb: f64,
    pub mean_steal_delay_us: f64,
    pub p99_latency_ms: f64,
    /// Busiest inter-package link's peak over any 1 µs window (GB/s).
    pub peak_inter_gbps: f64,
    /// Total bytes crossed on inter-package links (payload × hops).
    pub inter_bytes: u64,
    pub tokens: u64,
}

pub fn compute() -> Vec<FabricPoint> {
    let model = MllmConfig::fastvlm_0_6b();
    let policy = BatchPolicy { max_batch: MAX_BATCH, queue_capacity: 1024 };
    let mut out = Vec::new();
    for &packages in &PACKAGES {
        for kind in TopologyKind::ALL {
            let mut cfg = ChimeConfig::default();
            cfg.workload.output_tokens = HEAVY_TOKENS;
            cfg.hardware.topology.kind = kind;
            let mut srv = ShardedServer::new(
                &model,
                &cfg,
                policy.clone(),
                packages,
                RoutePolicy::RoundRobin,
            );
            srv.set_work_stealing(true);
            let outcome = srv.serve(tail_requests());
            assert_eq!(outcome.responses.len(), REQUESTS, "fabric stream must fully drain");
            let mut latency: Vec<f64> =
                outcome.responses.iter().map(|r| r.total_latency_ns()).collect();
            latency.sort_by(|a, b| a.total_cmp(b));
            let links = srv.fabric_links();
            let inter = || links.iter().filter(|(l, _)| matches!(l, Link::Inter { .. }));
            let peak_inter_gbps = inter().map(|(_, s)| s.peak_gbps()).fold(0.0, f64::max);
            let inter_bytes = inter().map(|(_, s)| s.bytes).sum();
            let m = outcome.metrics;
            out.push(FabricPoint {
                model: model.name.clone(),
                packages,
                topology: kind,
                steals: m.steals,
                stolen_kb: m.stolen_bytes as f64 / 1e3,
                mean_steal_delay_us: m.mean_steal_delay_ns() / 1e3,
                p99_latency_ms: percentile_sorted(&latency, 99.0) / 1e6,
                peak_inter_gbps,
                inter_bytes,
                tokens: m.tokens,
            });
        }
    }
    out
}

pub fn run() -> Experiment {
    let points = compute();
    let mut t = Table::new(
        "UCIe fabric — per-link peaks and the steal tail, poisson:40, steal on",
        &["model", "pkgs", "topology", "steals", "stolen (KB)", "steal delay (us)",
          "p99 lat (ms)", "peak link (GB/s)"],
    );
    let mut json_rows = Vec::new();
    for p in &points {
        t.row(vec![
            p.model.clone(),
            p.packages.to_string(),
            p.topology.name().to_string(),
            p.steals.to_string(),
            table::f(p.stolen_kb, 1),
            table::f(p.mean_steal_delay_us, 2),
            table::f(p.p99_latency_ms, 1),
            table::f(p.peak_inter_gbps, 1),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", p.model.as_str().into()),
            ("packages", p.packages.into()),
            ("topology", p.topology.name().into()),
            ("steals", (p.steals as i64).into()),
            ("stolen_kb", p.stolen_kb.into()),
            ("mean_steal_delay_us", p.mean_steal_delay_us.into()),
            ("p99_latency_ms", p.p99_latency_ms.into()),
            ("peak_inter_gbps", p.peak_inter_gbps.into()),
            ("inter_bytes", (p.inter_bytes as i64).into()),
            ("tokens", (p.tokens as i64).into()),
        ]));
    }
    Experiment {
        id: "fabric",
        text: t.render(),
        json: Json::obj(vec![
            ("points", Json::Arr(json_rows)),
            (
                "claim",
                Json::obj(vec![
                    (
                        "baseline",
                        "point-to-point steals are free: delay 0, no link traffic".into(),
                    ),
                    (
                        "routed",
                        "line/ring/mesh steals pay a multi-hop delivery and load the links"
                            .into(),
                    ),
                    ("one_package", "every topology is identical at one package".into()),
                ]),
            ),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point<'a>(
        pts: &'a [FabricPoint],
        packages: usize,
        kind: TopologyKind,
    ) -> &'a FabricPoint {
        pts.iter().find(|p| p.packages == packages && p.topology == kind).unwrap()
    }

    #[test]
    fn grid_covers_every_package_count_and_topology() {
        let pts = compute();
        assert_eq!(pts.len(), PACKAGES.len() * TopologyKind::ALL.len());
        // Routing work around the fabric never changes what is generated.
        for p in &pts {
            assert_eq!(p.tokens, pts[0].tokens, "{:?}: token count moved", p.topology);
        }
    }

    #[test]
    fn one_package_is_topology_invariant_with_no_inter_traffic() {
        let pts = compute();
        let base = point(&pts, 1, TopologyKind::PointToPoint);
        for kind in TopologyKind::ALL {
            let p = point(&pts, 1, kind);
            assert_eq!(p.steals, 0, "{kind:?}: one package cannot steal from itself");
            assert_eq!(p.inter_bytes, 0, "{kind:?}: no inter-package links at 1 package");
            assert_eq!(p.peak_inter_gbps, 0.0);
            assert_eq!(
                p.p99_latency_ms.to_bits(),
                base.p99_latency_ms.to_bits(),
                "{kind:?}: every topology must be identical at one package"
            );
        }
    }

    #[test]
    fn routed_steals_pay_and_load_the_links_at_scale() {
        let pts = compute();
        for &packages in PACKAGES.iter().filter(|&&p| p >= 4) {
            let p2p = point(&pts, packages, TopologyKind::PointToPoint);
            assert!(p2p.steals > 0, "{packages} pkgs: skewed overload must steal");
            assert!(p2p.stolen_kb > 0.0, "steal payloads are counted on every topology");
            assert_eq!(p2p.mean_steal_delay_us, 0.0, "point-to-point is the free baseline");
            assert_eq!(p2p.inter_bytes, 0, "free steals never touch the links");
            for kind in [TopologyKind::Line, TopologyKind::Ring, TopologyKind::Mesh] {
                let p = point(&pts, packages, kind);
                assert!(p.steals > 0, "{packages} pkgs {kind:?}: steals must still fire");
                assert!(p.stolen_kb > 0.0);
                assert!(
                    p.mean_steal_delay_us > p2p.mean_steal_delay_us,
                    "{packages} pkgs {kind:?}: routed delay must beat the 0-cost baseline"
                );
                assert!(
                    p.peak_inter_gbps > 0.0,
                    "{packages} pkgs {kind:?}: steal traffic must load the links"
                );
                assert!(p.inter_bytes > 0);
            }
        }
    }
}
