//! Table V: platform comparison — Jetson Orin NX vs FACIL vs CHIME on
//! throughput, power, energy efficiency, and hardware efficiency
//! (token/s/mm²).
//!
//! Paper claims: CHIME 233–533 tok/s @ ~2 W, 116.5–266.5 tok/J,
//! 4.35–9.95 tok/s/mm²; FACIL 7.7–19.3 tok/s; Jetson 7.4–11 tok/s;
//! CHIME/FACIL throughput 12.1–69.2x (cross-paired extremes).

use crate::baselines::{facil, jetson};
use crate::config::{ChimeConfig, FacilSpec, JetsonSpec, MllmConfig};
use crate::sim;
use crate::util::{table, Json, Table};

use super::Experiment;

pub struct PlatformRange {
    pub platform: &'static str,
    pub tps_min: f64,
    pub tps_max: f64,
    pub power_min: f64,
    pub power_max: f64,
    pub tok_j_min: f64,
    pub tok_j_max: f64,
    pub area_mm2: f64,
}

pub fn compute() -> Vec<PlatformRange> {
    let cfg = ChimeConfig::default();
    let jspec = JetsonSpec::default();
    let fspec = FacilSpec::default();
    let models = MllmConfig::paper_models();

    let mut chime = PlatformRange {
        platform: "CHIME",
        tps_min: f64::MAX, tps_max: 0.0, power_min: f64::MAX, power_max: 0.0,
        tok_j_min: f64::MAX, tok_j_max: 0.0,
        area_mm2: cfg.hardware.total_die_area_mm2(),
    };
    let mut jet = PlatformRange {
        platform: "Jetson Orin NX",
        tps_min: f64::MAX, tps_max: 0.0, power_min: f64::MAX, power_max: 0.0,
        tok_j_min: f64::MAX, tok_j_max: 0.0, area_mm2: jspec.die_area_mm2,
    };
    let mut fac = PlatformRange {
        platform: "FACIL",
        tps_min: f64::MAX, tps_max: 0.0, power_min: f64::MAX, power_max: 0.0,
        tok_j_min: f64::MAX, tok_j_max: 0.0, area_mm2: fspec.die_area_mm2,
    };

    for m in &models {
        let c = sim::simulate(m, &cfg);
        fold(&mut chime, c.tokens_per_s(), c.avg_power_w(), c.tokens_per_j());
        let j = jetson::run(m, &cfg.workload, &jspec);
        fold(&mut jet, j.tokens_per_s(), j.avg_power_w, j.tokens_per_j());
        let f = facil::run(m, &cfg.workload, &fspec);
        fold(&mut fac, f.tokens_per_s(), f.avg_power_w, f.tokens_per_j());
    }
    vec![jet, fac, chime]
}

fn fold(r: &mut PlatformRange, tps: f64, power: f64, tok_j: f64) {
    r.tps_min = r.tps_min.min(tps);
    r.tps_max = r.tps_max.max(tps);
    r.power_min = r.power_min.min(power);
    r.power_max = r.power_max.max(power);
    r.tok_j_min = r.tok_j_min.min(tok_j);
    r.tok_j_max = r.tok_j_max.max(tok_j);
}

pub fn run() -> Experiment {
    let rows = compute();
    let mut t = Table::new(
        "Table V — edge AI platform comparison (ranges over Table II models)",
        &["platform", "TPS", "power (W)", "tok/J", "tok/s/mm2", "area (mm2)"],
    );
    let mut json_rows = Vec::new();
    for r in &rows {
        t.row(vec![
            r.platform.to_string(),
            format!("{:.1}-{:.1}", r.tps_min, r.tps_max),
            format!("{:.1}-{:.1}", r.power_min, r.power_max),
            format!("{:.2}-{:.2}", r.tok_j_min, r.tok_j_max),
            format!("{:.3}-{:.3}", r.tps_min / r.area_mm2, r.tps_max / r.area_mm2),
            table::f(r.area_mm2, 2),
        ]);
        json_rows.push(Json::obj(vec![
            ("platform", r.platform.into()),
            ("tps_min", r.tps_min.into()),
            ("tps_max", r.tps_max.into()),
            ("power_min", r.power_min.into()),
            ("power_max", r.power_max.into()),
            ("tok_j_min", r.tok_j_min.into()),
            ("tok_j_max", r.tok_j_max.into()),
            ("hw_eff_min", (r.tps_min / r.area_mm2).into()),
            ("hw_eff_max", (r.tps_max / r.area_mm2).into()),
        ]));
    }
    let chime = &rows[2];
    let fac = &rows[1];
    let summary = format!(
        "CHIME/FACIL throughput: {:.1}x-{:.1}x (paper 12.1-69.2x, cross-paired extremes)",
        chime.tps_min / fac.tps_max,
        chime.tps_max / fac.tps_min
    );
    Experiment {
        id: "table5",
        text: format!("{}\n{}\n", t.render(), summary),
        json: Json::obj(vec![
            ("rows", Json::Arr(json_rows)),
            ("paper", Json::obj(vec![
                ("chime_tps", "233-533".into()),
                ("facil_tps", "7.7-19.3".into()),
                ("jetson_tps", "7.4-11".into()),
                ("chime_tok_j", "116.5-266.5".into()),
                ("chime_hw_eff", "4.35-9.95".into()),
            ])),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_matches_paper() {
        let rows = compute();
        let (jet, fac, chime) = (&rows[0], &rows[1], &rows[2]);
        // CHIME >> FACIL >= Jetson on every axis the paper ranks.
        assert!(chime.tps_min > fac.tps_max);
        assert!(fac.tps_max > jet.tps_max);
        assert!(chime.tok_j_min > fac.tok_j_max);
        assert!(chime.power_max < jet.power_min);
    }

    #[test]
    fn chime_facil_ratio_in_band() {
        let rows = compute();
        let lo = rows[2].tps_min / rows[1].tps_max;
        let hi = rows[2].tps_max / rows[1].tps_min;
        // Paper: 12.1x-69.2x.
        assert!(lo > 5.0 && hi < 120.0, "ratio band {lo}-{hi}");
        assert!(hi > lo);
    }

    #[test]
    fn hardware_efficiency_order_of_magnitude() {
        let rows = compute();
        let chime = &rows[2];
        let eff = chime.tps_max / chime.area_mm2;
        // Paper: 4.35-9.95 tok/s/mm2.
        assert!((2.0..20.0).contains(&eff), "hw eff {eff}");
    }
}
