//! Simulator performance benchmark (`chime bench`): wall-clock cost of
//! the simulator itself — simulated tokens/s, engine events/s, and wall
//! time per backend × memory fidelity over the Table II model zoo.
//!
//! Unlike every other module in `results`, the numbers here describe
//! the *simulator*, not the simulated hardware: events/s is the serving
//! event loop's throughput in host wall time, and exists so a perf
//! regression in the scheduling hot path (indexed event selection, SoA
//! bank state, parallel drain — DESIGN.md §11) shows up as a number,
//! not a feeling. `make bench-snapshot` writes the canonical JSON to
//! `BENCH_<pr>.json`; EXPERIMENTS.md tracks the snapshots as a
//! trajectory across PRs.
//!
//! Wall-clock numbers are machine-dependent by nature, so this module
//! is deliberately **not** part of [`super::run_all`] (whose output is
//! locked byte for byte by the `golden_paper` suite) — it is reachable
//! only via `chime bench` and `chime results --fig perf`. The
//! simulated-side numbers in each row (tokens, span, sim tok/s) *are*
//! deterministic, and bit-identical across `sharded4`, `sharded4-par`,
//! and `sharded4-exec` by the parallel-drain and windowed-executor
//! constructions (DESIGN.md §11 and §15).

use std::time::Instant;

use crate::config::{ChimeConfig, MemoryFidelity, MllmConfig};
use crate::coordinator::{BatchPolicy, RoutePolicy, ServeRequest, ShardedServer};
use crate::util::{table, Json, Table};

use super::Experiment;

/// PR number stamped into the snapshots (`BENCH_010.json`,
/// `HOTPATH_010.json`).
pub const PR: usize = 10;

/// The backend variants the matrix sweeps. `Sharded4Par` is the same
/// deployment as `Sharded4` with [`ShardedServer::set_parallel`] on,
/// and `Sharded4Exec` the same with the windowed executor drain
/// ([`ShardedServer::set_threads`] 4, DESIGN.md §15) — both simulated
/// outcomes are bit-identical to `Sharded4`, only the wall time moves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchBackend {
    /// Single-package heterogeneous CHIME simulator.
    Sim,
    /// Single-package DRAM-only ablation plan (Fig 9 baseline).
    DramOnly,
    /// Four packages behind the sharded coordinator, sequential drain.
    Sharded4,
    /// Four packages, parallel per-package drain (scoped threads).
    Sharded4Par,
    /// Four packages, windowed executor drain on 4 worker threads.
    Sharded4Exec,
}

impl BenchBackend {
    pub const ALL: [BenchBackend; 5] = [
        BenchBackend::Sim,
        BenchBackend::DramOnly,
        BenchBackend::Sharded4,
        BenchBackend::Sharded4Par,
        BenchBackend::Sharded4Exec,
    ];

    pub fn name(self) -> &'static str {
        match self {
            BenchBackend::Sim => "sim",
            BenchBackend::DramOnly => "dram-only",
            BenchBackend::Sharded4 => "sharded4",
            BenchBackend::Sharded4Par => "sharded4-par",
            BenchBackend::Sharded4Exec => "sharded4-exec",
        }
    }

    fn packages(self) -> usize {
        match self {
            BenchBackend::Sim | BenchBackend::DramOnly => 1,
            BenchBackend::Sharded4 | BenchBackend::Sharded4Par | BenchBackend::Sharded4Exec => 4,
        }
    }

    fn build(
        self,
        model: &MllmConfig,
        cfg: &ChimeConfig,
        policy: &BatchPolicy,
        exec_threads: usize,
    ) -> ShardedServer {
        let mut srv = match self {
            BenchBackend::DramOnly => ShardedServer::new_dram_only(
                model,
                cfg,
                policy.clone(),
                self.packages(),
                RoutePolicy::RoundRobin,
            ),
            _ => ShardedServer::new(
                model,
                cfg,
                policy.clone(),
                self.packages(),
                RoutePolicy::RoundRobin,
            ),
        };
        srv.set_parallel(self == BenchBackend::Sharded4Par);
        if self == BenchBackend::Sharded4Exec {
            srv.set_threads(exec_threads);
        }
        srv
    }
}

/// Workload + measurement knobs for one bench sweep.
pub struct BenchConfig {
    /// Burst size: requests submitted at virtual t = 0.
    pub requests: usize,
    /// Decode budget per request.
    pub tokens: usize,
    /// Timed repetitions per cell; the row reports the minimum.
    pub iters: usize,
    /// Executor worker threads for the `sharded4-exec` column
    /// (`chime bench --threads N`).
    pub exec_threads: usize,
    pub models: Vec<MllmConfig>,
}

impl BenchConfig {
    /// Default sweep: Table II zoo, 8-request burst, 16 tokens each.
    pub fn paper() -> BenchConfig {
        BenchConfig {
            requests: 8,
            tokens: 16,
            iters: 3,
            exec_threads: 4,
            models: MllmConfig::paper_models(),
        }
    }

    /// CI/test sweep: tiny model only, single timed iteration.
    pub fn quick() -> BenchConfig {
        BenchConfig {
            requests: 4,
            tokens: 8,
            iters: 1,
            exec_threads: 4,
            models: vec![MllmConfig::tiny()],
        }
    }
}

/// One (backend, fidelity, model) measurement.
#[derive(Debug, Clone)]
pub struct PerfPoint {
    pub backend: &'static str,
    pub memory: &'static str,
    pub model: String,
    pub requests: u64,
    /// Tokens generated across the stream (simulated side).
    pub tokens: u64,
    /// Serving events the sequential event loop emits for this stream
    /// (admissions + token/completion events). The parallel variant
    /// processes the same logical events — bit-identical outcome — so
    /// the count is measured once on the sequential instrumented pass.
    pub events: u64,
    /// Best-of-`iters` host wall time for one `serve` call, ns.
    pub wall_ns: f64,
    /// Simulated span covered by the stream (max completion - min
    /// arrival), ns — a *virtual*-time quantity, fidelity-dependent.
    pub sim_span_ns: f64,
    /// Simulated system throughput (tokens per simulated second).
    pub sim_tokens_per_s: f64,
    /// Event-loop throughput: events per host wall second.
    pub events_per_wall_s: f64,
}

fn burst_requests(n: usize, tokens: usize) -> Vec<ServeRequest> {
    (0..n)
        .map(|i| ServeRequest {
            id: i as u64,
            prompt: vec![],
            image_seed: i as u64,
            max_new_tokens: tokens,
            arrival_ns: 0.0,
        })
        .collect()
}

fn measure(
    backend: BenchBackend,
    model: &MllmConfig,
    fidelity: MemoryFidelity,
    bc: &BenchConfig,
) -> PerfPoint {
    let mut cfg = ChimeConfig::default();
    cfg.workload.output_tokens = bc.tokens;
    cfg.hardware.memory_fidelity = fidelity;
    // Small per-package batch so queues form and the event loop actually
    // schedules; capacity holds the whole burst so nothing is rejected.
    let policy = BatchPolicy { max_batch: 2, queue_capacity: bc.requests.max(1) };
    let reqs = burst_requests(bc.requests, bc.tokens);

    // Instrumented pass (untimed): drive the streaming session to count
    // the event stream and take the simulated-side outcome.
    let mut srv = backend.build(model, &cfg, &policy, bc.exec_threads);
    let mut session = srv.open_serving();
    for r in reqs.clone() {
        session.submit(r);
    }
    let events = session.drain().len() as u64;
    let out = session.finish();
    assert!(out.shed.is_empty(), "bench burst must fit the queue capacity");
    let metrics = out.metrics;

    // Timed passes: a fresh server per iteration (KV wear persists across
    // sessions on a reused one), each timing one batch `serve` call — the
    // parallel variant takes its scoped-thread drain inside `finish`.
    let mut wall_ns = f64::INFINITY;
    for _ in 0..bc.iters.max(1) {
        let mut srv = backend.build(model, &cfg, &policy, bc.exec_threads);
        let t0 = Instant::now();
        let timed = srv.serve(reqs.clone());
        let dt_ns = t0.elapsed().as_secs_f64() * 1e9;
        assert_eq!(
            timed.responses.len(),
            out.responses.len(),
            "timed pass served a different stream"
        );
        wall_ns = wall_ns.min(dt_ns);
    }

    PerfPoint {
        backend: backend.name(),
        memory: fidelity.name(),
        model: model.name.clone(),
        requests: metrics.completed,
        tokens: metrics.tokens,
        events,
        wall_ns,
        sim_span_ns: metrics.span_ns(),
        sim_tokens_per_s: metrics.tokens_per_s(),
        events_per_wall_s: if wall_ns > 0.0 { events as f64 / (wall_ns / 1e9) } else { 0.0 },
    }
}

/// Sweep the full matrix: model × fidelity × backend variant.
pub fn compute(bc: &BenchConfig) -> Vec<PerfPoint> {
    let mut out = Vec::new();
    for m in &bc.models {
        for fidelity in [MemoryFidelity::FirstOrder, MemoryFidelity::CycleAccurate] {
            for backend in BenchBackend::ALL {
                out.push(measure(backend, m, fidelity, bc));
            }
        }
    }
    out
}

/// The canonical-JSON snapshot (`BENCH_<pr>.json`). Wall-clock fields
/// are machine-dependent; everything else is deterministic.
pub fn snapshot_json(points: &[PerfPoint], bc: &BenchConfig) -> Json {
    let rows = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("backend", p.backend.into()),
                ("memory", p.memory.into()),
                ("model", p.model.as_str().into()),
                ("requests", (p.requests as i64).into()),
                ("tokens", (p.tokens as i64).into()),
                ("events", (p.events as i64).into()),
                ("wall_ns", p.wall_ns.into()),
                ("sim_span_ns", p.sim_span_ns.into()),
                ("sim_tokens_per_s", p.sim_tokens_per_s.into()),
                ("events_per_wall_s", p.events_per_wall_s.into()),
            ])
        })
        .collect();
    Json::obj(vec![
        ("bench", "chime simulator performance".into()),
        ("pr", PR.into()),
        (
            "config",
            Json::obj(vec![
                ("requests", bc.requests.into()),
                ("tokens_per_request", bc.tokens.into()),
                ("iters", bc.iters.into()),
                ("exec_threads", bc.exec_threads.into()),
                (
                    "models",
                    Json::Arr(bc.models.iter().map(|m| m.name.as_str().into()).collect()),
                ),
            ]),
        ),
        ("rows", Json::Arr(rows)),
    ])
}

pub fn run() -> Experiment {
    run_with(&BenchConfig::paper())
}

/// `chime bench --profile`: self-profile the serving hot path and report
/// host wall time per instrumented span class (tick / submit /
/// steal_pass). Runs the sharded deployment — work stealing on, so every
/// class is exercised — over the sweep's models at both fidelities with
/// the observability profiler enabled, and aggregates the per-class
/// wall-clock totals into the `HOTPATH_<pr>.json` baseline (ROADMAP
/// item 4). Wall times are machine-dependent; calls-per-class are
/// deterministic for a fixed config.
pub fn profile_with(bc: &BenchConfig) -> Experiment {
    let mut totals: std::collections::BTreeMap<&'static str, (u64, f64)> =
        std::collections::BTreeMap::new();
    for m in &bc.models {
        for fidelity in [MemoryFidelity::FirstOrder, MemoryFidelity::CycleAccurate] {
            let mut cfg = ChimeConfig::default();
            cfg.workload.output_tokens = bc.tokens;
            cfg.hardware.memory_fidelity = fidelity;
            let policy = BatchPolicy { max_batch: 2, queue_capacity: bc.requests.max(1) };
            let mut srv = BenchBackend::Sharded4.build(m, &cfg, &policy, bc.exec_threads);
            srv.set_work_stealing(true);
            srv.set_profiling(true);
            for _ in 0..bc.iters.max(1) {
                let out = srv.serve(burst_requests(bc.requests, bc.tokens));
                assert!(out.shed.is_empty(), "profile burst must fit the queue capacity");
            }
            let tracer = srv.take_trace().expect("profiling installs a tracer");
            for (&class, &(calls, wall_ns)) in tracer.profile_entries() {
                let e = totals.entry(class).or_insert((0, 0.0));
                e.0 += calls;
                e.1 += wall_ns;
            }
        }
    }
    let grand_total_ns: f64 = totals.values().map(|&(_, ns)| ns).sum();
    let mut t = Table::new(
        "Bench — serving hot-path profile (wall clock per span class, machine-dependent)",
        &["span class", "calls", "wall (ms)", "mean (us)", "share"],
    );
    let mut rows = Vec::new();
    for (&class, &(calls, wall_ns)) in &totals {
        let mean_ns = if calls > 0 { wall_ns / calls as f64 } else { 0.0 };
        let share = if grand_total_ns > 0.0 { wall_ns / grand_total_ns } else { 0.0 };
        t.row(vec![
            class.to_string(),
            calls.to_string(),
            table::f(wall_ns / 1e6, 3),
            table::f(mean_ns / 1e3, 2),
            format!("{:.1}%", share * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("class", class.into()),
            ("calls", (calls as i64).into()),
            ("wall_ns", wall_ns.into()),
            ("mean_ns", mean_ns.into()),
            ("share", share.into()),
        ]));
    }
    let json = Json::obj(vec![
        ("bench", "chime serving hot-path profile".into()),
        ("pr", PR.into()),
        (
            "config",
            Json::obj(vec![
                ("requests", bc.requests.into()),
                ("tokens_per_request", bc.tokens.into()),
                ("iters", bc.iters.into()),
                (
                    "models",
                    Json::Arr(bc.models.iter().map(|m| m.name.as_str().into()).collect()),
                ),
            ]),
        ),
        ("total_wall_ns", grand_total_ns.into()),
        ("spans", Json::Arr(rows)),
    ]);
    Experiment { id: "hotpath", text: t.render(), json }
}

pub fn run_with(bc: &BenchConfig) -> Experiment {
    let points = compute(bc);
    let mut t = Table::new(
        "Bench — simulator wall-clock performance (events/s, machine-dependent)",
        &["backend", "memory", "model", "reqs", "tokens", "events", "wall (ms)",
          "sim span (ms)", "sim tok/s", "events/s"],
    );
    for p in &points {
        t.row(vec![
            p.backend.to_string(),
            p.memory.to_string(),
            p.model.clone(),
            p.requests.to_string(),
            p.tokens.to_string(),
            p.events.to_string(),
            table::f(p.wall_ns / 1e6, 3),
            table::f(p.sim_span_ns / 1e6, 3),
            table::f(p.sim_tokens_per_s, 1),
            table::f(p.events_per_wall_s, 0),
        ]);
    }
    Experiment { id: "perf", text: t.render(), json: snapshot_json(&points, bc) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_bench_covers_the_matrix_and_parallel_matches_sequential_sim_side() {
        let bc = BenchConfig::quick();
        let pts = compute(&bc);
        assert_eq!(pts.len(), bc.models.len() * 2 * BenchBackend::ALL.len());
        for p in &pts {
            assert_eq!(p.requests, bc.requests as u64, "{}: burst must fully complete", p.backend);
            assert_eq!(p.tokens, (bc.requests * bc.tokens) as u64);
            assert!(p.events > 0, "{}: event stream must be observed", p.backend);
            assert!(p.wall_ns > 0.0 && p.wall_ns.is_finite());
            assert!(p.events_per_wall_s > 0.0);
            assert!(p.sim_span_ns > 0.0 && p.sim_tokens_per_s > 0.0);
        }
        // The parallel variants are the same simulation: every simulated-
        // side number matches the sequential row bit for bit.
        for memory in ["first-order", "cycle"] {
            let find = |b: &str| pts.iter().find(|p| p.backend == b && p.memory == memory).unwrap();
            let seq = find("sharded4");
            for variant in ["sharded4-par", "sharded4-exec"] {
                let par = find(variant);
                assert_eq!(par.tokens, seq.tokens, "{variant}/{memory}");
                assert_eq!(par.events, seq.events, "{variant}/{memory}");
                assert_eq!(par.sim_span_ns.to_bits(), seq.sim_span_ns.to_bits());
                assert_eq!(par.sim_tokens_per_s.to_bits(), seq.sim_tokens_per_s.to_bits());
            }
        }
    }

    #[test]
    fn snapshot_json_is_canonical_and_stamped() {
        let bc = BenchConfig::quick();
        let pts = compute(&bc);
        let s = snapshot_json(&pts, &bc).pretty();
        assert!(s.contains(&format!("\"pr\": {PR}")));
        assert!(s.contains("\"events_per_wall_s\""));
        assert!(s.contains("\"sharded4-par\""));
        assert!(s.contains("\"sharded4-exec\""));
    }

    #[test]
    fn profile_reports_wall_time_per_span_class() {
        let e = profile_with(&BenchConfig::quick());
        let spans = e.json.get("spans").as_arr().unwrap().clone();
        assert!(!spans.is_empty(), "profiled run must record span classes");
        let classes: Vec<&str> =
            spans.iter().filter_map(|s| s.get("class").as_str()).collect();
        for required in ["tick", "submit"] {
            assert!(classes.contains(&required), "missing class {required:?} in {classes:?}");
        }
        let mut share_sum = 0.0;
        for s in &spans {
            assert!(s.get("calls").as_i64().unwrap() > 0);
            assert!(s.get("wall_ns").as_f64().unwrap() >= 0.0);
            share_sum += s.get("share").as_f64().unwrap();
        }
        assert!((share_sum - 1.0).abs() < 1e-9, "shares must sum to 1, got {share_sum}");
        assert!(e.json.pretty().contains(&format!("\"pr\": {PR}")));
        assert!(e.text.contains("span class"));
    }
}
