//! Ablation studies for CHIME's design choices (beyond the paper's Fig 9
//! memory ablation — these exercise the knobs DESIGN.md calls out):
//!
//!   * **fusion off** — one NMP kernel per operator instead of the Table I
//!     fused schedule: every kernel pays dispatch, and intermediates
//!     write back to memory instead of staying in on-die SRAM;
//!   * **tiering off** — KV placed in the *slowest* tier instead of the
//!     endurance-aware hot-first policy;
//!   * **UCIe bandwidth sensitivity** — the two-cut-point dataflow's whole
//!     point is that link bandwidth barely matters; sweep it to show how
//!     little traffic crosses the package.

use crate::config::{ChimeConfig, MllmConfig};
use crate::mapping::Plan;
use crate::sim::{self, SimEngine};
use crate::util::{table, Json, Table};

use super::Experiment;

/// Fusion-off decode step: split every fused kernel into per-op kernels
/// that each pay dispatch and materialize their activation boundary.
fn defused_step_time(model: &MllmConfig, cfg: &ChimeConfig, pos: usize) -> f64 {
    let plan = Plan::build(model, &cfg.hardware, &cfg.workload);
    let mut engine = SimEngine::new(&cfg.hardware, &plan);
    let kernels = plan.decode_kernels(pos);
    let mut unfused = Vec::new();
    for k in &kernels {
        for op in &k.ops {
            let mut solo = k.clone();
            solo.ops = vec![op.clone()];
            // Intermediates that fusion kept in PU SRAM now round-trip
            // through the memory arrays: bill them as extra KV-free
            // streaming bytes on the owning chiplet (weight_bytes channel).
            solo.ops[0].weight_bytes += op.act_out_bytes;
            unfused.push(solo);
        }
    }
    engine.run_kernels(&unfused).time_ns
}

fn fused_step_time(model: &MllmConfig, cfg: &ChimeConfig, pos: usize) -> f64 {
    let plan = Plan::build(model, &cfg.hardware, &cfg.workload);
    let mut engine = SimEngine::new(&cfg.hardware, &plan);
    let kernels = plan.decode_kernels(pos);
    engine.run_kernels(&kernels).time_ns
}

/// Tiering-off: price this model's steady-state KV scan as if every block
/// lived in the slowest tier, vs the tiered mix the policy produces.
fn kv_scan_penalty_no_tiering(model: &MllmConfig, cfg: &ChimeConfig) -> (f64, f64) {
    let d = &cfg.hardware.dram;
    let kv_bytes = model.llm.kv_bytes_per_token()
        * (cfg.workload.text_tokens + model.visual_tokens() + cfg.workload.output_tokens) as u64;
    let tiered_ns = kv_bytes as f64 / d.tier_stream_bw_gbps(0, 1.0); // hot policy: tier 0
    let flat_ns = kv_bytes as f64 / d.tier_stream_bw_gbps(d.tiers - 1, 1.0);
    (tiered_ns, flat_ns)
}

pub fn run() -> Experiment {
    let cfg = ChimeConfig::default();
    let mut text = String::new();
    let mut json = Vec::new();

    // --- fusion ablation -----------------------------------------------
    let mut t = Table::new(
        "Ablation A — kernel fusion (Table I) on vs off (decode step)",
        &["model", "fused step", "unfused step", "fusion speedup"],
    );
    for m in [MllmConfig::fastvlm_0_6b(), MllmConfig::mobilevlm_3b()] {
        let pos = 192 + 488;
        let fused = fused_step_time(&m, &cfg, pos);
        let unfused = defused_step_time(&m, &cfg, pos);
        t.row(vec![
            m.name.clone(),
            format!("{:.2} ms", fused / 1e6),
            format!("{:.2} ms", unfused / 1e6),
            table::x(unfused / fused),
        ]);
        json.push(Json::obj(vec![
            ("ablation", "fusion".into()),
            ("model", m.name.as_str().into()),
            ("speedup", (unfused / fused).into()),
        ]));
    }
    text.push_str(&t.render());

    // --- tiering ablation ------------------------------------------------
    let mut t = Table::new(
        "Ablation B — KV tiering: hot-first vs all-in-slowest-tier (per-step KV scan)",
        &["model", "tiered scan", "untiered scan", "tiering speedup"],
    );
    for m in MllmConfig::paper_models() {
        let (tiered, flat) = kv_scan_penalty_no_tiering(&m, &cfg);
        t.row(vec![
            m.name.clone(),
            format!("{:.1} µs", tiered / 1e3),
            format!("{:.1} µs", flat / 1e3),
            table::x(flat / tiered),
        ]);
        json.push(Json::obj(vec![
            ("ablation", "tiering".into()),
            ("model", m.name.as_str().into()),
            ("speedup", (flat / tiered).into()),
        ]));
    }
    text.push_str(&t.render());

    // --- UCIe bandwidth sensitivity --------------------------------------
    let mut t = Table::new(
        "Ablation C — UCIe link bandwidth sensitivity (two-cut-point traffic)",
        &["link GB/s", "fastvlm-0.6b TPS", "mobilevlm-3b TPS"],
    );
    for bw in [16.0, 32.0, 64.0, 128.0, 256.0] {
        let mut c = cfg.clone();
        c.hardware.ucie.bandwidth_gbps = bw;
        let a = sim::simulate(&MllmConfig::fastvlm_0_6b(), &c).tokens_per_s();
        let b = sim::simulate(&MllmConfig::mobilevlm_3b(), &c).tokens_per_s();
        t.row(vec![format!("{bw:.0}"), table::f(a, 1), table::f(b, 1)]);
        json.push(Json::obj(vec![
            ("ablation", "ucie_bw".into()),
            ("bw_gbps", bw.into()),
            ("fastvlm_tps", a.into()),
            ("mobilevlm_tps", b.into()),
        ]));
    }
    text.push_str(&t.render());
    text.push_str(
        "\nThe flat TPS across an 16x UCIe range is the design working as \
         intended: only AttnOut/FFNOut cross the package.\n",
    );

    Experiment { id: "ablations", text, json: Json::Arr(json) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fusion_saves_meaningful_time() {
        let cfg = ChimeConfig::default();
        let m = MllmConfig::fastvlm_0_6b();
        let fused = fused_step_time(&m, &cfg, 500);
        let unfused = defused_step_time(&m, &cfg, 500);
        assert!(unfused > fused * 1.3, "fusion gain {:.2}x", unfused / fused);
    }

    #[test]
    fn tiering_saves_kv_scan_time() {
        let cfg = ChimeConfig::default();
        for m in MllmConfig::paper_models() {
            let (tiered, flat) = kv_scan_penalty_no_tiering(&m, &cfg);
            assert!(flat > tiered * 1.5, "{}: {:.2}x", m.name, flat / tiered);
        }
    }

    #[test]
    fn ucie_bandwidth_barely_matters() {
        // The two-cut-point dataflow's defining property.
        let base = ChimeConfig::default();
        let mut narrow = base.clone();
        narrow.hardware.ucie.bandwidth_gbps = 16.0;
        let m = MllmConfig::mobilevlm_3b();
        let wide_tps = sim::simulate(&m, &base).tokens_per_s();
        let narrow_tps = sim::simulate(&m, &narrow).tokens_per_s();
        assert!(
            narrow_tps > wide_tps * 0.9,
            "an 8x narrower link must cost <10% ({} vs {})",
            narrow_tps,
            wide_tps
        );
    }
}
