//! Fig 6: CHIME vs Jetson Orin NX across the four Table II models.
//! (a) speedup + energy-efficiency gain; (b) throughput (TPS) + power.
//!
//! Paper claims: ~41x mean speedup (31–54x), ~185x mean energy gain
//! (113–246x); CHIME 233–533 TPS @ ~2 W vs Jetson 7–11 TPS.

use crate::baselines::jetson;
use crate::config::{ChimeConfig, JetsonSpec, MllmConfig};
use crate::sim;
use crate::util::{table, Json, Table};

use super::Experiment;

pub struct Fig6Row {
    pub model: String,
    pub chime_tps: f64,
    pub chime_tok_per_j: f64,
    pub chime_power_w: f64,
    pub jetson_tps: f64,
    pub jetson_tok_per_j: f64,
    pub jetson_power_w: f64,
    pub speedup: f64,
    pub energy_gain: f64,
}

pub fn compute() -> Vec<Fig6Row> {
    let cfg = ChimeConfig::default();
    let spec = JetsonSpec::default();
    MllmConfig::paper_models()
        .iter()
        .map(|m| {
            let c = sim::simulate(m, &cfg);
            let j = jetson::run(m, &cfg.workload, &spec);
            Fig6Row {
                model: m.name.clone(),
                chime_tps: c.tokens_per_s(),
                chime_tok_per_j: c.tokens_per_j(),
                chime_power_w: c.avg_power_w(),
                jetson_tps: j.tokens_per_s(),
                jetson_tok_per_j: j.tokens_per_j(),
                jetson_power_w: j.avg_power_w,
                speedup: c.tokens_per_s() / j.tokens_per_s(),
                energy_gain: c.tokens_per_j() / j.tokens_per_j(),
            }
        })
        .collect()
}

pub fn run() -> Experiment {
    let rows = compute();
    let mut t = Table::new(
        "Fig 6 — CHIME vs Jetson Orin NX (default VQA: 512x512, 128 in, 488 out)",
        &["model", "chime TPS", "jetson TPS", "speedup", "chime tok/J",
          "jetson tok/J", "energy gain", "chime W", "jetson W"],
    );
    let mut json_rows = Vec::new();
    for r in &rows {
        t.row(vec![
            r.model.clone(),
            table::f(r.chime_tps, 1),
            table::f(r.jetson_tps, 1),
            table::x(r.speedup),
            table::f(r.chime_tok_per_j, 1),
            table::f(r.jetson_tok_per_j, 2),
            table::x(r.energy_gain),
            table::f(r.chime_power_w, 2),
            table::f(r.jetson_power_w, 1),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", r.model.as_str().into()),
            ("chime_tps", r.chime_tps.into()),
            ("jetson_tps", r.jetson_tps.into()),
            ("speedup", r.speedup.into()),
            ("chime_tok_per_j", r.chime_tok_per_j.into()),
            ("jetson_tok_per_j", r.jetson_tok_per_j.into()),
            ("energy_gain", r.energy_gain.into()),
            ("chime_power_w", r.chime_power_w.into()),
        ]));
    }
    let mean_speedup = rows.iter().map(|r| r.speedup).sum::<f64>() / rows.len() as f64;
    let mean_gain = rows.iter().map(|r| r.energy_gain).sum::<f64>() / rows.len() as f64;
    let summary = format!(
        "mean speedup {:.1}x (paper ~41x, 31-54x); mean energy gain {:.1}x (paper ~185x, 113-246x)",
        mean_speedup, mean_gain
    );
    Experiment {
        id: "fig6",
        text: format!("{}\n{}\n", t.render(), summary),
        json: Json::obj(vec![
            ("rows", Json::Arr(json_rows)),
            ("mean_speedup", mean_speedup.into()),
            ("mean_energy_gain", mean_gain.into()),
            ("paper", Json::obj(vec![
                ("speedup_range", "31-54x".into()),
                ("energy_range", "113-246x".into()),
                ("chime_tps_range", "233-533".into()),
                ("jetson_tps_range", "7.4-11".into()),
            ])),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedups_in_paper_ballpark() {
        for r in compute() {
            assert!(
                (15.0..80.0).contains(&r.speedup),
                "{}: speedup {} out of shape",
                r.model,
                r.speedup
            );
            assert!(r.energy_gain > 50.0, "{}: gain {}", r.model, r.energy_gain);
        }
    }

    #[test]
    fn smaller_family_member_gains_more() {
        // Paper: "gains are larger for the smaller variants in each family".
        let rows = compute();
        let get = |n: &str| rows.iter().find(|r| r.model == n).unwrap().speedup;
        assert!(get("fastvlm-0.6b") > get("fastvlm-1.7b"));
        assert!(get("mobilevlm-1.7b") > get("mobilevlm-3b"));
    }

    #[test]
    fn chime_power_in_edge_envelope() {
        for r in compute() {
            assert!(r.chime_power_w < 4.0, "{}: {} W", r.model, r.chime_power_w);
        }
    }
}
