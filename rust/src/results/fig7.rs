//! Fig 7: CHIME logic-die area breakdown (a: DRAM die, b: RRAM die) and
//! power breakdown (c: FastVLM 0.6B, d: MobileVLM 1.7B).
//!
//! Paper claims: DRAM die — peripherals 51.5%, UCIe PHY 22.3%, PUs 26.2%;
//! RRAM die — PU share 34.0%; power — RRAM dominates (it runs the FFN),
//! UCIe ~1 W, power stable across models.

use crate::config::{ChimeConfig, MllmConfig};
use crate::sim;
use crate::sim::energy::Component;
use crate::util::{table, Json, Table};

use super::Experiment;

pub fn run() -> Experiment {
    let cfg = ChimeConfig::default();
    let area = &cfg.hardware.area;

    // (a)/(b) Area breakdowns are design constants (Synopsys synthesis in
    // the paper; Table-derived constants here).
    let mut ta = Table::new(
        "Fig 7(a) — M3D DRAM logic-die area breakdown",
        &["block", "share", "mm2"],
    );
    let dram_total = area.dram_logic_die_mm2;
    for (name, frac) in [
        ("peripherals", area.dram_peripheral_frac),
        ("UCIe PHY", area.dram_ucie_frac),
        ("PUs", area.dram_pu_frac),
    ] {
        ta.row(vec![name.into(), table::pct(frac), table::f(dram_total * frac, 2)]);
    }
    let mut tb = Table::new(
        "Fig 7(b) — M3D RRAM logic-die area breakdown",
        &["block", "share", "mm2"],
    );
    let rram_total = area.rram_logic_die_mm2;
    let rram_pu = area.rram_pu_frac;
    let rram_ucie = area.dram_ucie_frac * dram_total / rram_total; // same PHY macro
    let rram_periph = 1.0 - rram_pu - rram_ucie;
    for (name, frac) in [
        ("peripherals", rram_periph),
        ("UCIe PHY", rram_ucie),
        ("PUs", rram_pu),
    ] {
        tb.row(vec![name.into(), table::pct(frac), table::f(rram_total * frac, 2)]);
    }

    // (c)/(d) Power breakdowns from the simulator's energy ledger.
    let mut power_rows = Vec::new();
    let mut text = format!("{}\n{}", ta.render(), tb.render());
    for (fig, model) in [("c", MllmConfig::fastvlm_0_6b()), ("d", MllmConfig::mobilevlm_1_7b())] {
        let stats = sim::simulate(&model, &cfg);
        let ledger = stats.energy();
        let time_ns = stats.total_time_ns();
        let mut t = Table::new(
            &format!("Fig 7({fig}) — power breakdown, {}", model.name),
            &["component", "avg W", "share"],
        );
        let total_w = ledger.avg_power_w(time_ns);
        let mut comps = Vec::new();
        for (c, frac) in ledger.breakdown() {
            let w = total_w * frac;
            t.row(vec![c.name().into(), table::f(w, 3), table::pct(frac)]);
            comps.push(Json::obj(vec![
                ("component", c.name().into()),
                ("watts", w.into()),
                ("share", frac.into()),
            ]));
        }
        t.row(vec!["TOTAL".into(), table::f(total_w, 3), table::pct(1.0)]);
        text.push_str(&format!("\n{}", t.render()));
        power_rows.push(Json::obj(vec![
            ("model", model.name.as_str().into()),
            ("total_w", total_w.into()),
            ("components", Json::Arr(comps)),
            ("rram_share",
             (ledger.get(Component::RramArray) + ledger.get(Component::RramNmp))
                 .map_share(&ledger)),
        ]));
    }

    Experiment {
        id: "fig7",
        text,
        json: Json::obj(vec![
            ("area_dram", Json::obj(vec![
                ("peripherals", area.dram_peripheral_frac.into()),
                ("ucie", area.dram_ucie_frac.into()),
                ("pus", area.dram_pu_frac.into()),
            ])),
            ("area_rram_pu_share", rram_pu.into()),
            ("power", Json::Arr(power_rows)),
            ("paper", Json::obj(vec![
                ("dram_peripheral", (0.515).into()),
                ("dram_ucie", (0.223).into()),
                ("dram_pu", (0.262).into()),
                ("rram_pu", (0.34).into()),
                ("ucie_power_w", (1.0).into()),
            ])),
        ]),
    }
}

// Small helper: share of total as Json.
trait ShareExt {
    fn map_share(self, ledger: &crate::sim::energy::EnergyLedger) -> Json;
}
impl ShareExt for f64 {
    fn map_share(self, ledger: &crate::sim::energy::EnergyLedger) -> Json {
        Json::Num(self / ledger.total_pj().max(1e-30))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_fractions_match_paper_constants() {
        let e = run();
        let a = e.json.get("area_dram");
        assert!((a.get("peripherals").as_f64().unwrap() - 0.515).abs() < 1e-9);
        assert!((a.get("ucie").as_f64().unwrap() - 0.223).abs() < 1e-9);
        assert!((a.get("pus").as_f64().unwrap() - 0.262).abs() < 1e-9);
    }

    #[test]
    fn power_stable_across_models() {
        // Paper: "power stays stable across models".
        let e = run();
        let p = e.json.get("power").as_arr().unwrap();
        let w0 = p[0].get("total_w").as_f64().unwrap();
        let w1 = p[1].get("total_w").as_f64().unwrap();
        assert!((w0 / w1 - 1.0).abs() < 0.5, "power {w0} vs {w1} not stable");
    }

    #[test]
    fn rram_side_dominates_power() {
        // Paper: "RRAM dominates because it runs the data-intensive FFN".
        let e = run();
        for model in e.json.get("power").as_arr().unwrap() {
            let share = model.get("rram_share").as_f64().unwrap();
            let comps = model.get("components").as_arr().unwrap();
            let dram_share: f64 = comps
                .iter()
                .filter(|c| c.get("component").as_str().unwrap().starts_with("dram"))
                .map(|c| c.get("share").as_f64().unwrap())
                .sum();
            assert!(share > dram_share, "rram {share} <= dram {dram_share}");
        }
    }
}
