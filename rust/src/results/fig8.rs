//! Fig 8: sequence-length sensitivity — latency (a) and energy per
//! inference (b) as text length grows 128 -> 4k tokens.
//!
//! Paper claims: both grow roughly linearly (about an order of magnitude
//! from 128 to 4k); larger models have steeper slopes; gaps narrow at
//! short contexts (encoder/connector amortization) and widen at long
//! contexts (decode dominates).

use crate::config::{ChimeConfig, MemoryFidelity, MllmConfig, TopologyKind, WorkloadConfig};
use crate::sim;
use crate::util::{table, Json, Table};

use super::Experiment;

pub const LENGTHS: [usize; 6] = [128, 256, 512, 1024, 2048, 4096];

pub struct SweepPoint {
    pub model: String,
    pub text_len: usize,
    pub latency_ms: f64,
    pub energy_j: f64,
    pub kv_offloaded_mb: f64,
}

pub fn compute() -> Vec<SweepPoint> {
    compute_with(MemoryFidelity::FirstOrder, TopologyKind::PointToPoint)
}

/// Sweep at an explicit memory fidelity and fabric topology (`chime
/// sweep --memory cycle --topology ring`). The default path is
/// byte-identical to [`compute`]; the sweep is single-package, where
/// every topology is identical by construction (`sim::fabric`), so the
/// topology knob is threaded into the config for CLI uniformity without
/// changing any number.
pub fn compute_with(fidelity: MemoryFidelity, topology: TopologyKind) -> Vec<SweepPoint> {
    let mut cfg = ChimeConfig::default();
    cfg.hardware.memory_fidelity = fidelity;
    cfg.hardware.topology.kind = topology;
    let mut out = Vec::new();
    for m in MllmConfig::paper_models() {
        for &len in &LENGTHS {
            let w = WorkloadConfig {
                image_size: cfg.workload.image_size,
                text_tokens: len,
                output_tokens: cfg.workload.output_tokens,
            };
            let s = sim::simulate_with_workload(&m, &cfg, &w);
            out.push(SweepPoint {
                model: m.name.clone(),
                text_len: len,
                latency_ms: s.total_time_ns() / 1e6,
                energy_j: s.total_energy_j(),
                kv_offloaded_mb: s.kv_offloaded_bytes as f64 / 1e6,
            });
        }
    }
    out
}

pub fn run() -> Experiment {
    run_with(MemoryFidelity::FirstOrder, TopologyKind::PointToPoint)
}

/// The Fig 8 experiment at an explicit memory fidelity and fabric
/// topology. The defaults are byte-identical to [`run`] (the golden
/// snapshot path).
pub fn run_with(fidelity: MemoryFidelity, topology: TopologyKind) -> Experiment {
    let points = compute_with(fidelity, topology);
    let mut t = Table::new(
        "Fig 8 — sequence-length sensitivity (128 -> 4k text tokens, 488 out)",
        &["model", "text len", "latency (ms)", "energy (J)", "KV offloaded (MB)"],
    );
    let mut json_rows = Vec::new();
    for p in &points {
        t.row(vec![
            p.model.clone(),
            p.text_len.to_string(),
            table::f(p.latency_ms, 1),
            table::f(p.energy_j, 3),
            table::f(p.kv_offloaded_mb, 1),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", p.model.as_str().into()),
            ("text_len", p.text_len.into()),
            ("latency_ms", p.latency_ms.into()),
            ("energy_j", p.energy_j.into()),
            ("kv_offloaded_mb", p.kv_offloaded_mb.into()),
        ]));
    }
    Experiment {
        id: "fig8",
        text: t.render(),
        json: Json::obj(vec![
            ("points", Json::Arr(json_rows)),
            ("paper", Json::obj(vec![
                ("scaling", "near-linear, ~order of magnitude 128->4k".into()),
                ("slope", "larger models steeper".into()),
            ])),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series<'a>(points: &'a [SweepPoint], model: &str) -> Vec<&'a SweepPoint> {
        points.iter().filter(|p| p.model == model).collect()
    }

    #[test]
    fn latency_monotone_in_length() {
        let pts = compute();
        for m in ["fastvlm-0.6b", "mobilevlm-3b"] {
            let s = series(&pts, m);
            for w in s.windows(2) {
                assert!(w[1].latency_ms > w[0].latency_ms, "{m} not monotone");
            }
        }
    }

    #[test]
    fn growth_accelerates_with_length() {
        // Decode streams the KV prefix every step, so latency grows
        // superlinearly-in-context overall but each doubling should at
        // least grow, and 4k should be several x the 128 point.
        let pts = compute();
        for m in ["fastvlm-1.7b", "mobilevlm-3b"] {
            let s = series(&pts, m);
            let first = s.first().unwrap().latency_ms;
            let last = s.last().unwrap().latency_ms;
            assert!(last / first > 1.5, "{m}: {first} -> {last}");
        }
    }

    #[test]
    fn larger_model_steeper_slope() {
        let pts = compute();
        let small = series(&pts, "fastvlm-0.6b");
        let big = series(&pts, "mobilevlm-3b");
        let slope = |s: &[&SweepPoint]| {
            (s.last().unwrap().latency_ms - s[0].latency_ms)
                / (s.last().unwrap().text_len - s[0].text_len) as f64
        };
        assert!(slope(&big) > slope(&small));
    }

    #[test]
    fn energy_tracks_latency() {
        let pts = compute();
        for m in ["mobilevlm-1.7b"] {
            let s = series(&pts, m);
            for w in s.windows(2) {
                assert!(w[1].energy_j > w[0].energy_j, "{m} energy not monotone");
            }
        }
    }
}
