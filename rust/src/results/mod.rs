//! Paper-results harness: regenerates every table and figure of the
//! paper's evaluation section (DESIGN.md §5 per-experiment index).
//!
//! Each module prints the same rows/series the paper reports and returns
//! a JSON blob for EXPERIMENTS.md. Absolute numbers come from this repo's
//! simulator; the *shape* (orderings, ratios, crossovers) is the
//! reproduction target.

pub mod ablations;
pub mod fabric;
pub mod fig1;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod memcheck;
pub mod perf;
pub mod scaling;
pub mod table5;
pub mod tail;

use crate::util::Json;

/// One experiment's rendered output.
pub struct Experiment {
    pub id: &'static str,
    pub text: String,
    pub json: Json,
}

/// Run every experiment (the `chime results --all` path).
pub fn run_all() -> Vec<Experiment> {
    vec![
        fig1::run(),
        fig6::run(),
        table5::run(),
        fig7::run(),
        fig8::run(),
        fig9::run(),
        ablations::run(),
        scaling::run(),
        memcheck::run(),
        tail::run(),
    ]
}

/// Run one experiment by id ("1", "6", "7", "8", "9", "table5",
/// "scaling", "memcheck", "tail", "perf", "fabric").
///
/// "perf" and "fabric" are reachable only here (perf also via
/// `chime bench`), never from [`run_all`]: perf's wall-clock columns are
/// machine-dependent, fabric post-dates the lock, and the `--all` output
/// is locked byte for byte by the `golden_paper` suite.
pub fn run_one(id: &str) -> Option<Experiment> {
    match id {
        "1" | "fig1" => Some(fig1::run()),
        "6" | "fig6" => Some(fig6::run()),
        "7" | "fig7" => Some(fig7::run()),
        "8" | "fig8" => Some(fig8::run()),
        "9" | "fig9" => Some(fig9::run()),
        "5" | "table5" => Some(table5::run()),
        "ablations" | "a" => Some(ablations::run()),
        "scaling" | "packages" => Some(scaling::run()),
        "memcheck" | "mem" => Some(memcheck::run()),
        "tail" | "latency" => Some(tail::run()),
        "perf" | "bench" => Some(perf::run()),
        "fabric" | "links" => Some(fabric::run()),
        _ => None,
    }
}
