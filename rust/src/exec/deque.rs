//! Lock-free Chase-Lev work-stealing deque (DESIGN.md §15).
//!
//! One owner, many thieves: the worker that owns the deque pushes and
//! pops at the *bottom* in LIFO order (hot work stays cache-warm), while
//! any number of [`Stealer`] clones take from the *top* in FIFO order
//! (the oldest queued item moves, which is also the fairest one to
//! migrate). The only synchronized contention point is the last item,
//! resolved by a single compare-exchange on `top`.
//!
//! The implementation follows the classic formulation of Chase & Lev
//! ("Dynamic circular work-stealing deque", SPAA '05) with the C11
//! memory orderings of Lê et al. ("Correct and efficient work-stealing
//! for weak memory models", PPoPP '13), written here from first
//! principles over `std::sync::atomic` — no dependencies, per the
//! repo-wide std-only rule.
//!
//! Memory reclamation is grow-by-retire: when the circular buffer fills,
//! the owner allocates a doubled buffer, copies the live window, and
//! *retires* the old allocation instead of freeing it (a thief may still
//! be reading a slot). Retired buffers are freed when the shared state
//! drops — bounded by O(capacity) total, since sizes double.
//!
//! Items are returned by value; `T` must be `Send` because items cross
//! from the owner thread to thief threads. The deque makes no `Sync`
//! demand on `T` — each item is only ever observed by the one thread
//! that popped or stole it.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicIsize, AtomicPtr, Ordering};
use std::sync::{Arc, Mutex};

/// A circular buffer of `MaybeUninit`-like raw slots. Slot reads/writes
/// race by design (a thief may read a slot the owner is overwriting);
/// the Chase-Lev index protocol guarantees a racing read is never
/// *used* — the compare-exchange on `top` fails for the loser — so the
/// value-copy is done with volatile-free raw pointer reads on
/// `ManuallyDrop`-semantics storage.
struct Buffer<T> {
    /// Power-of-two capacity; index masking is `i & (cap - 1)`.
    cap: usize,
    /// Raw storage. Slots hold bitwise copies of `T`; ownership is
    /// tracked purely by the `top`/`bottom` indices.
    slots: *mut T,
}

impl<T> Buffer<T> {
    fn alloc(cap: usize) -> Buffer<T> {
        debug_assert!(cap.is_power_of_two());
        let mut v = Vec::<T>::with_capacity(cap);
        let slots = v.as_mut_ptr();
        std::mem::forget(v);
        Buffer { cap, slots }
    }

    /// Reconstitute the allocation for drop. Length 0: the live items
    /// were either taken (and dropped elsewhere) or copied into a grown
    /// buffer, so the storage is freed without running destructors.
    unsafe fn dealloc(&self) {
        drop(Vec::from_raw_parts(self.slots, 0, self.cap));
    }

    unsafe fn write(&self, index: isize, value: T) {
        self.slots.add(index as usize & (self.cap - 1)).write(value);
    }

    unsafe fn read(&self, index: isize) -> T {
        self.slots.add(index as usize & (self.cap - 1)).read()
    }
}

/// State shared between the [`Worker`] and its [`Stealer`]s.
struct Inner<T> {
    /// Next index to steal from (grows monotonically).
    top: AtomicIsize,
    /// Next index the owner writes (only the owner moves it).
    bottom: AtomicIsize,
    /// Current circular buffer. Only the owner swaps it (on grow);
    /// thieves load it after reading `top`.
    buffer: AtomicPtr<Buffer<T>>,
    /// Buffers replaced by grows, kept alive until drop because a
    /// concurrent thief may still be reading the old allocation.
    retired: Mutex<Vec<*mut Buffer<T>>>,
}

// The protocol moves `T` values across threads (owner → thief), so the
// shared state is Send/Sync exactly when `T: Send`. No `T: Sync` bound:
// no two threads ever hold a reference to the same item.
unsafe impl<T: Send> Send for Inner<T> {}
unsafe impl<T: Send> Sync for Inner<T> {}

impl<T> Drop for Inner<T> {
    fn drop(&mut self) {
        // Sole owner now: drop any items still queued, then every
        // allocation (current + retired).
        let top = self.top.load(Ordering::Relaxed);
        let bottom = self.bottom.load(Ordering::Relaxed);
        let buf = self.buffer.load(Ordering::Relaxed);
        unsafe {
            for i in top..bottom {
                drop((*buf).read(i));
            }
            (*buf).dealloc();
            drop(Box::from_raw(buf));
            for old in self.retired.lock().unwrap().drain(..) {
                (*old).dealloc();
                drop(Box::from_raw(old));
            }
        }
    }
}

/// The owning end: push/pop at the bottom (LIFO). `Send` but not `Sync`
/// — exactly one thread may own it at a time (a static test in
/// `exec::tests` asserts both bounds).
pub struct Worker<T> {
    inner: Arc<Inner<T>>,
    /// Strips the auto-`Sync` that `Arc<Inner>` would otherwise grant:
    /// push/pop are single-owner operations.
    _not_sync: PhantomData<std::cell::Cell<()>>,
}

/// A thieving end: steal from the top (FIFO). Cloneable and shareable;
/// any thread may steal through any clone concurrently.
pub struct Stealer<T> {
    inner: Arc<Inner<T>>,
}

/// Outcome of a [`Stealer::steal`] attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Steal<T> {
    /// Got the oldest queued item.
    Taken(T),
    /// The deque was observed empty.
    Empty,
    /// Lost a race (with the owner or another thief); worth retrying.
    Retry,
}

const INITIAL_CAP: usize = 16;

/// Build a deque: the owner's [`Worker`] plus one [`Stealer`] to clone.
pub fn deque<T: Send>() -> (Worker<T>, Stealer<T>) {
    let buffer = Box::into_raw(Box::new(Buffer::alloc(INITIAL_CAP)));
    let inner = Arc::new(Inner {
        top: AtomicIsize::new(0),
        bottom: AtomicIsize::new(0),
        buffer: AtomicPtr::new(buffer),
        retired: Mutex::new(Vec::new()),
    });
    let stealer = Stealer { inner: Arc::clone(&inner) };
    (Worker { inner, _not_sync: PhantomData }, stealer)
}

impl<T: Send> Worker<T> {
    /// Push at the bottom. Never blocks; grows the buffer when full.
    pub fn push(&self, value: T) {
        let inner = &*self.inner;
        let b = inner.bottom.load(Ordering::Relaxed);
        let t = inner.top.load(Ordering::Acquire);
        let mut buf = inner.buffer.load(Ordering::Relaxed);
        unsafe {
            if b - t >= (*buf).cap as isize {
                buf = self.grow(buf, t, b);
            }
            (*buf).write(b, value);
        }
        // Release: the slot write must be visible before the new bottom.
        inner.bottom.store(b + 1, Ordering::Release);
    }

    /// Pop at the bottom (LIFO). `None` when empty. On the last item,
    /// races thieves via the `top` compare-exchange.
    pub fn pop(&self) -> Option<T> {
        let inner = &*self.inner;
        let b = inner.bottom.load(Ordering::Relaxed) - 1;
        let buf = inner.buffer.load(Ordering::Relaxed);
        // Publish the claim on slot b before reading top (SeqCst fence
        // pairing with the fence in steal).
        inner.bottom.store(b, Ordering::Relaxed);
        std::sync::atomic::fence(Ordering::SeqCst);
        let t = inner.top.load(Ordering::Relaxed);
        if t > b {
            // Empty: undo the claim.
            inner.bottom.store(b + 1, Ordering::Relaxed);
            return None;
        }
        let value = unsafe { (*buf).read(b) };
        if t == b {
            // Last item: win it from any concurrent thief.
            let won = inner
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok();
            inner.bottom.store(b + 1, Ordering::Relaxed);
            if won {
                Some(value)
            } else {
                // A thief took it; the bitwise copy in `value` must not
                // drop here (the thief owns the item now).
                std::mem::forget(value);
                None
            }
        } else {
            Some(value)
        }
    }

    /// Items currently queued (owner's view; advisory under contention).
    pub fn len(&self) -> usize {
        let b = self.inner.bottom.load(Ordering::Relaxed);
        let t = self.inner.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    /// Whether the owner's view of the deque is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Hand out another thieving end.
    pub fn stealer(&self) -> Stealer<T> {
        Stealer { inner: Arc::clone(&self.inner) }
    }

    /// Double the buffer, copying the live window `[t, b)`. The old
    /// buffer is retired, not freed — a concurrent thief may be mid-read.
    unsafe fn grow(&self, old: *mut Buffer<T>, t: isize, b: isize) -> *mut Buffer<T> {
        let new = Box::into_raw(Box::new(Buffer::alloc((*old).cap * 2)));
        for i in t..b {
            (*new).write(i, (*old).read(i));
        }
        self.inner.buffer.store(new, Ordering::Release);
        self.inner.retired.lock().unwrap().push(old);
        new
    }
}

impl<T: Send> Stealer<T> {
    /// Steal from the top (FIFO). Single attempt: [`Steal::Retry`] means
    /// a race was lost and the caller may loop or move to another victim.
    pub fn steal(&self) -> Steal<T> {
        let inner = &*self.inner;
        let t = inner.top.load(Ordering::Acquire);
        // Pair with the fence in pop: after it, this load observes any
        // bottom decrement that claimed slot t.
        std::sync::atomic::fence(Ordering::SeqCst);
        let b = inner.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        // Read the item *before* the CAS; on CAS failure the copy is
        // forgotten (someone else owns it), on success it is ours.
        let buf = inner.buffer.load(Ordering::Acquire);
        let value = unsafe { (*buf).read(t) };
        match inner.top.compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed) {
            Ok(_) => Steal::Taken(value),
            Err(_) => {
                std::mem::forget(value);
                Steal::Retry
            }
        }
    }

    /// Steal with bounded retries, collapsing [`Steal::Retry`] loops.
    /// `None` means the deque looked empty (or stayed contended).
    pub fn steal_some(&self) -> Option<T> {
        for _ in 0..4 {
            match self.steal() {
                Steal::Taken(v) => return Some(v),
                Steal::Empty => return None,
                Steal::Retry => std::hint::spin_loop(),
            }
        }
        None
    }

    /// Advisory queue length from the thief side.
    pub fn len(&self) -> usize {
        let b = self.inner.bottom.load(Ordering::Acquire);
        let t = self.inner.top.load(Ordering::Acquire);
        (b - t).max(0) as usize
    }

    /// Whether the thief's view of the deque is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Stealer<T> {
    fn clone(&self) -> Stealer<T> {
        Stealer { inner: Arc::clone(&self.inner) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn owner_pops_lifo_thief_steals_fifo() {
        let (w, s) = deque::<u32>();
        for i in 0..4 {
            w.push(i);
        }
        // Thief sees the *oldest* item.
        assert_eq!(s.steal(), Steal::Taken(0));
        // Owner sees the *newest*.
        assert_eq!(w.pop(), Some(3));
        assert_eq!(s.steal(), Steal::Taken(1));
        assert_eq!(w.pop(), Some(2));
        assert_eq!(w.pop(), None);
        assert_eq!(s.steal(), Steal::Empty);
    }

    #[test]
    fn grows_past_the_initial_capacity_without_loss() {
        let (w, s) = deque::<usize>();
        let n = INITIAL_CAP * 8 + 3;
        for i in 0..n {
            w.push(i);
        }
        assert_eq!(w.len(), n);
        assert_eq!(s.len(), n);
        // FIFO from the top across every grow boundary.
        for want in 0..n {
            assert_eq!(s.steal(), Steal::Taken(want));
        }
        assert_eq!(s.steal(), Steal::Empty);
    }

    #[test]
    fn interleaved_push_pop_preserves_stack_order() {
        let (w, _s) = deque::<u32>();
        w.push(1);
        w.push(2);
        assert_eq!(w.pop(), Some(2));
        w.push(3);
        assert_eq!(w.pop(), Some(3));
        assert_eq!(w.pop(), Some(1));
        assert_eq!(w.pop(), None);
        assert!(w.is_empty());
    }

    #[test]
    fn queued_items_drop_exactly_once_on_deque_drop() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct Token;
        impl Drop for Token {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        DROPS.store(0, Ordering::SeqCst);
        {
            let (w, s) = deque::<Token>();
            for _ in 0..40 {
                w.push(Token); // crosses a grow at 16
            }
            drop(w.pop().unwrap()); // 1 dropped by the owner
            match s.steal() {
                Steal::Taken(t) => drop(t), // 1 dropped by the thief
                other => panic!("expected a steal, got {other:?}"),
            }
        } // remaining 38 dropped by Inner::drop
        assert_eq!(DROPS.load(Ordering::SeqCst), 40);
    }

    /// Multi-thread conservation: every pushed item is popped or stolen
    /// exactly once — no loss, no duplication — under real contention
    /// (the satellite stress test; single-thread order is locked above).
    #[test]
    fn stress_every_item_popped_or_stolen_exactly_once() {
        const ITEMS: usize = 20_000;
        const THIEVES: usize = 4;
        let (w, s) = deque::<usize>();
        let taken: Vec<Stealer<usize>> = (0..THIEVES).map(|_| s.clone()).collect();
        let done = std::sync::atomic::AtomicBool::new(false);
        let mut seen: Vec<Vec<usize>> = Vec::new();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for thief in taken {
                let done = &done;
                handles.push(scope.spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match thief.steal() {
                            Steal::Taken(v) => got.push(v),
                            Steal::Retry => std::hint::spin_loop(),
                            Steal::Empty => {
                                if done.load(Ordering::SeqCst) && thief.is_empty() {
                                    break;
                                }
                                std::hint::spin_loop();
                            }
                        }
                    }
                    got
                }));
            }
            // Owner: interleave pushes with occasional pops.
            let mut owner_got = Vec::new();
            for i in 0..ITEMS {
                w.push(i);
                if i % 3 == 0 {
                    if let Some(v) = w.pop() {
                        owner_got.push(v);
                    }
                }
            }
            while let Some(v) = w.pop() {
                owner_got.push(v);
            }
            done.store(true, Ordering::SeqCst);
            seen.push(owner_got);
            for h in handles {
                seen.push(h.join().unwrap());
            }
        });
        let total: usize = seen.iter().map(|v| v.len()).sum();
        assert_eq!(total, ITEMS, "items lost or duplicated under contention");
        let unique: BTreeSet<usize> = seen.iter().flatten().copied().collect();
        assert_eq!(unique.len(), ITEMS, "duplicate deliveries under contention");
        assert_eq!(unique.iter().next_back(), Some(&(ITEMS - 1)));
    }
}
