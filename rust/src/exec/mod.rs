//! Parallel serving runtime (DESIGN.md §15): thread-per-package
//! execution for the sharded coordinator.
//!
//! Two execution modes share this subsystem, split by what they promise:
//!
//! * **Deterministic seeded mode** — `ShardedSession::finish` with
//!   `ShardedServer::set_threads(n > 1)` drains every arrival-free
//!   window of the virtual-time event loop on up to `n` scoped worker
//!   threads (one package chunk each) and merges the per-tick event
//!   streams back by `(tick_start_ns, package, seq)`, the exact
//!   sequential event-loop order. The `ServeOutcome` is **bit-identical**
//!   to the single-thread path (locked by
//!   `exec_drain_is_bit_identical_to_sequential` and
//!   `prop_exec_drain_is_bit_identical_to_sequential`). That drain lives
//!   beside the event loop in `coordinator::sharded`; this module
//!   provides its thread plumbing rationale and the shared deque.
//!
//! * **Free-running wall-clock mode** — [`serve_wall_clock`] abandons
//!   the global virtual-time total order entirely: worker threads race
//!   over real time, each driving its own package chunk through the
//!   same `admit`/`step` methods, pulling admissions from a per-worker
//!   injector and *work stealing* queued requests from sibling workers
//!   through the lock-free Chase-Lev [`deque`]. Host events/s scales
//!   with threads; per-request simulated numbers are still priced by
//!   the same per-package simulators, but cross-package interleaving is
//!   racy by design, so outcomes are **not** bit-reproducible across
//!   runs. What it does promise — and assert — is conservation: every
//!   offered request is completed, rejected, or shed, exactly once.
//!
//! Everything here is std-only: the deque is written over
//! `std::sync::atomic` (no crossbeam), threads are `std::thread::scope`
//! scoped borrows, and the injectors reuse the coordinator's
//! `AdmissionQueue`.

pub mod deque;

pub use deque::{deque, Steal, Stealer, Worker};

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::Instant;

use crate::coordinator::sharded::PackageState;
use crate::coordinator::streaming::guard_submission;
use crate::coordinator::{
    AdmissionQueue, ServeEvent, ServeOutcome, ServeRequest, ServeResponse, ServingMetrics,
    ShardedServer,
};

/// What one wall-clock serve produced: the merged [`ServeOutcome`] plus
/// the host-side execution counters the virtual-time path has no notion
/// of.
#[derive(Debug, Clone)]
pub struct WallReport {
    /// Completions (sorted by simulated completion instant, then id —
    /// the same order `ShardedSession::finish` uses), shed requests, and
    /// merged metrics. Conservation holds:
    /// `responses.len() + shed.len() == offered`.
    pub outcome: ServeOutcome,
    /// Host wall-clock time the executor ran for (ns).
    pub wall_ns: f64,
    /// Serve events the package steps emitted (FirstToken/Token/
    /// Completed), plus one per inline zero-token completion — the
    /// numerator of the events/s scaling metric.
    pub events: u64,
    /// Worker threads actually used: `threads.min(packages)`.
    pub workers: usize,
    /// Requests migrated between workers through the Chase-Lev deques.
    pub deque_steals: u64,
}

/// Per-worker tallies carried back to the merge step.
#[derive(Default)]
struct WorkerResult {
    /// `(arrival_ns, response)` per completion, in this worker's local
    /// completion order.
    completions: Vec<(f64, ServeResponse)>,
    /// Requests this worker's whole package chunk refused (every queue
    /// full at admission time).
    rejected: Vec<ServeRequest>,
    events: u64,
    deque_steals: u64,
}

/// Serve `requests` in free-running wall-clock mode on up to `threads`
/// worker threads (DESIGN.md §15).
///
/// Architecture — one admission thread (the caller's) plus
/// `threads.min(packages)` workers over `std::thread::scope`:
///
/// 1. The admission thread guards submissions exactly like the
///    streaming protocol (duplicate ids panic, non-finite arrivals are
///    shed and recorded) and round-robins the schedulable requests into
///    per-worker [`AdmissionQueue`] injectors sized to the offered load,
///    so injection itself can never reject.
/// 2. Each worker owns a contiguous package chunk (`chunks_mut` — no
///    locks on simulator state), one Chase-Lev [`Worker`] deque, and
///    [`Stealer`] handles to every sibling. Its loop: drain injector →
///    deque; pop deque → admit into the least-loaded chunk package with
///    failover across the chunk (all full ⇒ rejected — wall mode does
///    not fail over across workers, the deque steal path is how load
///    migrates instead); zero-token requests complete inline at
///    arrival, mirroring the sequential engine's contract; step every
///    package whose `next_event_ns` is finite; when nothing progressed,
///    steal a queued request from a sibling's deque before going idle.
/// 3. Termination is by conservation, not time: an `outstanding`
///    counter starts at the schedulable count and decrements exactly
///    once per completion/rejection; workers exit when arrivals are
///    done and it reaches zero.
///
/// The merge sorts completions by simulated completion instant
/// (`arrival + total_latency`, then id — the `ShardedSession::finish`
/// order) and replays them into one [`ServingMetrics`], then asserts
/// conservation: `admitted + rejected + shed == offered` and
/// `responses.len() == admitted`.
///
/// Panics on `threads == 0` (the CLI and session builder reject it
/// first) and on a duplicate request id, per the protocol contract.
pub fn serve_wall_clock(
    srv: &mut ShardedServer,
    requests: Vec<ServeRequest>,
    threads: usize,
) -> WallReport {
    assert!(threads >= 1, "the wall-clock executor needs at least one worker thread");
    let offered = requests.len();

    // Admission guard: duplicate ids panic, non-finite arrivals shed.
    let mut metrics = ServingMetrics::new();
    let mut shed: Vec<ServeRequest> = Vec::new();
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    let mut schedulable: Vec<ServeRequest> = Vec::with_capacity(offered);
    for req in requests {
        if let Ok(req) = guard_submission(&mut seen, &mut metrics, &mut shed, req) {
            schedulable.push(req);
        }
    }

    let packages = srv.begin_wall_session();
    let npkg = packages.len();
    let chunk = npkg.div_ceil(threads.min(npkg).max(1));
    // The number of chunks `chunks_mut` actually yields — NOT
    // `threads.min(npkg)`: 4 packages on 3 threads chunk as 2+2, i.e.
    // two workers, and sizing injectors/deques for three would park
    // round-robined requests on a mailbox nobody drains.
    let workers = npkg.div_ceil(chunk);

    // Injectors sized to the offered load: injection never rejects, so
    // the only rejections are package-queue backpressure at admit time.
    let injectors: Vec<AdmissionQueue> =
        (0..workers).map(|_| AdmissionQueue::new(schedulable.len().max(1))).collect();
    let outstanding = AtomicUsize::new(schedulable.len());
    let arrivals_done = AtomicBool::new(false);

    let mut decks: Vec<Worker<ServeRequest>> = Vec::with_capacity(workers);
    let mut stealers: Vec<Stealer<ServeRequest>> = Vec::with_capacity(workers);
    for _ in 0..workers {
        let (w, s) = deque::deque();
        decks.push(w);
        stealers.push(s);
    }

    let start = Instant::now();
    let mut per_worker: Vec<WorkerResult> = Vec::with_capacity(workers);
    std::thread::scope(|scope| {
        let handles: Vec<_> = packages
            .chunks_mut(chunk)
            .zip(decks)
            .enumerate()
            .map(|(w, (slab, own))| {
                let injector = &injectors[w];
                let siblings: Vec<Stealer<ServeRequest>> = stealers
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| i != w)
                    .map(|(_, s)| s.clone())
                    .collect();
                let outstanding = &outstanding;
                let arrivals_done = &arrivals_done;
                scope.spawn(move || {
                    worker_loop(w, chunk, slab, own, injector, siblings, outstanding, arrivals_done)
                })
            })
            .collect();

        // This thread is the admission thread: round-robin injection,
        // concurrent with the workers already draining.
        for (i, req) in schedulable.into_iter().enumerate() {
            injectors[i % workers]
                .admit(req)
                .expect("injectors are sized to the offered load and never closed early");
        }
        for inj in &injectors {
            inj.close();
        }
        arrivals_done.store(true, Ordering::SeqCst);

        for h in handles {
            per_worker.push(h.join().expect("wall-clock worker thread panicked"));
        }
    });
    let wall_ns = start.elapsed().as_nanos() as f64;

    // Merge: simulated-completion order (then id), exactly like
    // `ShardedSession::finish`, so downstream percentile/JSON consumers
    // see the same shape either way.
    let mut completions: Vec<(f64, ServeResponse)> = Vec::new();
    let mut rejected: Vec<ServeRequest> = Vec::new();
    let mut events: u64 = 0;
    let mut deque_steals: u64 = 0;
    for r in per_worker {
        completions.extend(r.completions);
        rejected.extend(r.rejected);
        events += r.events;
        deque_steals += r.deque_steals;
    }
    completions.sort_by(|a, b| {
        let da = a.0 + a.1.total_latency_ns();
        let db = b.0 + b.1.total_latency_ns();
        da.total_cmp(&db).then(a.1.id.cmp(&b.1.id))
    });
    rejected.sort_by_key(|r| r.id);
    for r in rejected {
        metrics.record_rejected();
        shed.push(r);
    }
    for (arrival_ns, resp) in &completions {
        metrics.record_admitted();
        metrics.record(*arrival_ns, resp);
    }
    let responses: Vec<ServeResponse> = completions.into_iter().map(|(_, r)| r).collect();

    assert_eq!(
        metrics.offered() as usize,
        offered,
        "wall-clock conservation violated: every offered request must be \
         admitted, rejected, or shed exactly once"
    );
    assert_eq!(
        responses.len() as u64,
        metrics.admitted,
        "wall-clock conservation violated: completion events must equal admissions"
    );

    WallReport {
        outcome: ServeOutcome { responses, shed, metrics },
        wall_ns,
        events,
        workers,
        deque_steals,
    }
}

/// One worker's life: injector → deque → package admission → simulator
/// steps, stealing from siblings when starved, until the system drains.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    w: usize,
    chunk: usize,
    slab: &mut [PackageState],
    own: Worker<ServeRequest>,
    injector: &AdmissionQueue,
    siblings: Vec<Stealer<ServeRequest>>,
    outstanding: &AtomicUsize,
    arrivals_done: &AtomicBool,
) -> WorkerResult {
    let mut res = WorkerResult::default();
    loop {
        let mut progress = false;

        // Injector → deque (non-blocking; the injector is this worker's
        // admission mailbox, the deque is what siblings can steal from).
        for req in injector.try_pop_batch(usize::MAX) {
            own.push(req);
            progress = true;
        }

        // Deque → package admission.
        while let Some(req) = own.pop() {
            progress = true;
            if req.max_new_tokens == 0 {
                // Zero-token contract (see `ServeResponse`): no
                // schedulable work, completes at arrival with zeros.
                let resp = ServeResponse {
                    id: req.id,
                    tokens: Vec::new(),
                    queue_ns: 0.0,
                    ttft_ns: 0.0,
                    service_ns: 0.0,
                    energy_j: 0.0,
                };
                res.completions.push((req.arrival_ns, resp));
                res.events += 1;
                outstanding.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            // Least-loaded within this worker's chunk, failing over
            // across the chunk; rejected only when the whole chunk is
            // out of queue capacity.
            let mut order: Vec<usize> = (0..slab.len()).collect();
            order.sort_by_key(|&i| slab[i].load_tokens());
            let mut req = Some(req);
            for &i in &order {
                match slab[i].admit(req.take().unwrap()) {
                    Ok(()) => break,
                    Err(r) => req = Some(r),
                }
            }
            if let Some(r) = req {
                res.rejected.push(r);
                outstanding.fetch_sub(1, Ordering::SeqCst);
            }
        }

        // Step every package that can make progress.
        for (off, p) in slab.iter_mut().enumerate() {
            if p.next_event_ns().is_finite() {
                let events = p.step(w * chunk + off, None);
                if !events.is_empty() {
                    progress = true;
                }
                res.events += events.len() as u64;
                for ev in events {
                    if let ServeEvent::Completed { arrival_ns, response, .. } = ev {
                        res.completions.push((arrival_ns, response));
                        outstanding.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
        }
        if progress {
            continue;
        }

        // Starved: steal a queued request from a sibling's deque.
        let mut stole = false;
        for s in &siblings {
            if let Some(req) = s.steal_some() {
                own.push(req);
                res.deque_steals += 1;
                stole = true;
                break;
            }
        }
        if stole {
            continue;
        }

        // Drained? Conservation-based exit: all arrivals injected and
        // every schedulable request retired (completed or rejected).
        if arrivals_done.load(Ordering::SeqCst) && outstanding.load(Ordering::SeqCst) == 0 {
            break;
        }
        std::thread::yield_now();
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChimeConfig, MllmConfig, WorkloadConfig};
    use crate::coordinator::{BatchPolicy, RoutePolicy};

    fn assert_send<T: Send>() {}
    fn assert_sync<T: Sync>() {}

    /// Satellite audit: every type that crosses the executor's thread
    /// boundary is `Send` (moved/borrowed into scoped workers) and the
    /// shared handles are `Sync`. Compile-time only — a regression (say,
    /// an `Rc` slipping into `ServeRequest`) fails the build here with a
    /// readable error instead of deep inside `thread::scope` inference.
    /// `Worker<T>` is deliberately *not* `Sync` (single-owner pushes);
    /// that half of the contract is enforced by the `PhantomData<Cell>`
    /// marker in `exec::deque` and cannot be asserted positively here.
    #[test]
    fn serving_types_are_send_sync_across_the_executor_boundary() {
        assert_send::<ServeRequest>();
        assert_send::<ServeResponse>();
        assert_send::<ServeEvent>();
        assert_send::<ServeOutcome>();
        assert_send::<ServingMetrics>();
        assert_send::<PackageState>();
        assert_send::<AdmissionQueue>();
        assert_sync::<AdmissionQueue>();
        assert_send::<Worker<ServeRequest>>();
        assert_send::<Stealer<ServeRequest>>();
        assert_sync::<Stealer<ServeRequest>>();
        assert_send::<WallReport>();
    }

    fn tiny_cfg() -> (MllmConfig, ChimeConfig) {
        let mut cfg = ChimeConfig::default();
        cfg.workload = WorkloadConfig { image_size: 64, text_tokens: 8, output_tokens: 4 };
        (MllmConfig::tiny(), cfg)
    }

    fn mixed_requests(n: usize) -> Vec<ServeRequest> {
        let skew = [3usize, 1, 4, 0, 5, 2];
        (0..n)
            .map(|i| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: skew[i % skew.len()],
                arrival_ns: i as f64 * 2.0e4,
            })
            .collect()
    }

    /// The acceptance-criteria conservation smoke: a multi-thread wall
    /// run over a mixed stream (zero-token inline completions, a NaN
    /// arrival to shed, staggered arrivals) accounts for every offered
    /// request exactly once.
    #[test]
    fn wall_clock_serving_conserves_every_request() {
        let (model, cfg) = tiny_cfg();
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy { max_batch: 2, queue_capacity: 64 },
            4,
            RoutePolicy::LeastLoaded,
        );
        let mut reqs = mixed_requests(24);
        reqs.push(ServeRequest {
            id: 99,
            prompt: vec![],
            image_seed: 99,
            max_new_tokens: 4,
            arrival_ns: f64::NAN,
        });
        let offered = reqs.len();

        let report = serve_wall_clock(&mut srv, reqs, 4);
        let m = &report.outcome.metrics;
        assert_eq!(m.offered() as usize, offered);
        assert_eq!((m.admitted + m.rejected + m.shed) as usize, offered);
        assert_eq!(report.outcome.responses.len() as u64, m.admitted);
        assert_eq!(report.outcome.responses.len() + report.outcome.shed.len(), offered);
        assert_eq!(m.shed, 1, "exactly the NaN arrival is shed");
        assert_eq!(report.workers, 4);
        assert!(report.wall_ns > 0.0);
        assert!(report.events >= m.completed, "every completion is an event");
        // Zero-token requests complete inline with the zero contract.
        for r in report.outcome.responses.iter().filter(|r| r.tokens.is_empty()) {
            assert_eq!(r.total_latency_ns(), 0.0);
        }
        // Exactly-once delivery: no response id appears twice.
        let ids: std::collections::BTreeSet<u64> =
            report.outcome.responses.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), report.outcome.responses.len());
    }

    /// Backpressure path: a tiny queue capacity forces rejections, which
    /// must show up in `rejected` + `shed` without breaking conservation,
    /// on the single-worker degenerate case too.
    #[test]
    fn wall_clock_backpressure_rejects_without_losing_requests() {
        let (model, cfg) = tiny_cfg();
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy { max_batch: 1, queue_capacity: 1 },
            2,
            RoutePolicy::RoundRobin,
        );
        // A t=0 burst: far more work than 2 packages × (1 slot + 1 queue
        // entry) can hold at once.
        let reqs = ServeRequest::burst(16, 6);
        let report = serve_wall_clock(&mut srv, reqs, 2);
        let m = &report.outcome.metrics;
        assert_eq!(m.offered(), 16);
        assert!(m.rejected > 0, "a saturating burst must hit backpressure");
        assert_eq!(report.outcome.responses.len() as u64, m.admitted);
        assert_eq!(report.outcome.responses.len() + report.outcome.shed.len(), 16);
        assert_eq!(m.shed, 0);
    }

    /// Oversubscription clamps: more threads than packages still runs
    /// (workers == packages), and one thread is the sequential floor.
    #[test]
    fn wall_clock_worker_count_clamps_to_packages() {
        let (model, cfg) = tiny_cfg();
        let mut srv = ShardedServer::new(
            &model,
            &cfg,
            BatchPolicy { max_batch: 2, queue_capacity: 64 },
            2,
            RoutePolicy::LeastLoaded,
        );
        let report = serve_wall_clock(&mut srv, mixed_requests(8), 16);
        assert_eq!(report.workers, 2);
        assert_eq!(report.outcome.metrics.offered(), 8);

        let report = serve_wall_clock(&mut srv, mixed_requests(8), 1);
        assert_eq!(report.workers, 1);
        assert_eq!(report.outcome.responses.len() + report.outcome.shed.len(), 8);
        assert_eq!(report.deque_steals, 0, "a lone worker has nobody to steal from");
    }
}
