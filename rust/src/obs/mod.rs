//! Observability: deterministic span/event tracing, Chrome-trace export,
//! Prometheus text exposition, and wall-clock self-profiling (DESIGN.md
//! §14).
//!
//! The subsystem is std-only and **zero-overhead when disabled**: every
//! instrumented layer holds an `Option<Tracer>` (default `None`) and the
//! instrumentation is a read-only side channel — enabling it never
//! changes a simulated timestamp, an energy figure, or a byte counter.
//! The serving drain falls back from the parallel to the sequential
//! driver while tracing (the two are bit-identical by construction, see
//! `ShardedSession::finish`), so a traced run still produces the exact
//! golden numbers.
//!
//! Three consumers sit on top of one [`Tracer`]:
//!
//! * [`chrome`] — Chrome trace-event / Perfetto-loadable JSON
//!   (`chime simulate|serve --trace-out FILE`): process = package,
//!   track = chiplet/coordinator/fabric, args carry bytes, energy, and
//!   stall causes. Serialization goes through the canonical
//!   [`crate::util::Json`] writer, so a fixed seed yields a
//!   byte-identical trace.
//! * [`prom`] — Prometheus text exposition
//!   (`GET /v1/metrics?format=prometheus` on the net server), rendering
//!   the serving counters, per-link fabric telemetry, and memory stall
//!   totals. Every exported value is finite by policy.
//! * profiling (`chime bench --profile`) — wall-clock time per
//!   instrumented span class, aggregated into the `HOTPATH_*.json`
//!   baseline (ROADMAP item 4). Wall times never enter the trace JSON —
//!   they exist only in the profile aggregate, so traces stay
//!   deterministic.

pub mod chrome;
pub mod prom;

use std::collections::BTreeMap;
use std::time::Instant;

use crate::sim::fabric::{Fabric, Link};
use crate::sim::memory::{DramMem, RramMem};
use crate::sim::SimEngine;
use crate::util::Json;

/// One timeline per (package, track) pair in the exported trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Track {
    /// Per-package serving coordinator: tick spans, admission work.
    Coordinator,
    /// DRAM chiplet: stall-cause instants.
    Dram,
    /// RRAM chiplet: stall-cause instants.
    Rram,
    /// UCIe fabric: per-link leg instants (bytes conservation).
    Fabric,
    /// Global serving-protocol transitions (one instant per
    /// [`crate::coordinator::ServeEvent`]).
    Serving,
}

impl Track {
    /// Stable thread id for the Chrome export.
    pub fn tid(self) -> usize {
        match self {
            Track::Coordinator => 0,
            Track::Dram => 1,
            Track::Rram => 2,
            Track::Fabric => 3,
            Track::Serving => 4,
        }
    }

    /// Track name for the Chrome thread-name metadata.
    pub fn name(self) -> &'static str {
        match self {
            Track::Coordinator => "coordinator",
            Track::Dram => "dram",
            Track::Rram => "rram",
            Track::Fabric => "fabric",
            Track::Serving => "serving",
        }
    }
}

/// One recorded span (duration) or instant (point event), in virtual
/// nanoseconds. Spans on one (pid, track) timeline never overlap — the
/// recorder is driven by sequential per-package clocks — which is the
/// well-nestedness invariant `prop_trace_spans_are_well_nested_and_conserving`
/// locks.
#[derive(Debug, Clone)]
pub struct Record {
    /// Span class ("package_step", "prefill", "fabric_leg", ...).
    pub name: &'static str,
    /// Owning package (Chrome process id).
    pub pid: usize,
    /// Timeline within the package.
    pub track: Track,
    /// Virtual start time (ns).
    pub start_ns: f64,
    /// Duration in virtual ns; `None` marks an instant event.
    pub dur_ns: Option<f64>,
    /// Structured payload (bytes, energy, stall cause, ...).
    pub args: Vec<(&'static str, Json)>,
}

/// The span/event recorder. Owned (optionally) by the instrumented
/// layers; collected once at the end of a run via `take_trace`.
#[derive(Debug, Default)]
pub struct Tracer {
    records: Vec<Record>,
    profiling: bool,
    profile: BTreeMap<&'static str, (u64, f64)>,
}

impl Tracer {
    /// A recording tracer (profiling off): deterministic virtual-time
    /// records only.
    pub fn new() -> Tracer {
        Tracer::default()
    }

    /// A recording tracer that additionally aggregates wall-clock time
    /// per span class (`chime bench --profile`).
    pub fn with_profiling() -> Tracer {
        Tracer { profiling: true, ..Tracer::default() }
    }

    /// Whether wall-clock profiling is on.
    pub fn profiling(&self) -> bool {
        self.profiling
    }

    /// A tracer with the records dropped (new serving session). The mode
    /// and the wall-clock profile aggregates carry over — profiling spans
    /// many sessions (`chime bench --profile`), traces cover one.
    pub fn fresh(&self) -> Tracer {
        Tracer {
            records: Vec::new(),
            profiling: self.profiling,
            profile: self.profile.clone(),
        }
    }

    /// Record a complete span `[start_ns, end_ns]` on a timeline.
    pub fn span(
        &mut self,
        pid: usize,
        track: Track,
        name: &'static str,
        start_ns: f64,
        end_ns: f64,
        args: Vec<(&'static str, Json)>,
    ) {
        self.records.push(Record {
            name,
            pid,
            track,
            start_ns,
            dur_ns: Some((end_ns - start_ns).max(0.0)),
            args,
        });
    }

    /// Record an instant event at `ts_ns` on a timeline.
    pub fn instant(
        &mut self,
        pid: usize,
        track: Track,
        name: &'static str,
        ts_ns: f64,
        args: Vec<(&'static str, Json)>,
    ) {
        self.records.push(Record { name, pid, track, start_ns: ts_ns, dur_ns: None, args });
    }

    /// Start a wall-clock measurement (Some only while profiling, so the
    /// disabled path never touches the OS clock).
    pub fn wall_start(&self) -> Option<Instant> {
        if self.profiling {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a wall-clock measurement against a span class.
    pub fn wall_end(&mut self, name: &'static str, started: Option<Instant>) {
        if let Some(t0) = started {
            let e = self.profile.entry(name).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += t0.elapsed().as_nanos() as f64;
        }
    }

    /// All records, in deterministic recording order.
    pub fn records(&self) -> &[Record] {
        &self.records
    }

    /// Whether anything has been recorded.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Wall-clock profile: span class -> (count, total wall ns).
    pub fn profile_entries(&self) -> &BTreeMap<&'static str, (u64, f64)> {
        &self.profile
    }

    /// The Chrome trace-event export (see [`chrome::trace_json`]).
    pub fn chrome_trace(&self) -> Json {
        chrome::trace_json(self)
    }

    /// Merge per-worker tracers from an executor drain (DESIGN.md §15)
    /// into this tracer, deterministically: the union of the worker
    /// records is sorted by `(start time, package, per-worker order)`
    /// and appended, and the wall-clock profile aggregates are summed.
    ///
    /// The sort key is invariant to how packages were chunked across
    /// workers: one package's records always come from exactly one
    /// worker, in non-decreasing start order, so ties on
    /// `(start, package)` are resolved within a single worker and the
    /// per-worker index preserves that worker's recording order. A fixed
    /// request stream therefore merges to the byte-same trace for every
    /// worker count (locked by
    /// `exec_drain_traces_deterministically_across_worker_counts`).
    pub fn merge_workers(&mut self, workers: Vec<Tracer>) {
        let mut tagged: Vec<(usize, Record)> = Vec::new();
        for w in workers {
            let Tracer { records, profile, .. } = w;
            for (name, (count, wall_ns)) in profile {
                let e = self.profile.entry(name).or_insert((0, 0.0));
                e.0 += count;
                e.1 += wall_ns;
            }
            tagged.extend(records.into_iter().enumerate());
        }
        tagged.sort_by(|(ia, a), (ib, b)| {
            a.start_ns.total_cmp(&b.start_ns).then(a.pid.cmp(&b.pid)).then(ia.cmp(ib))
        });
        self.records.extend(tagged.into_iter().map(|(_, r)| r));
    }
}

/// Canonical label for a fabric link, shared between trace args and
/// Prometheus series so the two reconcile textually.
pub fn link_label(link: &Link) -> String {
    match link {
        Link::Local { package } => format!("local{package}"),
        Link::Inter { a, b } => format!("inter{a}-{b}"),
    }
}

/// Per-link byte/transfer snapshot of a fabric, for delta-based leg
/// events (a traced region snapshots before/after and emits one
/// `fabric_leg` instant per link that moved — Σ leg bytes therefore
/// equals the link counters exactly).
pub fn link_snapshot(fabric: &Fabric) -> Vec<(Link, u64, u64)> {
    fabric.link_states().map(|(l, s)| (*l, s.bytes, s.transfers)).collect()
}

/// Links whose byte counters advanced since `before`, with the deltas.
pub fn link_deltas(fabric: &Fabric, before: &[(Link, u64, u64)]) -> Vec<(Link, u64, u64)> {
    let prior: BTreeMap<Link, (u64, u64)> =
        before.iter().map(|&(l, b, t)| (l, (b, t))).collect();
    fabric
        .link_states()
        .filter_map(|(l, s)| {
            let (b0, t0) = prior.get(l).copied().unwrap_or((0, 0));
            if s.bytes > b0 {
                Some((*l, s.bytes - b0, s.transfers - t0))
            } else {
                None
            }
        })
        .collect()
}

/// Cumulative memory stall-cause totals of one engine's chiplet pair, by
/// cause. All zero at first-order fidelity — the cycle subsystem is
/// where the causes exist (DESIGN.md §9).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MemStalls {
    /// DRAM precharge (row-conflict) stall, ns.
    pub dram_precharge_ns: f64,
    /// DRAM tFAW-window stall, ns.
    pub dram_faw_ns: f64,
    /// DRAM refresh stall, ns.
    pub dram_refresh_ns: f64,
    /// DRAM whole-row activations issued.
    pub dram_activations: u64,
    /// DRAM row conflicts (precharge-before-activate events).
    pub dram_row_conflicts: u64,
    /// RRAM sense-amp pulse occupancy stall, ns.
    pub rram_pulse_ns: f64,
    /// RRAM SET/RESET verify-pulse time, ns.
    pub rram_verify_ns: f64,
    /// RRAM wear-remap bookkeeping stall, ns.
    pub rram_remap_ns: f64,
    /// RRAM wear remaps performed.
    pub rram_remaps: u64,
}

impl MemStalls {
    /// Snapshot the cumulative stall counters of one engine.
    pub fn of(engine: &SimEngine) -> MemStalls {
        let mut s = MemStalls::default();
        if let DramMem::CycleAccurate(c) = &engine.dram {
            s.dram_precharge_ns = c.precharge_stall_ns;
            s.dram_faw_ns = c.faw_stall_ns;
            s.dram_refresh_ns = c.refresh_stall_ns;
            s.dram_activations = c.activations;
            s.dram_row_conflicts = c.row_conflicts;
        }
        if let RramMem::CycleAccurate(c) = &engine.rram {
            s.rram_pulse_ns = c.pulse_stall_ns;
            s.rram_verify_ns = c.verify_ns;
            s.rram_remap_ns = c.remap_stall_ns;
            s.rram_remaps = c.remaps;
        }
        s
    }

    /// Component-wise difference (`self` is the later snapshot).
    pub fn minus(&self, earlier: &MemStalls) -> MemStalls {
        MemStalls {
            dram_precharge_ns: self.dram_precharge_ns - earlier.dram_precharge_ns,
            dram_faw_ns: self.dram_faw_ns - earlier.dram_faw_ns,
            dram_refresh_ns: self.dram_refresh_ns - earlier.dram_refresh_ns,
            dram_activations: self.dram_activations - earlier.dram_activations,
            dram_row_conflicts: self.dram_row_conflicts - earlier.dram_row_conflicts,
            rram_pulse_ns: self.rram_pulse_ns - earlier.rram_pulse_ns,
            rram_verify_ns: self.rram_verify_ns - earlier.rram_verify_ns,
            rram_remap_ns: self.rram_remap_ns - earlier.rram_remap_ns,
            rram_remaps: self.rram_remaps - earlier.rram_remaps,
        }
    }

    /// Component-wise sum (aggregation over packages).
    pub fn accumulate(&mut self, other: &MemStalls) {
        self.dram_precharge_ns += other.dram_precharge_ns;
        self.dram_faw_ns += other.dram_faw_ns;
        self.dram_refresh_ns += other.dram_refresh_ns;
        self.dram_activations += other.dram_activations;
        self.dram_row_conflicts += other.dram_row_conflicts;
        self.rram_pulse_ns += other.rram_pulse_ns;
        self.rram_verify_ns += other.rram_verify_ns;
        self.rram_remap_ns += other.rram_remap_ns;
        self.rram_remaps += other.rram_remaps;
    }

    /// Whether any stall-cause counter is non-zero.
    pub fn any(&self) -> bool {
        *self != MemStalls::default()
    }
}

/// Emit the DRAM/RRAM stall-cause instants for one traced region, if any
/// cause advanced (first-order fidelity records nothing).
pub fn trace_stalls(tracer: &mut Tracer, pid: usize, ts_ns: f64, delta: &MemStalls) {
    let dram_any = delta.dram_precharge_ns > 0.0
        || delta.dram_faw_ns > 0.0
        || delta.dram_refresh_ns > 0.0;
    if dram_any {
        tracer.instant(
            pid,
            Track::Dram,
            "dram_stall",
            ts_ns,
            vec![
                ("precharge_ns", delta.dram_precharge_ns.into()),
                ("tfaw_ns", delta.dram_faw_ns.into()),
                ("refresh_ns", delta.dram_refresh_ns.into()),
                ("row_conflicts", (delta.dram_row_conflicts as f64).into()),
            ],
        );
    }
    let rram_any =
        delta.rram_pulse_ns > 0.0 || delta.rram_verify_ns > 0.0 || delta.rram_remap_ns > 0.0;
    if rram_any {
        tracer.instant(
            pid,
            Track::Rram,
            "rram_stall",
            ts_ns,
            vec![
                ("pulse_ns", delta.rram_pulse_ns.into()),
                ("verify_ns", delta.rram_verify_ns.into()),
                ("remap_ns", delta.rram_remap_ns.into()),
                ("remaps", (delta.rram_remaps as f64).into()),
            ],
        );
    }
}

/// Per-link fabric telemetry, flattened for export.
#[derive(Debug, Clone)]
pub struct LinkTelemetry {
    /// Canonical link label (see [`link_label`]).
    pub link: String,
    /// Total payload bytes that crossed the link.
    pub bytes: u64,
    /// Transfers that crossed the link.
    pub transfers: u64,
    /// Total wire-serialization time, ns.
    pub busy_ns: f64,
    /// Peak sustained bandwidth over any tick window, GB/s.
    pub peak_gbps: f64,
}

/// Live engine-side telemetry a serving protocol can expose mid-run
/// (fabric links + memory stall totals), rendered by the net server's
/// Prometheus endpoint.
#[derive(Debug, Clone, Default)]
pub struct EngineTelemetry {
    /// Per-link fabric counters, in canonical link order.
    pub links: Vec<LinkTelemetry>,
    /// Memory stall-cause totals summed over packages.
    pub stalls: MemStalls,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{TopologyKind, UcieConfig};
    use crate::sim::fabric::Endpoint;

    #[test]
    fn disabled_tracer_paths_cost_nothing_and_record_nothing() {
        let t = Tracer::new();
        assert!(t.is_empty());
        assert!(!t.profiling());
        assert!(t.wall_start().is_none(), "no OS clock without profiling");
    }

    #[test]
    fn spans_and_instants_record_in_order() {
        let mut t = Tracer::new();
        t.span(0, Track::Coordinator, "package_step", 10.0, 30.0, vec![("slots", 2.0.into())]);
        t.instant(0, Track::Serving, "admitted", 12.0, vec![("id", 7.0.into())]);
        assert_eq!(t.records().len(), 2);
        assert_eq!(t.records()[0].dur_ns, Some(20.0));
        assert_eq!(t.records()[1].dur_ns, None);
        assert_eq!(t.records()[1].name, "admitted");
    }

    #[test]
    fn fresh_keeps_the_mode_and_drops_the_records() {
        let mut t = Tracer::with_profiling();
        t.instant(0, Track::Serving, "x", 0.0, vec![]);
        let f = t.fresh();
        assert!(f.is_empty());
        assert!(f.profiling());
    }

    #[test]
    fn profiling_aggregates_wall_time_per_span_class() {
        let mut t = Tracer::with_profiling();
        for _ in 0..3 {
            let w = t.wall_start();
            assert!(w.is_some());
            t.wall_end("tick", w);
        }
        let (count, wall_ns) = t.profile_entries()["tick"];
        assert_eq!(count, 3);
        assert!(wall_ns >= 0.0);
    }

    #[test]
    fn link_deltas_report_only_links_that_moved() {
        let mut f = Fabric::new(UcieConfig::default(), TopologyKind::Line, 4, 0);
        let before = link_snapshot(&f);
        let d = f.transfer(Endpoint::dram(0), Endpoint::dram(2), 1000);
        assert_eq!(d.hops, 2);
        let deltas = link_deltas(&f, &before);
        assert_eq!(deltas.len(), 2, "two line hops moved");
        assert!(deltas.iter().all(|&(_, bytes, transfers)| bytes == 1000 && transfers == 1));
        let labels: Vec<String> = deltas.iter().map(|(l, _, _)| link_label(l)).collect();
        assert_eq!(labels, vec!["inter0-1".to_string(), "inter1-2".to_string()]);
    }

    #[test]
    fn mem_stalls_delta_and_accumulate_are_componentwise() {
        let a = MemStalls { dram_refresh_ns: 10.0, rram_remaps: 3, ..MemStalls::default() };
        let b = MemStalls { dram_refresh_ns: 4.0, rram_remaps: 1, ..MemStalls::default() };
        let d = a.minus(&b);
        assert_eq!(d.dram_refresh_ns, 6.0);
        assert_eq!(d.rram_remaps, 2);
        assert!(d.any());
        assert!(!MemStalls::default().any());
        let mut sum = b;
        sum.accumulate(&d);
        assert_eq!(sum, a);
    }

    #[test]
    fn merge_workers_is_chunking_invariant_and_sums_profiles() {
        // Two workers with one package each vs one worker holding both
        // (recorded in a different package order): the merged record
        // stream must sort to the identical sequence, because the key
        // (start, pid, per-worker order) never depends on the chunking.
        let mut w0 = Tracer::new();
        w0.span(0, Track::Coordinator, "package_step", 10.0, 20.0, vec![]);
        w0.span(0, Track::Coordinator, "package_step", 20.0, 30.0, vec![]);
        let mut w1 = Tracer::new();
        w1.span(1, Track::Coordinator, "package_step", 5.0, 10.0, vec![]);
        w1.instant(1, Track::Dram, "dram_stall", 10.0, vec![]);
        let mut big = Tracer::new();
        big.span(1, Track::Coordinator, "package_step", 5.0, 10.0, vec![]);
        big.instant(1, Track::Dram, "dram_stall", 10.0, vec![]);
        big.span(0, Track::Coordinator, "package_step", 10.0, 20.0, vec![]);
        big.span(0, Track::Coordinator, "package_step", 20.0, 30.0, vec![]);
        let mut two = Tracer::new();
        two.merge_workers(vec![w0, w1]);
        let mut one = Tracer::new();
        one.merge_workers(vec![big]);
        let key = |t: &Tracer| {
            t.records()
                .iter()
                .map(|r| (r.start_ns.to_bits(), r.pid, r.name))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&two), key(&one));
        // Equal-start ties across packages resolve by package index.
        assert_eq!(
            key(&two),
            vec![
                (5.0f64.to_bits(), 1, "package_step"),
                (10.0f64.to_bits(), 0, "package_step"),
                (10.0f64.to_bits(), 1, "dram_stall"),
                (20.0f64.to_bits(), 0, "package_step"),
            ]
        );
        // Worker profile aggregates sum into the session profile.
        let mut main = Tracer::with_profiling();
        let w = main.wall_start();
        main.wall_end("tick", w);
        let mut prof = Tracer::with_profiling();
        for _ in 0..2 {
            let w = prof.wall_start();
            prof.wall_end("tick", w);
        }
        main.merge_workers(vec![prof]);
        assert_eq!(main.profile_entries()["tick"].0, 3);
    }

    #[test]
    fn stall_instants_only_fire_when_a_cause_advanced() {
        let mut t = Tracer::new();
        trace_stalls(&mut t, 0, 5.0, &MemStalls::default());
        assert!(t.is_empty(), "first-order fidelity records nothing");
        let d = MemStalls { dram_faw_ns: 1.0, rram_verify_ns: 2.0, ..MemStalls::default() };
        trace_stalls(&mut t, 0, 5.0, &d);
        assert_eq!(t.records().len(), 2);
        assert_eq!(t.records()[0].track, Track::Dram);
        assert_eq!(t.records()[1].track, Track::Rram);
    }
}
