//! Chrome trace-event export: turns a [`Tracer`] into the JSON object
//! format Perfetto (ui.perfetto.dev) and `chrome://tracing` load.
//!
//! Mapping (DESIGN.md §14): Chrome *process* = CHIME package, Chrome
//! *thread* = per-package track (coordinator / dram / rram / fabric /
//! serving). Spans become `ph: "X"` complete events, instants become
//! `ph: "i"` thread-scoped instant events; `ts`/`dur` are microseconds
//! of *virtual* simulation time, so a fixed seed serializes to a
//! byte-identical file through the canonical [`Json`] writer (sorted
//! object keys, deterministic number formatting).

use std::collections::BTreeSet;

use crate::util::Json;

use super::Tracer;

/// Nanoseconds → trace-event microseconds.
fn us(ns: f64) -> f64 {
    ns / 1000.0
}

/// The full trace-event JSON document for a recorded run.
pub fn trace_json(tracer: &Tracer) -> Json {
    let mut events = Vec::new();

    // Metadata first: stable process/thread names so Perfetto labels the
    // timelines. Sorted sets keep the order deterministic regardless of
    // recording order.
    let pids: BTreeSet<usize> = tracer.records().iter().map(|r| r.pid).collect();
    let tracks: BTreeSet<(usize, usize, &'static str)> =
        tracer.records().iter().map(|r| (r.pid, r.track.tid(), r.track.name())).collect();
    for pid in &pids {
        events.push(Json::obj(vec![
            ("ph", "M".into()),
            ("name", "process_name".into()),
            ("pid", (*pid).into()),
            ("tid", 0usize.into()),
            ("args", Json::obj(vec![("name", format!("package{pid}").into())])),
        ]));
    }
    for (pid, tid, name) in &tracks {
        events.push(Json::obj(vec![
            ("ph", "M".into()),
            ("name", "thread_name".into()),
            ("pid", (*pid).into()),
            ("tid", (*tid).into()),
            ("args", Json::obj(vec![("name", (*name).into())])),
        ]));
    }

    for r in tracer.records() {
        let args = Json::Obj(r.args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect());
        let mut fields = vec![
            ("name", r.name.into()),
            ("cat", r.track.name().into()),
            ("pid", r.pid.into()),
            ("tid", r.track.tid().into()),
            ("ts", us(r.start_ns).into()),
            ("args", args),
        ];
        match r.dur_ns {
            Some(dur) => {
                fields.push(("ph", "X".into()));
                fields.push(("dur", us(dur).into()));
            }
            None => {
                fields.push(("ph", "i".into()));
                fields.push(("s", "t".into()));
            }
        }
        events.push(Json::obj(fields));
    }

    Json::obj(vec![
        ("displayTimeUnit", "ms".into()),
        ("traceEvents", Json::Arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::Track;
    use super::*;

    fn sample() -> Tracer {
        let mut t = Tracer::new();
        t.span(1, Track::Coordinator, "package_step", 2000.0, 5000.0, vec![
            ("slots", 2.0.into()),
        ]);
        t.instant(0, Track::Serving, "admitted", 1500.0, vec![("id", 3.0.into())]);
        t.instant(1, Track::Fabric, "fabric_leg", 5000.0, vec![
            ("link", "local1".into()),
            ("bytes", 4096.0.into()),
        ]);
        t
    }

    #[test]
    fn export_is_valid_json_with_metadata_and_events() {
        let doc = sample().chrome_trace();
        let parsed = Json::parse(&doc.pretty()).unwrap();
        let events = parsed.get("traceEvents").as_arr().unwrap();
        // 2 pids + 3 distinct (pid, track) threads + 3 records.
        assert_eq!(events.len(), 2 + 3 + 3);
        let span = events.iter().find(|e| e.get("ph").as_str() == Some("X")).unwrap();
        assert_eq!(span.get("name").as_str(), Some("package_step"));
        assert_eq!(span.get("ts").as_f64(), Some(2.0), "µs of virtual time");
        assert_eq!(span.get("dur").as_f64(), Some(3.0));
        assert_eq!(span.get("pid").as_usize(), Some(1));
        let inst = events.iter().find(|e| e.get("name").as_str() == Some("fabric_leg")).unwrap();
        assert_eq!(inst.get("ph").as_str(), Some("i"));
        assert_eq!(inst.get("s").as_str(), Some("t"), "thread-scoped instant");
        assert_eq!(inst.get("args").get("bytes").as_i64(), Some(4096));
    }

    #[test]
    fn export_is_byte_deterministic() {
        let a = sample().chrome_trace().pretty();
        let b = sample().chrome_trace().pretty();
        assert_eq!(a, b);
        assert!(a.contains("\"process_name\""));
        assert!(a.contains("\"package1\""));
        assert!(a.contains("\"thread_name\""));
    }

    #[test]
    fn empty_tracer_exports_an_empty_event_list() {
        let doc = Tracer::new().chrome_trace();
        assert_eq!(doc.get("traceEvents").as_arr().unwrap().len(), 0);
    }
}
