//! Prometheus text exposition (format version 0.0.4): the `# HELP` /
//! `# TYPE` / sample-line format `GET /v1/metrics?format=prometheus`
//! serves on the net server.
//!
//! Naming conventions (DESIGN.md §14): every series is prefixed
//! `chime_`, counters end in `_total`, times are exported in seconds
//! (`_seconds_total`), and label values are escaped per the exposition
//! spec. Every exported value is **finite by policy** — non-finite
//! inputs are clamped to 0 so a scrape can never see `NaN` (the
//! `ServingMetrics` rate helpers uphold the same policy at the source).

/// Incremental builder for one exposition document.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
}

/// Render a sample value: integers without a fraction, non-finite
/// clamped to 0 (see module policy).
fn fmt_value(v: f64) -> String {
    if !v.is_finite() {
        return "0".to_string();
    }
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Escape a label value per the exposition format.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

impl PromText {
    /// An empty document.
    pub fn new() -> PromText {
        PromText::default()
    }

    /// Write the `# HELP` / `# TYPE` header for a metric family.
    pub fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// Write one sample line, with optional labels.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(&format!("{k}=\"{}\"", escape_label(v)));
            }
            self.out.push('}');
        }
        self.out.push_str(&format!(" {}\n", fmt_value(value)));
    }

    /// Header + single unlabeled sample: a simple counter.
    pub fn counter(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "counter");
        self.sample(name, &[], value);
    }

    /// Header + single unlabeled sample: a simple gauge.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "gauge");
        self.sample(name, &[], value);
    }

    /// The finished exposition text.
    pub fn render(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_the_exposition_shape() {
        let mut p = PromText::new();
        p.counter("chime_tokens_total", "Tokens generated.", 42.0);
        p.gauge("chime_tokens_per_s", "Serving throughput.", 1.5);
        let text = p.render();
        assert!(text.contains("# HELP chime_tokens_total Tokens generated.\n"));
        assert!(text.contains("# TYPE chime_tokens_total counter\n"));
        assert!(text.contains("\nchime_tokens_total 42\n"));
        assert!(text.contains("# TYPE chime_tokens_per_s gauge\n"));
        assert!(text.contains("\nchime_tokens_per_s 1.5\n"));
        assert!(text.ends_with('\n'), "exposition must end with a newline");
    }

    #[test]
    fn labeled_series_group_under_one_header() {
        let mut p = PromText::new();
        p.header("chime_fabric_link_bytes_total", "Payload bytes per link.", "counter");
        p.sample("chime_fabric_link_bytes_total", &[("link", "local0")], 100.0);
        p.sample("chime_fabric_link_bytes_total", &[("link", "inter0-1")], 250.0);
        let text = p.render();
        assert_eq!(text.matches("# TYPE").count(), 1);
        assert!(text.contains("chime_fabric_link_bytes_total{link=\"local0\"} 100\n"));
        assert!(text.contains("chime_fabric_link_bytes_total{link=\"inter0-1\"} 250\n"));
    }

    #[test]
    fn values_are_always_finite_and_integers_stay_integral() {
        assert_eq!(fmt_value(f64::NAN), "0");
        assert_eq!(fmt_value(f64::INFINITY), "0");
        assert_eq!(fmt_value(3.0), "3");
        assert_eq!(fmt_value(0.25), "0.25");
    }

    #[test]
    fn label_values_are_escaped() {
        let mut p = PromText::new();
        p.sample("m", &[("l", "a\"b\\c")], 1.0);
        assert_eq!(p.render(), "m{l=\"a\\\"b\\\\c\"} 1\n");
    }
}
