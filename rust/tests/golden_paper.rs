//! Golden paper-results regression suite.
//!
//! Locks the paper's numbers behind the `chime::results` harness in two
//! layers (EXPERIMENTS.md describes the workflow):
//!
//! 1. **Shape invariants** — every experiment must stay inside the
//!    paper-shape windows (speedup/energy bands, orderings, monotonicity)
//!    that the reproduction targets: Fig 6's 31–54x speedup envelope,
//!    Table V's CHIME > FACIL > Jetson ranking, Fig 9 / the abstract's
//!    DRAM-only ablation (2.4x perf, ~7% energy), Fig 7's synthesis
//!    constants, Fig 8's monotone context scaling.
//! 2. **Deterministic snapshots** — each experiment serializes to a
//!    canonical JSON blob via `chime::util::Json` (sorted keys, stable
//!    float formatting); two back-to-back regenerations must be
//!    byte-identical, and when a committed golden file exists under
//!    `tests/golden/<id>.json` the blob must match it byte-for-byte.
//!    Refresh the files with `CHIME_UPDATE_GOLDEN=1 cargo test --test
//!    golden_paper` after an intentional model change.
//!
//! Everything in `results` is seed-free and deterministic by
//! construction; the serving snapshot at the bottom additionally pins the
//! `Prng`-seeded request-stream path.

use std::fs;
use std::path::PathBuf;

use chime::results::{self, Experiment};

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Regenerate an experiment twice, assert byte-stable canonical JSON, and
/// compare/update the committed golden snapshot. Returns the first run
/// for shape assertions.
fn snapshot(run: fn() -> Experiment) -> Experiment {
    let a = run();
    let b = run();
    let blob_a = a.json.pretty();
    let blob_b = b.json.pretty();
    assert_eq!(
        blob_a, blob_b,
        "{}: two regenerations must serialize byte-identically",
        a.id
    );
    assert!(!a.text.is_empty(), "{}: experiment renders no text", a.id);

    let update = matches!(
        std::env::var("CHIME_UPDATE_GOLDEN").as_deref(),
        Ok(v) if !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false")
    );
    let path = golden_dir().join(format!("{}.json", a.id));
    if update {
        fs::create_dir_all(golden_dir()).unwrap();
        fs::write(&path, blob_a.as_bytes()).unwrap();
        eprintln!("updated golden snapshot {}", path.display());
    } else if path.exists() {
        let committed = fs::read_to_string(&path).unwrap();
        assert_eq!(
            committed, blob_a,
            "{}: snapshot drifted from {} — if intentional, refresh with \
             CHIME_UPDATE_GOLDEN=1 cargo test --test golden_paper",
            a.id,
            path.display()
        );
    } else {
        eprintln!(
            "note: no committed golden for {} yet (run with \
             CHIME_UPDATE_GOLDEN=1 to create {})",
            a.id,
            path.display()
        );
    }
    a
}

#[test]
fn golden_fig6_speedup_energy() {
    let e = snapshot(results::fig6::run);
    let rows = e.json.get("rows").as_arr().expect("fig6 rows");
    assert_eq!(rows.len(), 4, "one row per Table II model");
    for r in rows {
        let model = r.get("model").as_str().unwrap();
        let speedup = r.get("speedup").as_f64().unwrap();
        let egain = r.get("energy_gain").as_f64().unwrap();
        let tps = r.get("chime_tps").as_f64().unwrap();
        let tok_j = r.get("chime_tok_per_j").as_f64().unwrap();
        let power = r.get("chime_power_w").as_f64().unwrap();
        // Paper: 31–54x speedup, 113–246x energy gain, 233–533 TPS,
        // 116.5–266.5 tok/J at ~2 W. Shape windows (not exact points).
        assert!((15.0..90.0).contains(&speedup), "{model}: speedup {speedup}");
        assert!(egain > 50.0, "{model}: energy gain {egain}");
        assert!((100.0..900.0).contains(&tps), "{model}: {tps} TPS");
        assert!((30.0..2000.0).contains(&tok_j), "{model}: {tok_j} tok/J");
        assert!(power < 4.0, "{model}: {power} W outside the edge envelope");
    }
    let mean = e.json.get("mean_speedup").as_f64().unwrap();
    assert!((15.0..90.0).contains(&mean), "mean speedup {mean}");
}

#[test]
fn golden_fig7_area_power() {
    let e = snapshot(results::fig7::run);
    // Synthesis constants are exact paper numbers, not simulation outputs.
    let a = e.json.get("area_dram");
    assert!((a.get("peripherals").as_f64().unwrap() - 0.515).abs() < 1e-9);
    assert!((a.get("ucie").as_f64().unwrap() - 0.223).abs() < 1e-9);
    assert!((a.get("pus").as_f64().unwrap() - 0.262).abs() < 1e-9);
    assert!((e.json.get("area_rram_pu_share").as_f64().unwrap() - 0.34).abs() < 1e-9);
    // Paper: RRAM side dominates power (it runs the FFN); power stable.
    let power = e.json.get("power").as_arr().unwrap();
    assert_eq!(power.len(), 2);
    for model in power {
        let rram = model.get("rram_share").as_f64().unwrap();
        let comps = model.get("components").as_arr().unwrap();
        let dram: f64 = comps
            .iter()
            .filter(|c| c.get("component").as_str().unwrap().starts_with("dram"))
            .map(|c| c.get("share").as_f64().unwrap())
            .sum();
        assert!(rram > dram, "rram share {rram} <= dram share {dram}");
    }
    let w0 = power[0].get("total_w").as_f64().unwrap();
    let w1 = power[1].get("total_w").as_f64().unwrap();
    assert!((w0 / w1 - 1.0).abs() < 0.5, "power not stable: {w0} vs {w1} W");
}

#[test]
fn golden_fig8_seqlen_scaling() {
    let e = snapshot(results::fig8::run);
    let pts = e.json.get("points").as_arr().unwrap();
    assert_eq!(pts.len(), 4 * results::fig8::LENGTHS.len());
    for model in ["fastvlm-0.6b", "fastvlm-1.7b", "mobilevlm-1.7b", "mobilevlm-3b"] {
        let series: Vec<(usize, f64, f64)> = pts
            .iter()
            .filter(|p| p.get("model").as_str() == Some(model))
            .map(|p| {
                (
                    p.get("text_len").as_usize().unwrap(),
                    p.get("latency_ms").as_f64().unwrap(),
                    p.get("energy_j").as_f64().unwrap(),
                )
            })
            .collect();
        assert_eq!(series.len(), results::fig8::LENGTHS.len());
        // Paper: latency and energy grow monotonically with context.
        for w in series.windows(2) {
            assert!(w[1].0 > w[0].0, "{model}: lengths out of order");
            assert!(w[1].1 > w[0].1, "{model}: latency not monotone");
            assert!(w[1].2 > w[0].2, "{model}: energy not monotone");
        }
        let growth = series.last().unwrap().1 / series[0].1;
        assert!(growth > 1.5, "{model}: 128->4k latency growth only {growth}x");
    }
}

#[test]
fn golden_table5_platform_ranking() {
    let e = snapshot(results::table5::run);
    let rows = e.json.get("rows").as_arr().unwrap();
    assert_eq!(rows.len(), 3);
    let get = |i: usize, k: &str| rows[i].get(k).as_f64().unwrap();
    // Row order: Jetson, FACIL, CHIME (as rendered).
    assert_eq!(rows[0].get("platform").as_str(), Some("Jetson Orin NX"));
    assert_eq!(rows[1].get("platform").as_str(), Some("FACIL"));
    assert_eq!(rows[2].get("platform").as_str(), Some("CHIME"));
    // Paper ranking on every axis Table V ranks.
    assert!(get(2, "tps_min") > get(1, "tps_max"), "CHIME must beat FACIL on TPS");
    assert!(get(1, "tps_max") > get(0, "tps_max"), "FACIL must beat Jetson on TPS");
    assert!(get(2, "tok_j_min") > get(1, "tok_j_max"), "CHIME must beat FACIL on tok/J");
    assert!(get(2, "power_max") < get(0, "power_min"), "CHIME power must undercut Jetson");
    // Paper: CHIME/FACIL throughput 12.1–69.2x across cross-paired extremes.
    let lo = get(2, "tps_min") / get(1, "tps_max");
    let hi = get(2, "tps_max") / get(1, "tps_min");
    assert!(lo > 5.0 && hi < 120.0 && hi > lo, "CHIME/FACIL ratio band {lo:.1}-{hi:.1}");
    // Paper: CHIME 4.35–9.95 tok/s/mm² hardware efficiency (order of magnitude).
    let eff = get(2, "hw_eff_max");
    assert!((2.0..20.0).contains(&eff), "hw efficiency {eff}");
}

#[test]
fn golden_fig9_dram_only_ablation() {
    let e = snapshot(results::fig9::run);
    let rows = e.json.get("rows").as_arr().unwrap();
    assert_eq!(rows.len(), 4);
    for r in rows {
        let model = r.get("model").as_str().unwrap();
        let speedup = r.get("speedup").as_f64().unwrap();
        let egain = r.get("energy_gain").as_f64().unwrap();
        // Abstract: heterogeneous memory improves performance 2.4x and
        // energy efficiency by 7% over the M3D DRAM-only design
        // (Fig 9: 2.38–2.49x / 1.04–1.07x). Shape windows around both.
        assert!((1.7..3.0).contains(&speedup), "{model}: dram-only speedup {speedup}");
        assert!((0.8..1.8).contains(&egain), "{model}: dram-only energy gain {egain}");
    }
    // The FFN-heaviest model must benefit at least as much as its sibling.
    let get = |name: &str| {
        rows.iter()
            .find(|r| r.get("model").as_str() == Some(name))
            .unwrap()
            .get("speedup")
            .as_f64()
            .unwrap()
    };
    assert!(get("mobilevlm-3b") >= get("mobilevlm-1.7b") * 0.95);
}

#[test]
fn golden_fig1_motivation_profile() {
    let e = snapshot(results::fig1::run);
    for row in e.json.get("stages").as_arr().unwrap() {
        let b = row.get("backbone").as_f64().unwrap();
        // Paper Fig 1(b): backbone 85.4–95.7% of GPU time.
        assert!(b > 0.8, "backbone share {b}");
    }
    let total: f64 = e
        .json
        .get("backbone_ops")
        .as_arr()
        .unwrap()
        .iter()
        .map(|o| o.get("share").as_f64().unwrap())
        .sum();
    assert!((total - 1.0).abs() < 1e-9, "op shares must sum to 1, got {total}");
}

#[test]
fn golden_ablations() {
    let e = snapshot(results::ablations::run);
    let entries = e.json.as_arr().unwrap();
    for a in entries {
        match a.get("ablation").as_str().unwrap() {
            "fusion" => {
                let s = a.get("speedup").as_f64().unwrap();
                assert!(s > 1.3, "fusion speedup only {s}x");
            }
            "tiering" => {
                let s = a.get("speedup").as_f64().unwrap();
                assert!(s > 1.5, "tiering speedup only {s}x");
            }
            "ucie_bw" => {
                let tps = a.get("mobilevlm_tps").as_f64().unwrap();
                assert!(tps > 0.0);
            }
            other => panic!("unknown ablation entry {other:?}"),
        }
    }
    // Two-cut-point property: TPS flat across the 16x UCIe sweep.
    let ucie: Vec<f64> = entries
        .iter()
        .filter(|a| a.get("ablation").as_str() == Some("ucie_bw"))
        .map(|a| a.get("mobilevlm_tps").as_f64().unwrap())
        .collect();
    assert!(ucie.len() >= 2);
    let min = ucie.iter().cloned().fold(f64::MAX, f64::min);
    let max = ucie.iter().cloned().fold(f64::MIN, f64::max);
    assert!(max / min < 1.15, "UCIe sweep moved TPS {min}..{max}");
}

#[test]
fn golden_scaling_packages() {
    let e = snapshot(results::scaling::run);
    let points = e.json.get("points").as_arr().expect("scaling points");
    assert_eq!(points.len(), 8, "2 models x 4 package counts");
    for model in ["fastvlm-0.6b", "mobilevlm-3b"] {
        let series: Vec<_> = points
            .iter()
            .filter(|p| p.get("model").as_str() == Some(model))
            .collect();
        assert_eq!(series.len(), 4);
        let tps: Vec<f64> = series
            .iter()
            .map(|p| p.get("tokens_per_s").as_f64().unwrap())
            .collect();
        // Acceptance gate: 2 packages >= 1.5x one package on saturation,
        // and throughput keeps climbing toward 8 packages.
        assert!(
            tps[1] >= tps[0] * 1.5,
            "{model}: 2-package scaling only {:.2}x",
            tps[1] / tps[0]
        );
        for w in tps.windows(2) {
            assert!(
                w[1] >= w[0] * 0.98,
                "{model}: tok/s regressed {} -> {}",
                w[0],
                w[1]
            );
        }
        // Sharding divides time, not energy: token/J stays in a tight band.
        let tpj: Vec<f64> = series
            .iter()
            .map(|p| p.get("tokens_per_j").as_f64().unwrap())
            .collect();
        for v in &tpj {
            assert!(
                (v / tpj[0] - 1.0).abs() < 0.25,
                "{model}: tok/J drifted {v} vs {}",
                tpj[0]
            );
        }
    }
}

#[test]
fn golden_memcheck_fidelity_divergence() {
    // Cross-validation of the cycle-accurate memory subsystem against the
    // first-order streaming model: every per-phase ratio must sit inside
    // the stated tolerance band (the analytic model is an exact lower
    // bound; discrete bank/row/refresh effects bound it from above), and
    // the memory-bound decode phase must diverge strictly.
    let e = snapshot(results::memcheck::run);
    let band_min = e.json.get("band").get("ratio_min").as_f64().unwrap();
    let band_max = e.json.get("band").get("ratio_max").as_f64().unwrap();
    assert_eq!(band_min, results::memcheck::RATIO_MIN);
    assert_eq!(band_max, results::memcheck::RATIO_MAX);
    let rows = e.json.get("rows").as_arr().expect("memcheck rows");
    assert_eq!(rows.len(), 4 * 4, "4 models x (encode, prefill, decode, total)");
    for r in rows {
        let model = r.get("model").as_str().unwrap();
        let phase = r.get("phase").as_str().unwrap();
        let fo = r.get("first_order_ns").as_f64().unwrap();
        let cy = r.get("cycle_ns").as_f64().unwrap();
        let ratio = r.get("ratio").as_f64().unwrap();
        assert!(fo > 0.0 && cy > 0.0, "{model}/{phase}: degenerate times");
        assert!(
            ratio >= band_min && ratio <= band_max,
            "{model}/{phase}: divergence {ratio} outside [{band_min}, {band_max}]"
        );
        if phase == "decode" {
            assert!(
                ratio > 1.0001,
                "{model}: decode is memory-bound — cycle fidelity must diverge, got {ratio}"
            );
        }
    }
}

#[test]
fn golden_tail_work_stealing() {
    // Acceptance gate for the streaming redesign: under the seeded
    // open-loop Poisson process with skewed token budgets, cross-package
    // work stealing (1) is a bitwise no-op at 1 package, (2) strictly
    // improves p99 total latency at >= 4 packages, (3) never changes the
    // token count, and (4) leaves tok/J within 1% of steal-off.
    let e = snapshot(results::tail::run);
    let points = e.json.get("points").as_arr().expect("tail points");
    assert_eq!(points.len(), results::tail::PACKAGES.len() * 2, "packages x steal grid");
    let point = |packages: i64, steal: bool| {
        points
            .iter()
            .find(|p| {
                p.get("packages").as_i64() == Some(packages)
                    && p.get("steal").as_bool() == Some(steal)
            })
            .unwrap_or_else(|| panic!("missing tail point ({packages}, {steal})"))
    };
    for &packages in &results::tail::PACKAGES {
        let (off, on) = (point(packages as i64, false), point(packages as i64, true));
        for p in [off, on] {
            assert_eq!(
                p.get("completed").as_i64(),
                Some(results::tail::REQUESTS as i64),
                "{packages} pkgs: tail stream must fully drain"
            );
            // Percentile sanity: p50 <= p95 <= p99 on every metric family.
            for fam in ["ttft", "tpot", "latency"] {
                let v = |q: &str| p.get(&format!("{q}_{fam}_ms")).as_f64().unwrap();
                assert!(v("p50") <= v("p95") && v("p95") <= v("p99"), "{packages}/{fam}");
            }
        }
        assert_eq!(
            on.get("tokens").as_i64(),
            off.get("tokens").as_i64(),
            "{packages} pkgs: stealing must not change token output"
        );
        let (tj_off, tj_on) = (
            off.get("tokens_per_j").as_f64().unwrap(),
            on.get("tokens_per_j").as_f64().unwrap(),
        );
        assert!(
            (tj_on / tj_off - 1.0).abs() < 0.01,
            "{packages} pkgs: tok/J drifted {tj_on} vs {tj_off}"
        );
        let (p99_off, p99_on) = (
            off.get("p99_latency_ms").as_f64().unwrap(),
            on.get("p99_latency_ms").as_f64().unwrap(),
        );
        match packages {
            1 => {
                assert_eq!(on.get("steals").as_i64(), Some(0), "no sibling to steal from");
                assert_eq!(p99_on, p99_off, "1 pkg: stealing must be an exact no-op");
            }
            2 => assert!(
                p99_on <= p99_off * 1.02,
                "2 pkgs: stealing may not degrade p99 ({p99_on} vs {p99_off})"
            ),
            _ => {
                assert!(on.get("steals").as_i64().unwrap() > 0, "{packages} pkgs: no steals");
                assert!(
                    p99_on < p99_off,
                    "{packages} pkgs: p99 {p99_on} (on) must strictly beat {p99_off} (off)"
                );
            }
        }
    }
}

#[test]
fn golden_fabric_topologies() {
    // Acceptance gate for the routed-fabric refactor: at >= 4 packages
    // with stealing on, every routed topology (line/ring/mesh) reports
    // strictly positive stolen bytes and per-link peak GB/s, and a steal
    // delay strictly above the 0-cost point-to-point baseline; at 1
    // package all four topologies are identical by construction with no
    // inter-package traffic.
    let e = snapshot(results::fabric::run);
    let points = e.json.get("points").as_arr().expect("fabric points");
    assert_eq!(
        points.len(),
        results::tail::PACKAGES.len() * 4,
        "packages x topology grid"
    );
    let point = |packages: i64, topology: &str| {
        points
            .iter()
            .find(|p| {
                p.get("packages").as_i64() == Some(packages)
                    && p.get("topology").as_str() == Some(topology)
            })
            .unwrap_or_else(|| panic!("missing fabric point ({packages}, {topology})"))
    };
    let base = point(1, "point-to-point");
    for topo in ["point-to-point", "line", "ring", "mesh"] {
        let p = point(1, topo);
        assert_eq!(p.get("steals").as_i64(), Some(0), "{topo}: no sibling at 1 package");
        assert_eq!(p.get("inter_bytes").as_i64(), Some(0), "{topo}: no links at 1 package");
        assert_eq!(
            p.get("p99_latency_ms").as_f64(),
            base.get("p99_latency_ms").as_f64(),
            "{topo}: every topology must be identical at 1 package"
        );
    }
    for packages in [4i64, 8] {
        let p2p = point(packages, "point-to-point");
        assert!(
            p2p.get("steals").as_i64().unwrap() > 0,
            "{packages} pkgs: skewed overload must steal"
        );
        assert!(
            p2p.get("stolen_kb").as_f64().unwrap() > 0.0,
            "{packages} pkgs: steal payloads are counted on every topology"
        );
        assert_eq!(
            p2p.get("mean_steal_delay_us").as_f64(),
            Some(0.0),
            "{packages} pkgs: point-to-point is the 0-cost baseline"
        );
        assert_eq!(
            p2p.get("inter_bytes").as_i64(),
            Some(0),
            "{packages} pkgs: free steals never touch the links"
        );
        for topo in ["line", "ring", "mesh"] {
            let p = point(packages, topo);
            assert!(p.get("steals").as_i64().unwrap() > 0, "{packages}/{topo}: no steals");
            assert!(
                p.get("stolen_kb").as_f64().unwrap() > 0.0,
                "{packages}/{topo}: stolen bytes must be positive"
            );
            assert!(
                p.get("mean_steal_delay_us").as_f64().unwrap()
                    > p2p.get("mean_steal_delay_us").as_f64().unwrap(),
                "{packages}/{topo}: routed steal delay must beat the 0-cost baseline"
            );
            assert!(
                p.get("peak_inter_gbps").as_f64().unwrap() > 0.0,
                "{packages}/{topo}: steal traffic must show up as per-link peak GB/s"
            );
            assert!(
                p.get("inter_bytes").as_i64().unwrap() > 0,
                "{packages}/{topo}: inter-package links must carry bytes"
            );
        }
    }
}

#[test]
fn golden_serve_outcome_wrapper_bit_identity() {
    // Locks the api_redesign acceptance criterion: the batch
    // `Backend::serve(Vec<_>)` is a wrapper over the streaming protocol,
    // and its ServeOutcome serializes to byte-identical canonical JSON on
    // the sim, dram-only, and 2-package sharded paths — both against a
    // manually driven streaming session (asserted inside the runner) and
    // against the committed snapshot (CHIME_UPDATE_GOLDEN flow).
    use chime::api::{BackendKind, ServeRequest, Session};
    use chime::coordinator::ServeOutcome;
    use chime::util::Json;

    fn outcome_json(out: &ServeOutcome) -> Json {
        let rows: Vec<Json> = out
            .responses
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", (r.id as i64).into()),
                    ("tokens", r.tokens.len().into()),
                    ("queue_ns", r.queue_ns.into()),
                    ("ttft_ns", r.ttft_ns.into()),
                    ("service_ns", r.service_ns.into()),
                    ("energy_j", r.energy_j.into()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("responses", Json::Arr(rows)),
            ("shed", Json::arr(out.shed.iter().map(|r| Json::from(r.id as i64)))),
            ("completed", (out.metrics.completed as i64).into()),
            ("rejected", (out.metrics.rejected as i64).into()),
            ("shed_count", (out.metrics.shed as i64).into()),
            ("tokens", (out.metrics.tokens as i64).into()),
            ("steals", (out.metrics.steals as i64).into()),
            ("stolen_bytes", (out.metrics.stolen_bytes as i64).into()),
        ])
    }

    // Mixed stream: staggered arrivals, a zero-token request, skewed
    // budgets — every admission path the wrapper must reproduce.
    fn mixed_requests() -> Vec<ServeRequest> {
        let budgets = [4usize, 0, 6, 2, 4, 3, 5, 1];
        budgets
            .iter()
            .enumerate()
            .map(|(i, &tokens)| ServeRequest {
                id: i as u64,
                prompt: vec![],
                image_seed: i as u64,
                max_new_tokens: tokens,
                arrival_ns: i as f64 * 4e4,
            })
            .collect()
    }

    fn build(kind: BackendKind, packages: usize) -> Session {
        Session::builder()
            .model("tiny")
            .image_size(64)
            .text_tokens(8)
            .output_tokens(8)
            .backend(kind)
            .packages(packages)
            .build()
            .unwrap()
    }

    fn run() -> Experiment {
        let paths: [(&str, BackendKind, usize); 3] = [
            ("sim", BackendKind::Sim, 1),
            ("dram_only", BackendKind::DramOnly, 1),
            ("sharded2", BackendKind::Sharded, 2),
        ];
        let mut entries = Vec::new();
        for (key, kind, packages) in paths {
            let mut batch = build(kind, packages);
            let out = batch.serve(mixed_requests()).unwrap();
            // The streaming session, driven by hand, must serialize
            // byte-identically to the batch wrapper.
            let mut streaming = build(kind, packages);
            let mut session = streaming.open_serving().unwrap();
            for r in mixed_requests() {
                session.submit(r);
            }
            let streamed = session.finish().unwrap();
            assert_eq!(
                outcome_json(&out).pretty(),
                outcome_json(&streamed).pretty(),
                "{key}: streaming session drifted from the batch wrapper"
            );
            entries.push((key, outcome_json(&out)));
        }
        Experiment {
            id: "serve_outcome",
            text: "canonical ServeOutcome for sim / dram-only / 2-package sharded\n".to_string(),
            json: Json::obj(entries),
        }
    }
    snapshot(run);
}

#[test]
fn golden_serving_deterministic_under_fixed_seeds() {
    // The Prng-seeded serving path must be byte-stable too: same seed,
    // same model, same policy -> identical responses and canonical JSON.
    use chime::config::{ChimeConfig, MllmConfig};
    use chime::coordinator::{BatchPolicy, ServeRequest, SimulatedServer};
    use chime::model::workload::RequestStream;
    use chime::util::Json;

    let run = || {
        let mut cfg = ChimeConfig::default();
        cfg.workload.output_tokens = 8;
        let mut stream = RequestStream::new(7, 4.0, 32, 8, 256);
        let reqs: Vec<ServeRequest> = stream
            .take(6)
            .into_iter()
            .map(|r| ServeRequest {
                id: r.id,
                prompt: r.prompt,
                image_seed: r.image_seed,
                max_new_tokens: r.max_new_tokens,
                arrival_ns: r.arrival_ns,
            })
            .collect();
        let mut srv =
            SimulatedServer::new(&MllmConfig::fastvlm_0_6b(), &cfg, BatchPolicy::default());
        let out = srv.serve(reqs);
        let (resps, metrics) = (out.responses, out.metrics);
        assert!(out.shed.is_empty(), "default queue must not shed 6 requests");
        let rows: Vec<Json> = resps
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", (r.id as i64).into()),
                    ("tokens", r.tokens.len().into()),
                    ("queue_ns", r.queue_ns.into()),
                    ("ttft_ns", r.ttft_ns.into()),
                    ("service_ns", r.service_ns.into()),
                    ("energy_j", r.energy_j.into()),
                ])
            })
            .collect();
        (Json::Arr(rows).pretty(), metrics.tokens)
    };
    let (a, tokens_a) = run();
    let (b, tokens_b) = run();
    assert_eq!(a, b, "seeded serving must be byte-stable across runs");
    assert_eq!(tokens_a, tokens_b);
    assert_eq!(tokens_a, 48);
}
